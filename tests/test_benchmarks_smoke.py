"""Benchmark-script rot guard (ISSUE 2 satellite).

The paper-table and kernel-micro bench scripts are not exercised by the
unit suite, so API refactors could silently break them. This smoke tier
(a) imports every module registered in ``benchmarks.run`` (catches
syntax/import rot) and (b) *executes* the two scripts named in the issue —
``kernels_bench`` and ``table2_rbf`` — through their quick paths, so every
jit/pallas entry point they touch actually compiles. Runs under
``-m "not slow"``; the ``bench_smoke`` marker (pytest.ini) lets callers
deselect it separately.
"""
import importlib
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

pytestmark = pytest.mark.bench_smoke

BENCH_MODULES = ["run", "common", "kernels_bench", "table2_rbf",
                 "table3_linear", "table4_svm", "fig2_speedup",
                 "fig4_gradient", "roofline_report"]


@pytest.mark.parametrize("name", BENCH_MODULES)
def test_bench_module_imports(name):
    importlib.import_module(f"benchmarks.{name}")


def test_run_registry_covers_all_tables():
    from benchmarks import run
    assert set(run.ALL) == {"table2", "table3", "table4", "fig2", "fig4",
                            "kernels", "roofline"}


def test_kernels_bench_quick_executes():
    """Compile-and-run the full kernels_bench script path at toy sizes.

    Also pins the fused-pass acceptance numbers: exactly one pallas_call
    per pass, one matvec launch saved vs the PR 1 layout.
    """
    from benchmarks import kernels_bench
    out = []
    kernels_bench.run(out, quick=True)
    assert any(line.startswith("kernels,sodm_level_pallas") for line in out)
    for name in ("linear", "rbf", "laplacian", "poly"):
        assert any(f"gram_matvec_{name}" in line for line in out), name
    fused = [line for line in out if "fused_pass_op_count" in line]
    assert len(fused) == 1
    assert "pallas_calls_per_pass_fused=1" in fused[0]
    assert "matvec_launches_saved=1" in fused[0]


def test_table2_rbf_quick_executes():
    """One tiny data set through the full table-2 harness (all methods)."""
    from benchmarks import table2_rbf
    out = []
    # one data set at ~1/10 scale: the ~15s floor is the jit compiles of
    # the five methods, not the solve — small enough for the fast tier
    table2_rbf.run(out, datasets=["svmguide1"], scale_factor=0.1)
    methods = {line.split(",")[2] for line in out
               if line.startswith("table2,svmguide1")}
    assert {"SODM", "SODM-blk", "Ca-ODM", "DiP-ODM", "DC-ODM"} <= methods
    assert any(line.startswith("table2,summary") for line in out)
