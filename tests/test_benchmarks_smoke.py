"""Benchmark-script rot guard (ISSUE 2 satellite).

The paper-table and kernel-micro bench scripts are not exercised by the
unit suite, so API refactors could silently break them. This smoke tier
(a) imports every module registered in ``benchmarks.run`` (catches
syntax/import rot) and (b) *executes* the scripts named in the issues —
``kernels_bench``, ``table2_rbf``, ``table3_linear`` and
``fig4_gradient`` — through their quick paths, so every jit/pallas entry
point they touch actually compiles.
Runs under ``-m "not slow"``; the ``bench_smoke`` marker (pytest.ini) lets
callers deselect it separately.
"""
import importlib
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

pytestmark = pytest.mark.bench_smoke

BENCH_MODULES = ["run", "common", "kernels_bench", "table2_rbf",
                 "table3_linear", "table4_svm", "fig2_speedup",
                 "fig4_gradient", "roofline_report", "serve_bench",
                 "data_bench"]


@pytest.mark.parametrize("name", BENCH_MODULES)
def test_bench_module_imports(name):
    importlib.import_module(f"benchmarks.{name}")


def test_run_registry_covers_all_tables():
    from benchmarks import run
    assert set(run.ALL) == {"table2", "table3", "table4", "fig2", "fig4",
                            "kernels", "roofline", "serve", "data"}


def test_bench_persist_schema(tmp_path):
    """ISSUE 7 satellite (schema bumped to v2 by ISSUE 9): `python -m
    benchmarks.run --quick --out-dir D` persists a BENCH_<name>.json per
    bench with route, wall-clock, peak bytes, device kind, and an
    instrument-snapshot `metrics` dict, so CI runs leave artifacts the
    perf gate can trend."""
    import json
    from benchmarks import run

    rc = run.main(["kernels", "--quick", "--out-dir", str(tmp_path)])
    assert rc == 0
    path = tmp_path / "BENCH_kernels.json"
    assert path.exists()
    rec = json.loads(path.read_text())
    assert rec["schema_version"] == 2
    assert rec["bench"] == "kernels"
    assert rec["backend"] and rec["device_kind"] and rec["jax_version"]
    assert rec["wall_clock_s"] > 0
    assert isinstance(rec["peak_bytes"], int)    # 0 on CPU is fine
    assert rec["rows"] == len(rec["lines"]) > 0
    assert any(line.startswith("kernels,") for line in rec["lines"])
    assert isinstance(rec["metrics"], dict)      # {} for metric-less benches
    # the trend loader accepts what run.py persists
    from repro.observe import trend
    assert trend.load_dir(tmp_path)["kernels"]["bench"] == "kernels"
    # no torn temp file left behind
    assert not list(tmp_path.glob("*.tmp"))


def test_bench_cli_rejects_unknowns(tmp_path, capsys):
    from benchmarks import run
    assert run.main(["nope"]) == 1
    assert "unknown benchmark" in capsys.readouterr().out
    assert run.main(["--out-dir"]) == 1


def test_kernels_bench_quick_executes():
    """Compile-and-run the full kernels_bench script path at toy sizes.

    Also pins the fused-pass acceptance numbers: exactly one pallas_call
    per pass, one matvec launch saved vs the PR 1 layout.
    """
    from benchmarks import kernels_bench
    out = []
    kernels_bench.run(out, quick=True)
    assert any(line.startswith("kernels,sodm_level_pallas") for line in out)
    for name in ("linear", "rbf", "laplacian", "poly"):
        assert any(f"gram_matvec_{name}" in line for line in out), name
    fused = [line for line in out if "fused_pass_op_count" in line]
    assert len(fused) == 1
    assert "pallas_calls_per_pass_fused=1" in fused[0]
    assert "matvec_launches_saved=1" in fused[0]
    # serving scorer pins (ISSUE 4 satellite): one pallas_call per request
    # batch, tile scratch a fraction of the dense (T, S) Gram bytes
    sc = [line for line in out if "serve_score_op_count" in line]
    assert len(sc) == 1
    assert "pallas_calls_per_batch=1" in sc[0]
    dense = int(sc[0].split("dense_gram_bytes=")[1].split("_")[0])
    tile = int(sc[0].split("tile_scratch_bytes=")[1].split(",")[0])
    assert tile < dense, (tile, dense)
    assert any("serve_score_blocked" in line for line in out)


def test_table2_rbf_quick_executes():
    """One tiny data set through the full table-2 harness (all methods)."""
    from benchmarks import table2_rbf
    out = []
    # one data set at ~1/10 scale: the ~15s floor is the jit compiles of
    # the five methods, not the solve — small enough for the fast tier
    table2_rbf.run(out, datasets=["svmguide1"], scale_factor=0.1)
    methods = {line.split(",")[2] for line in out
               if line.startswith("table2,svmguide1")}
    assert {"SODM", "SODM-blk", "Ca-ODM", "DiP-ODM", "DC-ODM"} <= methods
    assert any(line.startswith("table2,summary") for line in out)


def test_table3_linear_quick_executes():
    """The linear benchmark can no longer rot silently (ISSUE 3 satellite).

    Executes the full table-3 harness on one tiny data set and pins the
    acceptance criterion: the DSVRG engine route (`SODMConfig.engine=
    "dsvrg"` through sodm.solve) lands within 0.5 accuracy points of the
    dual-CD level-loop path on the quick data set.
    """
    from benchmarks import table3_linear
    out = []
    table3_linear.run(out, datasets=["svmguide1"], scale_factor=0.1)
    rows = {line.split(",")[2]: float(line.split(",")[3]) for line in out
            if line.startswith("table3,svmguide1")}
    assert {"SODM(dsvrg)", "SODM(dsvrg-eng)", "SODM(dual-cd)", "Ca-ODM",
            "DiP-ODM", "DC-ODM"} <= set(rows)
    gap = abs(rows["SODM(dsvrg-eng)"] - rows["SODM(dual-cd)"])
    assert gap <= 0.005 + 1e-9, f"engine-vs-dual-CD accuracy gap {gap}"
    assert any(line.startswith("table3,summary") for line in out)


def test_fig2_speedup_quick_executes():
    """The last previously-untested benchmark script (ISSUE 4 satellite):
    the scheduling-model figure runs end to end at quick scale and emits
    both regimes' speedup curves."""
    from benchmarks import fig2_speedup
    out = []
    fig2_speedup.run(out, quick=True)
    for regime in ("tight", "loose"):
        assert any(line.startswith(f"fig2,{regime},32,") for line in out), \
            regime
        assert any(f"fig2,{regime},sweeps_per_level" in line
                   for line in out), regime


def test_serve_bench_quick_executes():
    """Serving acceptance (ISSUE 4): the compressed/microbatched path must
    beat the naive dense predict on wall-clock at quick scale, peak
    scoring memory must be below the dense (T, M) Gram, and the jit cache
    must stay inside the bucket ladder (asserted inside the script too)."""
    from benchmarks import serve_bench
    out = []
    metrics = serve_bench.run(out, quick=True)
    # ISSUE 9: the bench returns an instrument snapshot that lands in
    # BENCH_serve.json's "metrics" field — histogram-derived latency
    # percentiles plus request/batch accounting
    for k in ("serve.request.latency_s.p50", "serve.request.latency_s.p95",
              "serve.request.latency_s.p99", "serve.requests.count",
              "serve.batches.count", "serve.queue_depth.max"):
        assert k in metrics, k
    assert metrics["serve.requests.count"] == 64
    summary = [line for line in out if "compressed_beats_dense" in line][0]
    assert summary.split(",")[3] == "1", summary
    peak = [line for line in out if line.startswith("serve,peak_bytes")][0]
    dense = int(peak.split("dense=")[1].split(",")[0])
    tiled = int(peak.split("tiled=")[1].split("_")[0])
    assert tiled < dense, peak
    art = [line for line in out if line.startswith("serve,artifact")][0]
    n_sv = int(art.split("n_sv=")[1].split(",")[0])
    comp = int(art.split("compressed_sv=")[1].split("_")[0])
    assert comp <= max(8, n_sv // 4), art
    assert any(line.startswith("serve,stream") for line in out)
    assert any(line.startswith("serve,jit_cache") for line in out)


def test_fig4_gradient_quick_executes():
    """One tiny data set through the gradient-methods figure script; all
    three methods must share the device-computed DSVRG step size."""
    from benchmarks import fig4_gradient
    out = []
    fig4_gradient.run(out, datasets=[("a7a", 0.01)])
    methods = {line.split(",")[2] for line in out if line.startswith("fig4,")}
    assert {"DSVRG", "SVRG", "CSVRG", "eta"} <= methods
    eta = float([line for line in out if ",eta," in line][0].split(",")[3])
    assert eta > 0.0
