"""Speculative scheduler: first-completion-wins, duplicates, failures."""
import threading
import time

from repro.distributed.straggler import SpecConfig, SpeculativeScheduler


class TestScheduler:
    def test_results_in_order(self):
        sched = SpeculativeScheduler(SpecConfig(max_workers=4))
        tasks = [lambda i=i: i * i for i in range(10)]
        assert sched.run(tasks) == [i * i for i in range(10)]

    def test_straggler_gets_duplicated(self):
        """One task sleeps 50x the median; a speculative duplicate (which
        does not sleep on its 2nd attempt) must finish the job early."""
        attempts = {"n": 0}
        lock = threading.Lock()

        def straggler():
            with lock:
                attempts["n"] += 1
                first = attempts["n"] == 1
            if first:
                time.sleep(5.0)       # pathological first attempt
            return "done"

        tasks = [lambda: (time.sleep(0.01) or "fast") for _ in range(7)]
        tasks.append(straggler)
        sched = SpeculativeScheduler(SpecConfig(
            max_workers=4, spec_quantile=0.5, spec_factor=2.0))
        t0 = time.monotonic()
        out = sched.run(tasks)
        dt = time.monotonic() - t0
        assert out[-1] == "done"
        assert dt < 4.0, f"speculation failed to rescue ({dt:.1f}s)"
        assert attempts["n"] >= 2

    def test_failed_attempt_retried(self):
        state = {"fails": 0}
        lock = threading.Lock()

        def flaky():
            with lock:
                state["fails"] += 1
                if state["fails"] == 1:
                    raise RuntimeError("transient")
            return 42

        sched = SpeculativeScheduler(SpecConfig(max_workers=2))
        assert sched.run([flaky]) == [42]

    def test_idempotent_partition_solve(self):
        """Duplicated SODM partition solves give identical results
        (pure function of the inputs) — first-wins is safe."""
        import jax
        import jax.numpy as jnp
        from repro.core import dual_cd, kernel_fns as kf, odm

        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (32, 4))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (32,)))
        Q = kf.signed_gram(kf.KernelSpec("rbf", 0.5), x, y)
        p = odm.ODMParams()

        def solve_task():
            return dual_cd.solve(Q, p, mscale=32.0, tol=1e-6).alpha

        sched = SpeculativeScheduler(SpecConfig(max_workers=4))
        outs = sched.run([solve_task] * 4)
        for o in outs[1:]:
            assert bool(jnp.array_equal(outs[0], o))
