"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + no NaNs; decode/prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import model as M
from repro.train import steps as steps_mod

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16, with_labels=True):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if with_labels:
        b["labels"] = jax.random.randint(jax.random.fold_in(KEY, 1),
                                         (B, S), 0, cfg.vocab)
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        b["pos3"] = jnp.stack([pos, pos, pos])
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            jax.random.fold_in(KEY, 2),
            (B, cfg.encoder.frontend_len, cfg.encoder.frontend_dim),
            jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = configs.get_smoke(arch)
        p, _ = M.init_params(KEY, cfg)
        batch = _batch(cfg)
        logits, aux = M.logits_fn(p, batch, cfg)
        assert logits.shape == (2, 16, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        loss, mets = M.loss_fn(p, batch, cfg)
        assert bool(jnp.isfinite(loss))

    def test_train_step_runs_and_updates(self, arch):
        cfg = configs.get_smoke(arch)
        p, _ = M.init_params(KEY, cfg)
        state = steps_mod.TrainState.create(p, use_ef=False)
        step = jax.jit(steps_mod.make_train_step(cfg,
                                                 steps_mod.TrainConfig()))
        batch = _batch(cfg)
        new_state, mets = step(state, batch)
        assert bool(jnp.isfinite(mets["loss"]))
        # parameters actually moved
        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            state["params"], new_state["params"])
        assert max(jax.tree.leaves(diffs)) > 0.0

    def test_decode_consistency(self, arch):
        """prefill(T0) + decode(T0..S) logits must match the full forward
        (tolerance covers fp32-ordering noise in the recurrences)."""
        cfg = dataclasses.replace(configs.get_smoke(arch),
                                  compute_dtype="float32")
        p, _ = M.init_params(KEY, cfg)
        B, S, Tp = 2, 12, 8
        batch = _batch(cfg, B=B, S=S, with_labels=False)
        full, _ = M.logits_fn(p, batch, cfg)
        pb = dict(batch)
        pb["tokens"] = batch["tokens"][:, :Tp]
        if "pos3" in batch:
            pb["pos3"] = batch["pos3"][:, :, :Tp]
        lg, cache = M.prefill(p, pb, cfg, max_len=S)
        errs = [float(jnp.max(jnp.abs(lg[:, 0] - full[:, Tp - 1])))]
        for t in range(Tp, S - 1):
            kw = {}
            if "pos3" in batch:
                kw["pos3"] = batch["pos3"][:, :, t:t + 1]
            lg, cache = M.decode(p, cache, batch["tokens"][:, t:t + 1],
                                 jnp.int32(t), cfg, **kw)
            errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
        scale = float(jnp.max(jnp.abs(full))) + 1e-6
        assert max(errs) / scale < 0.02, (max(errs), scale)

    def test_param_shapes_match_init(self, arch):
        cfg = configs.get_smoke(arch)
        shapes, axes = M.param_shapes(cfg)
        p, axes2 = M.init_params(KEY, cfg)
        s1 = jax.tree.map(lambda s: (tuple(s.shape), str(s.dtype)), shapes)
        s2 = jax.tree.map(lambda a: (tuple(a.shape), str(a.dtype)), p)
        assert jax.tree.all(jax.tree.map(lambda a, b: a == b, s1, s2))
        # axes tree mirrors params structurally
        assert jax.tree_util.tree_structure(axes) == \
            jax.tree_util.tree_structure(axes2)

    def test_input_specs_cover_all_shapes(self, arch):
        cfg = configs.get(arch)
        for sname, shape in configs.SHAPES.items():
            ok, why = configs.shape_applicable(cfg, shape)
            if not ok:
                assert "sub-quadratic" in why
                continue
            specs = M.input_specs(cfg, shape)
            assert "tokens" in specs
            axes = M.batch_axes(cfg, shape)
            assert set(axes) == set(specs)


class TestLossDecreases:
    @pytest.mark.parametrize("arch", ["granite-8b", "falcon-mamba-7b",
                                      "recurrentgemma-9b", "dbrx-132b"])
    def test_overfit_tiny_batch(self, arch):
        """A few steps on one repeated batch must reduce the loss."""
        cfg = configs.get_smoke(arch)
        p, _ = M.init_params(KEY, cfg)
        state = steps_mod.TrainState.create(p, use_ef=False)
        tc = steps_mod.TrainConfig()
        tc = dataclasses.replace(
            tc, optimizer=dataclasses.replace(tc.optimizer, lr=1e-3,
                                              warmup_steps=1))
        step = jax.jit(steps_mod.make_train_step(cfg, tc))
        batch = _batch(cfg)
        losses = []
        for _ in range(8):
            state, mets = step(state, batch)
            losses.append(float(mets["loss"]))
        assert losses[-1] < losses[0], losses
