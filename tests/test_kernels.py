"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import dual_cd_block, flash_attn, odm_grad, ops, ref, rbf_gram


KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


class TestRbfGram:
    @pytest.mark.parametrize("M,N,D", [(64, 64, 32), (128, 64, 64),
                                       (64, 128, 96)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, M, N, D, dtype):
        x = jax.random.normal(KEY, (M, D), dtype)
        z = jax.random.normal(jax.random.fold_in(KEY, 1), (N, D), dtype)
        got = rbf_gram.rbf_gram(x, z, gamma=0.2, bm=32, bn=32, bd=32,
                                interpret=True)
        want = ref.rbf_gram(x.astype(jnp.float32), z.astype(jnp.float32),
                            0.2)
        assert float(jnp.max(jnp.abs(got.astype(jnp.float32) - want))) \
            < _tol(dtype)

    def test_signed(self):
        M, N, D = 64, 64, 32
        x = jax.random.normal(KEY, (M, D))
        z = jax.random.normal(jax.random.fold_in(KEY, 1), (N, D))
        yx = jnp.sign(jax.random.normal(jax.random.fold_in(KEY, 2), (M,)))
        yz = jnp.sign(jax.random.normal(jax.random.fold_in(KEY, 3), (N,)))
        got = rbf_gram.rbf_gram(x, z, yx, yz, gamma=0.5, signed=True,
                                bm=32, bn=32, bd=32, interpret=True)
        want = ref.signed_rbf_gram(x, z, yx, yz, 0.5)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5

    @pytest.mark.parametrize("M,N,D", [(100, 70, 33), (33, 190, 17)])
    def test_ops_wrapper_ragged(self, M, N, D):
        x = jax.random.normal(KEY, (M, D))
        z = jax.random.normal(jax.random.fold_in(KEY, 1), (N, D))
        got = ops.rbf_gram(x, z, 0.3, bm=32, bn=32, bd=32)
        want = ref.rbf_gram(x, z, 0.3)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-4


class TestDualCdBlock:
    def test_tile_sweep_matches_ref(self):
        from repro.core import kernel_fns as kf
        M, B = 128, 32
        x = jax.random.normal(KEY, (M, 8))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(KEY, 1), (M,)))
        Q = kf.signed_gram(kf.KernelSpec("rbf", 0.5), x, y)
        qb = dual_cd_block.extract_diag_blocks(Q, B)
        a0 = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 2),
                                       (M // B, 2 * B))) * 0.01
        u0 = jax.random.normal(jax.random.fold_in(KEY, 3), (M // B, B)) * 0.1
        kw = dict(c=2.0, ups=0.5, theta=0.1, mscale=float(M), n_steps=24)
        a1, u1 = dual_cd_block.cd_block_sweep(qb, a0, u0, interpret=True,
                                              **kw)
        a2, u2 = ref.cd_block_sweep(qb, a0, u0, **kw)
        assert float(jnp.max(jnp.abs(a1 - a2))) < 1e-6
        assert float(jnp.max(jnp.abs(u1 - u2))) < 1e-5

    def test_full_solve_reaches_exact_objective(self):
        from repro.core import dual_cd as cd, kernel_fns as kf, odm
        M = 96
        x = jax.random.normal(KEY, (M, 6))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(KEY, 1), (M,)))
        Q = kf.signed_gram(kf.KernelSpec("rbf", 0.5), x, y)
        p = odm.ODMParams()
        alpha, kkt, _ = ops.dual_cd_solve(Q, c=p.c, ups=p.ups, theta=p.theta,
                                          mscale=float(M), block=32,
                                          tol=1e-6)
        exact = cd.solve(Q, p, mscale=float(M), tol=1e-6, max_sweeps=500)
        o1 = odm.dual_objective(Q, alpha, p, float(M))
        o2 = odm.dual_objective(Q, exact.alpha, p, float(M))
        assert abs(float(o1 - o2)) < 1e-4
        assert float(kkt) < 1e-5


class TestOdmGrad:
    @pytest.mark.parametrize("M,d", [(128, 64), (256, 32), (96, 130)])
    def test_matches_ref(self, M, d):
        x = jax.random.normal(KEY, (M, d))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(KEY, 1), (M,)))
        w = jax.random.normal(jax.random.fold_in(KEY, 2), (d,)) * 0.2
        got = ops.odm_grad(w, x, y, lam=1.0, theta=0.1, ups=0.5, bm=32)
        want = ref.odm_grad(w, x, y, lam=1.0, theta=0.1, ups=0.5)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-5


class TestFlashAttn:
    @pytest.mark.parametrize("causal,window", [(True, None), (True, 32),
                                               (False, None)])
    def test_matches_ref(self, causal, window):
        B, Hq, Hkv, T, D = 2, 4, 2, 128, 64
        q = jax.random.normal(KEY, (B, Hq, T, D)) * 0.3
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hkv, T, D)) * 0.3
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, T, D)) * 0.3
        got = flash_attn.flash_attention(q, k, v, causal=causal,
                                         window=window, bq=32, bk=32,
                                         interpret=True)
        want = ref.mha(q, k, v, causal=causal, window=window)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-6

    def test_decode_history(self):
        """T < S: queries at the end of a longer kv history."""
        B, Hq, Hkv, T, S, D = 1, 4, 2, 32, 128, 64
        q = jax.random.normal(KEY, (B, Hq, T, D)) * 0.3
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Hkv, S, D)) * 0.3
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hkv, S, D)) * 0.3
        got = flash_attn.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                                         interpret=True)
        want = ref.mha(q, k, v, causal=True)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-6

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        B, Hq, Hkv, T, D = 1, 2, 1, 64, 32
        q = (jax.random.normal(KEY, (B, Hq, T, D)) * 0.3).astype(dtype)
        k = (jax.random.normal(jax.random.fold_in(KEY, 1),
                               (B, Hkv, T, D)) * 0.3).astype(dtype)
        v = (jax.random.normal(jax.random.fold_in(KEY, 2),
                               (B, Hkv, T, D)) * 0.3).astype(dtype)
        got = flash_attn.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                                         interpret=True)
        want = ref.mha(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), causal=True)
        assert float(jnp.max(jnp.abs(got.astype(jnp.float32) - want))) \
            < _tol(dtype)


class TestBlockedFlashVJP:
    """The model-side scan flash (attention.py) — grads vs reference."""

    @pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                               (False, None)])
    def test_grads(self, causal, window):
        from repro.models import attention as A
        B, T, H, KV, dh = 2, 50, 4, 2, 32
        q = jax.random.normal(KEY, (B, T, H, dh)) * 0.4
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, KV, dh)) * 0.4
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, T, KV, dh)) * 0.4

        def f(q, k, v):
            o = A._blocked_flash(q, k, v, causal=causal, window=window,
                                 q_offset=0, bk=16)
            return jnp.sum(jnp.sin(o))

        def g(q, k, v):
            o = ref.mha(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                        jnp.moveaxis(v, 2, 1), causal=causal, window=window)
            return jnp.sum(jnp.sin(jnp.moveaxis(o, 1, 2)))

        g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-5
