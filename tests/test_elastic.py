"""Elastic resharding unit coverage: reshard / restore_elastic /
validate_resharding, including a shrink-then-grow mesh round trip.

Runs in a subprocess with --xla_force_host_platform_device_count=8 so the
main pytest process keeps its single real device; one subprocess executes
the whole battery to amortize jax startup (same pattern as test_spmd.py).
"""
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile

import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding
from repro.distributed import elastic
from repro.distributed.checkpoint import CheckpointManager
from repro.launch.mesh import make_host_mesh

failures = []
def check(name, cond, info=""):
    print(("PASS " if cond else "FAIL ") + name, info)
    if not cond: failures.append(name)

def submesh(n, shape, axes):
    # a mesh over the FIRST n host devices — the "shrunk cluster"
    return Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)

tree = {
    "w": jnp.arange(64.0).reshape(16, 4),
    "b": jnp.arange(8.0),
    "step": jnp.int32(3),
}
axes_tree = {
    "w": ("batch", None),     # batch -> ("pod", "data"); pod absent here
    "b": ("embed",),          # embed -> "data"
    "step": (),
}

# --- 1. reshard places leaves on the requested mesh axes ----------------
mesh8 = make_host_mesh((4, 2), ("data", "model"))
on8 = elastic.reshard(tree, axes_tree, mesh8)
check("reshard w spec", on8["w"].sharding.spec == P("data"),
      str(on8["w"].sharding.spec))
check("reshard b spec", on8["b"].sharding.spec == P("data"),
      str(on8["b"].sharding.spec))
check("reshard scalar replicated", on8["step"].sharding.spec == P(),
      str(on8["step"].sharding.spec))
check("reshard values", elastic.validate_resharding(tree, on8))

# --- 2. validate_resharding detects value drift -------------------------
bad = dict(on8)
bad["b"] = on8["b"] + 1.0
check("validate catches drift", not elastic.validate_resharding(tree, bad))

# --- 3. divisibility fallback: non-dividing dim replicates --------------
odd = {"v": jnp.arange(6.0)}           # 6 % 4 != 0 on data=4
odd_axes = {"v": ("batch",)}
on_odd = elastic.reshard(odd, odd_axes, mesh8)
check("divisibility fallback replicates",
      on_odd["v"].sharding.spec == P(), str(on_odd["v"].sharding.spec))
check("fallback values", elastic.validate_resharding(odd, on_odd))

# --- 4. shrink-then-grow round trip: 8 -> 2 -> 8 devices ----------------
mesh2 = submesh(2, (2, 1), ("data", "model"))       # job lost 6 workers
shrunk = elastic.reshard(on8, axes_tree, mesh2)
check("shrink devices", len(shrunk["w"].sharding.device_set) <= 2)
check("shrink values", elastic.validate_resharding(tree, shrunk))
regrown = elastic.reshard(shrunk, axes_tree, mesh8)  # workers came back
check("grow devices", len(regrown["w"].sharding.device_set) == 8)
check("grow values", elastic.validate_resharding(tree, regrown))

# --- 5. restore_elastic: checkpoint on mesh A, restore on mesh B --------
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, on8, {"mesh": "8dev"})
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back2 = elastic.restore_elastic(mgr, template, axes_tree, mesh2)
    check("restore_elastic shrink values",
          elastic.validate_resharding(tree, back2))
    check("restore_elastic shrink placement",
          len(back2["w"].sharding.device_set) <= 2)
    # the same checkpoint restores onto the regrown mesh too
    back8 = elastic.restore_elastic(mgr, template, axes_tree, mesh8)
    check("restore_elastic grow values",
          elastic.validate_resharding(tree, back8))
    check("restore_elastic grow spec",
          back8["w"].sharding.spec == P("data"),
          str(back8["w"].sharding.spec))

print("FAILURES:", failures)
raise SystemExit(1 if failures else 0)
"""


def test_elastic_battery():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    print(proc.stdout)
    print(proc.stderr[-3000:] if proc.stderr else "")
    assert proc.returncode == 0, "elastic battery failed (see output)"
