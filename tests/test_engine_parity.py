"""Engine-parity property battery (ISSUE 2 satellite).

For random small problems across all four ``KernelSpec`` families and all
solver engines (scalar / block / pallas-dense / pallas-matrix-free):

* final duals agree within tolerance (the QP is strongly convex — the
  m·c·I regularizer makes the optimum unique, so every correct engine
  must land on it);
* dual objective values are monotone non-increasing across passes for
  every engine's pass/sweep stepper (the line-search safeguard makes each
  Jacobi pass a descent step; Gauss-Seidel sweeps descend coordinatewise);
* the adaptive in-tile early exit never lets the solver report
  convergence while the *true* full-problem KKT residual exceeds tol, and
  never costs extra passes vs the fixed-step sweep.

Runs in the fast tier and is seed-stable: with hypothesis installed the
seeds are drawn (derandomized); without it the same tests run over a
fixed seed sweep — identical assertions either way.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import dual_cd, engines, kernel_fns as kf, odm
from repro.kernels import ops

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # container without hypothesis
    HAVE_HYPOTHESIS = False

N_SEEDS = 3
PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)
SPECS = {
    "linear": kf.make_spec("linear"),
    "rbf": kf.make_spec("rbf", gamma=0.5),
    "laplacian": kf.make_spec("laplacian", gamma=0.4),
    "poly": kf.make_spec("poly", gamma=0.3, degree=2, coef0=1.0),
}
K_PARTS, M_PART, DIM, BLOCK = 2, 24, 5, 16


def seeded(fn):
    """Property decorator: drawn seeds under hypothesis, fixed sweep without.

    Both paths call ``fn(..., seed=<int>)`` and are deterministic
    (derandomize=True), so failures reproduce exactly in CI.
    """
    if HAVE_HYPOTHESIS:
        return settings(deadline=None, max_examples=N_SEEDS,
                        derandomize=True)(
            given(seed=st.integers(0, 2 ** 16))(fn))
    return pytest.mark.parametrize("seed", range(N_SEEDS))(fn)


def _level_problem(seed):
    """One SODM level: K partitions of m points each, labels balanced."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    xs = jax.random.normal(k1, (K_PARTS, M_PART, DIM))
    ys = jnp.sign(jax.random.normal(k2, (K_PARTS, M_PART)) + 1e-6)
    xs = xs + ys[:, :, None]           # separable-ish: both classes active
    return xs, ys, jnp.zeros((K_PARTS, 2 * M_PART))


def _engine_solvers():
    return {
        "scalar": lambda xs, ys, a0, spec: engines.solve_level_scalar(
            xs, ys, a0, spec=spec, params=PARAMS, tol=1e-7, max_sweeps=800),
        "block": lambda xs, ys, a0, spec: engines.solve_level_block(
            xs, ys, a0, spec=spec, params=PARAMS, tol=1e-7, max_sweeps=800,
            block=BLOCK),
        "pallas": lambda xs, ys, a0, spec: engines.solve_level_pallas(
            xs, ys, a0, spec=spec, params=PARAMS, tol=1e-7, max_sweeps=800,
            block=BLOCK, gram_threshold=10 ** 9),
        "pallas-mfree": lambda xs, ys, a0, spec: engines.solve_level_pallas(
            xs, ys, a0, spec=spec, params=PARAMS, tol=1e-7, max_sweeps=800,
            block=BLOCK, gram_threshold=0),
    }


class TestEngineParity:
    @pytest.mark.parametrize("kernel", list(SPECS))
    @seeded
    def test_final_duals_agree(self, kernel, seed):
        """All engines land on the same (unique) strongly-convex optimum."""
        xs, ys, a0 = _level_problem(seed)
        spec = SPECS[kernel]
        sols = {}
        for name, solver in _engine_solvers().items():
            alphas, _, kkts = solver(xs, ys, a0, spec)
            assert bool(jnp.all(jnp.isfinite(alphas))), (kernel, name)
            sols[name] = alphas
        ref = sols["scalar"]
        for name, alphas in sols.items():
            err = float(jnp.max(jnp.abs(alphas - ref)))
            assert err < 1e-3, (kernel, name, err)

    @pytest.mark.parametrize("kernel", list(SPECS))
    @seeded
    def test_objective_monotone_across_passes(self, kernel, seed):
        """Every engine's pass stepper is a descent step on the dual."""
        xs, ys, _ = _level_problem(seed)
        spec = SPECS[kernel]
        x, y = xs[0], ys[0]
        m = x.shape[0]
        Q = kf.signed_gram(spec, x, y)
        p = PARAMS

        def objs(stepper, n=6):
            alpha = jnp.zeros(2 * m)
            out = [float(odm.dual_objective(Q, alpha, p, float(m)))]
            for _ in range(n):
                alpha = stepper(alpha)
                out.append(float(odm.dual_objective(Q, alpha, p, float(m))))
            return out

        q_diag = jnp.diagonal(Q)

        def scalar_step(alpha):
            zeta, beta = odm.split_alpha(alpha)
            u = Q @ (zeta - beta)
            alpha, _ = dual_cd.sweep(Q, q_diag, alpha, u, p, float(m))
            return alpha

        def block_step(alpha):
            return dual_cd.solve_block(Q, p, mscale=float(m), block=BLOCK,
                                       alpha0=alpha, tol=0.0,
                                       max_outer=1).alpha

        def pallas_step(alpha):
            out, _, _ = ops.dual_cd_solve(
                Q, c=p.c, ups=p.ups, theta=p.theta, mscale=float(m),
                block=BLOCK, n_passes=1, tol=0.0, alpha0=alpha)
            return out

        for name, stepper in (("scalar", scalar_step),
                              ("block", block_step),
                              ("pallas", pallas_step)):
            trace = objs(stepper)
            for a, b in zip(trace, trace[1:]):
                slack = 1e-6 * max(1.0, abs(a))
                assert b <= a + slack, (kernel, name, trace)


class TestAdaptiveEarlyExitKKTOracle:
    """The in-tile early exit must never weaken the convergence claim."""

    def _solve(self, Q, adaptive, tol=1e-5, n_passes=300):
        p = PARAMS
        return ops.dual_cd_solve(
            Q, c=p.c, ups=p.ups, theta=p.theta, mscale=float(Q.shape[0]),
            block=BLOCK, n_passes=n_passes, tol=tol, adaptive=adaptive)

    @seeded
    def test_reported_convergence_implies_true_kkt_below_tol(self, seed):
        """On random convex QPs the solver may only claim convergence when
        the *recomputed-from-scratch* full-problem KKT residual is within
        tol — the incremental u cache and the tile early exits must not
        let a fake convergence through."""
        xs, ys, _ = _level_problem(seed)
        for kernel in ("rbf", "poly"):
            Q = kf.signed_gram(SPECS[kernel], xs[0], ys[0])
            tol = 1e-5
            alpha, kkt, passes = self._solve(Q, adaptive=True, tol=tol)
            assert int(passes) < 300, (kernel, "did not converge")
            true_kkt = float(odm.kkt_residual(Q, alpha, PARAMS,
                                              float(Q.shape[0])))
            # small fp slack: the in-solver residual is evaluated from the
            # incrementally maintained u (same math, different rounding)
            assert true_kkt <= tol * (1.0 + 1e-2) + 1e-7, (kernel, true_kkt)

    @seeded
    def test_adaptive_never_needs_more_passes(self, seed):
        """Early exit only skips steps inside already-converged tiles, so
        the outer pass count can never exceed the fixed-step sweep's."""
        xs, ys, _ = _level_problem(seed)
        for kernel in ("rbf", "laplacian"):
            Q = kf.signed_gram(SPECS[kernel], xs[0], ys[0])
            _, _, p_ad = self._solve(Q, adaptive=True)
            _, _, p_fx = self._solve(Q, adaptive=False)
            assert int(p_ad) <= int(p_fx), (kernel, int(p_ad), int(p_fx))
