"""Unified-API battery (ISSUE 5): spec validation, registry capability
errors, resolve-policy property tests vs the PR 3 dispatch, estimator
parity with the legacy entry points (bit-identical predictions through
the shims), artifact round trips, and the frozen ``sodm.fit`` tuple
contract.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ODMEstimator, ProblemSpec, registry
from repro.api.registry import SolverEntry
from repro.core import baselines, dsvrg, engines, kernel_fns as kf, odm, sodm
from repro.serve.model import FittedODM


def _data(M=128, d=5, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 1.0,
                         jax.random.normal(k2, (M // 2, d)) - 1.0])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)
RBF = ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.5),
                  params=PARAMS)
LIN = ProblemSpec(kernel=kf.KernelSpec(name="linear"), params=PARAMS)
CFG = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                      max_sweeps=200)
DCFG = sodm.SODMConfig(dsvrg=dsvrg.DSVRGConfig(n_partitions=8, epochs=4,
                                               batch=8))


@pytest.fixture
def quiet_legacy():
    """Silence (but keep functional) the legacy-entry FutureWarnings."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", FutureWarning)
        yield


# ---------------------------------------------------------------------------
# ProblemSpec: eager validation
# ---------------------------------------------------------------------------

class TestProblemSpec:
    def test_bad_hyperparameters_raise_eagerly(self):
        with pytest.raises(ValueError, match="kernel"):
            ProblemSpec(kernel=kf.KernelSpec(name="sigmoid"))
        with pytest.raises(ValueError, match="gamma"):
            ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.0))
        with pytest.raises(ValueError, match="degree"):
            ProblemSpec(kernel=kf.KernelSpec(name="poly", degree=0))
        with pytest.raises(ValueError, match="lam"):
            ProblemSpec(params=odm.ODMParams(lam=0.0))
        with pytest.raises(ValueError, match="theta"):
            ProblemSpec(params=odm.ODMParams(theta=1.0))
        with pytest.raises(ValueError, match="ups"):
            ProblemSpec(params=odm.ODMParams(ups=-1.0))

    def test_create_convenience(self):
        p = ProblemSpec.create("poly", gamma=0.3, degree=2, lam=10.0)
        assert p.kernel.name == "poly" and p.kernel.degree == 2
        assert p.params.lam == 10.0

    def test_data_validation(self):
        x, y = _data(M=32)
        with pytest.raises(ValueError, match=r"\(M, d\)"):
            RBF.validate(x[:, 0], y)
        with pytest.raises(ValueError, match="disagree"):
            RBF.validate(x, y[:-2])
        with pytest.raises(ValueError, match=r"\+1/-1"):
            RBF.validate(x, jnp.where(y > 0, 1.0, 0.0))
        xv, yv = RBF.validate(x, y.astype(jnp.int32))
        assert yv.dtype == x.dtype            # int labels are cast

    def test_spec_is_hashable_static(self):
        assert hash(RBF) != hash(LIN)


# ---------------------------------------------------------------------------
# registry: capability errors (satellite: no silent fallbacks)
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_routes_registered(self):
        assert set(registry.routes()) == {"sodm", "dsvrg", "cascade",
                                          "dip", "dc", "svrg", "csvrg"}

    def test_duplicate_registration_raises(self):
        entry = SolverEntry(name="sodm", fit=lambda *a, **k: None,
                            algorithm="dup")
        with pytest.raises(ValueError, match="already registered"):
            registry.register(entry)
        # the error lists the existing routes so the clash is debuggable
        try:
            registry.register(entry)
        except ValueError as e:
            assert "sodm" in str(e) and "dsvrg" in str(e)

    def test_register_unregister_round_trip(self):
        entry = SolverEntry(name="_test_route", fit=lambda *a, **k: None,
                            algorithm="test")
        registry.register(entry)
        try:
            assert registry.get("_test_route") is entry
        finally:
            registry.unregister("_test_route")
        with pytest.raises(ValueError, match="unknown route"):
            registry.get("_test_route")

    def test_unknown_route_lists_options(self):
        with pytest.raises(ValueError, match="registered routes"):
            registry.resolve(RBF, 100, route="bogus")

    def test_unsupported_kernel_lists_capabilities(self):
        for route in ("dsvrg", "svrg", "csvrg"):
            with pytest.raises(ValueError) as ei:
                registry.resolve(RBF, 100, route=route)
            msg = str(ei.value)
            assert "linear" in msg               # the supported family
            assert "capabilities" in msg
            assert "sodm" in msg                 # routes that DO support rbf

    def test_mesh_on_mesh_unaware_route_raises(self):
        from repro.sharding import make_mesh
        mesh = make_mesh((1,), ("data",))
        with pytest.raises(ValueError, match="mesh"):
            registry.resolve(RBF, 100, route="cascade", mesh=mesh)
        # mesh-aware routes accept the same mesh
        assert registry.resolve(RBF, 100, route="sodm",
                                mesh=mesh).name == "sodm"

    def test_estimator_rejects_unknown_route_eagerly(self):
        with pytest.raises(ValueError, match="unknown route"):
            ODMEstimator(RBF, route="bogus")


# ---------------------------------------------------------------------------
# resolve policy == the PR 3 dispatch (property battery)
# ---------------------------------------------------------------------------

def _legacy_wants_dsvrg(engine, kernel_name, M, threshold):
    """The exact PR 3 ``engines.wants_dsvrg`` semantics (reference)."""
    if engine == "dsvrg":
        if kernel_name != "linear":
            raise ValueError("linear required")
        return True
    return engine is None and kernel_name == "linear" and M >= threshold


class TestResolvePolicy:
    ENGINES = (None, "scalar", "block", "pallas", "dsvrg")
    KERNELS = ("linear", "rbf", "laplacian", "poly")
    BANDS = ((10, 5), (10, 50), (199_999, 200_000), (200_000, 200_000),
             (1, 1), (10 ** 7, 200_000))

    def test_matches_legacy_dispatch_exhaustively(self):
        """Full cartesian sweep: the registry's auto policy reproduces the
        PR 3 behavior bit for bit, including the nonlinear-dsvrg error."""
        for engine in self.ENGINES:
            for kernel in self.KERNELS:
                for M, thr in self.BANDS:
                    try:
                        want = _legacy_wants_dsvrg(engine, kernel, M, thr)
                    except ValueError:
                        with pytest.raises(ValueError, match="linear"):
                            registry.resolve_auto(kernel, M, engine=engine,
                                                  threshold=thr)
                        continue
                    entry = registry.resolve_auto(kernel, M, engine=engine,
                                                  threshold=thr)
                    assert (entry.name == "dsvrg") == want, \
                        (engine, kernel, M, thr)

    def test_explicit_engine_never_rerouted(self):
        for engine in ("scalar", "block", "pallas"):
            e = registry.resolve_auto("linear", 10 ** 9, engine=engine,
                                      threshold=1)
            assert e.name == "sodm"

    def test_linear_above_threshold_auto_routes(self):
        assert registry.resolve_auto("linear", 200_000).name == "dsvrg"
        assert registry.resolve_auto("linear", 199_999).name == "sodm"

    def test_nonlinear_never_auto_routes(self):
        for kernel in ("rbf", "laplacian", "poly"):
            assert registry.resolve_auto(kernel, 10 ** 9,
                                         threshold=1).name == "sodm"

    def test_engines_wants_dsvrg_shim_delegates(self):
        """The legacy predicate is now a view onto the registry policy."""
        assert engines.wants_dsvrg(None, "linear", 10, threshold=5)
        assert not engines.wants_dsvrg("scalar", "linear", 10, threshold=5)
        with pytest.raises(ValueError, match="linear"):
            engines.wants_dsvrg("dsvrg", "rbf", 10, threshold=5)

    def test_resolve_reads_config(self):
        cfg = sodm.SODMConfig(dsvrg_threshold=64)
        assert registry.resolve(LIN, 128, cfg=cfg).name == "dsvrg"
        assert registry.resolve(LIN, 32, cfg=cfg).name == "sodm"
        pinned = sodm.SODMConfig(engine="scalar", dsvrg_threshold=64)
        assert registry.resolve(LIN, 128, cfg=pinned).name == "sodm"
        # explicit route beats everything the config says
        assert registry.resolve(LIN, 8, route="dsvrg",
                                cfg=pinned).name == "dsvrg"


# ---------------------------------------------------------------------------
# estimator: parity with the legacy entry points (bit-identical)
# ---------------------------------------------------------------------------

class TestEstimatorParity:
    def test_sodm_route_bit_identical(self, quiet_legacy):
        x, y = _data()
        key = jax.random.PRNGKey(1)
        model, rep = ODMEstimator(RBF, route="sodm", cfg=CFG).fit(x, y, key)
        res = sodm.solve(RBF.kernel, x, y, PARAMS, CFG, key)
        legacy_pred = sodm.predict(RBF.kernel, res, x, y, x)
        assert np.array_equal(np.asarray(model.predict(x)),
                              np.asarray(legacy_pred))
        assert np.array_equal(np.asarray(rep.raw.alpha),
                              np.asarray(res.alpha))
        assert rep.route == "sodm" and rep.passes == \
            tuple(res.sweeps_per_level)

    def test_dsvrg_route_bit_identical(self, quiet_legacy):
        x, y = _data()
        key = jax.random.PRNGKey(2)
        model, rep = ODMEstimator(LIN, route="dsvrg", cfg=DCFG).fit(
            x, y, key)
        dres = dsvrg.solve(x, y, PARAMS, DCFG.dsvrg, key)
        assert np.array_equal(np.asarray(model.w), np.asarray(dres.w))
        assert np.array_equal(np.asarray(model.predict(x)),
                              np.asarray(jnp.sign(x @ dres.w)))
        assert rep.eta == pytest.approx(float(dres.eta))
        assert rep.history == tuple(float(h) for h in dres.history)

    def test_auto_route_end_to_end(self):
        """Tiny threshold: the facade lands on dsvrg exactly where
        sodm.solve's old auto dispatch did, and reports it."""
        x, y = _data()
        auto_cfg = dataclasses.replace(DCFG, dsvrg_threshold=64)
        _, rep = ODMEstimator(LIN, cfg=auto_cfg).fit(x, y)
        assert rep.route == "dsvrg"
        pinned = dataclasses.replace(auto_cfg, engine="scalar",
                                     p=2, levels=2)
        _, rep2 = ODMEstimator(LIN, cfg=pinned).fit(x, y)
        assert rep2.route == "sodm" and len(rep2.passes) == 3

    def test_baseline_routes_fit_and_score(self):
        x, y = _data()
        for route in ("cascade", "dip", "dc"):
            est = ODMEstimator(RBF, route=route, cfg=CFG)
            model, rep = est.fit(x, y, jax.random.PRNGKey(3))
            assert est.score(x, y) > 0.9, route
            assert rep.route == route and rep.wall_clock > 0
        for route in ("svrg", "csvrg"):
            est = ODMEstimator(LIN, route=route, cfg=DCFG)
            model, rep = est.fit(x, y, jax.random.PRNGKey(3))
            assert est.score(x, y) > 0.9, route
            assert model.w is not None and rep.eta > 0
            assert rep.history[-1] < rep.history[0]

    def test_explicit_routes_reject_dsvrg_engine(self):
        """An explicit non-dsvrg route with SODMConfig.engine='dsvrg' is
        contradictory and fails loudly — never a silent re-route through
        the level loop's own dispatch (or a silently ignored pin)."""
        x, y = _data(M=32)
        cfg = dataclasses.replace(DCFG, engine="dsvrg", levels=2,
                                  n_landmarks=4)
        for route in ("sodm", "dip", "dc", "cascade", "svrg", "csvrg"):
            with pytest.raises(ValueError, match="contradictory"):
                ODMEstimator(LIN, route=route, cfg=cfg).fit(x, y)
        # the same engine pin WITH the matching route is of course fine
        ODMEstimator(LIN, route="dsvrg", cfg=cfg).fit(x, y)

    def test_gradient_routes_reject_nonlinear(self):
        x, y = _data(M=32)
        for route in ("svrg", "csvrg", "dsvrg"):
            with pytest.raises(ValueError, match="linear"):
                ODMEstimator(RBF, route=route, cfg=DCFG).fit(x, y)

    def test_report_uniform_fields(self):
        x, y = _data()
        _, rep = ODMEstimator(RBF, route="sodm", cfg=CFG).fit(x, y)
        assert rep.n_train == x.shape[0]
        assert rep.n_sv > 0 and rep.compression in ("exact", "pruned")
        assert rep.kkt is not None and rep.kkt <= CFG.tol * 1.01
        assert "route=sodm" in rep.summary()
        assert isinstance(rep.raw, sodm.SODMResult)

    def test_unfitted_estimator_raises(self):
        est = ODMEstimator(RBF)
        with pytest.raises(ValueError, match="not fitted"):
            est.predict(jnp.zeros((2, 5)))


# ---------------------------------------------------------------------------
# estimator: persistence round trip
# ---------------------------------------------------------------------------

class TestPersistence:
    def test_save_load_predict_round_trip(self, tmp_path):
        x, y = _data()
        est = ODMEstimator(RBF, route="sodm", cfg=CFG)
        est.fit(x, y, jax.random.PRNGKey(4))
        est.save(str(tmp_path))
        loaded = ODMEstimator.load(str(tmp_path))
        assert np.array_equal(np.asarray(est.predict(x)),
                              np.asarray(loaded.predict(x)))
        assert loaded.problem.kernel == RBF.kernel
        assert loaded.model_.compression == est.model_.compression

    def test_save_load_linear_route(self, tmp_path):
        x, y = _data()
        est = ODMEstimator(LIN, route="dsvrg", cfg=DCFG)
        est.fit(x, y, jax.random.PRNGKey(5))
        est.save(str(tmp_path))
        loaded = ODMEstimator.load(str(tmp_path))
        assert np.array_equal(np.asarray(est.model_.w),
                              np.asarray(loaded.model_.w))

    def test_compression_knobs_forward(self):
        x, y = _data()
        est = ODMEstimator(RBF, route="sodm", cfg=CFG, budget=16)
        model, rep = est.fit(x, y, jax.random.PRNGKey(6))
        assert model.n_sv <= 16
        assert rep.compression == "nystrom"


# ---------------------------------------------------------------------------
# legacy shims: frozen contracts + warn-once behavior
# ---------------------------------------------------------------------------

class TestLegacyShims:
    def test_sodm_fit_keeps_tuple_shape(self, quiet_legacy):
        """Satellite: the shimmed ``sodm.fit`` keeps its historical
        ``(SODMResult, FittedODM)`` tuple; the estimator path is the
        supported API (and returns (FittedODM, FitReport))."""
        x, y = _data()
        out = sodm.fit(RBF.kernel, x, y, PARAMS, CFG, jax.random.PRNGKey(7))
        assert isinstance(out, tuple) and len(out) == 2
        res, model = out
        assert isinstance(res, sodm.SODMResult)
        assert isinstance(model, FittedODM)

    def test_legacy_entries_warn_once_and_delegate(self):
        from repro.core import deprecation
        x, y = _data(M=64, d=4)
        cfg = sodm.SODMConfig(p=2, levels=1, n_landmarks=4, tol=1e-4,
                              max_sweeps=50)
        deprecation.reset()
        with pytest.warns(FutureWarning, match="ODMEstimator"):
            sodm.solve(RBF.kernel, x, y, PARAMS, cfg, jax.random.PRNGKey(0))
        # second call: silent (warn-once)
        with warnings.catch_warnings():
            warnings.simplefilter("error", FutureWarning)
            sodm.solve(RBF.kernel, x, y, PARAMS, cfg, jax.random.PRNGKey(0))
        deprecation.reset()
        with pytest.warns(FutureWarning, match="route='svrg'"):
            baselines.svrg_solve(x, y, PARAMS, epochs=1, eta=0.05,
                                 key=jax.random.PRNGKey(0), batch=8)

    def test_facade_never_triggers_legacy_warnings(self):
        from repro.core import deprecation
        x, y = _data(M=64, d=4)
        deprecation.reset()
        cfg = sodm.SODMConfig(p=2, levels=1, n_landmarks=4, tol=1e-4,
                              max_sweeps=50)
        with warnings.catch_warnings():
            warnings.simplefilter("error", FutureWarning)
            ODMEstimator(RBF, route="sodm", cfg=cfg).fit(x, y)
            ODMEstimator(LIN, route="dsvrg", cfg=DCFG).fit(x, y)
            ODMEstimator(RBF, route="cascade", cfg=cfg).fit(x, y)
