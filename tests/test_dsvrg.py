"""DSVRG (Algorithm 2): faithful serial chain + parallel variant.

PR 3 battery on top of the convergence smoke tests:
  * regressions for the three silent-wrong-answer bugs (hardcoded sharded
    eta, dropped ragged-tail samples, host objective recompute),
  * sharded-vs-serial parity on a CPU mesh for both schedules,
  * fused-Pallas vs jnp inner-direction parity,
  * the trace-once pin of the epoch-scan drivers.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import pytest

from repro import sharding
from repro.core import dsvrg, engines, kernel_fns as kf, odm, sodm
from repro.kernels import ops


def _data(M=512, d=12, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 0.7,
                         jax.random.normal(k2, (M // 2, d)) - 0.7])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)


def _gd_ref(x, y, iters=400, eta=0.05):
    w = jnp.zeros(x.shape[1])
    for _ in range(iters):
        w = w - eta * odm.primal_grad(w, x, y, PARAMS)
    return odm.primal_objective(w, x, y, PARAMS)


def _mesh1():
    return sharding.make_mesh((1,), ("data",))


class TestDSVRG:
    def test_serial_converges_to_gd_objective(self):
        x, y = _data()
        ref = _gd_ref(x, y)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, eta=0.05, batch=8)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        assert float(res.history[-1]) < float(ref) * 1.02

    def test_parallel_converges(self):
        x, y = _data()
        ref = _gd_ref(x, y)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, eta=0.05,
                                batch=8, schedule="parallel")
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        assert float(res.history[-1]) < float(ref) * 1.02

    def test_objective_monotone_late(self):
        """After warmup the epoch objective should be non-increasing."""
        x, y = _data()
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, eta=0.03, batch=8)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(2))
        h = [float(v) for v in res.history]
        assert h[-1] <= h[2] + 1e-6

    def test_accuracy(self):
        x, y = _data()
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=6, eta=0.05, batch=8)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(3))
        acc = float(odm.accuracy(y, jnp.sign(x @ res.w)))
        assert acc > 0.9

    def test_stratified_vs_random_partitions(self):
        """Both run; stratified should not be worse in objective."""
        x, y = _data()
        base = dict(n_partitions=8, epochs=5, eta=0.05, batch=8)
        r1 = dsvrg.solve(x, y, PARAMS,
                         dsvrg.DSVRGConfig(**base), jax.random.PRNGKey(4))
        r2 = dsvrg.solve(x, y, PARAMS,
                         dsvrg.DSVRGConfig(partition_strategy="random",
                                           **base), jax.random.PRNGKey(4))
        assert float(r1.history[-1]) <= float(r2.history[-1]) * 1.05

    def test_monotone_on_device_history_auto_eta(self):
        """The device-side history with the auto smoothness step is
        monotone non-increasing from the first epoch (the conservative
        0.5/L_hat step never overshoots on this convex objective)."""
        x, y = _data()
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, batch=8)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(5))
        assert res.history.shape == (8,)
        h = [float(v) for v in res.history]
        assert all(b <= a + 1e-6 for a, b in zip(h, h[1:])), h
        assert float(res.eta) > 0.0


# ---------------------------------------------------------------------------
# PR 3 regressions: the three silent-wrong-answer bugs
# ---------------------------------------------------------------------------

class TestEtaRegression:
    """make_sharded_epoch used to fall back to a hardcoded eta=0.05 when
    cfg.eta <= 0 and no explicit eta was passed, ignoring auto_eta."""

    def test_sharded_epoch_uses_auto_eta(self):
        x, y = _data(M=128, d=5)
        mesh = _mesh1()
        # lam=4 pushes auto_eta well away from the old 0.05 constant
        params = odm.ODMParams(lam=4.0, theta=0.1, ups=0.5)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=1, batch=4)
        xs = x.reshape(8, 16, 5)
        ys = y.reshape(8, 16)
        w0 = jnp.zeros(5)
        eta_ref = dsvrg.auto_eta(x, params)
        assert abs(eta_ref - 0.05) > 1e-3   # else the regression can't bite

        w_auto, _ = dsvrg.make_sharded_epoch(mesh, params, cfg, 128)(
            w0, xs, ys)
        w_explicit, _ = dsvrg.make_sharded_epoch(
            mesh, params, cfg, 128, eta=eta_ref)(w0, xs, ys)
        w_old_bug, _ = dsvrg.make_sharded_epoch(
            mesh, params, cfg, 128, eta=0.05)(w0, xs, ys)
        assert jnp.allclose(w_auto, w_explicit, atol=1e-6)
        assert not jnp.allclose(w_auto, w_old_bug, atol=1e-6)

    def test_sharded_and_single_process_same_step_size(self):
        x, y = _data(M=128, d=5)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=2, batch=4)
        r1 = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(0))
        r2 = dsvrg.solve_sharded(x, y, PARAMS, cfg, jax.random.PRNGKey(0),
                                 _mesh1())
        assert jnp.allclose(r1.eta, r2.eta, rtol=1e-6)
        assert jnp.allclose(r1.eta, dsvrg.auto_eta(x, PARAMS), rtol=1e-5)


class TestTailRegression:
    """_epoch_serial/_epoch_parallel used to run m // batch steps and
    silently skip the last m % batch samples of every partition."""

    def _setup(self, m=13, batch=5, K=2, d=4, seed=7):
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 5)
        xs = jax.random.normal(ks[0], (K, m, d))
        ys = jnp.sign(jax.random.normal(ks[1], (K, m)))
        w = jax.random.normal(ks[2], (d,)) * 0.1
        anchor = jax.random.normal(ks[3], (d,)) * 0.1
        h = odm.primal_grad(anchor, xs.reshape(-1, d), ys.reshape(-1),
                            PARAMS)
        return xs, ys, w, anchor, h

    @staticmethod
    def _serial_ref(w, xs, ys, anchor, h, eta, batch, *, drop_tail):
        """Plain-python without-replacement chain; the oracle consumes the
        ragged tail as a final short batch (mean over its true size)."""
        K, m, d = xs.shape
        stop = (m // batch) * batch if drop_tail else m
        for k in range(K):
            for i in range(0, stop, batch):
                xb, yb = xs[k, i:i + batch], ys[k, i:i + batch]
                w = w - eta * odm.svrg_direction(w, anchor, h, xb, yb,
                                                 PARAMS)
        return w

    def test_serial_consumes_every_sample(self):
        xs, ys, w, anchor, h = self._setup()
        eta = 0.05
        xsb, ysb, wts = dsvrg._pad_batches(xs, ys, 5)
        got = dsvrg._epoch_serial(w, xsb, ysb, wts, anchor, h, eta, PARAMS,
                                  fused=False)
        ref = self._serial_ref(w, xs, ys, anchor, h, eta, 5, drop_tail=False)
        old = self._serial_ref(w, xs, ys, anchor, h, eta, 5, drop_tail=True)
        assert not jnp.allclose(ref, old, atol=1e-6)  # the tail must matter
        assert jnp.allclose(got, ref, atol=1e-5)

    def test_parallel_consumes_every_sample(self):
        xs, ys, w, anchor, h = self._setup()
        eta = 0.05
        xsb, ysb, wts = dsvrg._pad_batches(xs, ys, 5)
        got = dsvrg._epoch_parallel(w, xsb, ysb, wts, anchor, h, eta,
                                    PARAMS, fused=False)
        chains = [self._serial_ref(w, xs[k:k + 1], ys[k:k + 1], anchor, h,
                                   eta, 5, drop_tail=False)
                  for k in range(xs.shape[0])]
        ref = jnp.mean(jnp.stack(chains), axis=0)
        assert jnp.allclose(got, ref, atol=1e-5)

    def test_ragged_batch_matches_batch1_coverage(self):
        """batch ∤ m must consume the same sample set as batch=1: with a
        common anchor-only direction (w == anchor ⇒ direction == h) the
        two batch sizes take the same total step, whatever the slicing."""
        x, y = _data(M=104, d=4)          # m = 13 per partition, 13 % 5 != 0
        cfg5 = dsvrg.DSVRGConfig(n_partitions=8, epochs=1, batch=5, eta=1e-9)
        cfg1 = dsvrg.DSVRGConfig(n_partitions=8, epochs=1, batch=1, eta=1e-9)
        r5 = dsvrg.solve(x, y, PARAMS, cfg5, jax.random.PRNGKey(0))
        r1 = dsvrg.solve(x, y, PARAMS, cfg1, jax.random.PRNGKey(0))
        # at eta -> 0 the epoch is sum over steps of eta*(direction at w0);
        # equal coverage ⇔ equal first-order displacement. The old tail
        # drop loses 3/13 of every partition's anchor mass here.
        d5 = (r5.w) / 1e-9
        d1 = (r1.w) / 1e-9
        n_steps5 = 3 * 8    # ceil(13/5) per partition
        n_steps1 = 13 * 8
        assert jnp.allclose(d5 / n_steps5, d1 / n_steps1, rtol=1e-3)


class TestHistoryOnDevice:
    """solve_sharded used to discard the epoch fn's objective and
    recompute primal_objective over the full permuted data on host."""

    def test_sharded_history_is_global_objective(self):
        x, y = _data(M=128, d=5)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=3, batch=4)
        res = dsvrg.solve_sharded(x, y, PARAMS, cfg, jax.random.PRNGKey(0),
                                  _mesh1())
        xp, yp = x[res.perm], y[res.perm]
        host_obj = float(odm.primal_objective(res.w, xp, yp, PARAMS))
        assert abs(float(res.history[-1]) - host_obj) < 1e-5

    def test_histories_match_across_layouts(self):
        x, y = _data(M=128, d=5)
        for schedule in ("serial", "parallel"):
            cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=3, batch=4,
                                    schedule=schedule)
            r1 = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(0))
            r2 = dsvrg.solve_sharded(x, y, PARAMS, cfg,
                                     jax.random.PRNGKey(0), _mesh1())
            assert jnp.allclose(r1.history, r2.history, atol=1e-5), schedule


# ---------------------------------------------------------------------------
# parity: sharded vs serial, fused vs jnp
# ---------------------------------------------------------------------------

class TestParity:
    def test_sharded_matches_single_process_both_schedules(self):
        x, y = _data(M=128, d=5)
        for schedule in ("serial", "parallel"):
            # batch 3 ∤ m = 16 exercises the masked tail through the full
            # sharded driver as well
            cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=4, batch=3,
                                    schedule=schedule)
            r1 = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(4))
            r2 = dsvrg.solve_sharded(x, y, PARAMS, cfg,
                                     jax.random.PRNGKey(4), _mesh1())
            assert jnp.allclose(r1.w, r2.w, atol=1e-5), schedule
            assert jnp.allclose(r1.history, r2.history, atol=1e-5), schedule

    def test_fused_pallas_matches_jnp_direction(self):
        """ops.svrg_grad (interpret-mode Pallas) vs odm.svrg_direction."""
        key = jax.random.PRNGKey(0)
        for B, d, masked in ((16, 8, False), (13, 7, True), (260, 5, True)):
            ks = jax.random.split(jax.random.fold_in(key, B), 6)
            x = jax.random.normal(ks[0], (B, d))
            y = jnp.sign(jax.random.normal(ks[1], (B,)))
            w = jax.random.normal(ks[2], (d,))
            a = jax.random.normal(ks[3], (d,))
            h = jax.random.normal(ks[4], (d,))
            wt = None
            if masked:
                wt = (jax.random.uniform(ks[5], (B,)) > 0.3).astype(x.dtype)
            ref = odm.svrg_direction(w, a, h, x, y, PARAMS, wb=wt)
            fused = ops.svrg_grad(w, a, h, x, y, wt, lam=PARAMS.lam,
                                  theta=PARAMS.theta, ups=PARAMS.ups)
            assert float(jnp.max(jnp.abs(ref - fused))) <= 1e-5

    def test_fused_solve_matches_jnp_solve(self):
        x, y = _data(M=64, d=6)
        for schedule in ("serial", "parallel"):
            base = dict(n_partitions=4, epochs=2, batch=5, schedule=schedule)
            r0 = dsvrg.solve(x, y, PARAMS,
                             dsvrg.DSVRGConfig(fused=False, **base),
                             jax.random.PRNGKey(1))
            r1 = dsvrg.solve(x, y, PARAMS,
                             dsvrg.DSVRGConfig(fused=True, **base),
                             jax.random.PRNGKey(1))
            assert jnp.allclose(r0.w, r1.w, atol=1e-5), schedule
            assert jnp.allclose(r0.history, r1.history, atol=1e-5), schedule


# ---------------------------------------------------------------------------
# the SODM engine route (paper: "when linear kernel is applied")
# ---------------------------------------------------------------------------

class TestEngineRoute:
    def test_engine_dsvrg_matches_dual_cd_accuracy(self):
        x, y = _data()
        spec = kf.KernelSpec(name="linear")
        cfg = sodm.SODMConfig(
            engine="dsvrg",
            dsvrg=dsvrg.DSVRGConfig(n_partitions=8, epochs=8, batch=16))
        res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        acc = float(odm.accuracy(y, sodm.predict(spec, res, x, y, x)))
        ref = sodm.solve(spec, x, y, PARAMS,
                         sodm.SODMConfig(p=2, levels=3, tol=1e-5,
                                         max_sweeps=200),
                         jax.random.PRNGKey(1))
        acc_cd = float(odm.accuracy(y, sodm.predict(spec, ref, x, y, x)))
        assert abs(acc - acc_cd) <= 0.005
        assert res.levels_run == 1 and res.sweeps_per_level == [8]

    def test_engine_dsvrg_requires_linear_kernel(self):
        x, y = _data(M=64, d=4)
        cfg = sodm.SODMConfig(engine="dsvrg")
        with pytest.raises(ValueError, match="linear"):
            sodm.solve(kf.KernelSpec(name="rbf"), x, y, PARAMS, cfg,
                       jax.random.PRNGKey(0))

    def test_auto_dispatch_upgrades_unset_engine_only(self):
        """Auto-route fires only when the engine is left unset (None) —
        every explicitly named engine, scalar included, is honored."""
        assert engines.wants_dsvrg(None, "linear", 10, threshold=5)
        assert not engines.wants_dsvrg(None, "linear", 10, threshold=50)
        assert not engines.wants_dsvrg(None, "rbf", 10, threshold=5)
        for explicit in ("scalar", "block", "pallas"):
            assert not engines.wants_dsvrg(explicit, "linear", 10,
                                           threshold=5)
        # end-to-end: tiny threshold routes the unset engine (the DSVRG
        # route reports levels_run=1, the level loop runs levels+1 solves)
        x, y = _data(M=128, d=5)
        spec = kf.KernelSpec(name="linear")
        auto = sodm.SODMConfig(
            dsvrg_threshold=64,
            dsvrg=dsvrg.DSVRGConfig(n_partitions=8, epochs=4, batch=8))
        res = sodm.solve(spec, x, y, PARAMS, auto, jax.random.PRNGKey(0))
        assert res.levels_run == 1 and res.sweeps_per_level == [4]
        pinned = sodm.SODMConfig(engine="scalar", p=2, levels=2,
                                 dsvrg_threshold=64)
        res2 = sodm.solve(spec, x, y, PARAMS, pinned, jax.random.PRNGKey(0))
        assert res2.levels_run == 3          # the level loop actually ran

    def test_auto_route_on_mesh_prefers_parallel_schedule(self):
        """An AUTO-dispatched sharded solve upgrades the default serial
        schedule to parallel (the serial chain replicates the whole slab
        on every device — wrong for the regime that triggers the route);
        an explicit engine="dsvrg" keeps the configured schedule."""
        x, y = _data(M=128, d=5)
        spec = kf.KernelSpec(name="linear")
        mesh = _mesh1()
        base = dsvrg.DSVRGConfig(n_partitions=8, epochs=2, batch=8)
        assert base.schedule == "serial"

        def last_routed_cfg(n_before):
            assert dsvrg.epoch_trace_count() > n_before  # fresh trace
            return dsvrg._TRACE_EVENTS[-1][1]

        n0 = dsvrg.epoch_trace_count()
        sodm.solve_sharded(
            spec, x, y, PARAMS,
            sodm.SODMConfig(dsvrg_threshold=64, dsvrg=base),
            jax.random.PRNGKey(0), mesh)
        assert last_routed_cfg(n0).schedule == "parallel"
        n1 = dsvrg.epoch_trace_count()
        sodm.solve_sharded(
            spec, x, y, PARAMS,
            sodm.SODMConfig(engine="dsvrg", dsvrg=base),
            jax.random.PRNGKey(0), mesh)
        assert last_routed_cfg(n1).schedule == "serial"

    def test_route_honors_outer_partition_strategy(self):
        """SODMConfig.partition_strategy carries onto the DSVRG route."""
        x, y = _data(M=128, d=5)
        spec = kf.KernelSpec(name="linear")
        base = dsvrg.DSVRGConfig(n_partitions=8, epochs=2, batch=8)
        r_strat = sodm.solve(
            spec, x, y, PARAMS,
            sodm.SODMConfig(engine="dsvrg", dsvrg=base),
            jax.random.PRNGKey(3))
        r_rand = sodm.solve(
            spec, x, y, PARAMS,
            sodm.SODMConfig(engine="dsvrg", partition_strategy="random",
                            dsvrg=base),
            jax.random.PRNGKey(3))
        d_rand = dsvrg.solve(
            x, y, PARAMS,
            dataclasses.replace(base, partition_strategy="random"),
            jax.random.PRNGKey(3))
        assert jnp.array_equal(r_rand.perm, d_rand.perm)
        assert not jnp.array_equal(r_strat.perm, r_rand.perm)


# ---------------------------------------------------------------------------
# trace-once pin of the epoch-scan drivers
# ---------------------------------------------------------------------------

class TestTraceOnce:
    def test_solve_traces_once_per_config(self):
        x, y = _data(M=96, d=5)
        cfg = dsvrg.DSVRGConfig(n_partitions=6, epochs=5, batch=4)
        n0 = dsvrg.epoch_trace_count()
        dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(0))
        assert dsvrg.epoch_trace_count() == n0 + 1
        # same config + shapes, different data: jit cache hit, no retrace
        dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        dsvrg.solve(-x, y, PARAMS, cfg, jax.random.PRNGKey(2))
        assert dsvrg.epoch_trace_count() == n0 + 1

    def test_sharded_traces_once_per_config(self):
        x, y = _data(M=96, d=5)
        mesh = _mesh1()
        cfg = dsvrg.DSVRGConfig(n_partitions=6, epochs=5, batch=4,
                                schedule="parallel")
        n0 = dsvrg.epoch_trace_count()
        dsvrg.solve_sharded(x, y, PARAMS, cfg, jax.random.PRNGKey(0), mesh)
        assert dsvrg.epoch_trace_count() == n0 + 1
        dsvrg.solve_sharded(x, y, PARAMS, cfg, jax.random.PRNGKey(1), mesh)
        assert dsvrg.epoch_trace_count() == n0 + 1

    def test_epoch_loop_is_a_scan(self):
        """The epochs ride a lax.scan of length cfg.epochs inside ONE
        jitted driver — not a host loop of per-epoch dispatches."""
        params = PARAMS
        cfg = dsvrg.DSVRGConfig(n_partitions=2, epochs=7, batch=4)
        xs = jnp.zeros((2, 3, 4, 5))
        ys = jnp.zeros((2, 3, 4))
        wts = jnp.ones((3, 4))
        jaxpr = jax.make_jaxpr(functools.partial(
            dsvrg._run.__wrapped__, params=params, cfg=cfg, M=24))(
                jnp.zeros(5), xs, ys, wts)
        assert f"length={cfg.epochs}" in str(jaxpr)
