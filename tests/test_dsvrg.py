"""DSVRG (Algorithm 2): faithful serial chain + parallel variant."""
import jax
import jax.numpy as jnp

from repro.core import dsvrg, odm


def _data(M=512, d=12, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 0.7,
                         jax.random.normal(k2, (M // 2, d)) - 0.7])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)


def _gd_ref(x, y, iters=400, eta=0.05):
    w = jnp.zeros(x.shape[1])
    for _ in range(iters):
        w = w - eta * odm.primal_grad(w, x, y, PARAMS)
    return odm.primal_objective(w, x, y, PARAMS)


class TestDSVRG:
    def test_serial_converges_to_gd_objective(self):
        x, y = _data()
        ref = _gd_ref(x, y)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, eta=0.05, batch=8)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        assert float(res.history[-1]) < float(ref) * 1.02

    def test_parallel_converges(self):
        x, y = _data()
        ref = _gd_ref(x, y)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, eta=0.05,
                                batch=8, schedule="parallel")
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        assert float(res.history[-1]) < float(ref) * 1.02

    def test_objective_monotone_late(self):
        """After warmup the epoch objective should be non-increasing."""
        x, y = _data()
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, eta=0.03, batch=8)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(2))
        h = [float(v) for v in res.history]
        assert h[-1] <= h[2] + 1e-6

    def test_accuracy(self):
        x, y = _data()
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=6, eta=0.05, batch=8)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(3))
        acc = float(odm.accuracy(y, jnp.sign(x @ res.w)))
        assert acc > 0.9

    def test_stratified_vs_random_partitions(self):
        """Both run; stratified should not be worse in objective."""
        x, y = _data()
        base = dict(n_partitions=8, epochs=5, eta=0.05, batch=8)
        r1 = dsvrg.solve(x, y, PARAMS,
                         dsvrg.DSVRGConfig(**base), jax.random.PRNGKey(4))
        r2 = dsvrg.solve(x, y, PARAMS,
                         dsvrg.DSVRGConfig(partition_strategy="random",
                                           **base), jax.random.PRNGKey(4))
        assert float(r1.history[-1]) <= float(r2.history[-1]) * 1.05
