"""SODM Algorithm 1: hierarchical merge, warm starts, convergence."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import baselines, dual_cd, kernel_fns as kf, odm, sodm
from repro.data import synthetic


def _data(M=256, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 1.0,
                         jax.random.normal(k2, (M // 2, d)) - 1.0])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)
SPEC = kf.KernelSpec(name="rbf", gamma=0.5)


class TestSODM:
    def test_matches_global_solve(self):
        x, y = _data()
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-6,
                              max_sweeps=500)
        res = sodm.solve(SPEC, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        xp, yp = x[res.perm], y[res.perm]
        Q = kf.signed_gram(SPEC, xp, yp)
        glob = dual_cd.solve(Q, PARAMS, mscale=256.0, tol=1e-6,
                             max_sweeps=500)
        o1 = odm.dual_objective(Q, res.alpha, PARAMS, 256.0)
        o2 = odm.dual_objective(Q, glob.alpha, PARAMS, 256.0)
        assert abs(float(o1 - o2)) < 1e-4

    def test_merge_alphas_layout(self):
        alphas = jnp.arange(12.0).reshape(2, 6)   # 2 parts, m=3
        merged = sodm.merge_alphas(alphas)
        # zetas: [0,1,2] + [6,7,8]; betas: [3,4,5] + [9,10,11]
        want = jnp.array([0, 1, 2, 6, 7, 8, 3, 4, 5, 9, 10, 11.0])
        assert bool(jnp.all(merged == want))

    def test_split_inverts_merge(self):
        alphas = jax.random.uniform(jax.random.PRNGKey(0), (4, 10))
        merged = sodm.merge_alphas(alphas)
        back = sodm.split_to_partitions(merged, 4)
        assert float(jnp.max(jnp.abs(back - alphas))) == 0.0

    def test_warm_start_reduces_sweeps(self):
        """Warm-started later levels should converge in fewer sweeps than a
        cold global solve."""
        x, y = _data(M=256)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-6,
                              max_sweeps=500)
        res = sodm.solve(SPEC, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        xp, yp = x[res.perm], y[res.perm]
        Q = kf.signed_gram(SPEC, xp, yp)
        cold = dual_cd.solve(Q, PARAMS, mscale=256.0, tol=1e-6,
                             max_sweeps=500)
        # last level ran on the full problem with a warm start
        assert res.sweeps_per_level[-1] <= int(cold.sweeps)

    def test_generalization_close_to_global(self):
        ds = synthetic.load("svmguide1", scale=0.05)
        x, y = ds.x_train, ds.y_train
        M = x.shape[0] - x.shape[0] % 8
        x, y = x[:M], y[:M]
        # features normalized to [0,1]: gamma must be larger than the
        # blob-scale default used by the other tests
        spec = kf.KernelSpec(name="rbf", gamma=2.0)
        params = odm.ODMParams(lam=10.0, theta=0.1, ups=0.5)
        cfg = sodm.SODMConfig(p=2, levels=3, n_landmarks=4, tol=1e-5,
                              max_sweeps=300)
        res = sodm.solve(spec, x, y, params, cfg, jax.random.PRNGKey(2))
        pred = sodm.predict(spec, res, x, y, ds.x_test)
        acc = float(odm.accuracy(ds.y_test, pred))
        assert acc > 0.85, acc

    def test_partition_strategies_run(self):
        x, y = _data(M=128)
        for strat in ("stratified", "random", "cluster", "identity"):
            cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4,
                                  partition_strategy=strat, tol=1e-5,
                                  max_sweeps=200)
            res = sodm.solve(SPEC, x, y, PARAMS, cfg, jax.random.PRNGKey(3))
            assert res.alpha.shape == (256,)


class TestBaselines:
    def test_cascade_accuracy(self):
        x, y = _data(M=256)
        res = baselines.cascade_solve(SPEC, x, y, PARAMS, levels=2,
                                      key=jax.random.PRNGKey(0))
        pred = baselines.cascade_predict(SPEC, res, x)
        assert float(odm.accuracy(y, pred)) > 0.9

    def test_dip_dc_run_and_predict(self):
        x, y = _data(M=256)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=200)
        for solver in (baselines.dip_solve, baselines.dc_solve):
            res = solver(SPEC, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
            pred = sodm.predict(SPEC, res, x, y, x)
            assert float(odm.accuracy(y, pred)) > 0.9

    def test_gradient_baselines_converge(self):
        x, y = _data(M=256, d=8)
        svrg = baselines.svrg_solve(x, y, PARAMS, epochs=6, eta=0.05,
                                    key=jax.random.PRNGKey(0), batch=8)
        csvrg = baselines.csvrg_solve(x, y, PARAMS, epochs=6, eta=0.05,
                                      key=jax.random.PRNGKey(0),
                                      coreset_frac=0.25, batch=8)
        assert float(svrg.history[-1]) < float(svrg.history[0])
        assert float(csvrg.history[-1]) < float(csvrg.history[0])
