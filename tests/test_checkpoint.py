"""Checkpoint manager: atomic commit, retention, async, bf16, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager


def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(key, (8, 4)),
                   "b": jnp.zeros((4,), jnp.bfloat16)},
        "opt": {"m": jnp.ones((8, 4)), "step": jnp.int32(7)},
    }


class TestRoundtrip:
    def test_save_restore_exact(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        t = _tree()
        mgr.save(10, t, {"data_step": 10})
        back = mgr.restore(t)
        eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), t, back)
        assert all(jax.tree.leaves(eq))

    def test_bf16_preserved(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = {"x": (jnp.arange(6, dtype=jnp.float32) / 3.0).astype(jnp.bfloat16)}
        mgr.save(1, t)
        back = mgr.restore(t)
        assert back["x"].dtype == jnp.bfloat16
        assert bool(jnp.array_equal(t["x"], back["x"]))

    def test_restore_into_shapestructs(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = _tree()
        mgr.save(3, t)
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
        back = mgr.restore(template)
        eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), t, back)
        assert all(jax.tree.leaves(eq))

    def test_metadata_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, _tree(), {"data_step": 5, "arch": "x"})
        mgr.save(9, _tree(1), {"data_step": 9})
        assert mgr.latest_step() == 9
        assert mgr.metadata(5)["metadata"]["arch"] == "x"
        assert mgr.metadata()["metadata"]["data_step"] == 9


class TestDurability:
    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s))
        assert mgr.all_steps() == [3, 4]

    def test_tmp_dirs_invisible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _tree())
        # simulate a crashed writer
        os.makedirs(str(tmp_path / "step_0000000002.tmp.999"))
        assert mgr.all_steps() == [1]
        assert mgr.latest_step() == 1

    def test_async_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = _tree()
        mgr.save_async(42, t, {"data_step": 42})
        mgr.wait()
        back = mgr.restore(t, step=42)
        eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), t, back)
        assert all(jax.tree.leaves(eq))

    def test_overwrite_same_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros(3)})
        mgr.save(1, {"x": jnp.ones(3)})
        back = mgr.restore({"x": jnp.zeros(3)})
        assert bool(jnp.all(back["x"] == 1.0))


@pytest.mark.chaos
class TestCrashWindow:
    """ISSUE 7: kill between the fsync'd temp write and the atomic rename."""

    def test_kill_mid_checkpoint_previous_step_survives(self, tmp_path):
        from repro.distributed.faults import FaultPlan, Preemption

        plan = FaultPlan()
        mgr = CheckpointManager(str(tmp_path), keep=3, faults=plan)
        t1 = _tree(1)
        mgr.save(1, t1, {"data_step": 1})
        plan.kill_mid_checkpoint()          # arm AFTER step 1 committed

        with pytest.raises(Preemption) as exc:
            mgr.save(2, _tree(2), {"data_step": 2})
        assert exc.value.site == "checkpoint.pre_rename"

        # the previous manifest is still the latest and fully loadable
        assert mgr.latest_step() == 1
        back = mgr.restore(t1)
        eq = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), t1, back)
        assert all(jax.tree.leaves(eq))
        assert mgr.metadata()["metadata"]["data_step"] == 1

        # the killed writer left an orphaned temp dir ...
        orphans = [n for n in os.listdir(tmp_path) if ".tmp." in n]
        assert orphans, "kill site is not inside the crash window"
        # ... which stays invisible to discovery
        assert mgr.all_steps() == [1]

        # and the next successful save garbage-collects it
        mgr.save(2, _tree(2), {"data_step": 2})
        assert [n for n in os.listdir(tmp_path) if ".tmp." in n] == []
        assert mgr.latest_step() == 2

    def test_fresh_manager_ignores_orphans(self, tmp_path):
        """A restarted process (new manager over the same dir) restores the
        committed step even with a crashed writer's droppings present."""
        from repro.distributed.faults import FaultPlan, Preemption

        plan = FaultPlan()
        mgr = CheckpointManager(str(tmp_path), faults=plan)
        mgr.save(7, {"x": jnp.arange(5.0)})
        plan.kill_mid_checkpoint()
        with pytest.raises(Preemption):
            mgr.save(8, {"x": jnp.arange(5.0) + 1})

        mgr2 = CheckpointManager(str(tmp_path))   # the restart
        assert mgr2.latest_step() == 7
        back = mgr2.restore({"x": jnp.zeros(5)})
        assert bool(jnp.array_equal(back["x"], jnp.arange(5.0)))


class TestTrainResume:
    def test_end_to_end_resume(self, tmp_path):
        """Train 6 steps with checkpointing == train 3, restart, train 3."""
        import dataclasses
        from repro import configs
        from repro.data import lm as lmdata
        from repro.models import model as M
        from repro.train import steps as steps_mod

        cfg = configs.get_smoke("smollm-135m")
        tc = steps_mod.TrainConfig()
        p, _ = M.init_params(jax.random.PRNGKey(0), cfg)
        step = jax.jit(steps_mod.make_train_step(cfg, tc))
        dc = lmdata.LMDataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)

        # straight run
        s_a = steps_mod.TrainState.create(p, use_ef=False)
        for i in range(6):
            s_a, _ = step(s_a, lmdata.batch_at(dc, i))

        # checkpointed run
        mgr = CheckpointManager(str(tmp_path))
        s_b = steps_mod.TrainState.create(p, use_ef=False)
        for i in range(3):
            s_b, _ = step(s_b, lmdata.batch_at(dc, i))
        mgr.save(3, s_b, {"data_step": 3})
        # "restart": restore into fresh state template
        fresh = steps_mod.TrainState.create(p, use_ef=False)
        s_c = mgr.restore(fresh)
        start = mgr.metadata()["metadata"]["data_step"]
        for i in range(start, 6):
            s_c, _ = step(s_c, lmdata.batch_at(dc, i))

        diff = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            s_a["params"], s_c["params"])
        assert max(jax.tree.leaves(diff)) < 1e-6
