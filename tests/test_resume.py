"""Chaos battery: preemption-proof cascade + dsvrg training (ISSUE 7).

Every test kills the driver with a deterministic fault plan
(repro.distributed.faults), restarts via ``fit(resume=<dir>)``, and
asserts the resumed model is BIT-identical to the uninterrupted fit —
with fewer level solves than a cold restart whenever a checkpoint was
committed before the kill.

The cascade level counter counts DOWN from cfg.levels to 0 (levels+1
solves total); the ``cascade.level`` fault site fires *before* each level
solve, so killing at level k leaves level k+1's checkpoint as the latest
committed state and the resumed run re-solves exactly levels k..0.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ODMEstimator, ProblemSpec
from repro.core import kernel_fns as kf
from repro.core import sodm
from repro.core.dsvrg import DSVRGConfig
from repro.distributed import resume as resume_mod
from repro.distributed.faults import FaultPlan, Preemption

pytestmark = pytest.mark.chaos


def _toy(M=32, d=4, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 1.0,
                         jax.random.normal(k2, (M // 2, d)) - 1.0])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


def _cascade_cfg(levels, strategy="stratified"):
    return sodm.SODMConfig(p=2, levels=levels, n_landmarks=4, tol=1e-4,
                           max_sweeps=50, partition_strategy=strategy)


def _rbf_problem():
    return ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.5))


def _fit(cfg, x, y, **kw):
    est = ODMEstimator(_rbf_problem(), route="sodm", cfg=cfg)
    return est.fit(x, y, jax.random.PRNGKey(0), **kw)


def _models_bit_identical(a, b):
    """FittedODM equality, bitwise, whichever representation is packed."""
    assert a.compression == b.compression
    for f in ("w", "x_sv", "coef"):
        fa, fb = getattr(a, f), getattr(b, f)
        assert (fa is None) == (fb is None), f
        if fa is not None:
            assert np.array_equal(np.asarray(fa), np.asarray(fb)), f
    return True


class TestCascadeKillAtLevel:
    """kill-at-level-k across every cascade depth and both partition
    schedules the toy problems support."""

    @pytest.mark.parametrize("levels,kill_level,strategy", [
        (1, 0, "stratified"),
        (2, 1, "stratified"),
        (2, 0, "random"),
        (3, 2, "stratified"),
        (3, 1, "random"),
    ])
    def test_bit_identical_with_fewer_solves(self, tmp_path, levels,
                                             kill_level, strategy):
        x, y = _toy()
        cfg = _cascade_cfg(levels, strategy)
        base_model, base = _fit(cfg, x, y)

        d = str(tmp_path)
        with pytest.raises(Preemption) as exc:
            _fit(cfg, x, y, resume=d,
                 faults=FaultPlan().kill_at_level(kill_level))
        assert exc.value.site == "cascade.level"
        assert exc.value.info["level"] == kill_level

        c0 = sodm.level_solve_count()
        model, resumed = _fit(cfg, x, y, resume=d)
        ran = sodm.level_solve_count() - c0

        cold = cfg.levels + 1
        # levels kill_level..0 remain: kill_level+1 solves, < cold restart
        assert ran == kill_level + 1 < cold
        assert np.array_equal(np.asarray(resumed.raw.alpha),
                              np.asarray(base.raw.alpha))
        assert _models_bit_identical(model, base_model)

    def test_kill_at_top_level_cold_starts(self, tmp_path):
        """Killed before the very first level solve: no checkpoint exists,
        so resume IS a cold start — and still bit-identical."""
        x, y = _toy()
        cfg = _cascade_cfg(2)
        _, base = _fit(cfg, x, y)

        d = str(tmp_path)
        with pytest.raises(Preemption):
            _fit(cfg, x, y, resume=d,
                 faults=FaultPlan().kill_at_level(cfg.levels))

        c0 = sodm.level_solve_count()
        _, resumed = _fit(cfg, x, y, resume=d)
        assert sodm.level_solve_count() - c0 == cfg.levels + 1
        assert np.array_equal(np.asarray(resumed.raw.alpha),
                              np.asarray(base.raw.alpha))

    def test_completed_dir_resumes_with_zero_solves(self, tmp_path):
        """Re-running fit(resume=) over a finished directory replays the
        final checkpoint and solves nothing."""
        x, y = _toy()
        cfg = _cascade_cfg(2)
        d = str(tmp_path)
        _, first = _fit(cfg, x, y, resume=d)

        c0 = sodm.level_solve_count()
        _, again = _fit(cfg, x, y, resume=d)
        assert sodm.level_solve_count() - c0 == 0
        assert np.array_equal(np.asarray(again.raw.alpha),
                              np.asarray(first.raw.alpha))


class TestCascadeKillMidCheckpoint:
    def test_kill_inside_crash_window_then_resume(self, tmp_path):
        """The driver dies INSIDE CheckpointManager._write (post-fsync,
        pre-rename) while committing level state. The torn write must not
        poison the directory: resume restarts from the previous committed
        level and stays bit-identical."""
        x, y = _toy()
        cfg = _cascade_cfg(2)
        _, base = _fit(cfg, x, y)

        d = str(tmp_path)
        # step = completed level solves; step=2 is the SECOND level commit,
        # so step=1 (the top level's state) is already durable when we die
        with pytest.raises(Preemption) as exc:
            _fit(cfg, x, y, resume=d,
                 faults=FaultPlan().kill("checkpoint.pre_rename", step=2))
        assert exc.value.site == "checkpoint.pre_rename"

        c0 = sodm.level_solve_count()
        _, resumed = _fit(cfg, x, y, resume=d)
        ran = sodm.level_solve_count() - c0
        assert ran == cfg.levels < cfg.levels + 1
        assert np.array_equal(np.asarray(resumed.raw.alpha),
                              np.asarray(base.raw.alpha))


class TestProvenance:
    def test_strict_mismatch_raises(self, tmp_path):
        x, y = _toy()
        cfg = _cascade_cfg(2)
        d = str(tmp_path)
        with pytest.raises(Preemption):
            _fit(cfg, x, y, resume=d, faults=FaultPlan().kill_at_level(1))
        x2, y2 = _toy(seed=7)                    # different data, same dir
        with pytest.raises(resume_mod.ProvenanceError):
            _fit(cfg, x2, y2, resume=d)

    def test_lenient_mismatch_cold_starts(self, tmp_path):
        x, y = _toy()
        cfg = _cascade_cfg(2)
        d = str(tmp_path)
        with pytest.raises(Preemption):
            _fit(cfg, x, y, resume=d, faults=FaultPlan().kill_at_level(1))
        x2, y2 = _toy(seed=7)
        _, base2 = _fit(cfg, x2, y2)
        rc = resume_mod.ResumeConfig(directory=d, strict=False)
        with pytest.warns(RuntimeWarning, match="different run"):
            _, resumed = _fit(cfg, x2, y2, resume=rc)
        assert np.array_equal(np.asarray(resumed.raw.alpha),
                              np.asarray(base2.raw.alpha))


class TestDsvrgResume:
    @pytest.mark.parametrize("schedule", ["serial", "parallel"])
    def test_resume_determinism(self, tmp_path, schedule):
        """Kill between scan segments at epoch 2 of 4; the resumed iterate
        is bitwise equal to the uninterrupted segmented run, for both
        inner-phase schedules."""
        x, y = _toy()
        dcfg = DSVRGConfig(n_partitions=4, epochs=4, batch=8,
                           n_landmarks=4, schedule=schedule)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-4,
                              max_sweeps=50, dsvrg=dcfg)
        problem = ProblemSpec(kernel=kf.KernelSpec(name="linear"))
        key = jax.random.PRNGKey(0)

        d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
        model_a, _ = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
            x, y, key, resume=d1)
        with pytest.raises(Preemption) as exc:
            ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
                x, y, key, resume=d2, faults=FaultPlan().kill_at_epoch(2))
        assert exc.value.site == "dsvrg.segment"
        model_b, _ = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
            x, y, key, resume=d2)
        assert np.array_equal(np.asarray(model_a.w), np.asarray(model_b.w))

    def test_segment_width_preserves_result(self, tmp_path):
        """Segmented execution (resume hooks on) is bitwise identical to
        the hook-free single-scan path regardless of segment width —
        SVRG re-anchors every epoch, so epoch boundaries are exact."""
        x, y = _toy()
        dcfg = DSVRGConfig(n_partitions=4, epochs=4, batch=8, n_landmarks=4)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-4,
                              max_sweeps=50, dsvrg=dcfg)
        problem = ProblemSpec(kernel=kf.KernelSpec(name="linear"))
        key = jax.random.PRNGKey(0)

        ref, _ = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(x, y, key)
        for seg in (1, 2, 4):
            rc = resume_mod.ResumeConfig(
                directory=str(tmp_path / f"s{seg}"), segment=seg)
            m, _ = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
                x, y, key, resume=rc)
            assert np.array_equal(np.asarray(ref.w), np.asarray(m.w)), seg


class TestFaultPlanBookkeeping:
    def test_fired_log_and_spent_rules(self):
        plan = FaultPlan(sleeper=None).delay("cascade.partition", 0.25,
                                            partition=1).kill_at_level(0)
        assert plan.site("cascade.partition", partition=0, attempt=1) == 0.0
        assert plan.site("cascade.partition", partition=1, attempt=1) == 0.25
        # rule spent: the retry of partition 1 is clean
        assert plan.site("cascade.partition", partition=1, attempt=2) == 0.0
        with pytest.raises(Preemption):
            plan.site("cascade.level", level=0, K=1)
        assert [(f[0], f[1]) for f in plan.fired] == [
            ("delay", "cascade.partition"), ("kill", "cascade.level")]

    def test_non_instrumented_route_rejects_hooks(self):
        x, y = _toy()
        est = ODMEstimator(_rbf_problem(), route="cascade",
                           cfg=_cascade_cfg(1))
        with pytest.raises(ValueError, match="no .*seam"):
            est.fit(x, y, jax.random.PRNGKey(0), faults=FaultPlan())
