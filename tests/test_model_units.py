"""Model-internals unit tests: MoE invariants, recurrence properties,
ring-buffer caches, RoPE, precision boundary."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import attention, layers as L, mamba, moe, rglru

KEY = jax.random.PRNGKey(0)


class TestMoE:
    def _setup(self):
        cfg = configs.get_smoke("dbrx-132b")
        p, _ = moe.init(KEY, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(KEY, 1),
                              (2, 8, cfg.d_model)) * 0.5
        return cfg, p, x

    def test_full_capacity_matches_everyexpert_reference(self):
        """Dropless dispatch == dense weighted mixture over selected experts."""
        cfg, p, x = self._setup()
        out, _ = moe._forward_local(p, x, cfg, jnp.float32,
                                    full_capacity=True)
        # reference: run every expert densely, combine with the same gates
        T = x.shape[0] * x.shape[1]
        xt = x.reshape(T, -1)
        logits = xt @ p["router"]["w"]
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eid = jax.lax.top_k(probs, cfg.moe.top_k)
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
        h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["wg"])) * \
            jnp.einsum("td,edf->tef", xt, p["wi"])
        eout = jnp.einsum("tef,efd->ted", h, p["wo"])     # (T, E, D)
        ref = jnp.zeros_like(xt)
        for k in range(cfg.moe.top_k):
            ref = ref + gate[:, k:k + 1] * jnp.take_along_axis(
                eout, eid[:, k][:, None, None].repeat(xt.shape[1], 2),
                axis=1)[:, 0]
        err = float(jnp.max(jnp.abs(out.reshape(T, -1) - ref)))
        assert err < 1e-4, err

    def test_capacity_drops_tokens(self):
        """With capacity_factor ~0, almost everything drops -> tiny output."""
        cfg, p, x = self._setup()
        cfg_tight = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e-9))
        out, _ = moe._forward_local(p, x, cfg_tight, jnp.float32)
        out_full, _ = moe._forward_local(p, x, cfg, jnp.float32,
                                         full_capacity=True)
        assert float(jnp.sum(out ** 2)) < float(jnp.sum(out_full ** 2))

    def test_aux_loss_near_one_for_uniform(self):
        """Switch aux loss == 1 exactly under perfect balance; random
        routers should be within a small factor."""
        cfg, p, x = self._setup()
        _, aux = moe._forward_local(p, x, cfg, jnp.float32,
                                    full_capacity=True)
        assert 0.5 < float(aux) < 4.0


class TestRGLRU:
    def test_decay_in_unit_interval(self):
        cfg = configs.get_smoke("recurrentgemma-9b")
        p, _ = rglru.init(KEY, cfg, jnp.float32)
        xc = jax.random.normal(jax.random.fold_in(KEY, 1),
                               (2, 16, rglru.width(cfg)))
        a, b = rglru._lru_coeffs(p, xc)
        assert float(jnp.min(a)) > 0.0
        assert float(jnp.max(a)) < 1.0

    def test_state_bounded_under_zero_input(self):
        """h_{t+1} = a h_t with a<1: state decays, never explodes."""
        cfg = configs.get_smoke("recurrentgemma-9b")
        p, _ = rglru.init(KEY, cfg, jnp.float32)
        state, _ = rglru.init_state(cfg, batch=2)
        state = {**state, "h": jnp.ones_like(state["h"]) * 10.0}
        x = jnp.zeros((2, 1, cfg.d_model))
        for _ in range(5):
            _, state = rglru.decode_step(p, state, x, cfg, jnp.float32)
        assert float(jnp.max(jnp.abs(state["h"]))) <= 10.0


class TestMamba:
    def test_scan_matches_stepwise(self):
        cfg = configs.get_smoke("falcon-mamba-7b")
        p, _ = mamba.init(KEY, cfg, jnp.float32)
        B, T = 2, 12
        x = jax.random.normal(jax.random.fold_in(KEY, 1),
                              (B, T, cfg.d_model)) * 0.5
        cfg32 = dataclasses.replace(cfg, compute_dtype="float32")
        full = mamba.forward(p, x, cfg, jnp.float32)
        state, _ = mamba.init_state(cfg, batch=B, dtype=jnp.float32)
        outs = []
        for t in range(T):
            o, state = mamba.decode_step(p, state, x[:, t:t + 1], cfg,
                                         jnp.float32)
            outs.append(o)
        step = jnp.concatenate(outs, axis=1)
        err = float(jnp.max(jnp.abs(full - step)))
        scale = float(jnp.max(jnp.abs(full))) + 1e-6
        assert err / scale < 1e-3, (err, scale)

    def test_state_decays(self):
        """A = -exp(A_log) < 0 => exp(delta A) in (0, 1)."""
        cfg = configs.get_smoke("falcon-mamba-7b")
        p, _ = mamba.init(KEY, cfg, jnp.float32)
        A = -jnp.exp(p["A_log"])
        assert float(jnp.max(A)) < 0.0


class TestRingBufferCache:
    def test_wraparound_matches_reference(self):
        """Windowed decode past the wrap point == reference windowed attn."""
        cfg = dataclasses.replace(configs.get_smoke("granite-8b"),
                                  compute_dtype="float32")
        p, _ = attention.init(KEY, cfg, jnp.float32)
        W, T = 8, 20
        B = 2
        xs = jax.random.normal(jax.random.fold_in(KEY, 2),
                               (B, T, cfg.d_model)) * 0.5
        # reference: full-sequence windowed attention, last position
        ref = attention.forward(p, xs, cfg,
                                pos=jnp.broadcast_to(jnp.arange(T), (B, T)),
                                causal=True, window=W, impl="ref",
                                compute_dtype=jnp.float32)
        # decode with a W-slot ring buffer
        cache, _ = attention.init_cache(cfg, B, max_len=T, window=W,
                                        dtype=jnp.float32)
        outs = []
        for t in range(T):
            o, cache = attention.decode_step(
                p, cache, xs[:, t:t + 1], cfg, pos=jnp.int32(t), window=W,
                compute_dtype=jnp.float32)
            outs.append(o)
        got = jnp.concatenate(outs, axis=1)
        err = float(jnp.max(jnp.abs(got[:, -1] - ref[:, -1])))
        assert err < 1e-4, err


class TestRoPE:
    def test_rotation_preserves_norm(self):
        x = jax.random.normal(KEY, (2, 8, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        y = L.apply_rope(x, pos, 10_000.0)
        n1 = jnp.linalg.norm(x, axis=-1)
        n2 = jnp.linalg.norm(y, axis=-1)
        assert float(jnp.max(jnp.abs(n1 - n2))) < 1e-4

    def test_relative_position_property(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        q = jax.random.normal(KEY, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 32))
        def dot_at(i, j):
            qr = L.apply_rope(q, jnp.full((1, 1), i), 10_000.0)
            kr = L.apply_rope(k, jnp.full((1, 1), j), 10_000.0)
            return float(jnp.sum(qr * kr))
        assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-4
        assert abs(dot_at(5, 3) - dot_at(5, 4)) > 1e-6  # actually varies

    def test_mrope_equals_rope_when_streams_equal(self):
        x = jax.random.normal(KEY, (2, 8, 4, 16))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        pos3 = jnp.stack([pos, pos, pos])
        y1 = L.apply_rope(x, pos, 10_000.0)
        y2 = L.apply_mrope(x, pos3, 10_000.0, (4, 2, 2))
        assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-5


class TestPrecisionBoundary:
    def test_identity_forward(self):
        x = jax.random.normal(KEY, (8, 8), jnp.bfloat16)
        y = L.precision_boundary(x)
        assert bool(jnp.array_equal(x, y))

    def test_cotangent_dtype_pinned(self):
        x = jax.random.normal(KEY, (8,), jnp.bfloat16)

        def f(x):
            y = L.precision_boundary(x)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        g = jax.grad(f)(x)
        assert g.dtype == jnp.bfloat16
