"""Logical-axis sharding resolution: rules, fallbacks, conflicts."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as shd


def _mesh():
    # 1-device mesh with the production axis names: resolution logic is
    # shape-driven, so axis sizes of 1 exercise the same code paths; the
    # divisibility tests use fake sizes via the fake-mesh helper below.
    # shd.make_mesh papers over the jax.make_mesh axis_types API skew.
    return shd.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Duck-typed mesh exposing only .shape (enough for logical_to_spec)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


class TestResolution:
    def test_basic_rules(self):
        m = FakeMesh(data=16, model=16)
        spec = shd.logical_to_spec(("vocab", "embed"), (49152, 576), m)
        assert spec == P("model", "data")

    def test_divisibility_fallback(self):
        m = FakeMesh(data=16, model=16)
        # 9 heads do not divide 16 -> replicated
        spec = shd.logical_to_spec(("embed", "heads"), (576, 9), m)
        assert spec == P("data")          # trailing None stripped

    def test_axis_used_once(self):
        m = FakeMesh(data=16, model=16)
        # batch takes (pod,data) -> data; embed would also want data ->
        # falls back to None (mesh axis may shard only one dim)
        spec = shd.logical_to_spec(("batch", "seq", "embed"),
                                   (256, 4096, 8192), m)
        assert spec == P("data")

    def test_multi_axis_batch(self):
        m = FakeMesh(pod=2, data=16, model=16)
        spec = shd.logical_to_spec(("batch", None), (256, 10), m)
        assert spec == P(("pod", "data"))

    def test_missing_mesh_axis_ignored(self):
        m = FakeMesh(data=8)              # no model axis at all
        spec = shd.logical_to_spec(("embed", "mlp"), (64, 256), m)
        assert spec == P("data")

    def test_rules_override(self):
        m = FakeMesh(data=16, model=16)
        rules = shd.ShardingRules().replace(embed=None, mlp="data")
        spec = shd.logical_to_spec(("embed", "mlp"), (64, 256), m, rules)
        assert spec == P(None, "data")

    def test_pure_dp_style(self):
        m = FakeMesh(pod=2, data=16, model=16)
        rules = shd.ShardingRules().replace(batch=("pod", "data", "model"))
        spec = shd.logical_to_spec(("batch", "seq", None),
                                   (512, 128, 64), m, rules)
        assert spec == P(("pod", "data", "model"))


class TestTreeHelpers:
    def test_tree_shardings_structure(self):
        mesh = _mesh()
        axes = {"a": ("embed", "mlp"), "b": {"c": ("vocab",)}}
        shapes = {"a": jax.ShapeDtypeStruct((4, 8), jnp.float32),
                  "b": {"c": jax.ShapeDtypeStruct((16,), jnp.float32)}}
        sh = shd.tree_shardings(axes, shapes, mesh)
        assert sh["a"].mesh.shape == {"data": 1, "model": 1}
        assert isinstance(sh["b"]["c"].spec, P)

    def test_constrain_noop_without_mesh(self):
        shd.set_mesh(None)
        x = jnp.ones((4, 4))
        y = shd.constrain(x, ("batch", "embed"))
        assert y is x

    def test_use_mesh_context_restores(self):
        mesh = _mesh()
        assert shd._ACTIVE["mesh"] is None
        with shd.use_mesh(mesh):
            assert shd._ACTIVE["mesh"] is mesh
        assert shd._ACTIVE["mesh"] is None
