import os
import sys

# Make `repro` importable when pytest runs without PYTHONPATH=src.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real device. SPMD tests spawn subprocesses that set their own
# --xla_force_host_platform_device_count.
