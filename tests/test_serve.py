"""Serving subsystem (PR 4): tiled scorer parity, compiled FittedODM
artifacts across every kernel family and solver route, compression
accuracy, checkpoint round trips, compile-once predict, microbatching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve
from repro.core import baselines, kernel_fns as kf, odm, sodm
from repro.data import synthetic
from repro.kernels import ops, score

KEY = jax.random.PRNGKey(0)

PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)

ALL_SPECS = [kf.KernelSpec("linear"), kf.KernelSpec("rbf", 0.5),
             kf.KernelSpec("laplacian", 0.3),
             kf.KernelSpec("poly", 0.5, 2, 1.0)]


def _blobs(M=128, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 1.0,
                         jax.random.normal(k2, (M // 2, d)) - 1.0])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


def _rel_gap(got, want, tol=1e-5):
    scale = max(1.0, float(jnp.max(jnp.abs(want))))
    return float(jnp.max(jnp.abs(got - want))) / scale


# ---------------------------------------------------------------------------
# the tiled decision-function kernel
# ---------------------------------------------------------------------------

class TestScoreKernel:
    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    @pytest.mark.parametrize("T,S,D", [(64, 96, 32), (70, 45, 33)])
    def test_tiled_matches_ref(self, spec, T, S, D):
        x = jax.random.normal(KEY, (T, D))
        z = jax.random.normal(jax.random.fold_in(KEY, 1), (S, D))
        c = jax.random.normal(jax.random.fold_in(KEY, 2), (S,))
        want = score.score_ref(x, z, c, kind=spec.name, gamma=spec.gamma,
                               degree=spec.degree, coef0=spec.coef0)
        got = ops.decision_scores(x, z, c, spec, bt=32, bs=32, tiled=True)
        assert _rel_gap(got, want) < 1e-5, spec.name

    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    def test_blocked_matches_ref(self, spec):
        T, S, D = 70, 45, 17
        x = jax.random.normal(KEY, (T, D))
        z = jax.random.normal(jax.random.fold_in(KEY, 1), (S, D))
        c = jax.random.normal(jax.random.fold_in(KEY, 2), (S,))
        want = score.score_ref(x, z, c, kind=spec.name, gamma=spec.gamma,
                               degree=spec.degree, coef0=spec.coef0)
        got = ops.decision_scores(x, z, c, spec, bt=32, tiled=None)
        assert _rel_gap(got, want) < 1e-5, spec.name

    def test_one_pallas_call_per_batch(self):
        """Serving acceptance: one request batch = ONE kernel launch."""
        x = jax.random.normal(KEY, (64, 16))
        z = jax.random.normal(jax.random.fold_in(KEY, 1), (96, 16))
        c = jax.random.normal(jax.random.fold_in(KEY, 2), (96,))
        score.score_tiles.clear_cache()
        n = ops.count_pallas_calls(lambda: score.score_tiles(
            x, z, c, kind="rbf", gamma=0.5, bt=32, bs=32, bd=16,
            interpret=True))
        assert n == 1, n

    def test_zero_coef_padding_is_transparent(self):
        """Padded SV rows carry zero coef => identical scores."""
        x = jax.random.normal(KEY, (40, 12))
        z = jax.random.normal(jax.random.fold_in(KEY, 1), (30, 12))
        c = jax.random.normal(jax.random.fold_in(KEY, 2), (30,))
        spec = kf.KernelSpec("rbf", 0.7)
        base = ops.decision_scores(x, z, c, spec, bt=16, bs=16, tiled=True)
        zp = jnp.concatenate([z, jax.random.normal(KEY, (10, 12))])
        cp = jnp.concatenate([c, jnp.zeros(10)])
        padded = ops.decision_scores(x, zp, cp, spec, bt=16, bs=16,
                                     tiled=True)
        assert _rel_gap(padded, base) < 1e-6


# ---------------------------------------------------------------------------
# compiled artifacts: every kernel family, every solver route
# ---------------------------------------------------------------------------

class TestFittedODMParity:
    @pytest.mark.parametrize("spec", ALL_SPECS, ids=lambda s: s.name)
    def test_every_kernel_family_scalar_route(self, spec):
        x, y = _blobs()
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=300, engine="scalar")
        res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        xp, yp = x[res.perm], y[res.perm]
        want = odm.decision_function(spec, xp, yp, res.alpha, x)
        model = serve.from_sodm(spec, res, x, y)
        assert _rel_gap(model.decision_function(x), want) < 1e-5
        if spec.name == "linear":
            assert model.w is not None and model.compression == "linear"
        else:
            assert model.n_sv <= model.n_train

    @pytest.mark.parametrize("engine", ["block", "pallas"])
    def test_engine_routes(self, engine):
        spec = kf.KernelSpec("rbf", 0.5)
        x, y = _blobs()
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=300, engine=engine, block=64)
        res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        xp, yp = x[res.perm], y[res.perm]
        want = odm.decision_function(spec, xp, yp, res.alpha, x)
        model = serve.from_sodm(spec, res, x, y)
        assert _rel_gap(model.decision_function(x), want) < 1e-5

    def test_dsvrg_route_is_born_compressed(self):
        spec = kf.KernelSpec("linear")
        x, y = _blobs(M=128, d=8)
        cfg = sodm.SODMConfig(engine="dsvrg")
        res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        xp, yp = x[res.perm], y[res.perm]
        want = odm.decision_function(spec, xp, yp, res.alpha, x)
        model = serve.from_sodm(spec, res, x, y)
        assert model.w is not None            # linear collapse: O(d) scoring
        assert _rel_gap(model.decision_function(x), want) < 1e-5

    def test_from_dsvrg_direct(self):
        from repro.core import dsvrg
        x, y = _blobs(M=128, d=8)
        cfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, batch=16)
        res = dsvrg.solve(x, y, PARAMS, cfg, jax.random.PRNGKey(7))
        model = serve.from_dsvrg(res)
        assert model.n_train == 128
        assert model.compression == "linear"
        assert float(jnp.max(jnp.abs(
            model.decision_function(x) - x @ res.w))) == 0.0

    def test_cascade_route(self):
        spec = kf.KernelSpec("rbf", 0.5)
        x, y = _blobs(M=256)
        res = baselines.cascade_solve(spec, x, y, PARAMS, levels=2,
                                      key=jax.random.PRNGKey(0))
        want = odm.decision_function(spec, res.x_sv, res.y_sv, res.alpha, x)
        model = serve.from_cascade(spec, res)
        assert _rel_gap(model.decision_function(x), want) < 1e-5
        pred = baselines.cascade_predict(spec, res, x)
        assert float(odm.accuracy(y, pred)) > 0.9


# ---------------------------------------------------------------------------
# compression: pruning + Nyström, and the checkpoint round trip
# ---------------------------------------------------------------------------

class TestCompression:
    def _fit(self, x, y, spec, lam=10.0):
        params = odm.ODMParams(lam=lam, theta=0.1, ups=0.5)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=300)
        res = sodm.solve(spec, x, y, params, cfg, jax.random.PRNGKey(2))
        return res, serve.from_sodm(spec, res, x, y)

    def test_pruned_and_nystrom_accuracy_synthetic(self):
        x, y = _blobs(M=256)
        spec = kf.KernelSpec("rbf", 0.5)
        res, exact = self._fit(x, y, spec)
        acc0 = float(odm.accuracy(y, exact.predict(x)))
        pruned = serve.from_sodm(spec, res, x, y, prune_tol=1e-4)
        assert pruned.n_sv <= exact.n_sv
        assert acc0 - float(odm.accuracy(y, pruned.predict(x))) <= 0.005
        # lossy pruning must report the decision gap it introduced
        assert pruned.gap >= 0.0
        if pruned.n_sv < exact.n_sv:
            assert pruned.gap > 0.0
        comp = serve.compress(exact, max(16, exact.n_sv // 4))
        assert comp.compression == "nystrom"
        assert comp.n_sv <= max(16, exact.n_sv // 4)
        assert acc0 - float(odm.accuracy(y, comp.predict(x))) <= 0.005

    def test_compression_accuracy_svmguide1(self):
        ds = synthetic.load("svmguide1", scale=0.05)
        M = ds.x_train.shape[0] - ds.x_train.shape[0] % 8
        x, y = ds.x_train[:M], ds.y_train[:M]
        spec = kf.KernelSpec("rbf", 2.0)
        res, exact = self._fit(x, y, spec)
        acc0 = float(odm.accuracy(ds.y_test, exact.predict(ds.x_test)))
        assert acc0 > 0.85, acc0
        for m in (serve.from_sodm(spec, res, x, y, prune_tol=1e-4),
                  serve.compress(exact, max(16, exact.n_sv // 4),
                                 target=0.05)):
            acc = float(odm.accuracy(ds.y_test, m.predict(ds.x_test)))
            assert acc0 - acc <= 0.005, (m.compression, acc0, acc)

    def test_target_grows_budget(self):
        x, y = _blobs(M=256)
        spec = kf.KernelSpec("rbf", 0.5)
        _, exact = self._fit(x, y, spec)
        loose = serve.compress(exact, 8, target=None)
        tight = serve.compress(exact, 8, target=loose.gap / 4)
        assert tight.n_sv >= loose.n_sv
        assert tight.compression in ("nystrom", exact.compression)

    def test_save_load_roundtrip_exact(self, tmp_path):
        x, y = _blobs()
        for spec in (kf.KernelSpec("rbf", 0.5), kf.KernelSpec("linear")):
            _, model = self._fit(x, y, spec)
            model.save(str(tmp_path / spec.name))
            back = serve.load_model(str(tmp_path / spec.name))
            assert back.compression == model.compression
            assert back.n_train == model.n_train
            a = model.decision_function(x)
            b = back.decision_function(x)
            assert float(jnp.max(jnp.abs(a - b))) == 0.0   # bit-exact
            assert dataclasses.asdict(back.spec) == \
                dataclasses.asdict(model.spec)


# ---------------------------------------------------------------------------
# compile-once predict (the per-call permutation-gather regression)
# ---------------------------------------------------------------------------

class TestPredictCompileOnce:
    def test_gather_runs_once_across_predict_calls(self):
        x, y = _blobs()
        spec = kf.KernelSpec("rbf", 0.5)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=300)
        res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(3))
        before = sodm.perm_gather_count()
        p1 = sodm.predict(spec, res, x, y, x[:32])
        p2 = sodm.predict(spec, res, x, y, x[32:64])
        p3 = sodm.predict(spec, res, x, y, x)
        assert sodm.perm_gather_count() - before == 1
        del p1, p2, p3

    def test_fit_seeds_the_predict_cache(self):
        x, y = _blobs()
        spec = kf.KernelSpec("rbf", 0.5)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=300)
        before = sodm.perm_gather_count()
        res, model = sodm.fit(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(4))
        sodm.predict(spec, res, x, y, x[:16])
        assert sodm.perm_gather_count() - before == 1
        assert model.n_train == x.shape[0]

    def test_different_perm_misses_the_cache(self):
        """Same alpha object, different permutation => different model
        (a cache hit here would score with stale SV gathers)."""
        x, y = _blobs()
        spec = kf.KernelSpec("rbf", 0.5)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=300)
        res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(8))
        sodm.predict(spec, res, x, y, x[:8])
        before = sodm.perm_gather_count()
        res2 = res._replace(perm=jnp.flip(res.perm))
        sodm.predict(spec, res2, x, y, x[:8])
        assert sodm.perm_gather_count() - before == 1   # recompiled

    def test_score_path_jaxpr_has_no_gather(self):
        """The per-call scoring trace must not permute/gather the training
        set — the compile step did that once."""
        x, y = _blobs()
        spec = kf.KernelSpec("rbf", 0.5)
        cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                              max_sweeps=300)
        res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(5))
        model = serve.from_sodm(spec, res, x, y)
        jaxpr = jax.make_jaxpr(
            lambda xt: model.decision_function(xt))(x[:32])
        assert "gather" not in str(jaxpr)


# ---------------------------------------------------------------------------
# microbatching server
# ---------------------------------------------------------------------------

def _small_model(seed=0):
    x, y = _blobs(M=128, seed=seed)
    spec = kf.KernelSpec("rbf", 0.5)
    cfg = sodm.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-5,
                          max_sweeps=300)
    res = sodm.solve(spec, x, y, PARAMS, cfg, jax.random.PRNGKey(6))
    return serve.from_sodm(spec, res, x, y), x


class TestMicrobatchScorer:
    def test_bucketed_scoring_matches_direct(self):
        model, x = _small_model()
        scorer = serve.MicrobatchScorer(model, max_batch=32)
        for B in (1, 3, 7, 17, 32, 77, 128):    # 77/128 exercise chunking
            want = model.decision_function(x[:B])
            got = scorer.score(x[:B])
            assert got.shape == (B,)
            assert _rel_gap(got, want) < 1e-6, B

    def test_jit_cache_bounded_by_bucket_ladder(self):
        model, x = _small_model()
        scorer = serve.MicrobatchScorer(model, max_batch=32)
        for B in range(1, 33):
            scorer.score(x[:B])
        assert scorer.compiles <= len(scorer.buckets)
        assert scorer.buckets == (1, 2, 4, 8, 16, 32)

    def test_empty_batch(self):
        model, x = _small_model()
        scorer = serve.MicrobatchScorer(model, max_batch=32)
        out = scorer.score(x[:0])
        assert out.shape == (0,)


class TestBatcher:
    def test_deadline_flush(self):
        model, x = _small_model()
        b = serve.Batcher(serve.MicrobatchScorer(model, max_batch=32),
                          max_batch=4, max_wait=1e-3)
        for i in range(3):
            b.submit(x[i], now=0.0)
        assert not b.ready(0.0005)              # under deadline, under size
        assert b.poll(0.0005) == []
        done = b.poll(0.0015)                   # oldest past the deadline
        assert [r.rid for r in done] == [0, 1, 2]
        assert b.batches == [3]

    def test_full_batch_flushes_immediately(self):
        model, x = _small_model()
        b = serve.Batcher(serve.MicrobatchScorer(model, max_batch=32),
                          max_batch=4, max_wait=10.0)
        for i in range(5):
            b.submit(x[i], now=0.0)
        done = b.poll(0.0)                      # size-triggered, no wait
        assert len(done) == 4 and len(b._pending) == 1

    def test_stream_scores_match_direct(self):
        model, x = _small_model()
        scorer = serve.MicrobatchScorer(model, max_batch=32)
        b = serve.Batcher(scorer, max_batch=8, max_wait=1e-3)
        n = 40
        stats = serve.serve_stream(
            b, ((i * 1e-4, x[i % x.shape[0]]) for i in range(n)))
        assert len(stats["results"]) == n
        want = np.asarray(model.decision_function(x[:x.shape[0]]))
        got = {r.rid: r.score for r in stats["results"]}
        for i in range(n):
            assert abs(got[i] - float(want[i % x.shape[0]])) < 1e-5
        assert stats["mean_batch"] > 1.0        # batching actually happened

    def test_stream_percentiles_nearest_rank(self):
        """PR 9 regression: p50/p95/p99 are exact nearest-rank over the
        latency sample, not the old lat[n // 2] indexing."""
        from repro import observe
        model, x = _small_model()
        b = serve.Batcher(serve.MicrobatchScorer(model, max_batch=32),
                          max_batch=8, max_wait=1e-3)
        stats = serve.serve_stream(
            b, ((i * 1e-4, x[i % x.shape[0]]) for i in range(40)))
        lat = stats["latencies"]
        assert stats["p50"] == observe.percentile(lat, 50)
        assert stats["p95"] == observe.percentile(lat, 95)
        assert stats["p99"] == observe.percentile(lat, 99)
        assert stats["p50"] <= stats["p95"] <= stats["p99"] <= max(lat)

    def test_request_batch_contains_score_span(self):
        """PR 9 acceptance: a traced serve replay emits nested
        serve.request_batch -> serve.score spans, and the metrics
        registry sees every request's latency."""
        from repro import observe
        model, x = _small_model()
        reg = observe.MetricsRegistry()
        b = serve.Batcher(
            serve.MicrobatchScorer(model, max_batch=32, metrics=reg),
            max_batch=8, max_wait=1e-3, metrics=reg)
        rec = observe.SpanRecorder()
        with observe.install(rec):
            serve.serve_stream(
                b, ((i * 1e-4, x[i % x.shape[0]]) for i in range(24)))
        outer = rec.spans("serve.request_batch")
        inner = rec.spans("serve.score")
        assert outer and len(inner) >= len(outer)
        for s in inner:         # every score sits inside some batch span
            assert any(o["ts"] <= s["ts"] and
                       s["ts"] + s["dur"] <= o["ts"] + o["dur"]
                       for o in outer)
        snap = reg.snapshot()
        assert snap["serve.request.latency_s.count"] == 24
        assert snap["serve.requests.count"] == 24
        assert snap["serve.batches.count"] == len(outer)
        assert snap["serve.queue_depth.max"] >= 1


class TestShardedScoring:
    def test_single_device_mesh_matches(self):
        from repro.launch.mesh import make_host_mesh
        model, x = _small_model()
        mesh = make_host_mesh((1,), ("data",))
        got = serve.score_sharded(model, x[:48], mesh)
        want = model.decision_function(x[:48])
        assert _rel_gap(got, want) < 1e-6

    def test_repeat_calls_share_one_trace(self):
        """score_sharded must not rebuild shard_map/jit per call."""
        from repro.launch.mesh import make_host_mesh
        from repro.serve import server as server_mod
        model, x = _small_model()
        mesh = make_host_mesh((1,), ("data",))
        serve.score_sharded(model, x[:48], mesh)
        info = server_mod._sharded_scorer.cache_info()
        serve.score_sharded(model, x[:48], mesh)
        serve.score_sharded(model, x[:48], mesh)
        after = server_mod._sharded_scorer.cache_info()
        assert after.misses == info.misses      # no new builder
        assert after.hits >= info.hits + 2
