"""The static-analysis battery (ISSUE 6).

Four parts: (1) unit tests for the jaxpr walker + rule engine on small
synthetic programs with KNOWN structure; (2) unit tests for the Pallas
VMEM/tiling checker, including the acceptance case — a deliberately
oversized tile config fails with a per-block sizing report, and the
m=10^6 fused-pass u_d plan is rejected at plan time; (3) ONE uniform
parametrized battery over every declared invariant in
``repro.analysis.invariants`` plus the meta-test that every registered
kernel and training route HAS a declaration; (4) the boundary lint —
seeded fixtures fail, the real tree passes, through the same
``scripts/lint.py`` CLI that CI runs.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import boundary_lint as bl
from repro.analysis import invariants as inv
from repro.analysis import jaxpr_lint as jl
from repro.analysis import pallas_check as pc
from repro.api import registry

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(ROOT, "tests", "fixtures", "lint")


# ---------------------------------------------------------------------------
# jaxpr_lint: walker + rules on programs with known structure
# ---------------------------------------------------------------------------

class TestJaxprWalker:
    def test_sites_cover_nested_scan_and_cond(self):
        def f(x):
            def body(c, _):
                c = jax.lax.cond(c[0] > 0, lambda v: v * 2.0,
                                 lambda v: v - 1.0, c)
                return c, None
            out, _ = jax.lax.scan(body, x, None, length=3)
            return out

        sites = list(jl.iter_sites(jl.trace(lambda: f(jnp.ones(4)))))
        prims = {s.prim for s in sites}
        assert "scan" in prims and "cond" in prims
        cond_sites = [s for s in sites if s.prim == "cond"]
        assert all(s.path == ("scan_body",) for s in cond_sites)
        # primitives inside the cond branches carry the full frame path
        inner = [s for s in sites if s.path[:2] == ("scan_body", "cond")]
        assert inner, "no sites recorded inside the cond branches"

    def test_loop_depth_counts_while_frames(self):
        def f(x):
            return jax.lax.while_loop(lambda c: c[0] < 10.0,
                                      lambda c: c * 2.0, x)

        sites = list(jl.iter_sites(jl.trace(lambda: f(jnp.ones(2)))))
        body = [s for s in sites if s.path == ("while_body",)]
        cond = [s for s in sites if s.path == ("while_cond",)]
        assert body and cond
        assert all(s.loop_depth == 1 for s in body + cond)

    def test_walks_into_pjit_subjaxprs(self):
        inner = jax.jit(lambda a: a @ a)
        n = jl.count_primitive(lambda: inner(jnp.ones((4, 4))), "dot_general")
        assert n == 1

    def test_scan_lengths(self):
        def f(x):
            a, _ = jax.lax.scan(lambda c, _: (c, None), x, None, length=7)
            b, _ = jax.lax.scan(lambda c, _: (c, None), a, None, length=3)
            return b

        assert sorted(jl.scan_lengths(lambda: f(jnp.ones(2)))) == [3, 7]


class TestJaxprRules:
    def test_max_pallas_calls_flags_excess(self):
        from repro.kernels import score
        x = jnp.ones((16, 8))
        c = jnp.ones((16,))

        def two_launches():
            a = score.score_tiles(x, x, c, kind="rbf", gamma=0.5, bt=8,
                                  bs=8, bd=8, interpret=True)
            b = score.score_tiles(x, x, c, kind="linear", gamma=0.5,
                                  bt=8, bs=8, bd=8, interpret=True)
            return a + b

        assert jl.lint(two_launches, [jl.max_pallas_calls(2)]) == []
        bad = jl.lint(two_launches, [jl.max_pallas_calls(1)])
        assert len(bad) == 1 and "2 x pallas_call" in bad[0].message

    def test_gather_free_flags_fancy_indexing(self):
        x = jnp.ones((8, 4))
        idx = jnp.array([1, 3])
        bad = jl.lint(lambda: x[idx], [jl.gather_free()])
        assert bad and bad[0].rule == "gather_free"
        with pytest.raises(jl.InvariantViolation, match="gather"):
            jl.check(lambda: x[idx], [jl.gather_free()])

    def test_collective_in_scan_body_detected(self):
        mesh = jax.sharding.Mesh(jax.devices()[:1], ("d",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def inside(x):
            def body(c, _):
                return c + jax.lax.psum(c, "d"), None
            out, _ = jax.lax.scan(body, x, None, length=4)
            return out

        def hoisted(x):
            g = jax.lax.psum(x, "d")

            def body(c, _):
                return c + g, None
            out, _ = jax.lax.scan(body, x, None, length=4)
            return out

        rule = jl.no_collectives_in_loops()
        shm_in = shard_map(inside, mesh=mesh, in_specs=P(), out_specs=P())
        got = jl.lint(lambda: shm_in(jnp.ones(4)), [rule])
        # psum under shard_map lowers to pbroadcast + psum2: two sites
        assert len(got) == 2, got
        assert any("psum2" in v.message for v in got)
        shm_out = shard_map(hoisted, mesh=mesh, in_specs=P(), out_specs=P())
        assert jl.lint(lambda: shm_out(jnp.ones(4)), [rule]) == []

    def test_allowlisted_collective_passes(self):
        mesh = jax.sharding.Mesh(jax.devices()[:1], ("d",))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def f(x):
            def body(c, _):
                return c + jax.lax.psum(c, "d"), None
            out, _ = jax.lax.scan(body, x, None, length=4)
            return out

        shm = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())
        # allow-list names the LOWERED primitives (psum -> psum2 +
        # pbroadcast under shard_map)
        ok = jl.lint(lambda: shm(jnp.ones(4)),
                     [jl.no_collectives_in_loops(
                         allow=("psum2", "pbroadcast"))])
        assert ok == []

    def test_host_sync_in_loop_detected(self):
        def f(x):
            def body(c, _):
                jax.debug.callback(lambda v: None, c)
                return c + 1.0, None
            out, _ = jax.lax.scan(body, x, None, length=2)
            return out

        bad = jl.lint(lambda: f(jnp.ones(2)),
                      [jl.no_host_sync_in_loops()])
        assert bad and "loop body" in bad[0].message

    def test_expect_scan(self):
        def f(x):
            out, _ = jax.lax.scan(lambda c, _: (c, None), x, None,
                                  length=5)
            return out

        thunk = lambda: f(jnp.ones(2))
        assert jl.lint(thunk, [jl.expect_scan(5)]) == []
        bad = jl.lint(thunk, [jl.expect_scan(9)])
        assert bad and "length 9" in bad[0].message


# ---------------------------------------------------------------------------
# pallas_check: VMEM budget + tiling
# ---------------------------------------------------------------------------

class TestPallasCheck:
    def test_default_plans_all_fit(self):
        reports = pc.check_kernels()
        assert set(reports) == set(pc.PLAN_BUILDERS)
        for rep in reports.values():
            assert "TOTAL" in rep

    def test_oversized_tile_config_fails_with_sizing_report(self):
        """Acceptance: a deliberately oversized tile config is rejected
        with a per-block VMEM sizing report."""
        plan = pc.gram_plan(M=8192, N=8192, bm=2048, bn=2048)
        with pytest.raises(pc.PallasBudgetError) as ei:
            pc.check_plan(plan)
        msg = str(ei.value)
        assert "exceeds" in msg and "budget" in msg
        # the report names the offending blocks with shape and bytes
        assert "2048x2048" in msg and "MiB" in msg
        assert "out" in msg and "acc" in msg

    def test_fused_ud_ceiling_at_1e6(self):
        """Acceptance: the ~4 MB (1, m) u_d row crosses the budget at
        m = 10^6 and fails at PLAN time, naming the resident block."""
        ok = pc.check_plan(pc.fused_cd_plan(m=400_000))
        assert "u_d" in ok
        with pytest.raises(pc.PallasBudgetError) as ei:
            pc.check_plan(pc.fused_cd_plan(m=1_000_000))
        msg = str(ei.value)
        assert "u_d" in msg and "resident" in msg

    def test_divisibility_violation(self):
        plan = pc.KernelPlan(
            kernel="toy", grid=(1,),
            blocks=(pc.Block("a", (8, 8)),),
            tiled_axes=(("M", 100, 128),))
        with pytest.raises(pc.PallasBudgetError, match="not divisible"):
            pc.check_plan(plan)

    def test_block_bytes_and_kinds(self):
        assert pc.Block("a", (256, 512)).bytes == 256 * 512 * 4
        assert pc.Block("b", (4,), dtype="bfloat16").bytes == 8
        with pytest.raises(ValueError, match="kind"):
            pc.Block("c", (1,), kind="mystery")

    def test_odm_grad_shrink_policy_fits_all_widths(self):
        from repro.kernels import ops
        for d in (512, 1024, 2048, 4096, 8192, 16384):
            bm = ops._shrink_bm(512, 1 << 20, d)
            pc.check_plan(pc.odm_grad_plan(M=1 << 20, d=d, bm=bm))


# ---------------------------------------------------------------------------
# the declared-invariant battery
# ---------------------------------------------------------------------------

_ALL = inv.invariants()


class TestInvariantRegistry:
    def test_duplicate_declaration_raises(self):
        existing = _ALL[0]
        with pytest.raises(ValueError, match="already declared"):
            inv.declare(existing)

    def test_unknown_name_lists_declared(self):
        with pytest.raises(KeyError, match="no invariant"):
            inv.get("kernels.nope.never")

    def test_counters_are_shared_objects(self):
        """The legacy pins alias the registry's counters in place."""
        from repro.core import dsvrg, sodm
        assert dsvrg._TRACE_EVENTS is inv.counter("dsvrg.epoch_trace").events
        assert sodm.perm_gather_count() == \
            inv.counter("sodm.perm_gather").count

    def test_every_kernel_and_route_is_covered(self):
        """Meta-acceptance: each registered Pallas kernel, each training
        route, AND each fault-tolerance/observability component has >= 1
        declared invariant."""
        kernels = {i.subject for i in _ALL if i.kind == "kernel"}
        assert kernels == set(pc.PLAN_BUILDERS), (
            f"kernels missing a declared invariant: "
            f"{set(pc.PLAN_BUILDERS) - kernels}")
        routes = {i.subject for i in _ALL if i.kind == "route"}
        assert routes == set(registry.routes()), (
            f"routes missing a declared invariant: "
            f"{set(registry.routes()) - routes}")
        comps = {i.subject for i in _ALL if i.kind == "component"}
        assert comps == set(inv.COMPONENTS), (
            f"components missing a declared invariant: "
            f"{set(inv.COMPONENTS) - comps}")


@pytest.mark.parametrize(
    "name", [i.name for i in _ALL if not i.slow])
def test_invariant(name):
    """The uniform battery: every quick declared invariant verifies."""
    inv.verify(name)


@pytest.mark.slow
@pytest.mark.parametrize("name", [i.name for i in _ALL if i.slow])
def test_invariant_slow(name):
    inv.verify(name)


# ---------------------------------------------------------------------------
# boundary lint: fixtures fail, the tree passes
# ---------------------------------------------------------------------------

def _run_lint(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "lint.py"), *args],
        capture_output=True, text=True, cwd=ROOT, timeout=300)


class TestBoundaryLint:
    def test_facade_fixture_fails(self):
        proc = _run_lint(os.path.join(FIXTURES, "bad_facade_call.py"))
        assert proc.returncode == 1, proc.stdout + proc.stderr
        assert proc.stdout.count("F001") == 4, proc.stdout
        assert "sodm.solve" in proc.stdout
        assert "baselines.cascade_solve" in proc.stdout

    def test_tile_literal_fixture_fails(self):
        proc = _run_lint(os.path.join(FIXTURES, "bad_tile_literal.py"))
        assert proc.returncode == 1, proc.stdout + proc.stderr
        assert proc.stdout.count("T001") == 2, proc.stdout
        # the config-constructor exemption: SODMConfig(block=512) is fine
        assert "SODMConfig" not in proc.stdout

    def test_real_tree_is_clean(self):
        """Acceptance: scripts/lint.py exits 0 on the shipped tree."""
        proc = _run_lint()
        assert proc.returncode == 0, proc.stdout + proc.stderr

    def test_warn_and_pallas_rules_inside_repro(self):
        """W001/P001 apply under src/repro — checked via the library API
        with an in-repro virtual path (the fixture never ships there)."""
        with open(os.path.join(FIXTURES, "bad_warn.py")) as fh:
            src = fh.read()
        got = bl.lint_file("src/repro/serve/bad_warn.py", source=src)
        codes = sorted(v.code for v in got)
        assert codes == ["P001", "W001"], got
        # the same file under kernels/ may import pallas
        got_k = bl.lint_file("src/repro/kernels/bad_warn.py", source=src)
        assert sorted(v.code for v in got_k) == ["W001"], got_k

    def test_pragma_suppression(self):
        src = ("from repro.kernels import ops\n"
               "ops.decision_scores(1, 2, 3, 4, bt=512)"
               "  # lint: ignore[T001]\n")
        assert bl.lint_file("benchmarks/x.py", source=src) == []
        src_allow = "# lint: allow[T001]\n" + src.replace(
            "  # lint: ignore[T001]", "")
        assert bl.lint_file("benchmarks/x.py", source=src_allow) == []

    def test_deprecation_module_is_exempt_from_w001(self):
        src = ("import warnings\n"
               "def warn_once(e, r):\n"
               "    warnings.warn(e, FutureWarning)\n")
        path = "src/repro/core/deprecation.py"
        assert bl.lint_file(path, source=src) == []

    def test_list_rules(self):
        proc = _run_lint("--list-rules")
        assert proc.returncode == 0
        for code in bl.RULES:
            assert code in proc.stdout


# ---------------------------------------------------------------------------
# count_pallas_calls migration: cache-warm counting stays exact
# ---------------------------------------------------------------------------

class TestLaunchCounterMigration:
    def test_warm_trace_cache_does_not_undercount(self):
        """The old monkeypatch counter needed clear_cache() before every
        count; the jaxpr walker must be exact on a WARM cache."""
        from repro.kernels import score
        x = jnp.ones((16, 8))
        c = jnp.ones((16,))
        thunk = lambda: score.score_tiles(x, x, c, kind="rbf", gamma=0.5,
                                          bt=8, bs=8, bd=8, interpret=True)
        jax.block_until_ready(thunk())       # warm the trace cache
        from repro.kernels import ops
        assert ops.count_pallas_calls(thunk) == 1
        assert ops.count_pallas_calls(thunk) == 1   # and stays exact
