"""Solver-engine layer: scalar / block / pallas SODM level solves.

Acceptance (ISSUE 1): the pallas engine (interpret mode on CPU) must match
the scalar engine's dual objective within 1e-3 on the synthetic SODM test
problem, honor Algorithm 1's warm starts (a warm-started parent solve takes
fewer passes than a cold start), and the sharded driver must solve every
level exactly once.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import sharding
from repro.core import engines, kernel_fns as kf, odm, sodm
from repro.kernels import ops


def _data(M=256, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 1.0,
                         jax.random.normal(k2, (M // 2, d)) - 1.0])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)
SPEC = kf.KernelSpec(name="rbf", gamma=0.5)


def _objective(spec, x, y, res, M):
    Q = kf.signed_gram(spec, x[res.perm], y[res.perm])
    return float(odm.dual_objective(Q, res.alpha, PARAMS, float(M)))


def _cfg(**kw):
    base = dict(p=2, levels=2, n_landmarks=4, tol=1e-6, max_sweeps=500)
    base.update(kw)
    return sodm.SODMConfig(**base)


class TestEngineParity:
    def test_block_matches_scalar(self):
        x, y = _data()
        o_s = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS, _cfg(engine="scalar"),
            jax.random.PRNGKey(1)), 256)
        o_b = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS, _cfg(engine="block", block=64),
            jax.random.PRNGKey(1)), 256)
        assert abs(o_s - o_b) < 1e-3, (o_s, o_b)

    def test_pallas_matches_scalar(self):
        x, y = _data()
        o_s = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS, _cfg(engine="scalar"),
            jax.random.PRNGKey(1)), 256)
        o_p = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS, _cfg(engine="pallas", block=64),
            jax.random.PRNGKey(1)), 256)
        assert abs(o_s - o_p) < 1e-3, (o_s, o_p)

    def test_pallas_matrix_free_u_refresh(self):
        """gram_threshold=0 forces the on-the-fly rbf_gram tile path for
        the u refresh; it must agree with the materialized-Q path."""
        x, y = _data()
        o_mat = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS,
            _cfg(engine="pallas", block=64, gram_threshold=10 ** 9),
            jax.random.PRNGKey(1)), 256)
        o_free = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS,
            _cfg(engine="pallas", block=64, gram_threshold=0),
            jax.random.PRNGKey(1)), 256)
        assert abs(o_mat - o_free) < 1e-4, (o_mat, o_free)

    def test_pallas_handles_non_tile_multiple_partitions(self):
        """m=72 with block=64 exercises the padded (masked) path."""
        M = 288
        x, y = _data(M=M)
        o_s = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS, _cfg(engine="scalar"),
            jax.random.PRNGKey(1)), M)
        o_p = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS, _cfg(engine="pallas", block=64),
            jax.random.PRNGKey(1)), M)
        assert abs(o_s - o_p) < 1e-3, (o_s, o_p)

    def test_unknown_engine_raises(self):
        with pytest.raises(ValueError):
            engines.make_local_solver("gauss")


class TestWarmStarts:
    def test_warm_start_takes_fewer_passes_than_cold(self):
        """Algorithm 1 line 12: the parent solve seeded from the merged
        child solutions must converge in fewer kernel passes than a cold
        start of the same problem. steps_per_pass is kept small so the
        pass count resolves the actual work (at the default 2B greedy
        steps per pass, tiny problems converge in a handful of passes
        either way and the difference vanishes into the granularity)."""
        M = 256
        x, y = _data(M=M)
        p = PARAMS
        # children: two independent half-problems (one SODM level)
        m = M // 2
        merged = []
        for k in range(2):
            sl = slice(k * m, (k + 1) * m)
            Qk = kf.signed_gram(SPEC, x[sl], y[sl])
            ak, _, _ = ops.dual_cd_solve(
                Qk, c=p.c, ups=p.ups, theta=p.theta, mscale=float(m),
                block=64, n_passes=200, tol=1e-6)
            merged.append(ak)
        # Algorithm 1 line 12 merge + the engines' warm-start conditioning
        # (exact line search along the ray; children were solved at scale
        # m, the parent at p·m — see the sodm module's scale note)
        warm0 = sodm.merge_alphas(jnp.stack(merged))
        Q = kf.signed_gram(SPEC, x, y)
        u0 = Q @ (warm0[:M] - warm0[M:])
        warm0 = warm0 * odm.warm_start_scale(u0, warm0, p, float(M))
        kw = dict(c=p.c, ups=p.ups, theta=p.theta, mscale=float(M),
                  block=64, n_passes=500, tol=1e-6, steps_per_pass=16)
        _, _, cold = ops.dual_cd_solve(Q, **kw)
        _, _, warm = ops.dual_cd_solve(Q, alpha0=warm0, **kw)
        assert int(warm) < int(cold), (int(warm), int(cold))

    def test_engine_warm_start_no_worse_than_cold(self):
        """End-to-end via the engine: the warm-started final level must not
        need more passes than a cold solve of the full problem."""
        M = 256
        x, y = _data(M=M)
        cfg = _cfg(engine="pallas", block=64)
        res = sodm.solve(SPEC, x, y, PARAMS, cfg, jax.random.PRNGKey(1))
        Q = kf.signed_gram(SPEC, x[res.perm], y[res.perm])
        p = PARAMS
        _, _, cold = ops.dual_cd_solve(
            Q, c=p.c, ups=p.ups, theta=p.theta, mscale=float(M), block=64,
            n_passes=200, tol=1e-6)
        assert res.sweeps_per_level[-1] <= int(cold)

    def test_converged_warm_start_is_zero_passes(self):
        M = 128
        x, y = _data(M=M)
        Q = kf.signed_gram(SPEC, x, y)
        p = PARAMS
        alpha, _, _ = ops.dual_cd_solve(
            Q, c=p.c, ups=p.ups, theta=p.theta, mscale=float(M), block=64,
            n_passes=200, tol=1e-6)
        _, _, passes = ops.dual_cd_solve(
            Q, c=p.c, ups=p.ups, theta=p.theta, mscale=float(M), block=64,
            n_passes=200, tol=1e-6, alpha0=alpha)
        assert int(passes) == 0


class TestRbfGramMatvec:
    def test_matches_dense_product(self):
        key = jax.random.PRNGKey(0)
        K, m, d = 3, 72, 10            # non-tile-multiple m
        x = jax.random.normal(key, (K, m, d))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (K, m)))
        g = jax.random.normal(jax.random.fold_in(key, 2), (K, m))
        u = ops.rbf_gram_matvec(x, g, gamma=0.7, y=y, bm=32, bn=32)
        ref = jnp.stack([
            kf.signed_gram(kf.KernelSpec("rbf", 0.7), x[k], y[k]) @ g[k]
            for k in range(K)])
        assert float(jnp.max(jnp.abs(u - ref))) < 1e-4


class TestWarmStartScaleRegression:
    def test_ray_search_beats_plain_and_inverse_p_scaling(self):
        """odm.warm_start_scale: on a constructed parent/child merge the
        closed-form ray search must land a strictly better dual objective
        than BOTH naive corrections — t = 1 (plain concatenation) and
        t = 1/p (pure regularizer-scale heuristic). lam is picked so the
        parent sits between the regularizer-dominant and Q-dominant
        regimes, where neither naive scale is optimal."""
        M, p_merge = 256, 2
        x, y = _data(M=M)
        params = odm.ODMParams(lam=10.0, theta=0.1, ups=0.5)
        m = M // p_merge
        merged = []
        for k in range(p_merge):
            sl = slice(k * m, (k + 1) * m)
            Qk = kf.signed_gram(SPEC, x[sl], y[sl])
            ak, _, _ = ops.dual_cd_solve(
                Qk, c=params.c, ups=params.ups, theta=params.theta,
                mscale=float(m), block=64, n_passes=300, tol=1e-7)
            merged.append(ak)
        warm = sodm.merge_alphas(jnp.stack(merged))
        Q = kf.signed_gram(SPEC, x, y)
        u = Q @ (warm[:M] - warm[M:])
        t = float(odm.warm_start_scale(u, warm, params, float(M)))
        assert 1.0 / p_merge < t < 1.0, t

        def obj(scale):
            return float(odm.dual_objective(Q, warm * scale, params,
                                            float(M)))

        f_star, f_one, f_inv = obj(t), obj(1.0), obj(1.0 / p_merge)
        assert f_star < f_one - 1e-9, (f_star, f_one)
        assert f_star < f_inv - 1e-9, (f_star, f_inv)

    def test_cold_start_is_identity(self):
        """A zero init must pass through unscaled (t = 1)."""
        zeros = jnp.zeros(64)
        t = odm.warm_start_scale(jnp.zeros(32), zeros, PARAMS, 32.0)
        assert float(t) == 1.0


class TestLineSearchSafeguard:
    def test_no_nan_at_weak_regularization_pr1_regression(self):
        """PR 1 regression, pinned: undamped Jacobi tile updates diverge to
        NaN when the off-diagonal Gram mass beats the m·c·I shift (weak
        regularization, lam large => c small). The exact line search along
        the joint step must keep every pass finite and descending — for
        the pure-jnp block oracle AND the fused pallas pass."""
        M = 192
        x, y = _data(M=M)
        weak = odm.ODMParams(lam=1e4, theta=0.1, ups=0.5)
        Q = kf.signed_gram(SPEC, x, y)
        from repro.core import dual_cd
        res = dual_cd.solve_block(Q, weak, mscale=float(M), block=32,
                                  tol=1e-6, max_outer=200)
        assert bool(jnp.all(jnp.isfinite(res.alpha))), "block oracle NaN"
        a_p, kkt, _ = ops.dual_cd_solve(
            Q, c=weak.c, ups=weak.ups, theta=weak.theta, mscale=float(M),
            block=32, n_passes=200, tol=1e-6)
        assert bool(jnp.all(jnp.isfinite(a_p))), "pallas NaN"
        assert float(kkt) < 1e-4, float(kkt)
        f0 = float(odm.dual_objective(Q, jnp.zeros(2 * M), weak, float(M)))
        f1 = float(odm.dual_objective(Q, a_p, weak, float(M)))
        assert f1 < f0, (f1, f0)


class TestFusedPassOpCount:
    def test_exactly_one_pallas_call_per_pass(self):
        """Acceptance: the fused pass loop issues exactly ONE pallas_call
        per pass — tile sweeps and the Gram matvec together — on both the
        dense and the matrix-free path (the PR 1 layout used two kernel
        launches: the sweep + a separate matvec)."""
        from repro.kernels import dual_cd_block as cdk, gram as gram_mod

        K, m, B, d = 2, 64, 32, 8
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (K, m, d))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (K, m)))
        qb = jax.vmap(lambda q: cdk.extract_diag_blocks(q, B))(
            jax.vmap(lambda xk, yk: kf.signed_gram(SPEC, xk, yk))(x, y))
        a = jnp.zeros((K, m // B, 2 * B))
        u = jnp.zeros((K, m // B, B))
        v = jnp.ones((K, m // B, B))
        p = PARAMS

        srcs = {
            "dense": gram_mod.DenseSource(
                jax.vmap(lambda xk, yk: kf.signed_gram(SPEC, xk, yk))(x, y)),
            "mfree": gram_mod.make_kernel_source(SPEC, x, y, bm=B, bn=B,
                                                 interpret=True),
        }
        for name, src in srcs.items():
            calls = ops.count_pallas_calls(lambda src=src: cdk.fused_cd_pass(
                qb, src, a, u, v, c=p.c, ups=p.ups, theta=p.theta,
                mscale=float(m), n_steps=2 * B, exit_tol=0.0,
                interpret=True))
            assert calls == 1, (name, calls)


class TestFusedPassNumericalParity:
    @pytest.mark.parametrize("source", ["dense", "mfree"])
    def test_fused_equals_two_launch_layout(self, source):
        """The fused pass and the two-launch layout run the same math —
        solve_level(fused=True) must reproduce fused=False bit-for-bit-ish
        on both gram sources (the TPU path vs the interpret-mode path)."""
        from repro.kernels import dual_cd_block as cdk, gram as gram_mod

        K, m, B, d = 2, 64, 16, 6
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (K, m, d))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (K, m)))
        Qs = jax.vmap(lambda xk, yk: kf.signed_gram(SPEC, xk, yk))(x, y)
        qb = jax.vmap(lambda q: cdk.extract_diag_blocks(q, B))(Qs)
        if source == "dense":
            src = gram_mod.DenseSource(Qs)
        else:
            src = gram_mod.make_kernel_source(SPEC, x, y, bm=B, bn=B,
                                              interpret=True)
        p = PARAMS
        outs = {}
        for fused in (True, False):
            a, kkts, passes = cdk.solve_level(
                qb, src, jnp.zeros((K, 2 * m)), c=p.c, ups=p.ups,
                theta=p.theta, mscale=float(m), n_passes=100, tol=1e-6,
                fused=fused, interpret=True)
            outs[fused] = (a, int(passes))
        assert outs[True][1] == outs[False][1]
        err = float(jnp.max(jnp.abs(outs[True][0] - outs[False][0])))
        assert err < 1e-6, err


class TestMaterializedFallbackWarning:
    def test_warns_once_with_memory_estimate(self, monkeypatch):
        """A kernel without a matrix-free lowering above gram_threshold
        must warn (once, with the memory estimate) instead of silently
        materializing the O(m²) Gram."""
        import warnings as _warnings
        from repro.kernels import gram as gram_mod

        monkeypatch.setattr(gram_mod, "MATRIX_FREE_KERNELS", ("rbf",))
        monkeypatch.setattr(engines, "_MATERIALIZED_WARNED", set())
        K, m, d = 2, 48, 5
        key = jax.random.PRNGKey(0)
        xs = jax.random.normal(key, (K, m, d))
        ys = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (K, m)))
        a0 = jnp.zeros((K, 2 * m))
        spec = kf.make_spec("poly", gamma=0.2, degree=2)
        with _warnings.catch_warnings(record=True) as caught:
            _warnings.simplefilter("always")
            for _ in range(2):
                engines.solve_level_pallas(
                    xs, ys, a0, spec=spec, params=PARAMS, tol=1e-4,
                    max_sweeps=50, block=16, gram_threshold=0)
        relevant = [w for w in caught
                    if "matrix-free" in str(w.message)]
        assert len(relevant) == 1, [str(w.message) for w in caught]
        assert "GiB" in str(relevant[0].message)

    def test_all_spec_kernels_have_matrix_free_path(self):
        """After the tentpole no KernelSpec family may hit the fallback."""
        from repro.kernels import gram as gram_mod
        assert set(kf.KERNELS) <= set(gram_mod.MATRIX_FREE_KERNELS)


class TestShardedAccounting:
    def test_tail_not_resolved_twice_and_levels_run_true(self):
        """Regression: with a 1-device mesh the old driver re-solved the
        K == 1 level in the replicated tail and hard-coded
        levels_run = cfg.levels + 1."""
        M = 128
        x, y = _data(M=M)
        mesh = sharding.make_mesh((1,), ("data",))
        cfg = _cfg(levels=2)
        res = sodm.solve_sharded(SPEC, x, y, PARAMS, cfg,
                                 jax.random.PRNGKey(1), mesh,
                                 data_axis="data")
        # levels+1 level solves (L, L-1, ..., 0), each exactly once
        assert len(res.sweeps_per_level) == cfg.levels + 1
        assert res.levels_run == len(res.sweeps_per_level)
        o_sh = _objective(SPEC, x, y, res, M)
        o_ref = _objective(SPEC, x, y, sodm.solve(
            SPEC, x, y, PARAMS, cfg, jax.random.PRNGKey(1)), M)
        assert abs(o_sh - o_ref) < 1e-3, (o_sh, o_ref)

    def test_levels_run_honest_under_early_stop(self):
        """levels_run must equal the number of level solves actually run,
        also in the single-process driver."""
        M = 128
        x, y = _data(M=M)
        res = sodm.solve(SPEC, x, y, PARAMS, _cfg(levels=2),
                         jax.random.PRNGKey(1))
        assert res.levels_run == len(res.sweeps_per_level)
