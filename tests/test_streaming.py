"""Out-of-core streaming data plane (ISSUE 10).

Four batteries:

* sources/loader — protocol conformance, file-backed round trips,
  synthetic determinism, prefetch accounting, label policing, and the
  load-bearing invariant that slab contents are BITWISE independent of
  how the source is sharded (slab boundaries are global row indices);
* one-pass partitioning — reservoir >= M degenerates to the stream, so
  the sketched Eqn. 8 landmark set exactly matches dense
  ``select_landmarks``; ``StreamingAssigner`` strata match dense
  ``assign_strata`` and its round-robin partition labels are
  layout-invariant;
* streaming fits — dsvrg and cascade streaming results are bitwise
  invariant to re-sharding, agree with the identically-ordered resident
  solve, and the end-to-end fit stays under the dataset's byte size
  (the accountant's peak is the proof);
* chaos (``chaos`` marker) — a mid-stream kill resumes through the
  route's resume manager bitwise, and the resumed cascade never
  re-reads a completed shard.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ODMEstimator, ProblemSpec
from repro.core import baselines, kernel_fns as kf, odm, partition, sodm
from repro.core.dsvrg import DSVRGConfig
from repro.data import streaming as ds
from repro.distributed import resume as resume_mod
from repro.distributed.faults import FaultPlan, Preemption
from repro.observe import MetricsRegistry

KEY = jax.random.PRNGKey(0)


def _data(M=256, d=6, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(M, d)).astype(np.float32)
    y = np.where(rng.random(M) < 0.5, -1.0, 1.0).astype(np.float32)
    return x, y


def _layouts(x, y, tmp_path):
    """The same rows presented four ways (and four shard geometries)."""
    return [
        ds.ArraySource(x, y, shard_rows=32),
        ds.ArraySource(x, y, shard_rows=48),     # straddles slab edges
        ds.NpyShardSource.write(str(tmp_path / "npy"), x, y, shard_rows=64),
        _raw_source(x, y, tmp_path / "raw", shard_rows=80),
    ]


def _raw_source(x, y, directory, shard_rows):
    os.makedirs(directory, exist_ok=True)
    pairs = []
    for i, lo in enumerate(range(0, x.shape[0], shard_rows)):
        xp = str(directory / f"{i}_x.bin")
        yp = str(directory / f"{i}_y.bin")
        x[lo:lo + shard_rows].tofile(xp)
        y[lo:lo + shard_rows].tofile(yp)
        pairs.append((xp, yp))
    return ds.RawBinarySource(pairs, n_features=x.shape[1])


# ---------------------------------------------------------------------------
# sources
# ---------------------------------------------------------------------------

class TestSources:
    def test_protocol_and_duck_check(self, tmp_path):
        x, y = _data(64)
        for src in _layouts(x, y, tmp_path):
            assert isinstance(src, ds.ShardedSource)
            assert ds.is_source(src)
        assert not ds.is_source(jnp.asarray(x))
        assert not ds.is_source(x)

    def test_every_layout_round_trips(self, tmp_path):
        x, y = _data(192, 5)
        for src in _layouts(x, y, tmp_path):
            assert src.n_rows == 192 and src.n_features == 5
            assert sum(src.shard_sizes()) == 192
            xm, ym = ds.materialize(src)
            np.testing.assert_array_equal(xm, x)
            np.testing.assert_array_equal(ym, y)
            assert src.total_bytes == 192 * 6 * 4

    def test_synthetic_pure_function_of_seed_and_shard(self):
        src = ds.SyntheticSource(1000, 8, shard_rows=256, seed=3)
        a = src.read_shard(2)
        b = src.read_shard(2)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])
        assert set(np.unique(a[1])) <= {-1.0, 1.0}
        # a different seed is different data
        other = ds.SyntheticSource(1000, 8, shard_rows=256, seed=4)
        assert not np.array_equal(other.read_shard(2)[0], a[0])
        # labels are learnable: the class means are separated by
        # 2 * noise * sep along the class direction (by construction)
        xs, ys = ds.materialize(src)
        mu = xs[ys > 0].mean(0) - xs[ys < 0].mean(0)
        assert float(np.linalg.norm(mu)) > 0.2

    def test_read_counters_track_reads(self):
        x, y = _data(96)
        src = ds.ArraySource(x, y, shard_rows=32)
        assert src.reads == [0, 0, 0]
        src.read_shard(1)
        src.read_shard(1)
        assert src.reads == [0, 2, 0]

    def test_validate_source(self):
        x, y = _data(64)
        spec = ProblemSpec()
        spec.validate_source(ds.ArraySource(x, y, shard_rows=16))

        class Hollow:
            n_rows, n_features = 0, 4
            def shard_sizes(self):
                return ()
            def read_shard(self, i):
                raise AssertionError

        with pytest.raises(ValueError, match="empty"):
            spec.validate_source(Hollow())


# ---------------------------------------------------------------------------
# loader
# ---------------------------------------------------------------------------

class TestLoader:
    def test_prefetch_yields_every_shard_in_order(self):
        x, y = _data(160)
        src = ds.ArraySource(x, y, shard_rows=32)
        mets = MetricsRegistry()
        got = list(ds.PrefetchLoader(src, depth=2, metrics=mets))
        assert [i for i, *_ in got] == [0, 1, 2, 3, 4]
        np.testing.assert_array_equal(np.concatenate([g[1] for g in got]), x)
        assert src.reads == [1] * 5
        snap = mets.snapshot()
        assert snap["data.rows.count"] == 160
        assert snap["data.shard.read_s.count"] == 5
        assert snap["data.prefetch.depth.max"] <= 2

    def test_slabs_bitwise_invariant_to_sharding(self, tmp_path):
        x, y = _data(200, 4)
        ref = None
        for src in _layouts(x, y, tmp_path):
            slabs = [(np.asarray(s.x).copy(), np.asarray(s.y).copy(),
                      s.start, s.n_valid)
                     for s in ds.iter_slabs(src, 48)]
            if ref is None:
                ref = slabs
                # tail slab is zero-padded past n_valid
                assert slabs[-1][3] == 200 - 48 * 4
                assert not slabs[-1][0][slabs[-1][3]:].any()
                continue
            for (xa, ya, sa, na), (xb, yb, sb, nb) in zip(ref, slabs,
                                                          strict=True):
                np.testing.assert_array_equal(xa, xb)
                np.testing.assert_array_equal(ya, yb)
                assert (sa, na) == (sb, nb)

    def test_start_row_skips_whole_shards_unread(self):
        x, y = _data(256)
        src = ds.ArraySource(x, y, shard_rows=32)
        slabs = list(ds.iter_slabs(src, 64, start_row=128))
        assert [s.start for s in slabs] == [128, 192]
        assert src.reads[:4] == [0, 0, 0, 0]     # skipped without reading
        np.testing.assert_array_equal(np.asarray(slabs[0].x), x[128:192])
        with pytest.raises(ValueError, match="multiple"):
            next(iter(ds.iter_slabs(src, 64, start_row=10)))

    def test_slab_arrays_do_not_alias_the_carry_buffer(self):
        # jnp.asarray zero-copies on CPU: if the loader reused its carry
        # buffer across yields, consumers' arrays would be corrupted
        x, y = _data(128)
        src = ds.ArraySource(x, y, shard_rows=32)
        kept = [s.x for s in ds.iter_slabs(src, 32)]
        for i, xs in enumerate(kept):
            np.testing.assert_array_equal(np.asarray(xs), x[32 * i:32 * (i + 1)])

    def test_labels_policed_per_shard(self):
        x, y = _data(64)
        y[40] = 0.5
        src = ds.ArraySource(x, y, shard_rows=32)
        with pytest.raises(ValueError, match="labels"):
            list(ds.iter_slabs(src, 32))

    def test_accountant_peak_bounded(self):
        x, y = _data(512, 8)
        src = ds.ArraySource(x, y, shard_rows=32)
        acct = ds.ByteAccountant()
        for _ in ds.iter_slabs(src, 64, depth=2, accountant=acct):
            pass
        assert 0 < acct.peak < src.total_bytes
        assert acct.current == 0                  # everything released
        with pytest.raises(RuntimeError, match="released more"):
            acct.release(1)

    def test_prefetch_kill_and_delay(self):
        x, y = _data(96)
        plan = FaultPlan(sleeper=None).delay_shard_read(1, 0.25) \
                                      .kill("data.prefetch", shard=2)
        src = ds.ArraySource(x, y, shard_rows=32)
        seen = []
        with pytest.raises(Preemption) as ei:
            for i, *_ in ds.PrefetchLoader(src, depth=1, faults=plan,
                                           executor=ds.SerialExecutor()):
                seen.append(i)
        assert ei.value.info == {"shard": 2}
        assert seen == [0, 1]
        assert ("delay", "data.prefetch", {"shard": 1}) in plan.fired


# ---------------------------------------------------------------------------
# one-pass partitioning (Eqn. 7 / Eqn. 8)
# ---------------------------------------------------------------------------

class TestStreamingPlan:
    SPEC = kf.KernelSpec(name="rbf", gamma=0.5)

    def test_reservoir_degenerates_to_stream(self):
        x, y = _data(128)
        src = ds.ArraySource(x, y, shard_rows=48)
        np.testing.assert_array_equal(ds.reservoir_sample(src, 128), x)
        np.testing.assert_array_equal(ds.reservoir_sample(src, 500), x)

    def test_reservoir_is_seed_deterministic_and_uniformish(self):
        x, y = _data(2048, 3, seed=5)
        src = ds.ArraySource(x, y, shard_rows=256)
        a = ds.reservoir_sample(src, 64, seed=9)
        b = ds.reservoir_sample(src, 64, seed=9)
        np.testing.assert_array_equal(a, b)
        c = ds.reservoir_sample(src, 64, seed=10)
        assert not np.array_equal(a, c)
        # sampled rows are actual rows of the stream
        matches = (x[None, :, :] == a[:, None, :]).all(-1).any(1)
        assert matches.all()

    def test_sketch_landmarks_exact_when_reservoir_covers(self, tmp_path):
        x, y = _data(160, 5)
        idx = partition.select_landmarks(self.SPEC, jnp.asarray(x), 8)
        dense = jnp.asarray(x)[idx]
        for src in _layouts(x, y, tmp_path):
            z = ds.sketch_landmarks(self.SPEC, src, 8, reservoir=160)
            np.testing.assert_array_equal(np.asarray(z), np.asarray(dense))
        with pytest.raises(ValueError, match="reservoir"):
            ds.sketch_landmarks(self.SPEC, src, 8, reservoir=4)

    def test_streaming_strata_match_dense(self):
        x, y = _data(256, 5)
        xj = jnp.asarray(x)
        idx = partition.select_landmarks(self.SPEC, xj, 6)
        dense = partition.assign_strata(self.SPEC, xj, idx)
        assigner = ds.StreamingAssigner(self.SPEC, xj[idx], n_partitions=4)
        got, _ = assigner.assign(x)
        np.testing.assert_array_equal(got, np.asarray(dense))

    def test_assignment_layout_invariant_and_balanced(self, tmp_path):
        x, y = _data(300, 5)
        src0 = ds.ArraySource(x, y, shard_rows=64)
        plan = ds.streaming_plan(self.SPEC, src0, n_partitions=4,
                                 n_landmarks=6, reservoir=300)
        ref_s, ref_p = plan.assigner.assign(x)     # whole stream at once
        for src in _layouts(x, y, tmp_path):
            assigner = ds.StreamingAssigner(self.SPEC, plan.landmarks, 4)
            ss, ps = [], []
            for _, xs, _ in ds.PrefetchLoader(src):
                s, p = assigner.assign(xs)
                ss.append(s)
                ps.append(p)
            np.testing.assert_array_equal(np.concatenate(ss), ref_s)
            np.testing.assert_array_equal(np.concatenate(ps), ref_p)
        # within every stratum the K partitions differ by at most one row
        for s in np.unique(ref_s):
            counts = np.bincount(ref_p[ref_s == s], minlength=4)
            assert counts.max() - counts.min() <= 1


# ---------------------------------------------------------------------------
# streaming fits
# ---------------------------------------------------------------------------

def _linear_problem():
    return ProblemSpec(kernel=kf.KernelSpec(name="linear"),
                       params=odm.ODMParams(lam=10.0))


def _dsvrg_cfg(**kw):
    kw.setdefault("epochs", 4)
    kw.setdefault("batch", 64)
    kw.setdefault("schedule", "serial")
    kw.setdefault("stream_slab", 128)
    return sodm.SODMConfig(engine="dsvrg", dsvrg=DSVRGConfig(**kw))


class TestDsvrgStreaming:
    def test_bitwise_invariant_to_sharding(self, tmp_path):
        x, y = _data(512, 8, seed=1)
        problem, cfg = _linear_problem(), _dsvrg_cfg()
        ws = []
        for src in _layouts(x, y, tmp_path):
            m, rep = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
                src, key=KEY)
            ws.append((np.asarray(m.w), rep.history, rep.kkt, rep.eta))
        w0, h0, k0, e0 = ws[0]
        for w, h, k, e in ws[1:]:
            np.testing.assert_array_equal(w, w0)
            assert h == h0 and k == k0 and e == e0

    def test_matches_resident_identity_solve(self):
        x, y = _data(512, 8, seed=1)
        problem = _linear_problem()
        cfg = _dsvrg_cfg(n_partitions=1, partition_strategy="identity")
        src = ds.ArraySource(x, y, shard_rows=128)
        m_s, rep_s = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
            src, key=KEY)
        m_m, rep_m = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
            jnp.asarray(x), jnp.asarray(y), KEY)
        # the hinge gradient is piecewise, so the two FP reduction trees
        # can flip individual margin-boundary samples (each worth O(1/M)
        # in a gradient) — agreement is a relative band, not a bitwise
        # pin; bitwise holds streaming-vs-streaming (test above)
        rel = float(jnp.max(jnp.abs(m_s.w - m_m.w))
                    / jnp.linalg.norm(m_m.w))
        assert rel <= 1e-2
        np.testing.assert_allclose(rep_s.eta, rep_m.eta, rtol=1e-5)
        np.testing.assert_allclose(rep_s.history, rep_m.history, rtol=1e-3)
        xt = jnp.asarray(_data(128, 8, seed=9)[0])
        assert float(jnp.mean(m_s.predict(xt) == m_m.predict(xt))) == 1.0

    def test_trace_once_across_refits(self):
        from repro.analysis.invariants import counter
        x, y = _data(256, 8, seed=2)
        problem, cfg = _linear_problem(), _dsvrg_cfg()
        est = ODMEstimator(problem, route="dsvrg", cfg=cfg)
        est.fit(ds.ArraySource(x, y, shard_rows=64), key=KEY)   # warm
        traces = counter("dsvrg.epoch_trace")
        n0 = traces.count
        est.fit(ds.ArraySource(x, y, shard_rows=64), key=KEY)
        assert traces.count == n0

    def test_streaming_capability_declared(self):
        from repro.api import registry
        assert "dsvrg" in registry.streaming_routes()
        assert "cascade" in registry.streaming_routes()
        assert "streaming=True" in registry.get("dsvrg").capabilities()


class TestCascadeStreaming:
    PROBLEM = ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.5),
                          params=odm.ODMParams(lam=50.0))
    CFG = sodm.SODMConfig(levels=3, tol=1e-6, max_sweeps=200)

    def test_bitwise_invariant_to_sharding(self, tmp_path):
        x, y = _data(256, 6)
        xt = jnp.asarray(_data(64, 6, seed=7)[0])
        ref = None
        for src in _layouts(x, y, tmp_path):
            m, rep = ODMEstimator(self.PROBLEM, route="cascade",
                                  cfg=self.CFG).fit(src, key=KEY)
            scores = np.asarray(m.decision_function(xt))
            assert rep.passes == (self.CFG.levels + 1,)
            if ref is None:
                ref = scores
            else:
                np.testing.assert_array_equal(scores, ref)

    def test_matches_dense_identity_cascade(self):
        x, y = _data(256, 6)
        dense = baselines._cascade_solve(
            self.PROBLEM.kernel, jnp.asarray(x), jnp.asarray(y),
            self.PROBLEM.params, levels=3, key=KEY, tol=1e-6,
            max_sweeps=200, perm=jnp.arange(256))
        m_s, _ = ODMEstimator(self.PROBLEM, route="cascade",
                              cfg=self.CFG).fit(
            ds.ArraySource(x, y, shard_rows=64), key=KEY)
        from repro.serve import model as serve_model
        xt = jnp.asarray(_data(64, 6, seed=7)[0])
        f_dense = serve_model.from_cascade(
            self.PROBLEM.kernel, dense).decision_function(xt)
        f_stream = m_s.decision_function(xt)
        assert float(jnp.max(jnp.abs(f_stream - f_dense))) <= 1e-5


# ---------------------------------------------------------------------------
# end-to-end: train past a host-memory budget
# ---------------------------------------------------------------------------

def test_e2e_fit_exceeds_resident_budget():
    """ISSUE 10 acceptance: the dataset never fits in the (accounted)
    resident budget, yet the streamed fit matches the in-memory one."""
    rows, d = 32_768, 16
    src = ds.SyntheticSource(rows, d, shard_rows=2_048, seed=2, sep=1.5)
    budget = src.total_bytes // 4              # the "capped host RAM"
    problem = _linear_problem()
    cfg = _dsvrg_cfg(epochs=2, batch=256, stream_slab=1_024,
                     n_partitions=1, partition_strategy="identity")
    acct = ds.ByteAccountant()
    m_s, rep = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
        src, key=KEY, accountant=acct)
    assert 0 < acct.peak < budget < src.total_bytes
    x, y = ds.materialize(src)
    m_m, _ = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
        jnp.asarray(x), jnp.asarray(y), KEY)
    rel = float(jnp.max(jnp.abs(m_s.w - m_m.w)) / jnp.linalg.norm(m_m.w))
    assert rel <= 1e-2
    agree = float(jnp.mean(m_s.predict(jnp.asarray(x))
                           == m_m.predict(jnp.asarray(x))))
    assert agree >= 0.99
    assert rep.passes[0] == cfg.dsvrg.epochs


# ---------------------------------------------------------------------------
# dispatch stays loud
# ---------------------------------------------------------------------------

class TestDispatch:
    def test_source_plus_y_rejected(self):
        x, y = _data(64)
        src = ds.ArraySource(x, y, shard_rows=32)
        with pytest.raises(ValueError, match="ambiguous"):
            ODMEstimator(_linear_problem()).fit(src, jnp.asarray(y))

    def test_non_streaming_route_rejected(self):
        x, y = _data(64)
        src = ds.ArraySource(x, y, shard_rows=32)
        with pytest.raises(ValueError, match="streaming"):
            ODMEstimator(ProblemSpec(), route="sodm").fit(src, key=KEY)

    def test_mesh_plus_source_rejected(self):
        from repro.api import registry
        with pytest.raises(ValueError, match="SPMD"):
            registry.resolve(ProblemSpec(), M=1024,
                             mesh="fake-mesh", route=None, streaming=True)

    def test_auto_policy_linear_dsvrg_kernel_cascade(self):
        from repro.api import registry
        lin = ProblemSpec(kernel=kf.KernelSpec(name="linear"))
        rbf = ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=1.0))
        assert registry.resolve(lin, M=1024, streaming=True).name == "dsvrg"
        assert registry.resolve(rbf, M=1024, streaming=True).name \
            == "cascade"

    def test_loader_knobs_rejected_on_dense_fit(self):
        x, y = _data(64)
        with pytest.raises(ValueError, match="loader"):
            ODMEstimator(_linear_problem(), route="dsvrg").fit(
                jnp.asarray(x), jnp.asarray(y), KEY,
                accountant=ds.ByteAccountant())


# ---------------------------------------------------------------------------
# chaos: mid-stream kills resume without rework
# ---------------------------------------------------------------------------

@pytest.mark.chaos
class TestStreamingChaos:
    PROBLEM = ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.5),
                          params=odm.ODMParams(lam=50.0))
    CFG = sodm.SODMConfig(levels=3, tol=1e-6, max_sweeps=200)

    def test_cascade_mid_stream_kill_resumes_without_rereads(
            self, tmp_path):
        x, y = _data(256, 6)
        m_ok, _ = ODMEstimator(self.PROBLEM, route="cascade",
                               cfg=self.CFG).fit(
            ds.NpyShardSource.write(str(tmp_path / "a"), x, y, 32),
            key=KEY)
        src = ds.NpyShardSource.write(str(tmp_path / "b"), x, y, 32)
        est = ODMEstimator(self.PROBLEM, route="cascade", cfg=self.CFG)
        rdir = str(tmp_path / "resume")
        with pytest.raises(Preemption):
            est.fit(src, key=KEY, resume=rdir,
                    faults=FaultPlan().kill_at_shard(5))
        killed_reads = list(src.reads)
        assert killed_reads[:5] == [1] * 5        # leaves 0-4 completed
        m2, _ = est.fit(src, key=KEY, resume=rdir)
        # completed shards are not re-read (prefetched-but-unconsumed
        # ones may be; prefetch is allowed to waste, resume is not)
        assert src.reads[:5] == [1] * 5
        xt = jnp.asarray(_data(64, 6, seed=7)[0])
        np.testing.assert_array_equal(
            np.asarray(m2.decision_function(xt)),
            np.asarray(m_ok.decision_function(xt)))

    def test_dsvrg_stream_kill_at_epoch_resumes_bitwise(self, tmp_path):
        x, y = _data(512, 8, seed=1)
        problem, cfg = _linear_problem(), _dsvrg_cfg()
        src_ok = ds.NpyShardSource.write(str(tmp_path / "a"), x, y, 96)
        m_ok, rep_ok = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
            src_ok, key=KEY)
        src = ds.NpyShardSource.write(str(tmp_path / "b"), x, y, 96)
        est = ODMEstimator(problem, route="dsvrg", cfg=cfg)
        rdir = str(tmp_path / "resume")
        with pytest.raises(Preemption):
            est.fit(src, key=KEY, resume=rdir,
                    faults=FaultPlan().kill_at_epoch(2))
        m2, rep2 = est.fit(src, key=KEY, resume=rdir)
        np.testing.assert_array_equal(np.asarray(m2.w), np.asarray(m_ok.w))
        assert rep2.history == rep_ok.history

    def test_stream_and_dense_checkpoints_do_not_splice(self, tmp_path):
        x, y = _data(256, 6)
        src = ds.ArraySource(x, y, shard_rows=32)
        est = ODMEstimator(self.PROBLEM, route="cascade", cfg=self.CFG)
        rdir = str(tmp_path / "resume")
        est.fit(src, key=KEY, resume=rdir)        # leaves stream ckpts
        prov = resume_mod.provenance_source(self.PROBLEM.kernel,
                                            self.PROBLEM.params, self.CFG,
                                            src, KEY)
        mgr = resume_mod.CascadeResumeManager(
            resume_mod.ResumeConfig(rdir), prov)
        with pytest.raises(resume_mod.ProvenanceError, match="stream"):
            mgr.restore()

    def test_foreign_source_provenance_rejected(self, tmp_path):
        x, y = _data(256, 6)
        est = ODMEstimator(self.PROBLEM, route="cascade", cfg=self.CFG)
        rdir = str(tmp_path / "resume")
        est.fit(ds.ArraySource(x, y, shard_rows=32), key=KEY, resume=rdir)
        x2, y2 = _data(256, 6, seed=42)
        with pytest.raises(resume_mod.ProvenanceError, match="different"):
            est.fit(ds.ArraySource(x2, y2, shard_rows=32), key=KEY,
                    resume=rdir)
