"""Dual coordinate descent: convergence, KKT, feasibility, warm starts."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import dual_cd, kernel_fns as kf, odm


def _problem(M=128, d=6, gamma=0.5, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 1.0,
                         jax.random.normal(k2, (M // 2, d)) - 1.0])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    x, y = x[perm], y[perm]
    spec = kf.KernelSpec(name="rbf", gamma=gamma)
    Q = kf.signed_gram(spec, x, y)
    return x, y, spec, Q


PARAMS = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)


class TestSolve:
    def test_converges_to_kkt(self):
        _, _, _, Q = _problem()
        res = dual_cd.solve(Q, PARAMS, mscale=128.0, tol=1e-6,
                            max_sweeps=500)
        assert float(res.kkt) < 1e-5
        assert int(res.sweeps) < 500

    def test_box_feasible(self):
        _, _, _, Q = _problem()
        res = dual_cd.solve(Q, PARAMS, mscale=128.0, tol=1e-6)
        assert bool(jnp.all(res.alpha >= 0.0))

    def test_objective_below_zero_start(self):
        # f(0) = 0; the optimum must improve on it
        _, _, _, Q = _problem()
        res = dual_cd.solve(Q, PARAMS, mscale=128.0, tol=1e-6)
        obj = odm.dual_objective(Q, res.alpha, PARAMS, 128.0)
        assert float(obj) < 0.0

    def test_warm_start_is_noop_at_optimum(self):
        _, _, _, Q = _problem()
        res = dual_cd.solve(Q, PARAMS, mscale=128.0, tol=1e-6)
        res2 = dual_cd.solve(Q, PARAMS, mscale=128.0, alpha0=res.alpha,
                             tol=1e-5)
        assert int(res2.sweeps) == 0

    def test_u_cache_consistent(self):
        _, _, _, Q = _problem()
        res = dual_cd.solve(Q, PARAMS, mscale=128.0, tol=1e-6)
        zeta, beta = odm.split_alpha(res.alpha)
        want = Q @ (zeta - beta)
        assert float(jnp.max(jnp.abs(res.u - want))) < 1e-4


class TestSolveBlock:
    @pytest.mark.parametrize("block", [32, 64, 128])
    def test_matches_exact(self, block):
        _, _, _, Q = _problem()
        exact = dual_cd.solve(Q, PARAMS, mscale=128.0, tol=1e-7,
                              max_sweeps=1000)
        blk = dual_cd.solve_block(Q, PARAMS, mscale=128.0, block=block,
                                  tol=1e-7, max_outer=300)
        o1 = odm.dual_objective(Q, exact.alpha, PARAMS, 128.0)
        o2 = odm.dual_objective(Q, blk.alpha, PARAMS, 128.0)
        assert abs(float(o1 - o2)) < 1e-4
        assert float(jnp.max(jnp.abs(exact.alpha - blk.alpha))) < 1e-3

    def test_ragged_block(self):
        # M=96 with block=64 exercises padding
        x, y, spec, _ = _problem(M=96)
        Q = kf.signed_gram(spec, x, y)
        blk = dual_cd.solve_block(Q, PARAMS, mscale=96.0, block=64,
                                  tol=1e-6, max_outer=200)
        assert float(blk.kkt) < 1e-5
        assert blk.alpha.shape == (192,)


class TestDualPrimalBridge:
    def test_strong_duality_linear(self):
        """p(w*) == -f(alpha*) for the linear kernel (strong duality)."""
        key = jax.random.PRNGKey(1)
        M, d = 96, 5
        x = jax.random.normal(key, (M, d))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (M,)))
        spec = kf.KernelSpec(name="linear")
        Q = kf.signed_gram(spec, x, y)
        res = dual_cd.solve(Q, PARAMS, mscale=float(M), tol=1e-8,
                            max_sweeps=3000)
        w = odm.w_from_alpha(x, y, res.alpha)
        p_val = odm.primal_objective(w, x, y, PARAMS)
        d_val = odm.dual_objective(Q, res.alpha, PARAMS, float(M))
        assert abs(float(p_val + d_val)) < 1e-3 * max(1.0, abs(float(p_val)))

    def test_grad_matches_autodiff(self):
        key = jax.random.PRNGKey(2)
        M, d = 64, 7
        x = jax.random.normal(key, (M, d))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (M,)))
        w = jax.random.normal(jax.random.fold_in(key, 2), (d,)) * 0.3
        g1 = odm.primal_grad(w, x, y, PARAMS)
        g2 = jax.grad(odm.primal_objective)(w, x, y, PARAMS)
        assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-5

    def test_minibatch_grad_unbiased(self):
        key = jax.random.PRNGKey(3)
        M, d = 128, 5
        x = jax.random.normal(key, (M, d))
        y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 1), (M,)))
        w = jax.random.normal(jax.random.fold_in(key, 2), (d,)) * 0.3
        full = odm.primal_grad(w, x, y, PARAMS)
        batch_mean = odm.minibatch_grad(w, x, y, PARAMS, M)  # batch == all
        assert float(jnp.max(jnp.abs(full - batch_mean))) < 1e-5
