"""HLO analysis: collective-bytes parser + trip-aware dot FLOPs counter,
validated against modules with KNOWN flops/collectives."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha


class TestShapeBytes:
    def test_simple(self):
        assert ha.shape_bytes("f32[16,4096,576]") == 16 * 4096 * 576 * 4
        assert ha.shape_bytes("bf16[8]") == 16

    def test_tuple(self):
        s = "(f32[4,4]{1,0}, bf16[2]{0})"
        assert ha.shape_bytes(s) == 64 + 4

    def test_non_numeric_ignored(self):
        assert ha.shape_bytes("token[]") == 0


class TestDotFlops:
    def test_plain_matmul(self):
        M = N = K = 64
        f = jax.jit(lambda a, b: a @ b)
        hlo = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                      jax.ShapeDtypeStruct((K, N), jnp.float32)) \
            .compile().as_text()
        got = ha.dot_flops(hlo)
        assert got == 2 * M * N * K, got

    def test_scan_multiplies_trip_count(self):
        """A matmul inside lax.scan must count trip-count times."""
        M = 32
        TRIPS = 7

        def f(a, b):
            def body(c, _):
                return c @ b, None
            c, _ = jax.lax.scan(body, a, None, length=TRIPS)
            return c

        hlo = jax.jit(f).lower(
            jax.ShapeDtypeStruct((M, M), jnp.float32),
            jax.ShapeDtypeStruct((M, M), jnp.float32)).compile().as_text()
        got = ha.dot_flops(hlo)
        want = 2 * M * M * M * TRIPS
        assert got == want, (got, want)

    def test_xla_cost_analysis_undercounts_scan(self):
        """Documents WHY dot_flops exists: XLA counts the body once."""
        M, TRIPS = 32, 7

        def f(a, b):
            def body(c, _):
                return c @ b, None
            c, _ = jax.lax.scan(body, a, None, length=TRIPS)
            return c

        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((M, M), jnp.float32),
            jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
        # ha.xla_flops normalizes the list-vs-dict cost_analysis() return
        # across jax versions
        xla_flops = ha.xla_flops(comp)
        assert xla_flops < 2 * M ** 3 * TRIPS  # undercounted


class TestWireBytes:
    def test_conventions(self):
        b = 1024
        assert ha._wire_bytes("all-gather", b, 4) == b * 3 / 4
        assert ha._wire_bytes("all-reduce", b, 4) == 2 * b * 3 / 4
        assert ha._wire_bytes("reduce-scatter", b, 4) == b * 3
        assert ha._wire_bytes("collective-permute", b, 4) == b
        assert ha._wire_bytes("all-reduce", b, 1) == 0.0


class TestCollectiveParse:
    def test_synthetic_module(self):
        hlo = """HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ag = f32[32]{0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %x)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ar = f32[8]{0} all-reduce(%a), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%add
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
        stats = ha.collective_bytes(hlo)
        # all-reduce: 8 floats = 32B, g=8 -> 2*32*7/8 = 56
        assert stats.bytes_by_kind["all-reduce"] == pytest.approx(56.0)
        # all-gather inside while x5 trips: result 32 floats = 128B, g=4
        # -> 5 * 128 * 3/4 = 480
        assert stats.bytes_by_kind["all-gather"] == pytest.approx(480.0)
        assert stats.count_by_kind["all-gather"] == 5


class TestRoofline:
    def test_terms_and_dominance(self):
        rl = ha.roofline(197e12, 819e9, 0.0)      # 1s compute, 1s memory
        assert rl.compute_s == pytest.approx(1.0)
        assert rl.memory_s == pytest.approx(1.0)
        assert rl.collective_s == 0.0
        rl2 = ha.roofline(1e12, 1e9, 500e9)
        assert rl2.dominant == "collective"
