"""HLO analysis: collective-bytes parser + trip-aware dot FLOPs counter,
validated against modules with KNOWN flops/collectives."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha


class TestShapeBytes:
    def test_simple(self):
        assert ha.shape_bytes("f32[16,4096,576]") == 16 * 4096 * 576 * 4
        assert ha.shape_bytes("bf16[8]") == 16

    def test_tuple(self):
        s = "(f32[4,4]{1,0}, bf16[2]{0})"
        assert ha.shape_bytes(s) == 64 + 4

    def test_non_numeric_ignored(self):
        assert ha.shape_bytes("token[]") == 0


class TestDotFlops:
    def test_plain_matmul(self):
        M = N = K = 64
        f = jax.jit(lambda a, b: a @ b)
        hlo = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                      jax.ShapeDtypeStruct((K, N), jnp.float32)) \
            .compile().as_text()
        got = ha.dot_flops(hlo)
        assert got == 2 * M * N * K, got

    def test_scan_multiplies_trip_count(self):
        """A matmul inside lax.scan must count trip-count times."""
        M = 32
        TRIPS = 7

        def f(a, b):
            def body(c, _):
                return c @ b, None
            c, _ = jax.lax.scan(body, a, None, length=TRIPS)
            return c

        hlo = jax.jit(f).lower(
            jax.ShapeDtypeStruct((M, M), jnp.float32),
            jax.ShapeDtypeStruct((M, M), jnp.float32)).compile().as_text()
        got = ha.dot_flops(hlo)
        want = 2 * M * M * M * TRIPS
        assert got == want, (got, want)

    def test_xla_cost_analysis_undercounts_scan(self):
        """Documents WHY dot_flops exists: XLA counts the body once."""
        M, TRIPS = 32, 7

        def f(a, b):
            def body(c, _):
                return c @ b, None
            c, _ = jax.lax.scan(body, a, None, length=TRIPS)
            return c

        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((M, M), jnp.float32),
            jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
        # ha.xla_flops normalizes the list-vs-dict cost_analysis() return
        # across jax versions
        xla_flops = ha.xla_flops(comp)
        assert xla_flops < 2 * M ** 3 * TRIPS  # undercounted


class TestWireBytes:
    def test_conventions(self):
        b = 1024
        assert ha._wire_bytes("all-gather", b, 4) == b * 3 / 4
        assert ha._wire_bytes("all-reduce", b, 4) == 2 * b * 3 / 4
        assert ha._wire_bytes("reduce-scatter", b, 4) == b * 3
        assert ha._wire_bytes("collective-permute", b, 4) == b
        assert ha._wire_bytes("all-reduce", b, 1) == 0.0


class TestCollectiveParse:
    def test_synthetic_module(self):
        hlo = """HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ag = f32[32]{0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %x)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ar = f32[8]{0} all-reduce(%a), channel_id=2, replica_groups=[1,8]<=[8], to_apply=%add
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
        stats = ha.collective_bytes(hlo)
        # all-reduce: 8 floats = 32B, g=8 -> 2*32*7/8 = 56
        assert stats.bytes_by_kind["all-reduce"] == pytest.approx(56.0)
        # all-gather inside while x5 trips: result 32 floats = 128B, g=4
        # -> 5 * 128 * 3/4 = 480
        assert stats.bytes_by_kind["all-gather"] == pytest.approx(480.0)
        assert stats.count_by_kind["all-gather"] == 5


class TestEntryName:
    def test_dotted_and_prefixed_names(self):
        assert ha._entry_name("ENTRY %main.42 (a: f32[4]) -> f32[4] {") \
            == "main.42"
        assert ha._entry_name("ENTRY main (a: f32[4]) -> f32[4] {") == "main"
        assert ha._entry_name("HloModule m\n\nENTRY %jit_f.7 (x) -> f32 {") \
            == "jit_f.7"

    def test_missing_entry_returns_none(self):
        assert ha._entry_name("%helper (p: f32[4]) -> f32[4] {") is None

    def test_missing_entry_falls_back_to_whole_text(self):
        """Without an ENTRY header the whole text is one computation and
        top-level collectives still count (multiplicity 1)."""
        hlo = ("%ar = f32[8]{0} all-reduce(%a), channel_id=1, "
               "replica_groups=[1,8]<=[8], to_apply=%add\n")
        stats = ha.collective_bytes(hlo)
        assert stats.count_by_kind["all-reduce"] == 1
        assert stats.bytes_by_kind["all-reduce"] == pytest.approx(56.0)


class TestGroupSize:
    def test_strided_form(self):
        assert ha._group_size("... replica_groups=[2,4]<=[8] ...") == 4

    def test_explicit_group_list(self):
        assert ha._group_size("... replica_groups={{0,1},{2,3}} ...") == 2
        assert ha._group_size("... replica_groups={{0,1,2,3}} ...") == 4

    def test_default_when_absent(self):
        assert ha._group_size("%ag = f32[8] all-gather(%x)") == 2


_NESTED_WHILE_HLO = """HloModule nested

%icond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%ibody (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), channel_id=1, replica_groups=[1,8]<=[8], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

%ocond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %j = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%j, %n), direction=LT
}

%obody (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %w = (s32[], f32[8]) while(%p), condition=%icond, body=%ibody
  ROOT %t = (s32[], f32[8]) tuple(%w)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %w = (s32[], f32[8]) while(%init), condition=%ocond, body=%obody
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""


class TestMultiplicity:
    def test_nested_while_multiplies(self):
        """Outer 3 trips x inner 4 trips = 12 executions of the inner
        body's all-reduce."""
        stats = ha.collective_bytes(_NESTED_WHILE_HLO)
        assert stats.count_by_kind["all-reduce"] == 12
        # 32B result, g=8 -> 2*32*7/8 = 56 per execution
        assert stats.bytes_by_kind["all-reduce"] == pytest.approx(12 * 56.0)

    def test_known_trip_count_annotation_wins(self):
        """XLA's backend_config trip annotation overrides the parsed
        compare-constant (here deliberately different: 2 vs 9)."""
        hlo = """HloModule annotated

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(2)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ag = f32[32]{0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={0}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %x)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"9"}}
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
        stats = ha.collective_bytes(hlo)
        assert stats.count_by_kind["all-gather"] == 9
        assert stats.bytes_by_kind["all-gather"] == \
            pytest.approx(9 * 128 * 3 / 4)

    def test_called_computation_inherits_caller_count(self):
        """A collective inside a computation reached via to_apply= is
        charged once per call site (twice here)."""
        hlo = """HloModule called

%helper (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  ROOT %ar = f32[8]{0} all-reduce(%p), channel_id=1, replica_groups=[1,8]<=[8], to_apply=%add
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %c1 = f32[8] call(%a), to_apply=%helper
  %c2 = f32[8] call(%c1), to_apply=%helper
  ROOT %r = f32[8] add(%c1, %c2)
}
"""
        stats = ha.collective_bytes(hlo)
        assert stats.count_by_kind["all-reduce"] == 2
        assert stats.bytes_by_kind["all-reduce"] == pytest.approx(2 * 56.0)

    def test_count_by_kind_attribution(self):
        """Mixed kinds attribute independently: explicit-group all-gather
        (g=2) at entry + permute, with per-kind byte accounting."""
        hlo = """HloModule mixed

ENTRY %main (a: f32[8]) -> f32[16] {
  %a = f32[8] parameter(0)
  %ag = f32[16]{0} all-gather(%a), channel_id=1, replica_groups={{0,1},{2,3}}, dimensions={0}
  %cp = f32[8]{0} collective-permute(%a), channel_id=2, source_target_pairs={{0,1},{1,0}}
  ROOT %r = f32[16] add(%ag, %ag)
}
"""
        stats = ha.collective_bytes(hlo)
        assert stats.count_by_kind == {"all-gather": 1,
                                       "collective-permute": 1}
        # all-gather: 64B result, g=2 -> 32; permute: full 32B payload
        assert stats.bytes_by_kind["all-gather"] == pytest.approx(32.0)
        assert stats.bytes_by_kind["collective-permute"] == \
            pytest.approx(32.0)


class TestRoofline:
    def test_terms_and_dominance(self):
        rl = ha.roofline(197e12, 819e9, 0.0)      # 1s compute, 1s memory
        assert rl.compute_s == pytest.approx(1.0)
        assert rl.memory_s == pytest.approx(1.0)
        assert rl.collective_s == 0.0
        rl2 = ha.roofline(1e12, 1e9, 500e9)
        assert rl2.dominant == "collective"
