"""Seeded W001/P001 fixture. The path passed to the linter in the test
carries a ``src/repro/`` prefix so the in-repro rules apply. NEVER
imported — parsed by the lint tests only."""
import warnings

from jax.experimental import pallas as pl                    # P001


def legacy_entry(x):
    warnings.warn("use the new thing", FutureWarning)        # W001
    return x
