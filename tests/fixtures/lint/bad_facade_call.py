"""Seeded F001 fixture: every way of calling a legacy solver entry point
from outside src/repro. NEVER imported — parsed by the lint tests only."""
import jax

from repro.core import baselines, dsvrg, sodm
from repro.core.sodm import solve as sodm_solve

KEY = jax.random.PRNGKey(0)


def train(spec, x, y, params, cfg):
    res = sodm.solve(spec, x, y, params, cfg, KEY)          # F001
    res2 = dsvrg.solve(x, y, params, cfg.dsvrg, KEY)        # F001
    res3 = baselines.cascade_solve(spec, x, y, params, cfg) # F001
    res4 = sodm_solve(spec, x, y, params, cfg, KEY)         # F001 (direct import)
    return res, res2, res3, res4
