"""Seeded T001 fixture: call-site tile/step literals that belong in a
config dataclass. NEVER imported — parsed by the lint tests only."""
from repro.core.sodm import SODMConfig
from repro.kernels import ops


def score_everything(x, z, coef, spec):
    # these two knobs are hardcoded at the call site: T001 twice
    return ops.decision_scores(x, z, coef, spec, bt=512, bs=512)


def config_is_the_right_place():
    # the same numbers inside a config constructor are FINE (exempt)
    return SODMConfig(block=512)
