"""Partition strategy (Section 3.2): landmarks, strata, distribution."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import kernel_fns as kf, partition as part


def _clustered_data(M=256, d=4, n_clusters=4, seed=0):
    key = jax.random.PRNGKey(seed)
    centers = jax.random.normal(key, (n_clusters, d)) * 4.0
    ks = jax.random.split(jax.random.fold_in(key, 1), n_clusters)
    xs = [jax.random.normal(k, (M // n_clusters, d)) * 0.5 + c
          for k, c in zip(ks, centers)]
    x = jnp.concatenate(xs)
    y = jnp.sign(jax.random.normal(jax.random.fold_in(key, 2), (M,)))
    perm = jax.random.permutation(jax.random.fold_in(key, 3), M)
    return x[perm], y[perm]


SPEC = kf.KernelSpec(name="rbf", gamma=0.5)


class TestLandmarks:
    def test_first_landmark_is_x1(self):
        x, _ = _clustered_data()
        lm = part.select_landmarks(SPEC, x, 4)
        assert int(lm[0]) == 0                 # paper: z_1 = x_1

    def test_landmarks_distinct(self):
        x, _ = _clustered_data()
        lm = part.select_landmarks(SPEC, x, 8)
        assert len(set(int(i) for i in lm)) == 8

    def test_gram_determinant_positive(self):
        """Greedy det-max must produce a well-conditioned landmark Gram."""
        x, _ = _clustered_data()
        lm = part.select_landmarks(SPEC, x, 6)
        K = kf.gram(SPEC, x[lm])
        sign, logdet = jnp.linalg.slogdet(K)
        assert float(sign) > 0
        # versus random landmarks: greedy should give a larger determinant
        rnd = jnp.arange(6) * 3 + 1
        K2 = kf.gram(SPEC, x[rnd])
        _, logdet2 = jnp.linalg.slogdet(K2)
        assert float(logdet) >= float(logdet2) - 1e-6


class TestStrata:
    def test_assignment_is_nearest(self):
        x, _ = _clustered_data()
        lm = part.select_landmarks(SPEC, x, 4)
        s = part.assign_strata(SPEC, x, lm)
        # brute force check on a few points
        z = x[lm]
        K = kf.gram(SPEC, x, z)
        want = jnp.argmax(K, axis=1)           # shift-invariant: max k = min dist
        assert bool(jnp.all(s == want))

    def test_landmark_in_own_stratum(self):
        x, _ = _clustered_data()
        lm = part.select_landmarks(SPEC, x, 4)
        s = part.assign_strata(SPEC, x, lm)
        for j, i in enumerate(lm):
            assert int(s[int(i)]) == j


class TestStratifiedPartitions:
    def test_equal_sizes(self):
        x, _ = _clustered_data(M=256)
        plan = part.make_plan(SPEC, x, 4, 8, jax.random.PRNGKey(0))
        assert plan.perm.shape == (256,)
        assert sorted(plan.perm.tolist()) == list(range(256))

    def test_preserves_stratum_proportions(self):
        x, _ = _clustered_data(M=256)
        plan = part.make_plan(SPEC, x, 4, 8, jax.random.PRNGKey(0))
        m = 256 // 8
        # each partition's stratum histogram ~ global/8 (+- slack from
        # the rebalance step)
        global_hist = jnp.bincount(plan.stratum, length=4)
        for k in range(8):
            pid = plan.perm[k * m:(k + 1) * m]
            h = jnp.bincount(plan.stratum[pid], length=4)
            assert bool(jnp.all(jnp.abs(h - global_hist / 8) <= 6)), (
                k, h, global_hist / 8)

    def test_lower_offdiag_mass_than_cluster(self):
        """The paper's central claim: stratified partitions leave less
        cross-partition kernel mass (Q-bar) than cluster-as-partition."""
        x, y = _clustered_data(M=256)
        K = 8
        plan = part.make_plan(SPEC, x, 4, K, jax.random.PRNGKey(0))
        strat = part.offdiag_mass(SPEC, x, y, plan.perm, K)
        clus = part.cluster_partitions(SPEC, x, K, jax.random.PRNGKey(1))
        clus_mass = part.offdiag_mass(SPEC, x, y, clus, K)
        # NOTE the direction: clusters concentrate kernel mass INSIDE a
        # partition, which *minimizes* Q-bar but destroys the per-partition
        # distribution. The paper's point is about distribution skew:
        from repro.data import stratified
        skew_s = stratified.distribution_skew(x, plan.perm, K)
        skew_c = stratified.distribution_skew(x, clus, K)
        assert float(skew_s) < float(skew_c)

    def test_stratified_beats_random_on_skew(self):
        x, _ = _clustered_data(M=256)
        from repro.data import stratified
        plan = part.make_plan(SPEC, x, 4, 8, jax.random.PRNGKey(0))
        rnd = part.random_partitions(256, 8, jax.random.PRNGKey(1))
        s1 = stratified.distribution_skew(x, plan.perm, 8)
        s2 = stratified.distribution_skew(x, rnd, 8)
        # stratified should never be much worse than random, usually better
        assert float(s1) <= float(s2) * 1.25
