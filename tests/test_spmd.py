"""SPMD integration: sharded == single-device numerics, elastic resharding.

These run in a subprocess with --xla_force_host_platform_device_count=8
(the main pytest process must keep the single real device for the smoke
tests). One subprocess executes the whole battery to amortize startup.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import configs, sharding
from repro.core import dsvrg, kernel_fns as kf, odm, sodm
from repro.data import lm as lmdata
from repro.distributed import elastic
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.train import steps as steps_mod

failures = []
def check(name, cond, info=""):
    print(("PASS " if cond else "FAIL ") + name, info)
    if not cond: failures.append(name)

mesh = make_host_mesh((2, 4), ("data", "model"))

# --- 1. sharded train step == unsharded --------------------------------
cfg = configs.get_smoke("granite-8b")
p, axes = M.init_params(jax.random.PRNGKey(0), cfg)
state = steps_mod.TrainState.create(p, use_ef=False)
tc = steps_mod.TrainConfig()
step = steps_mod.make_train_step(cfg, tc)
dc = lmdata.LMDataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
batch = lmdata.batch_at(dc, 0)

s1, m1 = jax.jit(step)(state, batch)

state_axes = steps_mod.TrainState.axes(axes, use_ef=False)
state_sh = sharding.tree_shardings(state_axes, state, mesh)
state_dev = jax.tree.map(lambda x, s: jax.device_put(x, s), state, state_sh)
def wrapped(st, b):
    with sharding.use_mesh(mesh):
        return step(st, b)
s2, m2 = jax.jit(wrapped, in_shardings=(state_sh, None),
                 out_shardings=(state_sh, None))(state_dev, batch)
dl = abs(float(m1["loss"]) - float(m2["loss"]))
check("train_step loss match", dl < 2e-2, f"diff={dl:.2e}")
pd = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)))),
    s1["params"], s2["params"])
mx = max(jax.tree.leaves(pd))
check("train_step params match", mx < 5e-2, f"max={mx:.2e}")

# --- 2. MoE arch sharded loss matches ----------------------------------
cfg2 = configs.get_smoke("dbrx-132b")
p2, axes2 = M.init_params(jax.random.PRNGKey(1), cfg2)
b2 = lmdata.batch_at(lmdata.LMDataConfig(vocab=cfg2.vocab, seq_len=16,
                                         global_batch=4), 0)
l_ref, _ = M.loss_fn(p2, b2, cfg2)
with sharding.use_mesh(mesh):
    l_sh, _ = jax.jit(lambda p, b: M.loss_fn(p, b, cfg2))(p2, b2)
d2 = abs(float(l_ref) - float(l_sh))
check("moe sharded loss", d2 < 5e-2, f"diff={d2:.2e}")

# --- 3. SODM solve_sharded == solve ------------------------------------
key = jax.random.PRNGKey(2)
Mn = 128
x = jnp.concatenate([jax.random.normal(key, (Mn//2, 5)) + 1.0,
                     jax.random.normal(jax.random.fold_in(key, 1), (Mn//2, 5)) - 1.0])
y = jnp.concatenate([jnp.ones(Mn//2), -jnp.ones(Mn//2)])
spec = kf.KernelSpec(name="rbf", gamma=0.5)
params = odm.ODMParams()
scfg = sodm.SODMConfig(p=2, levels=3, n_landmarks=4, tol=1e-6, max_sweeps=300)
r1 = sodm.solve(spec, x, y, params, scfg, jax.random.PRNGKey(3))
r2 = sodm.solve_sharded(spec, x, y, params, scfg, jax.random.PRNGKey(3),
                        mesh, data_axis="data")
xp, yp = x[r2.perm], y[r2.perm]
Q = kf.signed_gram(spec, xp, yp)
o2 = float(odm.dual_objective(Q, r2.alpha, params, float(Mn)))
xq, yq = x[r1.perm], y[r1.perm]
o1 = float(odm.dual_objective(kf.signed_gram(spec, xq, yq), r1.alpha,
                              params, float(Mn)))
check("sodm sharded objective", abs(o1 - o2) < 1e-3, f"{o1:.5f} vs {o2:.5f}")

# --- 4. DSVRG solve_sharded --------------------------------------------
# batch 3 ∤ m = 16: the ragged tail is exercised through the SPMD driver;
# eta <= 0 exercises the on-device auto_eta psum on a real multi-device
# mesh (must equal the single-process step size)
for sched in ("parallel", "serial"):
    dcfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=4, batch=3,
                             schedule=sched)
    rr1 = dsvrg.solve(x, y, params, dcfg, jax.random.PRNGKey(4))
    rr2 = dsvrg.solve_sharded(x, y, params, dcfg, jax.random.PRNGKey(4),
                              mesh)
    dd = abs(float(rr1.history[-1]) - float(rr2.history[-1]))
    dw = float(jnp.max(jnp.abs(rr1.w - rr2.w)))
    de = abs(float(rr1.eta) - float(rr2.eta))
    check(f"dsvrg sharded objective ({sched})", dd < 1e-3, f"diff={dd:.2e}")
    check(f"dsvrg sharded w parity ({sched})", dw < 1e-4, f"diff={dw:.2e}")
    check(f"dsvrg sharded auto-eta ({sched})", de < 1e-6, f"diff={de:.2e}")

# --- 4b. SODM dsvrg engine route on the mesh ---------------------------
ecfg = sodm.SODMConfig(engine="dsvrg",
                       dsvrg=dsvrg.DSVRGConfig(n_partitions=8, epochs=6,
                                               batch=4))
spec_lin = kf.KernelSpec(name="linear")
er1 = sodm.solve(spec_lin, x, y, params, ecfg, jax.random.PRNGKey(5))
er2 = sodm.solve_sharded(spec_lin, x, y, params, ecfg, jax.random.PRNGKey(5),
                         mesh, data_axis="data")
a1 = odm.accuracy(y, sodm.predict(spec_lin, er1, x, y, x))
a2 = odm.accuracy(y, sodm.predict(spec_lin, er2, x, y, x))
da = abs(float(a1) - float(a2))
check("sodm dsvrg engine sharded acc", da < 0.005, f"{float(a1):.4f} vs {float(a2):.4f}")

# --- 4d. unified API: estimator fit on the mesh -------------------------
from repro.api import ODMEstimator, ProblemSpec
est = ODMEstimator(ProblemSpec(kernel=spec, params=params), route="sodm",
                   cfg=scfg, mesh=mesh, data_axis="data")
am, ar = est.fit(x, y, jax.random.PRNGKey(3))
ra = ar.raw
oa = float(odm.dual_objective(kf.signed_gram(spec, x[ra.perm], y[ra.perm]),
                              ra.alpha, params, float(Mn)))
check("api estimator sharded sodm objective", abs(oa - o1) < 1e-3,
      f"{oa:.5f} vs {o1:.5f}")
est_l = ODMEstimator(ProblemSpec(kernel=spec_lin, params=params), cfg=ecfg,
                     mesh=mesh, data_axis="data")
lm_, lr = est_l.fit(x, y, jax.random.PRNGKey(5))
al = odm.accuracy(y, lm_.predict(x))
check("api estimator sharded dsvrg route",
      lr.route == "dsvrg" and abs(float(al) - float(a1)) < 0.005,
      f"route={lr.route} acc={float(al):.4f} vs {float(a1):.4f}")

# --- 4c. serving: SV slab sharded across the data axis ------------------
from repro import serve
smodel = serve.from_sodm(spec, r1, x, y)
f_rep = smodel.decision_function(x[:48])
f_shd = serve.score_sharded(smodel, x[:48], mesh, data_axis="data")
dsv = float(jnp.max(jnp.abs(f_rep - f_shd)))
check("serve sharded SV-slab scores", dsv < 1e-5, f"diff={dsv:.2e}")

# --- 5. elastic resharding (2,4) -> (4,2) ------------------------------
mesh_b = make_host_mesh((4, 2), ("data", "model"))
p_a = elastic.reshard(p, axes, mesh)
p_b = elastic.reshard(p_a, axes, mesh_b)
check("elastic values preserved", elastic.validate_resharding(p, p_b))

# --- 6. checkpoint save on mesh A, restore on mesh B --------------------
import tempfile
from repro.distributed.checkpoint import CheckpointManager
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, p_a)
    shard_b = sharding.tree_shardings(axes, p, mesh_b)
    p_c = mgr.restore(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), p), shardings=shard_b)
    check("ckpt cross-mesh restore", elastic.validate_resharding(p, p_c))

print("FAILURES:", failures)
raise SystemExit(1 if failures else 0)
"""


@pytest.mark.slow
def test_spmd_battery():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    print(proc.stdout)
    print(proc.stderr[-3000:] if proc.stderr else "")
    assert proc.returncode == 0, "SPMD battery failed (see output)"
