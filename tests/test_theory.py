"""Property tests (hypothesis): the paper's theorems must hold on any
valid instance, and core solver invariants must be maintained."""
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import dual_cd, kernel_fns as kf, odm, partition as part, theory

jax.config.update("jax_platform_name", "cpu")


def _data_from_seed(seed: int, M: int, d: int):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 0.8,
                         jax.random.normal(k2, (M // 2, d)) - 0.8])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       theta=st.floats(0.05, 0.5),
       ups=st.floats(0.2, 1.0),
       k_log=st.integers(1, 3))
def test_theorem1_bound_holds(seed, theta, ups, k_log):
    """0 <= d(a~*) - d(a*) <= U^2 (Qbar + M (M-m) c)  and the solution gap
    bound (Eqn. 5-6) for random problems and hyperparameters."""
    M, d = 64, 4
    x, y = _data_from_seed(seed, M, d)
    params = odm.ODMParams(lam=1.0, theta=theta, ups=ups)
    spec = kf.KernelSpec(name="rbf", gamma=0.7)
    ev = theory.eval_theorem1(spec, x, y, params, n_partitions=2 ** k_log,
                              tol=1e-8)
    assert bool(ev.holds), (float(ev.gap_objective),
                            float(ev.bound_objective),
                            float(ev.gap_solution),
                            float(ev.bound_solution))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), theta=st.floats(0.05, 0.4))
def test_theorem2_bound_holds(seed, theta):
    M, d = 48, 4
    x, y = _data_from_seed(seed, M, d)
    params = odm.ODMParams(lam=1.0, theta=theta, ups=0.5)
    spec = kf.KernelSpec(name="rbf", gamma=0.7)
    K = 4
    plan = part.make_plan(spec, x, K, K, jax.random.PRNGKey(seed))
    ev = theory.eval_theorem2(spec, x, y, params, plan.stratum, K, plan.perm,
                              tol=1e-8)
    assert bool(ev.holds), (float(ev.gap), float(ev.bound))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       theta=st.floats(0.05, 0.5), ups=st.floats(0.2, 1.0))
def test_cd_monotone_objective(seed, theta, ups):
    """Each CD sweep must not increase the dual objective."""
    M, d = 48, 4
    x, y = _data_from_seed(seed, M, d)
    params = odm.ODMParams(lam=1.0, theta=theta, ups=ups)
    spec = kf.KernelSpec(name="rbf", gamma=0.7)
    Q = kf.signed_gram(spec, x, y)
    q_diag = jnp.diagonal(Q)
    alpha = jnp.zeros(2 * M)
    u = jnp.zeros(M)
    prev = float(odm.dual_objective(Q, alpha, params, float(M)))
    for _ in range(5):
        alpha, u = dual_cd.sweep(Q, q_diag, alpha, u, params, float(M))
        cur = float(odm.dual_objective(Q, alpha, params, float(M)))
        assert cur <= prev + 1e-6
        prev = cur
    assert bool(jnp.all(alpha >= 0.0))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_partition_is_permutation(seed):
    M = 64
    x, _ = _data_from_seed(seed, M, 4)
    spec = kf.KernelSpec(name="rbf", gamma=0.7)
    plan = part.make_plan(spec, x, 4, 8, jax.random.PRNGKey(seed))
    assert sorted(plan.perm.tolist()) == list(range(M))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), gamma=st.floats(0.1, 2.0))
def test_gram_psd(seed, gamma):
    """RBF Gram matrices must be PSD (up to fp jitter)."""
    x, _ = _data_from_seed(seed, 32, 4)
    K = kf.rbf_gram(x, x, gamma)
    evals = jnp.linalg.eigvalsh(K)
    assert float(jnp.min(evals)) > -1e-4
    assert float(jnp.max(jnp.abs(jnp.diagonal(K) - 1.0))) < 1e-5
