"""Optimizers, compression, grad accumulation, data pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.data import lm as lmdata
from repro.models import model as M
from repro.optim import adamw, compress
from repro.train import steps as steps_mod

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def test_decreases_quadratic(self):
        w = {"x": jnp.array([3.0, -2.0])}
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                                weight_decay=0.0)
        st = adamw.init(w)
        for _ in range(50):
            g = jax.tree.map(lambda p: 2 * p, w)
            w, st, mets = adamw.update(cfg, st, w, g)
        assert float(jnp.max(jnp.abs(w["x"]))) < 0.2

    def test_grad_clip(self):
        w = {"x": jnp.zeros(4)}
        cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=0)
        st = adamw.init(w)
        g = {"x": jnp.full((4,), 100.0)}
        _, _, mets = adamw.update(cfg, st, w, g)
        assert float(mets["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_frac=0.1)
        assert float(adamw.schedule(cfg, jnp.int32(0))) == 0.0
        assert float(adamw.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(adamw.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


class TestCompression:
    def test_int8_bounded_error(self):
        g = {"x": jax.random.normal(KEY, (256,))}
        cfg = compress.CompressConfig(codec="int8")
        st = compress.init(g)
        out, st = compress.compress(cfg, st, g)
        err = float(jnp.max(jnp.abs(out["x"] - g["x"])))
        scale = float(jnp.max(jnp.abs(g["x"]))) / 127
        assert err <= scale * 0.51 + 1e-6

    def test_topk_keeps_largest(self):
        g = {"x": jnp.array([0.1, -5.0, 0.2, 3.0])}
        cfg = compress.CompressConfig(codec="topk", topk_frac=0.5)
        st = compress.init(g)
        out, st = compress.compress(cfg, st, g)
        assert float(out["x"][1]) == -5.0 and float(out["x"][3]) == 3.0
        assert float(out["x"][0]) == 0.0

    def test_error_feedback_accumulates(self):
        """Dropped mass must reappear via the EF residual."""
        g = {"x": jnp.array([1.0, 0.1, 0.0, 0.0])}
        cfg = compress.CompressConfig(codec="topk", topk_frac=0.25)
        st = compress.init(g)
        out1, st = compress.compress(cfg, st, g)      # keeps 1.0, drops 0.1
        assert float(st.residual["x"][1]) == pytest.approx(0.1)
        zero = {"x": jnp.zeros(4)}
        out2, st = compress.compress(cfg, st, zero)   # residual resurfaces
        assert float(out2["x"][1]) == pytest.approx(0.1)

    def test_wire_ratio(self):
        assert compress.wire_ratio(
            compress.CompressConfig(codec="int8")) == 0.25
        assert compress.wire_ratio(
            compress.CompressConfig(codec="topk", topk_frac=0.01)) == 0.02


class TestGradAccum:
    def test_accum_matches_full_batch(self):
        cfg = configs.get_smoke("granite-8b")
        p, _ = M.init_params(KEY, cfg)
        batch = lmdata.batch_at(
            lmdata.LMDataConfig(vocab=cfg.vocab, seq_len=16,
                                global_batch=8), 0)
        s0 = steps_mod.TrainState.create(p, use_ef=False)
        tc1 = steps_mod.TrainConfig()
        tc2 = dataclasses.replace(tc1, grad_accum=4)
        s1, m1 = jax.jit(steps_mod.make_train_step(cfg, tc1))(s0, batch)
        s2, m2 = jax.jit(steps_mod.make_train_step(cfg, tc2))(s0, batch)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
            s1["params"], s2["params"])
        assert max(jax.tree.leaves(d)) < 1e-4


class TestLMData:
    def test_deterministic(self):
        dc = lmdata.LMDataConfig(vocab=128, seq_len=32, global_batch=4)
        b1 = lmdata.batch_at(dc, 7)
        b2 = lmdata.batch_at(dc, 7)
        assert bool(jnp.array_equal(b1["tokens"], b2["tokens"]))

    def test_labels_are_shifted_tokens(self):
        dc = lmdata.LMDataConfig(vocab=128, seq_len=32, global_batch=4)
        b = lmdata.batch_at(dc, 0)
        assert bool(jnp.array_equal(b["labels"][:, :-1], b["tokens"][:, 1:]))
        assert bool(jnp.all(b["labels"][:, -1] == -1))

    def test_rank_slices_partition_batch(self):
        dc = lmdata.LMDataConfig(vocab=128, seq_len=8, global_batch=8)
        b = lmdata.batch_at(dc, 0)
        slices = [lmdata.rank_slice(b, r, 4)["tokens"] for r in range(4)]
        whole = jnp.concatenate(slices)
        assert bool(jnp.array_equal(whole, b["tokens"]))

    def test_in_vocab(self):
        dc = lmdata.LMDataConfig(vocab=100, seq_len=16, global_batch=2)
        b = lmdata.batch_at(dc, 3)
        assert int(jnp.max(b["tokens"])) < 100
        assert int(jnp.min(b["tokens"])) >= 0


class TestSyntheticDatasets:
    def test_stats_match_spec(self):
        from repro.data import synthetic
        ds = synthetic.load("a7a", scale=0.05)
        n = ds.x_train.shape[0] + ds.x_test.shape[0]
        assert abs(n - int(32561 * 0.05)) <= 8
        assert ds.x_train.shape[1] == 123
        # [0, 1] normalization
        assert float(jnp.min(ds.x_train)) >= 0.0
        assert float(jnp.max(ds.x_train)) <= 1.0
        # rough class balance
        frac = float(jnp.mean(ds.y_train > 0))
        assert 0.15 < frac < 0.35

    def test_linearly_separable_enough(self):
        # Root cause of the historical 0.757 plateau: GD converged fine,
        # but make_blobs drew the class-mean direction with a nonzero mean,
        # so after [0,1] min-max scaling the boundary no longer passed
        # through the origin — unreachable for the bias-free linear ODM.
        # Fixed in the generator (zero-mean direction + sep recalibrated to
        # the paper band), not by loosening this threshold.
        from repro.core import odm
        from repro.data import synthetic
        ds = synthetic.load("svmguide1", scale=0.1)
        w = jnp.zeros(ds.x_train.shape[1])
        params = odm.ODMParams()
        for _ in range(300):
            w = w - 0.1 * odm.primal_grad(w, ds.x_train, ds.y_train, params)
        acc = float(odm.accuracy(ds.y_test, jnp.sign(ds.x_test @ w)))
        assert acc > 0.85, acc
