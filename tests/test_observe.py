"""PR 9 telemetry tests: spans, instruments, trackers, trend gate.

Everything here is host-only (no jax import, no device work) — the
training/serving integration of the same pieces is pinned by
``analysis.invariants`` (components.observe.zero_cost_off) and the bench
smoke tier. Covers the ISSUE 9 satellites:

* the shared nearest-rank percentile over known distributions (the
  ``lat[n // 2]`` off-by-one regression);
* JsonlTracker's persistent handle + torn-tail tolerance;
* ``read_jsonl`` edge cases (empty / only-torn / interleaved writers);
* Tracker runtime-protocol conformance for every backend, the draining
  MetricsRegistry included;
* the bench gate failing on an injected 10x slowdown and passing on an
  unchanged run.
"""
import importlib.util
import json
import os
import threading

import pytest

from repro import observe
from repro.observe import trend


# ---------------------------------------------------------------------------
# percentile (satellite: serve_stream off-by-one fix)
# ---------------------------------------------------------------------------

class TestPercentile:
    def test_known_distribution_1_to_100(self):
        vals = list(range(1, 101))
        assert observe.percentile(vals, 50) == 50
        assert observe.percentile(vals, 95) == 95
        assert observe.percentile(vals, 99) == 99
        assert observe.percentile(vals, 0) == 1
        assert observe.percentile(vals, 100) == 100

    def test_even_small_n_median(self):
        # THE regression: lat[n // 2] returned 3 (the 75th percentile)
        # for n=4; nearest-rank p50 is the 2nd order statistic
        assert observe.percentile([1, 2, 3, 4], 50) == 2
        assert observe.percentile([1, 2, 3, 4], 95) == 4
        assert observe.percentile([10, 20], 50) == 10

    def test_single_element_and_unsorted(self):
        assert observe.percentile([7.0], 50) == 7.0
        assert observe.percentile([7.0], 99) == 7.0
        assert observe.percentile([3, 1, 2], 50) == 2
        sorted_in = [1, 2, 3]
        observe.percentile(sorted_in, 95)
        assert sorted_in == [1, 2, 3]      # never mutates the input

    def test_nearest_rank_exactness(self):
        # n=10: p90 is exactly the 9th order statistic, p91 the 10th
        vals = list(range(10))
        assert observe.percentile(vals, 90) == 8
        assert observe.percentile(vals, 91) == 9

    def test_errors(self):
        with pytest.raises(ValueError):
            observe.percentile([], 50)
        with pytest.raises(ValueError):
            observe.percentile([1.0], 101)


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

class TestSpans:
    def test_off_path_is_shared_noop(self):
        assert observe.current_recorder() is None
        assert observe.span("a", x=1) is observe.span("b")

    def test_record_and_nesting_by_containment(self):
        rec = observe.SpanRecorder()
        with observe.install(rec):
            with observe.span("outer", level=2):
                with observe.span("inner"):
                    pass
        outer, = rec.spans("outer")
        inner, = rec.spans("inner")
        assert outer["ph"] == inner["ph"] == "X"
        assert outer["args"] == {"level": 2}
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
        assert outer["tid"] == inner["tid"]

    def test_install_restores_previous(self):
        r1, r2 = observe.SpanRecorder(), observe.SpanRecorder()
        with observe.install(r1):
            with observe.install(r2):
                with observe.span("in2"):
                    pass
            with observe.span("in1"):
                pass
        assert observe.current_recorder() is None
        assert len(r2.spans("in2")) == 1 and not r2.spans("in1")
        assert len(r1.spans("in1")) == 1 and not r1.spans("in2")

    def test_worker_threads_record_with_own_tid(self):
        rec = observe.SpanRecorder()

        def work():
            with observe.span("worker"):
                pass

        with observe.install(rec):
            t = threading.Thread(target=work)
            t.start()
            t.join()
            with observe.span("main"):
                pass
        tids = {e["tid"] for e in rec.events()}
        assert len(tids) == 2

    def test_span_recorded_even_when_body_raises(self):
        rec = observe.SpanRecorder()
        with observe.install(rec):
            with pytest.raises(RuntimeError):
                with observe.span("boom"):
                    raise RuntimeError
        assert len(rec.spans("boom")) == 1

    def test_export_valid_chrome_trace(self, tmp_path):
        rec = observe.SpanRecorder()
        with observe.install(rec), observe.span("fit", route="sodm"):
            pass
        path = rec.export(tmp_path / "deep" / "trace.json")
        doc = json.loads(open(path).read())
        assert doc["displayTimeUnit"] == "ms"
        ev, = doc["traceEvents"]
        assert set(ev) >= {"name", "ph", "ts", "dur", "pid", "tid"}
        assert not list((tmp_path / "deep").glob("*.tmp"))

    def test_trace_ctx_none_is_noop(self):
        with observe.trace_ctx(None) as rec:
            assert rec is None
            assert observe.current_recorder() is None

    def test_trace_ctx_exports_even_on_raise(self, tmp_path):
        with pytest.raises(RuntimeError):
            with observe.trace_ctx(tmp_path):
                with observe.span("partial"):
                    pass
                raise RuntimeError
        doc = json.loads((tmp_path / "trace.json").read_text())
        assert [e["name"] for e in doc["traceEvents"]] == ["partial"]
        assert observe.current_recorder() is None

    def test_nonjson_attrs_coerced(self):
        rec = observe.SpanRecorder()
        with observe.install(rec), observe.span("s", obj=object(), f=1.5):
            pass
        args = rec.events()[0]["args"]
        json.dumps(args)                     # must be serialisable
        assert args["f"] == 1.5


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------

class TestInstruments:
    def test_counter_gauge(self):
        c = observe.Counter("req")
        c.inc(); c.inc(3)
        assert c.snapshot() == {"req.count": 4}
        g = observe.Gauge("depth")
        assert g.snapshot() == {}
        g.set(5); g.set(2); g.set(3)
        assert g.snapshot() == {"depth": 3, "depth.min": 2, "depth.max": 5}

    def test_histogram_exact_percentiles(self):
        h = observe.Histogram("lat")
        for v in range(1, 101):
            h.observe(v)
        snap = h.snapshot()
        assert snap["lat.count"] == 100
        assert snap["lat.p50"] == 50
        assert snap["lat.p95"] == 95
        assert snap["lat.p99"] == 99
        assert snap["lat.min"] == 1 and snap["lat.max"] == 100
        assert snap["lat.mean"] == pytest.approx(50.5)

    def test_histogram_bucket_counts_stay_exact_past_cap(self):
        h = observe.Histogram("x", buckets=(1.0, 10.0), max_samples=64)
        for i in range(1000):
            h.observe(0.5 if i % 2 else 5.0)
        assert h.n == 1000
        assert sum(h.counts) == 1000           # bucket counts never sampled
        assert len(h.samples) <= 64
        assert h.percentile(50) in (0.5, 5.0)  # sampled, still plausible

    def test_registry_get_or_create_and_type_conflict(self):
        m = observe.MetricsRegistry()
        assert m.counter("a") is m.counter("a")
        with pytest.raises(TypeError):
            m.gauge("a")

    def test_registry_log_metrics_observes_numerics_only(self):
        m = observe.MetricsRegistry()
        m.log_metrics(0, {"kkt": 0.5, "route": "sodm", "done": True})
        m.log_metrics(1, {"kkt": 1.5})
        snap = m.snapshot()
        assert snap["kkt.count"] == 2
        assert snap["kkt.p50"] == 0.5
        assert "route.count" not in snap and "done.count" not in snap

    def test_registry_drains_through_any_tracker(self, tmp_path):
        m = observe.MetricsRegistry()
        m.histogram("lat").observe(1.0)
        m.counter("req").inc(2)
        mem = observe.InMemoryTracker()
        path = tmp_path / "drain.jsonl"
        with observe.JsonlTracker(path) as jt:
            snap = m.drain(observe.CompositeTracker([mem, jt]), step=7)
        assert mem.steps[0][0] == 7
        assert mem.latest()["req.count"] == 2
        rec, = observe.read_jsonl(path)
        assert rec["step"] == 7 and rec["lat.p99"] == 1.0
        assert snap["lat.count"] == 1

    def test_snapshot_folds_in_invariant_counters(self):
        from repro.analysis import invariants as inv
        inv.counter("observe.test_counter").bump()
        m = observe.MetricsRegistry()
        snap = m.snapshot(include_counters=True)
        assert snap["counter.observe.test_counter.count"] >= 1
        assert "counter.observe.test_counter.count" not in m.snapshot()


# ---------------------------------------------------------------------------
# tracker backends (protocol conformance + jsonl lifecycle)
# ---------------------------------------------------------------------------

class TestTrackerBackends:
    def test_runtime_protocol_conformance(self, tmp_path):
        backends = [
            observe.InMemoryTracker(),
            observe.JsonlTracker(tmp_path / "t.jsonl"),
            observe.CompositeTracker([]),
            observe.MetricsRegistry(),
        ]
        for b in backends:
            assert isinstance(b, observe.Tracker), type(b).__name__
        class Nope:
            pass
        assert not isinstance(Nope(), observe.Tracker)

    def test_jsonl_persistent_handle(self, tmp_path):
        path = tmp_path / "m.jsonl"
        t = observe.JsonlTracker(path)
        assert t._file is None                 # lazy: no file until logged
        t.log_metrics(0, {"a": 1})
        f0 = t._file
        t.log_metrics(1, {"a": 2})
        assert t._file is f0                   # ONE handle across calls
        # every line is already durable before close
        assert [r["a"] for r in observe.read_jsonl(path)] == [1, 2]
        t.close()
        assert t._file is None
        t.log_metrics(2, {"a": 3})             # reopens transparently
        t.close()
        assert len(observe.read_jsonl(path)) == 3

    def test_jsonl_context_manager_closes(self, tmp_path):
        path = tmp_path / "m.jsonl"
        with observe.JsonlTracker(path) as t:
            t.log_metrics(0, {"x": 1.0})
            assert t._file is not None
        assert t._file is None

    def test_jsonl_torn_tail_still_tolerated(self, tmp_path):
        """Regression for the persistent-handle change: a torn final line
        (killed writer) must still be skipped by read_jsonl."""
        path = tmp_path / "m.jsonl"
        t = observe.JsonlTracker(path)
        for i in range(3):
            t.log_metrics(i, {"v": i})
        t.close()
        with open(path, "a") as f:
            f.write('{"step": 99, "v": tor')   # no newline, invalid json
        recs = observe.read_jsonl(path)
        assert [r["step"] for r in recs] == [0, 1, 2]


class TestReadJsonlEdgeCases:
    def test_empty_file(self, tmp_path):
        path = tmp_path / "e.jsonl"
        path.write_text("")
        assert observe.read_jsonl(path) == []

    def test_only_torn_lines(self, tmp_path):
        path = tmp_path / "torn.jsonl"
        path.write_text('{"a": \n{"b"\nnot json at all\n')
        assert observe.read_jsonl(path) == []

    def test_blank_lines_skipped(self, tmp_path):
        path = tmp_path / "b.jsonl"
        path.write_text('\n{"step": 0}\n\n{"step": 1}\n')
        assert [r["step"] for r in observe.read_jsonl(path)] == [0, 1]

    def test_interleaved_writers(self, tmp_path):
        """Two trackers appending to one path: O_APPEND + one write per
        line means whole lines interleave and nothing is lost."""
        path = tmp_path / "shared.jsonl"
        a = observe.JsonlTracker(path)
        b = observe.JsonlTracker(path)
        for i in range(5):
            a.log_metrics(i, {"w": "a"})
            b.log_metrics(i, {"w": "b"})
        a.close(); b.close()
        recs = observe.read_jsonl(path)
        assert len(recs) == 10
        assert {r["w"] for r in recs} == {"a", "b"}
        assert sorted(r["step"] for r in recs if r["w"] == "a") == \
            list(range(5))


# ---------------------------------------------------------------------------
# trend + bench gate
# ---------------------------------------------------------------------------

def _bench_record(name="serve", wall=1.0, peak=1 << 24, rows=3,
                  backend="cpu", device="cpu", metrics=None):
    return {"schema_version": 2, "bench": name, "device_kind": device,
            "backend": backend, "jax_version": "0.0.test",
            "wall_clock_s": wall, "peak_bytes": peak, "rows": rows,
            "lines": ["x"] * rows, "metrics": metrics or {}}


def _write_dir(d, *recs):
    os.makedirs(d, exist_ok=True)
    for r in recs:
        with open(os.path.join(d, f"BENCH_{r['bench']}.json"), "w") as f:
            json.dump(r, f)
    return d


def _gate_main():
    spec = importlib.util.spec_from_file_location(
        "bench_gate", os.path.join(os.path.dirname(__file__), "..",
                                   "scripts", "bench_gate.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.main


class TestTrendGate:
    def test_identical_run_passes(self, tmp_path):
        base = _write_dir(tmp_path / "base", _bench_record())
        cur = _write_dir(tmp_path / "cur", _bench_record())
        findings = trend.compare_dirs(cur, base)
        assert not any(f.regressed for f in findings)
        assert _gate_main()([str(cur), str(base)]) == 0

    def test_injected_10x_slowdown_fails(self, tmp_path):
        """The ISSUE 9 acceptance criterion: 10x wall-clock must trip the
        gate; the same 10x on different hardware only warns."""
        base = _write_dir(tmp_path / "base", _bench_record(wall=1.0))
        cur = _write_dir(tmp_path / "cur", _bench_record(wall=10.0))
        findings = trend.compare_dirs(cur, base)
        bad = [f for f in findings if f.regressed]
        assert [f.field for f in bad] == ["wall_clock_s"]
        assert _gate_main()([str(cur), str(base)]) == 1

    def test_noise_band_absorbs_small_jitter(self, tmp_path):
        # +60% on a 50ms bench: inside both the 2x band and the absolute
        # floor — the gate must not flake on scheduler noise
        base = _write_dir(tmp_path / "base", _bench_record(wall=0.05))
        cur = _write_dir(tmp_path / "cur", _bench_record(wall=0.08))
        assert not any(f.regressed
                       for f in trend.compare_dirs(cur, base))

    def test_cross_hardware_slowdown_demoted_to_warn(self, tmp_path):
        base = _write_dir(tmp_path / "base",
                          _bench_record(wall=1.0, backend="tpu",
                                        device="TPU v4"))
        cur = _write_dir(tmp_path / "cur", _bench_record(wall=10.0))
        findings = trend.compare_dirs(cur, base)
        walls = [f for f in findings if f.field == "wall_clock_s"]
        assert walls and all(f.level == "warn" for f in walls)
        assert not any(f.regressed for f in findings)

    def test_missing_bench_is_a_regression(self, tmp_path):
        base = _write_dir(tmp_path / "base", _bench_record("serve"),
                          _bench_record("kernels"))
        cur = _write_dir(tmp_path / "cur", _bench_record("serve"))
        findings = trend.compare_dirs(cur, base)
        gone = [f for f in findings if f.regressed]
        assert len(gone) == 1 and gone[0].bench == "kernels" \
            and gone[0].field == "presence"

    def test_new_bench_without_baseline_warns_only(self, tmp_path):
        base = _write_dir(tmp_path / "base", _bench_record("serve"))
        cur = _write_dir(tmp_path / "cur", _bench_record("serve"),
                         _bench_record("fresh"))
        findings = trend.compare_dirs(cur, base)
        assert not any(f.regressed for f in findings)
        assert any(f.bench == "fresh" and f.level == "warn"
                   for f in findings)

    def test_metric_percentiles_gated_like_wall_clock(self, tmp_path):
        m_base = {"serve.request.latency_s.p99": 0.01,
                  "serve.requests.count": 64}
        m_cur = {"serve.request.latency_s.p99": 5.0,
                 "serve.requests.count": 64}
        base = _write_dir(tmp_path / "base",
                          _bench_record(metrics=m_base))
        cur = _write_dir(tmp_path / "cur", _bench_record(metrics=m_cur))
        findings = trend.compare_dirs(cur, base)
        bad = {f.field for f in findings if f.regressed}
        assert bad == {"metrics.serve.request.latency_s.p99"}

    def test_empty_rows_fails(self, tmp_path):
        base = _write_dir(tmp_path / "base", _bench_record(rows=3))
        cur = _write_dir(tmp_path / "cur", _bench_record(rows=0))
        findings = trend.compare_dirs(cur, base)
        assert any(f.regressed and f.field == "rows" for f in findings)

    def test_no_baselines_raises(self, tmp_path):
        cur = _write_dir(tmp_path / "cur", _bench_record())
        os.makedirs(tmp_path / "base")
        with pytest.raises(FileNotFoundError):
            trend.compare_dirs(cur, tmp_path / "base")

    def test_unknown_schema_rejected(self, tmp_path):
        rec = _bench_record()
        rec["schema_version"] = 99
        d = _write_dir(tmp_path / "v", rec)
        with pytest.raises(ValueError):
            trend.load_dir(d)

    def test_format_report_orders_failures_first(self, tmp_path):
        base = _write_dir(tmp_path / "base", _bench_record(wall=1.0))
        cur = _write_dir(tmp_path / "cur", _bench_record(wall=10.0))
        report = trend.format_report(trend.compare_dirs(cur, base))
        assert "1 regression(s)" in report.splitlines()[0]
        assert "[FAIL]" in report.splitlines()[1]
