"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4
[hf:databricks/dbrx-base; unverified].
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4),
    act="silu",
)

SMOKE = ArchConfig(
    name="dbrx-132b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0),
    act="silu",
)
