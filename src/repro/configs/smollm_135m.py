"""smollm-135m [dense] — llama-arch small.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]. 9 heads do not divide the 16-way model
axis -> heads replicate, d_ff shards (divisibility fallback exercised).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    act="silu",
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="smollm-135m-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_ff=96,
    vocab=512,
    act="silu",
    tie_embeddings=True,
)
