"""falcon-mamba-7b [ssm] — attention-free mamba1 architecture.

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16
[arXiv:2410.05355; unverified]. d_inner = 2 * d_model = 8192.
O(1) decode state -> runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                   # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(state=16, conv=4, expand=2),
    act="silu",
)

SMOKE = ArchConfig(
    name="falcon-mamba-7b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=512,
    ssm=SSMConfig(state=4, conv=4, expand=2),
    act="silu",
)
