"""qwen2.5-14b [dense] — GQA + QKV bias.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064
[hf:Qwen/Qwen2.5 family; hf].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    act="silu",
)

SMOKE = ArchConfig(
    name="qwen2.5-14b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    qkv_bias=True,
    act="silu",
)
