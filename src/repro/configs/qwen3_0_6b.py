"""qwen3-0.6b [dense] — qk_norm + GQA.

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936
[hf:Qwen/Qwen3-8B family; hf]. head_dim=128 (> d_model/n_heads).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1e6,
    act="silu",
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="qwen3-0.6b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    head_dim=32,
    qk_norm=True,
    act="silu",
    tie_embeddings=True,
)
