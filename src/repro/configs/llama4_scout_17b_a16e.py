"""llama4-scout-17b-a16e [moe] — MoE 16e top-1 + shared expert, iRoPE.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. iRoPE: 3 chunked-
attention layers (window 8192, RoPE) then 1 global layer (NoPE), repeated;
every layer is MoE with a shared expert. Bounded window on 3/4 of layers
+ sequence-sharded cache on global layers -> runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    moe=MoEConfig(n_experts=16, top_k=1, shared_expert=True),
    attn_window=8192,
    global_every=4,              # (w, w, w, global) repeating
    rope_theta=5e5,
    act="silu",
)

SMOKE = ArchConfig(
    name="llama4-scout-17b-a16e-smoke",
    family="moe",
    n_layers=4,                  # one full (w, w, w, g) unit
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    moe=MoEConfig(n_experts=4, top_k=1, shared_expert=True,
                  capacity_factor=4.0),
    attn_window=16,
    global_every=4,
    act="silu",
)
