"""ArchConfig — the selectable architecture description.

One file per assigned architecture lives next to this module; each exports
``CONFIG`` (the exact published shape) and ``SMOKE`` (a reduced same-family
config for CPU tests). ``registry.get(name)`` resolves either.

The four assigned input shapes are global (see ``SHAPES``): ``train_4k``
lowers train_step; ``prefill_32k`` lowers prefill; ``decode_32k`` /
``long_500k`` lower serve_step (one new token against a seq_len KV cache).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 16
    top_k: int = 4
    d_ff_expert: int = 0          # per-expert hidden (defaults to d_ff)
    shared_expert: bool = False   # llama4-style always-on shared expert
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state: int = 16               # N: per-channel state size (mamba1)
    conv: int = 4                 # depthwise conv kernel width
    expand: int = 2               # d_inner = expand * d_model
    dt_rank: int = 0              # defaults to ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    # recurrentgemma/Griffin: pattern unit = (rec, rec, attn)
    block_pattern: tuple = ("rec", "rec", "attn")
    window: int = 2048            # local attention window
    conv: int = 4
    lru_width: int = 0            # defaults to d_model


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int = 12
    frontend_dim: int = 80        # stub modality frontend embedding dim
    frontend_len: int = 1024      # precomputed frame/patch positions


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str = "model"
    family: str = "dense"         # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    d_ff: int = 4096
    vocab: int = 32000
    head_dim: int = 0             # defaults to d_model // n_heads
    # attention options
    qk_norm: bool = False         # qwen3
    qkv_bias: bool = False        # qwen2.5 / qwen2-vl
    rope_theta: float = 10000.0
    rope_kind: str = "rope"       # rope | mrope | none
    mrope_sections: tuple = (16, 24, 24)   # qwen2-vl M-RoPE split of head_dim/2
    # llama4 iRoPE: every `global_every`-th layer is global attention w/o rope
    attn_window: Optional[int] = None      # chunked/local attention width
    global_every: int = 0                  # 0 = no interleaving
    # norm / act
    norm_kind: str = "rmsnorm"    # rmsnorm | layernorm
    act: str = "silu"             # silu (SwiGLU) | gelu (plain 2-mat MLP)
    tie_embeddings: bool = False
    # family payloads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    # modality frontend stub ([audio]/[vlm]): inputs are precomputed embeddings
    frontend_stub: bool = False
    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # scan stacking: layers per scan super-block (set by pattern families)
    remat: str = "full"           # full | dots | none

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """vocab rounded up to 256 so the vocab dim shards cleanly."""
        return -(-self.vocab // 256) * 256

    def supports_long_context(self) -> bool:
        """True if decode state is bounded (sub-quadratic attention)."""
        if self.family == "ssm":
            return True
        if self.rglru is not None:
            return True
        # llama4-style chunked attention: bounded window on most layers;
        # the few global layers use a sequence-sharded cache.
        if self.attn_window is not None:
            return True
        return False

    def layer_pattern(self) -> tuple:
        """The repeating unit of layer kinds + the remainder tail."""
        if self.family == "ssm":
            return ("ssm",), self.n_layers, ()
        if self.rglru is not None:
            unit = self.rglru.block_pattern
            reps = self.n_layers // len(unit)
            rem = self.n_layers - reps * len(unit)
            # recurrentgemma-9b: 38 = 12*(rec,rec,attn) + (rec, rec)
            return unit, reps, tuple(unit[:rem])
        if self.global_every > 1:
            # llama4 iRoPE: (windowed, ..., windowed, global) repeated
            unit = tuple("attn_window" for _ in range(self.global_every - 1)
                         ) + ("attn_global",)
            reps = self.n_layers // len(unit)
            rem = self.n_layers - reps * len(unit)
            return unit, reps, tuple(unit[:rem])
        return ("attn",), self.n_layers, ()


# ---------------------------------------------------------------------------
# the four assigned input shapes (global, LM-family)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, ("pure full-attention arch: 524k decode needs "
                       "sub-quadratic attention (skip noted in DESIGN.md)")
    return True, ""
