"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 ratio.

38L d_model=4096 16H (GQA kv=1 = MQA) d_ff=12288 vocab=256000
[arXiv:2402.19427 (Griffin); unverified]. Pattern: 12 x (rec, rec, attn)
+ 2 trailing rec = 38 layers; local attention window 2048. Bounded decode
state -> runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    rglru=RGLRUConfig(block_pattern=("rec", "rec", "attn"), window=2048,
                      conv=4),
    act="gelu",                  # Griffin uses GeGLU
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=5,                  # 1 x (rec, rec, attn) + (rec, rec)
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=512,
    rglru=RGLRUConfig(block_pattern=("rec", "rec", "attn"), window=16,
                      conv=4),
    act="gelu",
    tie_embeddings=True,
)
