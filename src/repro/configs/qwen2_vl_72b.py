"""qwen2-vl-72b [vlm] — M-RoPE + dynamic resolution (backbone only).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064
[arXiv:2409.12191; hf]. Vision frontend is a STUB: input_specs() provides
precomputed patch embeddings + 3-axis (temporal, h, w) position ids for
M-RoPE; the backbone is the standard qwen2 decoder with QKV bias.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    act="silu",
    frontend_stub=True,
)

SMOKE = ArchConfig(
    name="qwen2-vl-72b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    qkv_bias=True,
    rope_kind="mrope",
    mrope_sections=(4, 2, 2),
    act="silu",
    frontend_stub=True,
)
