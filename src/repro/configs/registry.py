"""Architecture registry: ``--arch <id>`` resolution.

``get(name)`` returns the exact published config; ``get_smoke(name)``
returns the reduced same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable

_MODULES = {
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "granite-8b": "repro.configs.granite_8b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "smollm-135m": "repro.configs.smollm_135m",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
}

ARCH_NAMES = tuple(_MODULES)


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells():
    """All 40 (arch, shape) cells with their applicability."""
    out = []
    for a in ARCH_NAMES:
        cfg = get(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
