"""seamless-m4t-medium [audio] — enc-dec multimodal backbone.

12L d_model=1024 16H (GQA kv=16 = MHA) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf]. The speech frontend is a STUB: input_specs()
provides precomputed frame embeddings (frontend_dim x frontend_len) to the
encoder; the text decoder is a standard transformer with cross-attention.
"""
from repro.configs.base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,                 # decoder layers; encoder in EncoderConfig
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    norm_kind="layernorm",
    act="gelu",
    rope_kind="none",            # learned/sinusoidal positions; stubbed as none
    encoder=EncoderConfig(n_layers=12, frontend_dim=1024, frontend_len=1024),
    frontend_stub=True,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="seamless-m4t-medium-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    norm_kind="layernorm",
    act="gelu",
    rope_kind="none",
    encoder=EncoderConfig(n_layers=2, frontend_dim=64, frontend_len=32),
    frontend_stub=True,
    tie_embeddings=True,
)
