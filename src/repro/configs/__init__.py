from repro.configs.base import (SHAPES, ArchConfig, EncoderConfig, MoEConfig,
                                RGLRUConfig, ShapeConfig, SSMConfig,
                                shape_applicable)
from repro.configs.registry import ARCH_NAMES, cells, get, get_shape, get_smoke

__all__ = ["SHAPES", "ArchConfig", "EncoderConfig", "MoEConfig",
           "RGLRUConfig", "ShapeConfig", "SSMConfig", "shape_applicable",
           "ARCH_NAMES", "cells", "get", "get_shape", "get_smoke"]
