"""Static VMEM / tiling checks for every registered Pallas kernel.

A Pallas TPU kernel's per-tile memory is decided entirely by its launch
plan: block shapes x dtype for every BlockSpec operand, plus scratch.
When the plan doesn't fit VMEM the failure today is a Mosaic compile
error deep inside the fused pass — most famously the partition-resident
``(1, m)`` ``u_d`` block of :func:`repro.kernels.dual_cd_block.fused_cd_pass`,
whose 4·m bytes cross the ceiling around m = 10⁶ (ROADMAP open item 1).
This module makes that failure a *plan-time* :class:`PallasBudgetError`
with a per-block sizing report instead.

Model: a TPU core has ~16 MiB of VMEM (see the Pallas guide). Mosaic
double-buffers streamed blocks to overlap DMA with compute, so we charge
the **single-copy footprint** (streams + residents + scratch) against
**half** the physical VMEM, reserving the other half for the pipeline's
second copies. That is deliberately conservative-but-simple: a plan that
fits half-VMEM single-copy always has room to double-buffer its streams.

Each kernel registers a *plan builder* in :data:`PLAN_BUILDERS` that
mirrors its real BlockSpecs (shapes are asserted against the kernel
modules' constants where possible, so a kernel refactor that changes
block shapes breaks the mirror loudly in tests, not silently).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.analysis.jaxpr_lint import InvariantViolation

__all__ = [
    "Block", "KernelPlan", "PallasBudgetError", "VMEM_BYTES",
    "vmem_budget", "sizing_report", "check_plan", "PLAN_BUILDERS",
    "default_plans", "check_kernels",
    "gram_plan", "gram_matvec_plan", "fused_cd_plan", "score_plan",
    "odm_grad_plan", "svrg_grad_plan",
]

#: physical VMEM per core, by backend
VMEM_BYTES = {"tpu": 16 * 2 ** 20}

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "int32": 4,
                "int8": 1, "bool": 1}


def vmem_budget(backend: str = "tpu") -> int:
    """Usable single-copy budget: half the physical VMEM (the other half
    is reserved for Mosaic's double-buffered stream copies)."""
    return VMEM_BYTES[backend] // 2


class PallasBudgetError(InvariantViolation):
    """A kernel launch plan exceeds the static VMEM budget (or violates a
    tiling assumption). The message carries the full sizing report."""


@dataclasses.dataclass(frozen=True)
class Block:
    """One VMEM-resident array in a kernel plan.

    kind:
      * ``stream``   — re-fetched per grid step (a BlockSpec with a
        grid-dependent index_map); Mosaic double-buffers these.
      * ``resident`` — same block across grid steps (constant index_map),
        e.g. the fused pass's partition-wide ``u_d`` and label rows, or
        ``odm_grad``'s full ``w``/``out`` slabs.
      * ``scratch``  — ``pltpu.VMEM`` scratch allocated for the launch.
    """

    name: str
    shape: tuple[int, ...]
    dtype: str = "float32"
    kind: str = "stream"

    def __post_init__(self):
        if self.kind not in ("stream", "resident", "scratch"):
            raise ValueError(f"unknown block kind {self.kind!r}")
        if self.dtype not in _DTYPE_BYTES:
            raise ValueError(f"unknown dtype {self.dtype!r}")

    @property
    def bytes(self) -> int:
        n = _DTYPE_BYTES[self.dtype]
        for dim in self.shape:
            n *= int(dim)
        return n


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Static description of one pallas_call launch."""

    kernel: str                              # registry name, e.g. "gram"
    grid: tuple[int, ...]
    blocks: tuple[Block, ...]
    #: (axis label, axis size, tile size) triples to divisibility-check;
    #: axes the kernel pads internally set tile size after padding.
    tiled_axes: tuple[tuple[str, int, int], ...] = ()
    notes: str = ""

    def footprint(self) -> int:
        return sum(b.bytes for b in self.blocks)


def _fmt_bytes(n: float) -> str:
    if n >= 2 ** 20:
        return f"{n / 2 ** 20:.2f} MiB"
    if n >= 2 ** 10:
        return f"{n / 2 ** 10:.1f} KiB"
    return f"{int(n)} B"


def sizing_report(plan: KernelPlan, backend: str = "tpu",
                  budget: int | None = None) -> str:
    """Human-readable per-block VMEM table for ``plan``."""
    budget = vmem_budget(backend) if budget is None else budget
    rows = sorted(plan.blocks, key=lambda b: -b.bytes)
    w = max((len(b.name) for b in rows), default=4)
    lines = [f"kernel {plan.kernel!r}  grid={plan.grid}"]
    for b in rows:
        shape = "x".join(str(d) for d in b.shape)
        lines.append(f"  {b.name:<{w}}  {b.kind:<8}  {shape:>16} "
                     f"{b.dtype:<8} {_fmt_bytes(b.bytes):>12}")
    total = plan.footprint()
    pct = 100.0 * total / budget if budget else float("inf")
    lines.append(f"  {'TOTAL':<{w}}  single-copy footprint "
                 f"{_fmt_bytes(total):>12}  "
                 f"({pct:.0f}% of {_fmt_bytes(budget)} budget, "
                 f"{backend} VMEM {_fmt_bytes(VMEM_BYTES[backend])}/2)")
    if plan.notes:
        lines.append(f"  note: {plan.notes}")
    return "\n".join(lines)


def check_plan(plan: KernelPlan, backend: str = "tpu",
               budget: int | None = None) -> str:
    """Validate ``plan``; returns the sizing report on success, raises
    :class:`PallasBudgetError` (report included) on failure."""
    budget = vmem_budget(backend) if budget is None else budget
    problems = []
    for axis, size, tile in plan.tiled_axes:
        if tile <= 0:
            problems.append(f"axis {axis}: nonpositive tile {tile}")
        elif size % tile:
            problems.append(
                f"axis {axis}: size {size} not divisible by tile {tile} "
                f"(kernel assumes exact tiling — pad the operand or "
                f"shrink the tile)")
    total = plan.footprint()
    if total > budget:
        problems.append(
            f"single-copy footprint {_fmt_bytes(total)} exceeds the "
            f"{_fmt_bytes(budget)} budget by "
            f"{_fmt_bytes(total - budget)}")
    report = sizing_report(plan, backend, budget)
    if problems:
        detail = "\n".join(f"  - {p}" for p in problems)
        raise PallasBudgetError(
            f"kernel {plan.kernel!r} fails static VMEM/tiling check:\n"
            f"{detail}\n{report}")
    return report


def _ceil_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


# ---------------------------------------------------------------------------
# plan builders — each mirrors the BlockSpecs of the real kernel
# ---------------------------------------------------------------------------

def gram_plan(M: int = 4096, N: int = 4096, D: int = 1024, *,
              kind: str = "rbf", bm: int = 256, bn: int = 256,
              bd: int = 512) -> KernelPlan:
    """Mirror of ``kernels.gram._gram_kernel``: out + f32 scratch are
    resident per (i, j) tile while the D axis streams."""
    from repro.kernels import gram as _g
    Mp, Np, Dp = _ceil_to(M, bm), _ceil_to(N, bn), _ceil_to(D, bd)
    bd = min(bd, Dp)
    blocks = [
        Block("xx", (1, bm)), Block("zz", (1, bn)),
        Block("yx", (1, bm)), Block("yz", (1, bn)),
        Block("x", (bm, bd)), Block("z", (bn, bd)),
        Block("out", (bm, bn), kind="resident"),
        Block("acc", (bm, bn), kind="scratch"),
    ]
    notes = ""
    if kind in _g.L1_KERNELS:
        # |x-z| has no dot shortcut; the kernel broadcasts a
        # (bm, bn, _L1_CHUNK) difference slab per D-chunk.
        blocks.append(Block("l1_diff", (bm, bn, _g._L1_CHUNK),
                            kind="scratch"))
        notes = (f"laplacian path materializes a (bm, bn, {_g._L1_CHUNK}) "
                 f"broadcast slab per chunk")
    return KernelPlan(
        kernel="gram", grid=(Mp // bm, Np // bn, Dp // bd),
        blocks=tuple(blocks),
        tiled_axes=(("M", Mp, bm), ("N", Np, bn), ("D", Dp, bd)),
        notes=notes)


def gram_matvec_plan(K: int = 2, M: int = 4096, N: int = 4096,
                     D: int = 1024, *, bm: int = 256, bn: int = 256,
                     bd: int = 512) -> KernelPlan:
    """Mirror of ``kernels.gram._gram_matvec_kernel``: matrix-free
    K(X,Z)g — the (bm, bn) Gram tile only ever exists in scratch."""
    Mp, Np, Dp = _ceil_to(M, bm), _ceil_to(N, bn), _ceil_to(D, bd)
    bd = min(bd, Dp)
    return KernelPlan(
        kernel="gram_matvec", grid=(K, Mp // bm, Np // bn, Dp // bd),
        blocks=(
            Block("xx", (1, 1, bm)), Block("zz", (1, 1, bn)),
            Block("g", (1, 1, bn)),
            Block("x", (1, bm, bd)), Block("z", (1, bn, bd)),
            Block("out", (1, bm, 1), kind="resident"),
            Block("acc", (bm, bn), kind="scratch"),
            Block("u", (bm, 1), kind="scratch"),
        ),
        tiled_axes=(("M", Mp, bm), ("N", Np, bn), ("D", Dp, bd)))


def fused_cd_plan(K: int = 8, m: int = 4096, B: int = 256, *,
                  source: str = "kernel", d: int = 1024,
                  bd: int = 512) -> KernelPlan:
    """Mirror of ``kernels.dual_cd_block.fused_cd_pass``: ONE launch per
    sweep; ``u_d`` (and labels, matrix-free) ride along as (1, m)
    partition-resident rows — 4·m bytes each, THE documented ceiling at
    m = 10⁶ (ROADMAP open item 1)."""
    if source not in ("kernel", "dense"):
        raise ValueError(f"source must be 'kernel' or 'dense': {source!r}")
    nblk = _ceil_to(m, B) // B
    mp = nblk * B
    blocks = [
        Block("qb", (1, 1, B, B)),
        Block("a", (1, 1, 2 * B)),
        Block("u", (1, 1, B)), Block("v", (1, 1, B)),
        Block("a_out", (1, 1, 2 * B)),
        Block("u_d", (1, mp), kind="resident"),
        Block("d", (B, 1), kind="scratch"),
    ]
    if source == "dense":
        blocks.append(Block("Q", (1, B, B)))
        grid = (K, nblk, nblk)
    else:
        Dp = _ceil_to(d, bd)
        bd = min(bd, Dp)
        blocks += [
            Block("y", (1, mp), kind="resident"),
            Block("xx_j", (1, 1, B)), Block("xx_i", (1, 1, B)),
            Block("x_j", (1, B, bd)), Block("x_i", (1, B, bd)),
            Block("acc", (B, B), kind="scratch"),
        ]
        grid = (K, nblk, nblk, Dp // bd)
    return KernelPlan(
        kernel="fused_cd", grid=grid, blocks=tuple(blocks),
        tiled_axes=(("m", mp, B),),
        notes=f"(1, m) u_d row is partition-resident: 4*m bytes fp32 "
              f"({_fmt_bytes(4 * mp)} here) — the fused layout's ceiling")


def score_plan(T: int = 1024, S: int = 4096, D: int = 1024, *,
               bt: int = 128, bs: int = 256,
               bd: int = 512) -> KernelPlan:
    """Mirror of ``kernels.score.score_tiles``: serving-side matrix-free
    sum_j c_j k(t, z_j) with the (bt, bs) tile living only in scratch."""
    Tp, Sp, Dp = _ceil_to(T, bt), _ceil_to(S, bs), _ceil_to(D, bd)
    bd = min(bd, Dp)
    return KernelPlan(
        kernel="score", grid=(Tp // bt, Sp // bs, Dp // bd),
        blocks=(
            Block("xx", (1, bt)), Block("zz", (1, bs)),
            Block("c", (1, bs)),
            Block("x", (bt, bd)), Block("z", (bs, bd)),
            Block("out", (bt, 1), kind="resident"),
            Block("acc", (bt, bs), kind="scratch"),
            Block("u", (bt, 1), kind="scratch"),
        ),
        tiled_axes=(("T", Tp, bt), ("S", Sp, bs), ("D", Dp, bd)))


def odm_grad_plan(M: int = 65536, d: int = 2048, *,
                  bm: int = 512) -> KernelPlan:
    """Mirror of ``kernels.odm_grad._odm_grad_kernel``: full-width w and
    out slabs resident while the batch streams in bm rows."""
    Mp = _ceil_to(M, bm)
    return KernelPlan(
        kernel="odm_grad", grid=(Mp // bm,),
        blocks=(
            Block("w", (1, d), kind="resident"),
            Block("x", (bm, d)),
            Block("y", (1, bm)),
            Block("out", (1, d), kind="resident"),
        ),
        tiled_axes=(("M", Mp, bm),),
        notes="w/out are full-width residents; ops._shrink_bm halves bm "
              "when the (bm, d) stream slab crosses 8 MiB")


def svrg_grad_plan(B: int = 4096, d: int = 2048, *,
                   bm: int = 512) -> KernelPlan:
    """Mirror of ``kernels.odm_grad._svrg_grad_kernel``: the DSVRG inner
    step — (w, w_anchor) pair + anchor full gradient resident."""
    Bp = _ceil_to(B, bm)
    return KernelPlan(
        kernel="odm_svrg_grad", grid=(Bp // bm,),
        blocks=(
            Block("wa", (2, d), kind="resident"),
            Block("h", (1, d), kind="resident"),
            Block("inv", (1, 1), kind="resident"),
            Block("x", (bm, d)),
            Block("y", (1, bm)),
            Block("wt", (1, bm)),
            Block("out", (1, d), kind="resident"),
        ),
        tiled_axes=(("B", Bp, bm),))


#: kernel registry name -> default plan builder (kwargs mirror the real
#: entry points' tiling knobs)
PLAN_BUILDERS: dict[str, Callable[..., KernelPlan]] = {
    "gram": gram_plan,
    "gram_matvec": gram_matvec_plan,
    "fused_cd": fused_cd_plan,
    "score": score_plan,
    "odm_grad": odm_grad_plan,
    "odm_svrg_grad": svrg_grad_plan,
}


def default_plans() -> dict[str, KernelPlan]:
    """One representative plan per registered kernel, at each kernel's
    default tiling and production-representative operand sizes."""
    return {name: build() for name, build in PLAN_BUILDERS.items()}


def check_kernels(backend: str = "tpu") -> dict[str, str]:
    """Check every registered kernel's default plan; returns the sizing
    reports, raises :class:`PallasBudgetError` on the first failure."""
    return {name: check_plan(plan, backend)
            for name, plan in default_plans().items()}
