"""repro.analysis — static analysis of the compiled-program plan.

The repo's correctness story at scale is plan-level, not math-level: the
bug classes that actually bit us (PR 3's silent-wrong-answer trio, the
collectives-stuck-inside-``while`` hoisting trap, the Mosaic VMEM ceiling
on the fused pass's partition-resident u_d block) are all visible in the
traced program or the kernel launch plan *before a device ever runs*.
This package turns those checks into a subsystem:

* :mod:`repro.analysis.jaxpr_lint`   — jaxpr walker + declarative rule
  engine over traced functions (launch budgets, gather-free paths,
  collectives/host-sync inside loop bodies, scan-length assertions).
* :mod:`repro.analysis.pallas_check` — static per-tile VMEM footprint and
  tile-divisibility checks for every registered Pallas kernel, against a
  per-backend budget, with a sizing report on failure.
* :mod:`repro.analysis.invariants`   — the registry where kernels and
  training routes DECLARE their invariants (launch counts, VMEM plans,
  trace/gather counters, collective ceilings); one uniform battery in
  ``tests/test_analysis.py`` verifies every declaration.
* :mod:`repro.analysis.boundary_lint` — AST lint of repo conventions
  (facade boundary, no hardcoded tile/step knobs, warn-once shims,
  pallas_call containment), run by ``scripts/lint.py`` and CI.

``boundary_lint`` is stdlib-only so ``scripts/lint.py`` stays fast; the
other modules import jax and are loaded lazily here.
"""
from __future__ import annotations

_SUBMODULES = ("jaxpr_lint", "pallas_check", "invariants", "boundary_lint")


def __getattr__(name: str):
    if name in _SUBMODULES:
        import importlib
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SUBMODULES))
