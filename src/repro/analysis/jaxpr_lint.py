"""Declarative jaxpr lint: walk a traced program, check plan invariants.

``jax.make_jaxpr`` gives the full program plan — every primitive, every
sub-jaxpr (scan/while bodies, cond branches, pjit calls, pallas kernel
bodies) — before anything compiles or runs. This module walks that tree
into a flat list of :class:`Site`\\ s (primitive + structural path) and
runs declarative :class:`Rule`\\ s over it, so the bug classes this repo
has actually hit become machine-checked assertions:

* **launch budgets** — :func:`max_pallas_calls` pins how many
  ``pallas_call``\\ s a traced function may contain (the fused-CD-pass
  "one launch per pass" pin, the serving "one launch per batch" pin).
* **gather-free paths** — :func:`gather_free` asserts a hot path contains
  no ``gather`` (the served score path must never re-apply the partition
  permutation per call).
* **collectives inside loops** — :func:`no_collectives_in_loops` detects
  the PR 3 hoisting trap statically: XLA will NOT hoist a loop-invariant
  collective out of a ``while``/``scan`` body, so an all-gather of an
  invariant slab inside an epoch loop multiplies its wire bytes by the
  trip count. Legitimately per-iteration collectives (e.g. the sharded
  DSVRG loss psum) are allow-listed by name.
* **host sync inside loops** — :func:`no_host_sync_in_loops` keeps
  callbacks/infeed out of hot loop bodies (each one is a device→host
  round trip per iteration).
* **scan-length assertions** — :func:`expect_scan` pins trace-once scan
  drivers (e.g. "all epochs live in ONE scan of length ``epochs``").

Entry points: :func:`trace` a zero-arg thunk to a jaxpr, :func:`lint`
to collect violations, :func:`check` to raise :class:`InvariantViolation`
with a formatted report, :func:`count_primitive` for count pins.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Sequence

import jax

__all__ = [
    "InvariantViolation", "Site", "Rule", "Violation", "trace",
    "iter_sites", "lint", "check", "count_primitive", "scan_lengths",
    "max_primitive", "max_pallas_calls", "forbid_primitive", "gather_free",
    "no_collectives_in_loops", "no_host_sync_in_loops", "expect_scan",
    "COLLECTIVE_PRIMS", "HOST_SYNC_PRIMS", "GATHER_PRIMS", "LOOP_FRAMES",
]


class InvariantViolation(AssertionError):
    """A declared plan-level invariant does not hold."""


#: structural frames that mean "inside a loop body" (trip count > 1 —
#: a while condition re-executes per trip, so it counts as loop context)
LOOP_FRAMES = frozenset({"scan_body", "while_body", "while_cond"})

#: cross-device communication primitives (jax names them stably)
COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "pmax", "pmin", "pbroadcast", "all_gather",
    "all_gather_invariant", "all_to_all", "ppermute", "reduce_scatter",
})

#: primitives that force a device <-> host round trip
HOST_SYNC_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "infeed", "outfeed",
})

#: dynamic-indexing primitives a "gather-free" hot path must not contain
GATHER_PRIMS = frozenset({"gather"})


@dataclasses.dataclass(frozen=True)
class Site:
    """One primitive occurrence inside a (possibly nested) jaxpr."""

    prim: str
    path: tuple[str, ...]                  # enclosing frames, outermost first
    eqn: object = dataclasses.field(compare=False, hash=False, default=None)

    @property
    def loop_depth(self) -> int:
        return sum(1 for f in self.path if f in LOOP_FRAMES)

    @property
    def where(self) -> str:
        return "/".join(self.path) if self.path else "<top>"

    def __str__(self) -> str:
        return f"{self.where}:{self.prim}"


def _as_jaxprs(val) -> Iterator:
    """Yield every Jaxpr inside a params value (ClosedJaxpr, Jaxpr, or a
    tuple/list of either — jax's own containers for sub-programs)."""
    if hasattr(val, "eqns"):                           # Jaxpr
        yield val
    elif hasattr(val, "jaxpr") and hasattr(val, "consts"):   # ClosedJaxpr
        yield val.jaxpr
    elif isinstance(val, (tuple, list)):
        for item in val:
            yield from _as_jaxprs(item)


def _frame_label(prim: str, key: str) -> str:
    if prim == "scan" and key == "jaxpr":
        return "scan_body"
    if prim == "while" and key == "body_jaxpr":
        return "while_body"
    if prim == "while" and key == "cond_jaxpr":
        return "while_cond"
    return prim                                        # pjit, cond, pallas...


def iter_sites(jaxpr, path: tuple[str, ...] = ()) -> Iterator[Site]:
    """Every primitive occurrence in ``jaxpr``, depth-first, sub-jaxprs
    included. ``jaxpr`` may be a Jaxpr or ClosedJaxpr."""
    for inner in _as_jaxprs(jaxpr):
        for eqn in inner.eqns:
            name = eqn.primitive.name
            yield Site(prim=name, path=path, eqn=eqn)
            for key, val in eqn.params.items():
                for sub in _as_jaxprs(val):
                    yield from iter_sites(sub,
                                          path + (_frame_label(name, key),))


def trace(fn: Callable[[], object]):
    """Trace a zero-arg thunk (closing over its inputs) to a ClosedJaxpr.
    Nothing executes and nothing compiles — this is the plan, pre-device."""
    return jax.make_jaxpr(fn)()


def _sites_of(target) -> list[Site]:
    if callable(target):
        target = trace(target)
    return list(iter_sites(target))


def count_primitive(fn: Callable[[], object], prim: str) -> int:
    """Occurrences of ``prim`` in the traced plan of ``fn`` (jitted
    constituents included — their sub-jaxprs are walked, so no trace-cache
    clearing is needed, unlike the old monkeypatch counter)."""
    return sum(1 for s in _sites_of(fn) if s.prim == prim)


def scan_lengths(target) -> list[int]:
    """``length`` of every scan in the plan, outermost-first."""
    return [int(s.eqn.params["length"]) for s in _sites_of(target)
            if s.prim == "scan"]


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    """A declarative invariant over the flattened site list."""

    name: str
    description: str
    check: Callable[[list[Site]], list[str]] = dataclasses.field(
        compare=False)

    def run(self, sites: list[Site]) -> list[Violation]:
        return [Violation(rule=self.name, message=m)
                for m in self.check(sites)]


def max_primitive(prim: str, n: int, *, name: str | None = None) -> Rule:
    """At most ``n`` occurrences of ``prim`` anywhere in the plan."""

    def chk(sites: list[Site]) -> list[str]:
        hits = [s for s in sites if s.prim == prim]
        if len(hits) > n:
            at = ", ".join(str(s) for s in hits)
            return [f"{len(hits)} x {prim} in the plan, budget is {n} "
                    f"(at: {at})"]
        return []

    return Rule(name=name or f"max_{prim}_{n}",
                description=f"at most {n} {prim} in the traced plan",
                check=chk)


def max_pallas_calls(n: int) -> Rule:
    """Kernel-launch budget: at most ``n`` ``pallas_call``\\ s."""
    return max_primitive("pallas_call", n, name=f"max_pallas_calls_{n}")


def forbid_primitive(prims: Sequence[str] | frozenset, *, name: str,
                     reason: str = "") -> Rule:
    """No occurrence of any of ``prims`` anywhere in the plan."""
    pset = frozenset(prims)

    def chk(sites: list[Site]) -> list[str]:
        why = f" — {reason}" if reason else ""
        return [f"forbidden primitive {s}{why}"
                for s in sites if s.prim in pset]

    return Rule(name=name, description=f"forbids {sorted(pset)}", check=chk)


def gather_free() -> Rule:
    """The plan contains no gather: hot score paths must never re-gather
    (the partition permutation is applied once at model-compile time)."""
    return forbid_primitive(
        GATHER_PRIMS, name="gather_free",
        reason="this path is pinned gather-free (permutations are applied "
               "once at compile_model time, never per call)")


def _in_loop_rule(pset: frozenset, *, name: str, reason: str,
                  allow: Sequence[str] = ()) -> Rule:
    allowed = frozenset(allow)

    def chk(sites: list[Site]) -> list[str]:
        return [f"{s} inside a loop body — {reason}"
                for s in sites
                if s.prim in pset and s.prim not in allowed
                and s.loop_depth > 0]

    return Rule(name=name,
                description=f"forbids {sorted(pset - allowed)} inside "
                            f"while/scan bodies", check=chk)


def no_collectives_in_loops(allow: Sequence[str] = ()) -> Rule:
    """No collective inside a ``while``/``scan`` body (the PR 3 hoisting
    trap: XLA does not hoist loop-invariant collectives, so a gather of an
    invariant slab pays its wire bytes once per trip). ``allow`` names
    collectives that are legitimately per-iteration (e.g. ``psum`` of a
    per-epoch loss)."""
    return _in_loop_rule(
        COLLECTIVE_PRIMS, name="no_collectives_in_loops", allow=allow,
        reason="XLA will not hoist it out; hoist loop-invariant "
               "collectives above the loop yourself (PR 3 trap)")


def no_host_sync_in_loops() -> Rule:
    """No host callback/infeed inside a loop body: one device-host round
    trip per iteration serializes the hot loop."""
    return _in_loop_rule(
        HOST_SYNC_PRIMS, name="no_host_sync_in_loops",
        reason="each iteration would synchronize with the host")


def expect_scan(length: int, count: int = 1, *,
                name: str | None = None) -> Rule:
    """Exactly ``count`` scans of trip count ``length`` in the plan — the
    trace-once driver shape ("all epochs in ONE lax.scan")."""

    def chk(sites: list[Site]) -> list[str]:
        lens = [int(s.eqn.params["length"]) for s in sites
                if s.prim == "scan"]
        got = sum(1 for ln in lens if ln == length)
        if got != count:
            return [f"expected {count} scan(s) of length {length}, found "
                    f"{got} (all scan lengths: {lens})"]
        return []

    return Rule(name=name or f"expect_scan_{length}x{count}",
                description=f"{count} scan(s) of length {length}",
                check=chk)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint(target, rules: Sequence[Rule]) -> list[Violation]:
    """Run ``rules`` over the plan of ``target`` (a zero-arg thunk, a
    Jaxpr, or a ClosedJaxpr); returns all violations."""
    sites = _sites_of(target)
    out: list[Violation] = []
    for rule in rules:
        out.extend(rule.run(sites))
    return out


def check(target, rules: Sequence[Rule], *, subject: str = "plan") -> None:
    """:func:`lint` and raise :class:`InvariantViolation` on violations."""
    violations = lint(target, rules)
    if violations:
        lines = "\n".join(f"  {v}" for v in violations)
        raise InvariantViolation(
            f"{subject}: {len(violations)} jaxpr invariant violation(s):\n"
            f"{lines}")
