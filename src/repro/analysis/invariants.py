"""The invariant registry: kernels and routes DECLARE, one battery checks.

Before this module the repo's plan-level pins lived wherever they were
first needed — ``sodm.perm_gather_count`` in core, launch-count asserts
in ``benchmarks/kernels_bench.py``, the trace-once pin in
``tests/test_dsvrg.py``. Each was real, none was discoverable, and a new
kernel or route shipped with whatever pins its author remembered to add.
Here every Pallas kernel and every registered training route declares
its invariants as data:

    declare(Invariant(
        name="kernels.score.single_launch", subject="score",
        kind="kernel", description="one pallas_call per request batch",
        verify=_score_single_launch))

``tests/test_analysis.py`` runs ONE parametrized battery over
:func:`invariants`, and a meta-test asserts every kernel in
``pallas_check.PLAN_BUILDERS`` and every route in
``api.registry.routes()`` has at least one declaration — forgetting the
pin is itself a test failure.

Also hosts the process-wide :class:`Counter` store backing the legacy
regression pins (``sodm.perm_gather_count``, ``dsvrg.epoch_trace_count``
are thin aliases over these), and :func:`count_pallas_calls`, the
jaxpr-walk launch counter (no monkeypatching, no trace-cache clearing).

Import discipline: this module imports only :mod:`repro.analysis` and
jax at the top level; every verify closure lazy-imports the subsystem it
checks, so ``repro.core`` modules can import this one for counters
without a cycle.
"""
from __future__ import annotations

# lint: allow[T001] — the verify closures trace kernels at minimal probe
# shapes; their tiny tile kwargs are the fixture, not production config.

import dataclasses
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_lint as jl
from repro.analysis import pallas_check as pc

__all__ = [
    "Counter", "counter", "counters", "Invariant", "declare", "invariants",
    "get", "verify", "verify_all", "count_pallas_calls", "COMPONENTS",
]


# ---------------------------------------------------------------------------
# counters (process-wide regression pins)
# ---------------------------------------------------------------------------

class Counter:
    """A named append-only event counter. ``events`` is a plain list so
    legacy module globals can alias it in place (``dsvrg._TRACE_EVENTS``
    IS ``counter("dsvrg.epoch_trace").events`` — same object)."""

    def __init__(self, name: str):
        self.name = name
        self.events: list = []

    @property
    def count(self) -> int:
        return len(self.events)

    def bump(self, event=None) -> None:
        self.events.append(event)

    def reset(self) -> None:
        del self.events[:]

    def __repr__(self) -> str:
        return f"Counter({self.name!r}, count={self.count})"


_COUNTERS: dict[str, Counter] = {}


def counter(name: str) -> Counter:
    """Get-or-create the process-wide counter ``name``."""
    got = _COUNTERS.get(name)
    if got is None:
        got = _COUNTERS[name] = Counter(name)
    return got


def counters() -> dict[str, Counter]:
    return dict(_COUNTERS)


def count_pallas_calls(fn) -> int:
    """Count ``pallas_call`` sites in the traced plan of the zero-arg
    thunk ``fn`` — by walking the jaxpr (sub-jaxprs of jitted
    constituents included), so unlike the old monkeypatch counter it
    needs no ``clear_cache()`` discipline and cannot undercount on a
    warm trace cache."""
    return jl.count_primitive(fn, "pallas_call")


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Invariant:
    """One declared plan-level invariant.

    ``subject`` is the kernel registry name (a ``pallas_check.
    PLAN_BUILDERS`` key) or the route name (an ``api.registry`` route);
    ``kind`` says which namespace that is. ``verify`` is a zero-arg
    callable that raises ``AssertionError`` (usually
    :class:`~repro.analysis.jaxpr_lint.InvariantViolation`) on failure;
    its return value, if any, is a human-readable result. ``slow`` marks
    declarations the quick CI tier skips (subprocess compiles etc.)."""

    name: str
    subject: str
    kind: str                      # "kernel" | "route" | "component"
    description: str
    verify: Callable[[], object] = dataclasses.field(compare=False)
    slow: bool = False

    def __post_init__(self):
        if self.kind not in ("kernel", "route", "component"):
            raise ValueError(f"kind must be 'kernel', 'route' or "
                             f"'component', got {self.kind!r}")


#: fault-tolerance / observability components under the PR 6 meta-coverage
#: rule: each must carry >= 1 ``kind="component"`` declaration (asserted by
#: tests/test_analysis.py alongside the kernel and route coverage)
COMPONENTS = ("checkpoint", "data", "faults", "resume", "tracker",
              "observe")

_REGISTRY: dict[str, Invariant] = {}


def declare(inv: Invariant) -> Invariant:
    """Register ``inv``; duplicate names raise (a pin silently replaced
    is a pin silently dropped)."""
    if inv.name in _REGISTRY:
        raise ValueError(f"invariant {inv.name!r} already declared")
    _REGISTRY[inv.name] = inv
    return inv


def invariants() -> tuple[Invariant, ...]:
    """All declared invariants, name-sorted (stable parametrize order)."""
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


def get(name: str) -> Invariant:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"no invariant {name!r}; declared: "
                       f"{sorted(_REGISTRY)}") from None


def verify(name: str):
    """Run one invariant by name; raises on violation."""
    return get(name).verify()


def verify_all(include_slow: bool = False) -> dict[str, object]:
    """Run every declared invariant; returns {name: result}. Raises on
    the first violation (the battery in tests runs them individually)."""
    return {inv.name: inv.verify() for inv in invariants()
            if include_slow or not inv.slow}


# ---------------------------------------------------------------------------
# shared tiny fixtures for the built-in declarations
# ---------------------------------------------------------------------------

def _toy_data(M: int = 32, d: int = 4, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jnp.concatenate([jax.random.normal(k1, (M // 2, d)) + 1.0,
                         jax.random.normal(k2, (M // 2, d)) - 1.0])
    y = jnp.concatenate([jnp.ones(M // 2), -jnp.ones(M // 2)])
    perm = jax.random.permutation(k3, M)
    return x[perm], y[perm]


def _assert_single_launch(thunk, what: str) -> str:
    n = count_pallas_calls(thunk)
    if n != 1:
        raise jl.InvariantViolation(
            f"{what}: expected exactly 1 pallas_call in the plan, "
            f"found {n}")
    return f"{what}: 1 launch"


# ---------------------------------------------------------------------------
# kernel invariants
# ---------------------------------------------------------------------------

def _gram_single_launch():
    from repro.kernels import ops
    x, _ = _toy_data(16, 8)
    z, _ = _toy_data(16, 8, seed=1)
    spec = ops._RbfSpec(0.5)
    return _assert_single_launch(
        lambda: ops.gram(x, z, spec, bm=8, bn=8, bd=8),
        "gram (one launch per Gram block, all D-chunks inside the grid)")


def _gram_vmem():
    out = [pc.check_plan(pc.gram_plan())]
    # the laplacian path carries an extra (bm, bn, chunk) broadcast slab
    out.append(pc.check_plan(pc.gram_plan(kind="laplacian")))
    return "\n".join(out)


def _gram_matvec_single_launch():
    from repro.kernels import ops
    x, y = _toy_data(16, 8)
    xs = x.reshape(2, 8, 8)
    g = jnp.ones((2, 8))
    spec = ops._RbfSpec(0.5)
    return _assert_single_launch(
        lambda: ops.gram_matvec(xs, g, spec, bm=8, bn=8, bd=8),
        "gram_matvec (all K partitions and tiles in one launch)")


def _gram_matvec_vmem():
    return pc.check_plan(pc.gram_matvec_plan())


def _score_single_launch():
    from repro.kernels import score
    x, _ = _toy_data(16, 8)
    z, _ = _toy_data(32, 8, seed=1)
    c = jnp.ones((32,))
    return _assert_single_launch(
        lambda: score.score_tiles(x, z, c, kind="rbf", gamma=0.5, bt=8,
                                  bs=8, bd=8, interpret=True),
        "score (one launch per request batch)")


def _score_gather_free():
    from repro.core import kernel_fns as kf
    from repro.kernels import ops
    x, _ = _toy_data(16, 8)
    z, _ = _toy_data(32, 8, seed=1)
    c = jnp.ones((32,))
    spec = kf.KernelSpec(name="rbf", gamma=0.5)
    rules = [jl.gather_free(), jl.no_host_sync_in_loops()]
    # the kernel path AND the interpret-mode streaming path must both
    # stay gather-free: the permutation is applied at compile_model time
    jl.check(lambda: ops.decision_scores(x, z, c, spec, bt=8, bs=8, bd=8,
                                         tiled=True),
             rules, subject="decision_scores(tiled=True)")
    jl.check(lambda: ops.decision_scores(x, z, c, spec, bt=8),
             rules, subject="decision_scores(auto)")
    return "score paths are gather-free"


def _score_vmem():
    return pc.check_plan(pc.score_plan())


def _odm_grad_single_launch():
    from repro.kernels import ops
    x, y = _toy_data(16, 8)
    w = jnp.zeros(8)
    return _assert_single_launch(
        lambda: ops.odm_grad(w, x, y, bm=8),
        "odm_grad (full primal gradient in one launch)")


def _odm_grad_vmem():
    out = [pc.check_plan(pc.odm_grad_plan())]
    # the _shrink_bm policy must keep wide-feature sweeps inside budget
    from repro.kernels import ops
    for d in (1024, 2048, 4096, 8192):
        bm = ops._shrink_bm(512, 65536, d)
        out.append(pc.check_plan(pc.odm_grad_plan(d=d, bm=bm)))
    return "\n".join(out)


def _svrg_grad_single_launch():
    from repro.kernels import ops
    x, y = _toy_data(16, 8)
    w = jnp.zeros(8)
    return _assert_single_launch(
        lambda: ops.svrg_grad(w, w, w, x, y, bm=8),
        "odm_svrg_grad (one launch per inner step)")


def _svrg_grad_vmem():
    return pc.check_plan(pc.svrg_grad_plan())


def _fused_cd_sources(B: int = 8, K: int = 2, d: int = 8):
    from repro.core import kernel_fns as kf
    from repro.kernels import gram as gram_mod
    m = 2 * B
    x, y = _toy_data(K * m, d)
    xs, ys = x.reshape(K, m, d), y.reshape(K, m)
    spec = kf.KernelSpec(name="rbf", gamma=0.5)
    import jax as _jax
    from repro.kernels import dual_cd_block as cdk
    qb = _jax.vmap(lambda q: cdk.extract_diag_blocks(q, B))(
        _jax.vmap(lambda xk, yk: kf.signed_gram(spec, xk, yk))(xs, ys))
    dense = gram_mod.DenseSource(
        _jax.vmap(lambda xk, yk: kf.signed_gram(spec, xk, yk))(xs, ys))
    mfree = gram_mod.make_kernel_source(spec, xs, ys, bm=B, bn=B,
                                        interpret=True)
    a = jnp.zeros((K, m // B, 2 * B))
    u = jnp.zeros((K, m // B, B))
    v = jnp.ones((K, m // B, B))
    return qb, dense, mfree, a, u, v, m


def _fused_cd_single_launch():
    from repro.kernels import dual_cd_block as cdk
    qb, dense, mfree, a, u, v, m = _fused_cd_sources()
    for label, src in (("dense", dense), ("matrix-free", mfree)):
        _assert_single_launch(
            lambda src=src: cdk.fused_cd_pass(
                qb, src, a, u, v, c=1.0, ups=0.5, theta=0.1,
                mscale=float(m), n_steps=4, exit_tol=0.0, interpret=True),
            f"fused_cd_pass[{label}] (one launch per sweep)")
    return "fused_cd_pass: 1 launch per pass, both sources"


def _fused_cd_vmem():
    return "\n".join([pc.check_plan(pc.fused_cd_plan(source="kernel")),
                      pc.check_plan(pc.fused_cd_plan(source="dense"))])


def _fused_cd_vmem_ceiling():
    plan = pc.fused_cd_plan(m=1_000_000, source="kernel")
    try:
        pc.check_plan(plan)
    except pc.PallasBudgetError as e:
        msg = str(e)
        assert "u_d" in msg and "exceeds" in msg, msg
        return ("m=10^6 fused plan correctly rejected at plan time "
                "(partition-resident u_d row)")
    raise jl.InvariantViolation(
        "the m=10^6 fused matrix-free plan fit the VMEM budget — the "
        "(1, m) u_d ceiling (ROADMAP open item 1) is no longer being "
        "caught; if the kernel layout changed, update fused_cd_plan")


# ---------------------------------------------------------------------------
# route invariants
# ---------------------------------------------------------------------------

_LINEAR_ROUTES = ("dsvrg", "svrg", "csvrg")


def _route_cfg(route: str):
    from repro.core import dsvrg as dsvrg_mod
    from repro.core import sodm as sodm_mod
    dcfg = dsvrg_mod.DSVRGConfig(n_partitions=4, epochs=2, batch=8,
                                 n_landmarks=4)
    return sodm_mod.SODMConfig(p=2, levels=2, n_landmarks=4, tol=1e-4,
                               max_sweeps=50, dsvrg=dcfg)


def _facade_artifact(route: str):
    """ODMEstimator.fit(route) on a toy problem returns a deployable
    FittedODM without tripping any legacy-shim FutureWarning — the facade
    never routes through its own deprecated entry points."""
    from repro.api import ODMEstimator, ProblemSpec
    from repro.core import kernel_fns as kf
    from repro.serve.model import FittedODM
    kernel = "linear" if route in _LINEAR_ROUTES else "rbf"
    problem = ProblemSpec(kernel=kf.KernelSpec(name=kernel, gamma=0.5))
    x, y = _toy_data(32, 4)
    est = ODMEstimator(problem, route=route, cfg=_route_cfg(route))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        model, report = est.fit(x, y, jax.random.PRNGKey(0))
    legacy = [w for w in caught if issubclass(w.category, FutureWarning)]
    if legacy:
        raise jl.InvariantViolation(
            f"route {route!r} fit raised legacy FutureWarning(s): "
            f"{[str(w.message) for w in legacy]}")
    assert isinstance(model, FittedODM), type(model)
    assert report.route == route, report.route
    preds = est.predict(x)
    assert preds.shape == (32,)
    return f"route {route}: FittedODM artifact, no legacy warnings"


def _make_facade_invariant(route: str) -> Callable[[], object]:
    return lambda: _facade_artifact(route)


def _sodm_gather_once():
    """The partition permutation is gathered ONCE per fitted model:
    repeated predicts through the cached compiled model add nothing."""
    from repro.core import kernel_fns as kf
    from repro.core import odm, sodm
    x, y = _toy_data(32, 4)
    spec = kf.KernelSpec(name="rbf", gamma=0.5)
    params = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)
    cfg = _route_cfg("sodm")
    res = sodm._solve(spec, x, y, params, cfg, jax.random.PRNGKey(0))
    c0 = sodm.perm_gather_count()
    sodm.predict(spec, res, x, y, x[:8])
    c1 = sodm.perm_gather_count()
    sodm.predict(spec, res, x, y, x[8:16])
    c2 = sodm.perm_gather_count()
    if not (c1 == c0 + 1 and c2 == c1):
        raise jl.InvariantViolation(
            f"perm gather pin broken: counts {c0} -> {c1} -> {c2}; "
            f"expected exactly one gather at model compile, zero per "
            f"predict")
    return "sodm: 1 perm gather per fitted model, 0 per predict"


def _dsvrg_trace_once():
    """A whole DSVRG solve is ONE jit trace; re-solving the same config
    and shapes re-traces nothing (the scan driver is cache-stable)."""
    from repro.core import dsvrg, odm
    x, y = _toy_data(32, 4)
    params = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)
    cfg = dsvrg.DSVRGConfig(n_partitions=4, epochs=2, batch=8,
                            n_landmarks=4)
    key = jax.random.PRNGKey(0)
    dsvrg._solve(x, y, params, cfg, key)          # warm (may or may not trace)
    n1 = dsvrg.epoch_trace_count()
    dsvrg._solve(x, y, params, cfg, key)
    n2 = dsvrg.epoch_trace_count()
    if n2 != n1:
        raise jl.InvariantViolation(
            f"dsvrg re-traced on an identical config: trace count "
            f"{n1} -> {n2} (cfg or shapes are not cache-stable)")
    return "dsvrg: identical re-solve adds 0 traces"


def _dsvrg_epoch_scan_shape():
    """The local driver's plan: ONE scan of length cfg.epochs (all epochs
    in one trace), and no collective or host-sync primitive anywhere in
    its loop bodies — a single-process solve never talks to the wire."""
    from repro.core import dsvrg, odm
    EPOCHS = 5                       # distinct from K=2 and S=2 below
    x, y = _toy_data(8, 4)
    params = odm.ODMParams(lam=1.0, theta=0.1, ups=0.5)
    cfg = dsvrg.DSVRGConfig(n_partitions=2, epochs=EPOCHS, batch=2)
    xs, ys, wts = dsvrg._pad_batches(x.reshape(2, 4, 4),
                                     y.reshape(2, 4), cfg.batch)
    w0 = jnp.zeros(4)
    thunk = lambda: dsvrg._run(w0, xs, ys, wts, params=params, cfg=cfg,
                               M=8)
    jl.check(thunk,
             [jl.expect_scan(EPOCHS, count=1, name="one_epoch_scan"),
              jl.no_collectives_in_loops(),
              jl.no_host_sync_in_loops()],
             subject="dsvrg._run")
    return f"dsvrg._run: one scan of length {EPOCHS}, loop bodies clean"


_SHARDED_HOIST_SCRIPT = r"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dsvrg
from repro.core.odm import ODMParams
from repro.launch import hlo_analysis as ha

mesh = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("data",))
params = ODMParams(lam=1.0, theta=0.1, ups=0.5)
M, d, K, batch = 32, 4, 2, 8


def all_gathers(epochs):
    cfg = dsvrg.DSVRGConfig(n_partitions=K, epochs=epochs, batch=batch,
                            schedule="serial")
    xs = jnp.zeros((K, M // K, d))
    ys = jnp.ones((K, M // K))
    xsb, ysb, wts = dsvrg._pad_batches(xs, ys, batch)
    run = dsvrg._make_sharded_run(mesh, params, cfg, M, "data")
    hlo = run.lower(jnp.zeros(d), xsb, ysb, wts).compile().as_text()
    return ha.collective_bytes(hlo).count_by_kind.get("all-gather", 0)


a2, a6 = all_gathers(2), all_gathers(6)
assert a2 > 0, "no all-gather found at all — serial schedule changed?"
assert a2 == a6, (
    f"all-gather count grows with the epoch count ({a2} at 2 epochs vs "
    f"{a6} at 6): the serial-schedule slab gather has slid back inside "
    f"the epoch scan (the PR 3 hoisting trap)")
print(f"OK all_gathers={a2} at both epoch counts")
"""


def _dsvrg_sharded_gather_hoisted():
    """The sharded serial schedule all-gathers its (loop-invariant) slab
    ONCE, outside the epoch scan. Machine check for the PR 3 trap: the
    trip-multiplicity-weighted all-gather count in the compiled HLO must
    not grow with cfg.epochs. Runs in a subprocess with 2 forced host
    devices (device count is fixed at jax init)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2")
    env["JAX_PLATFORMS"] = "cpu"
    src_root = os.path.join(os.path.dirname(__file__), "..", "..")
    env["PYTHONPATH"] = (os.path.abspath(src_root) + os.pathsep +
                         env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SHARDED_HOIST_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    if proc.returncode != 0:
        raise jl.InvariantViolation(
            f"sharded gather-hoist check failed:\n{proc.stdout}\n"
            f"{proc.stderr}")
    return proc.stdout.strip()


# ---------------------------------------------------------------------------
# component invariants (fault tolerance + observability, ISSUE 7)
# ---------------------------------------------------------------------------

def _faults_deterministic_replay():
    """The same FaultPlan spec against the same loop fires at the same
    site every time; kill rules raise Preemption, delay rules return (and
    with sleeper=None never wall-sleep) their seconds, and every rule is
    spent after its count."""
    from repro.distributed import faults as fm

    def drive(plan):
        visited = []
        try:
            for lvl in (3, 2, 1, 0):
                plan.site("cascade.level", level=lvl, K=2 ** lvl)
                visited.append(lvl)
        except fm.Preemption as e:
            visited.append(("kill", e.info["level"]))
        return visited

    a = drive(fm.FaultPlan().kill_at_level(1))
    b = drive(fm.FaultPlan().kill_at_level(1))
    if not (a == b == [3, 2, ("kill", 1)]):
        raise jl.InvariantViolation(
            f"fault replay is not deterministic: {a} vs {b}")
    plan = fm.FaultPlan(sleeper=None).delay_partition(2, 0.5)
    got = (plan.site("cascade.partition", partition=1),
           plan.site("cascade.partition", partition=2),
           plan.site("cascade.partition", partition=2))
    if got != (0.0, 0.5, 0.0):
        raise jl.InvariantViolation(
            f"delay rule mis-fired or was not spent: {got}")
    if plan.fired != [("delay", "cascade.partition", {"partition": 2})]:
        raise jl.InvariantViolation(f"fired log wrong: {plan.fired}")
    return "faults: deterministic replay, counts spend, virtual delays"


def _checkpoint_crash_window():
    """A kill between the fsync'd temp write and the atomic rename never
    disturbs the previously committed step, and the orphaned temp dir is
    garbage-collected by the next save."""
    import os
    import tempfile
    from repro.distributed import checkpoint as ck
    from repro.distributed import faults as fm

    with tempfile.TemporaryDirectory() as d:
        plan = fm.FaultPlan()
        mgr = ck.CheckpointManager(d, keep=3, faults=plan)
        mgr.save(1, {"a": jnp.arange(4.0)})
        plan.kill_mid_checkpoint()   # arm AFTER step 1 committed
        try:
            mgr.save(2, {"a": jnp.arange(4.0) + 1.0})
        except fm.Preemption:
            pass
        else:
            raise jl.InvariantViolation("kill_mid_checkpoint did not fire")
        if mgr.latest_step() != 1:
            raise jl.InvariantViolation(
                f"crash window corrupted the committed step: "
                f"latest={mgr.latest_step()}")
        back = mgr.restore({"a": jnp.zeros(4)})
        assert jnp.array_equal(back["a"], jnp.arange(4.0))
        orphans = [n for n in os.listdir(d) if ".tmp." in n]
        if not orphans:
            raise jl.InvariantViolation(
                "the killed writer left no orphan — the site is not in "
                "the crash window")
        mgr.save(2, {"a": jnp.arange(4.0) + 1.0})
        left = [n for n in os.listdir(d) if ".tmp." in n]
        if left:
            raise jl.InvariantViolation(f"orphans survived _gc: {left}")
    return "checkpoint: crash window safe, orphan GC'd on next save"


def _resume_cascade_bit_identical():
    """ISSUE 7 acceptance: kill the driver mid-cascade; fit(resume=)
    returns a bit-identical result with fewer level solves than a cold
    restart."""
    import tempfile

    import numpy as np

    from repro.api import ODMEstimator, ProblemSpec
    from repro.core import kernel_fns as kf
    from repro.core import sodm
    from repro.distributed import faults as fm

    x, y = _toy_data(32, 4)
    problem = ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.5))
    cfg = _route_cfg("sodm")                     # levels=2 -> 3 solves
    key = jax.random.PRNGKey(0)
    _, base = ODMEstimator(problem, route="sodm", cfg=cfg).fit(x, y, key)
    with tempfile.TemporaryDirectory() as d:
        try:
            ODMEstimator(problem, route="sodm", cfg=cfg).fit(
                x, y, key, resume=d, faults=fm.FaultPlan().kill_at_level(1))
        except fm.Preemption:
            pass
        else:
            raise jl.InvariantViolation("kill_at_level(1) did not fire")
        c0 = sodm.level_solve_count()
        _, resumed = ODMEstimator(problem, route="sodm", cfg=cfg).fit(
            x, y, key, resume=d)
        ran = sodm.level_solve_count() - c0
    cold = cfg.levels + 1
    if ran >= cold:
        raise jl.InvariantViolation(
            f"resume re-ran {ran} level solves, not fewer than the cold "
            f"restart's {cold}")
    if not np.array_equal(np.asarray(resumed.raw.alpha),
                          np.asarray(base.raw.alpha)):
        raise jl.InvariantViolation("resumed duals differ bitwise")
    return f"resume(cascade): bit-identical, {ran} < {cold} level solves"


def _resume_dsvrg_segments():
    """The dsvrg route checkpoints (w, epoch) between scan segments; a
    killed-and-resumed solve is bit-identical to the uninterrupted
    segmented run."""
    import dataclasses as dc
    import tempfile

    import numpy as np

    from repro.api import ODMEstimator, ProblemSpec
    from repro.core import kernel_fns as kf
    from repro.distributed import faults as fm

    x, y = _toy_data(32, 4)
    problem = ProblemSpec(kernel=kf.KernelSpec(name="linear"))
    cfg = _route_cfg("dsvrg")
    cfg = dc.replace(cfg, dsvrg=dc.replace(cfg.dsvrg, epochs=4))
    key = jax.random.PRNGKey(0)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        model_a, _ = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
            x, y, key, resume=d1)
        try:
            ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
                x, y, key, resume=d2, faults=fm.FaultPlan().kill_at_epoch(2))
        except fm.Preemption:
            pass
        else:
            raise jl.InvariantViolation("kill_at_epoch(2) did not fire")
        model_b, _ = ODMEstimator(problem, route="dsvrg", cfg=cfg).fit(
            x, y, key, resume=d2)
    if not np.array_equal(np.asarray(model_a.w), np.asarray(model_b.w)):
        raise jl.InvariantViolation(
            "resumed dsvrg iterate differs bitwise from the "
            "uninterrupted segmented run")
    return "resume(dsvrg): killed+resumed w bitwise == uninterrupted"


def _tracker_level_stream():
    """The tracker protocol receives one record per cascade level (with
    KKT / sweeps / SV-count / throughput) plus a final fit summary, and
    the jsonl backend round-trips the stream, tolerating a torn tail
    line from a killed writer."""
    import os
    import tempfile

    from repro import observe
    from repro.api import ODMEstimator, ProblemSpec
    from repro.core import kernel_fns as kf

    x, y = _toy_data(32, 4)
    problem = ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.5))
    cfg = _route_cfg("sodm")
    mem = observe.InMemoryTracker()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "metrics.jsonl")
        tracker = observe.CompositeTracker(
            [mem, observe.JsonlTracker(path)])
        ODMEstimator(problem, route="sodm", cfg=cfg).fit(
            x, y, jax.random.PRNGKey(0), tracker=tracker)
        with open(path, "a") as f:
            f.write('{"step": 99, "torn')       # killed mid-line
        records = observe.read_jsonl(path)
    levels = [m for _, m in mem.steps if "level" in m]
    if len(levels) != cfg.levels + 1:
        raise jl.InvariantViolation(
            f"expected {cfg.levels + 1} per-level records, got "
            f"{len(levels)}")
    need = {"level", "kkt", "sweeps", "sv_count", "rows_per_s"}
    missing = need - set(levels[0])
    if missing:
        raise jl.InvariantViolation(f"level record missing {missing}")
    if not mem.latest().get("fit_done"):
        raise jl.InvariantViolation("no final fit summary was logged")
    if len(records) != len(mem.steps):
        raise jl.InvariantViolation(
            f"jsonl round trip lost records ({len(records)} vs "
            f"{len(mem.steps)}) or kept the torn line")
    return "tracker: per-level stream + summary, torn-tail-safe jsonl"


def _observe_zero_cost_off():
    """PR 9 span/instrument telemetry is zero-cost when off and inert
    when on: (a) with no recorder installed ``span()`` returns the shared
    no-op singleton; (b) a fit with tracker + trace_dir produces a
    bitwise-identical model and the same number of level-solve launches
    as a bare fit, and its exported trace is valid Chrome JSON with
    cascade.level spans nested inside fit; (c) re-fitting the dsvrg route
    with trace_dir adds zero new epoch-scan traces (trace-once holds
    under tracing)."""
    import dataclasses as dc
    import json
    import os
    import tempfile

    import numpy as np

    from repro import observe
    from repro.api import ODMEstimator, ProblemSpec
    from repro.core import kernel_fns as kf
    from repro.observe import spans as spans_mod

    # (a) the off path allocates nothing per call
    if spans_mod.current_recorder() is not None:
        raise jl.InvariantViolation(
            "a span recorder leaked in from a previous test")
    if observe.span("a", k=1) is not observe.span("b"):
        raise jl.InvariantViolation(
            "span() with no recorder must return the shared no-op")

    x, y = _toy_data(32, 4)
    key = jax.random.PRNGKey(0)

    # (b) sodm: instrumented fit == bare fit, launch-for-launch
    problem = ProblemSpec(kernel=kf.KernelSpec(name="rbf", gamma=0.5))
    cfg = _route_cfg("sodm")
    solves = counter("sodm.level_solve")
    n0 = solves.count
    model_a, _ = ODMEstimator(problem, route="sodm", cfg=cfg).fit(
        x, y, key)
    bare_solves = solves.count - n0
    with tempfile.TemporaryDirectory() as d:
        n1 = solves.count
        model_b, _ = ODMEstimator(problem, route="sodm", cfg=cfg).fit(
            x, y, key, tracker=observe.MetricsRegistry(), trace_dir=d)
        traced_solves = solves.count - n1
        with open(os.path.join(d, "trace.json")) as f:
            trace = json.load(f)
    if traced_solves != bare_solves:
        raise jl.InvariantViolation(
            f"tracing changed the level-solve count: {bare_solves} bare "
            f"vs {traced_solves} traced")
    if not np.array_equal(np.asarray(model_a.coef),
                          np.asarray(model_b.coef)):
        raise jl.InvariantViolation(
            "model fitted under tracker+trace_dir differs bitwise from "
            "the bare fit")
    events = trace["traceEvents"]
    fits = [e for e in events if e["name"] == "fit"]
    lvls = [e for e in events if e["name"] == "cascade.level"]
    if len(fits) != 1 or not lvls:
        raise jl.InvariantViolation(
            f"expected 1 fit span and >=1 cascade.level spans, got "
            f"{len(fits)}/{len(lvls)}")
    f0 = fits[0]
    for e in lvls:
        if not (f0["ts"] <= e["ts"]
                and e["ts"] + e["dur"] <= f0["ts"] + f0["dur"]):
            raise jl.InvariantViolation(
                "cascade.level span not contained in the fit span")

    # (c) dsvrg trace-once survives tracing: a warm re-fit with trace_dir
    # must add zero epoch-scan traces
    lproblem = ProblemSpec(kernel=kf.KernelSpec(name="linear"))
    lcfg = _route_cfg("dsvrg")
    lcfg = dc.replace(lcfg, dsvrg=dc.replace(lcfg.dsvrg, epochs=2))
    traces = counter("dsvrg.epoch_trace")
    ODMEstimator(lproblem, route="dsvrg", cfg=lcfg).fit(x, y, key)  # warm
    n2 = traces.count
    with tempfile.TemporaryDirectory() as d:
        ODMEstimator(lproblem, route="dsvrg", cfg=lcfg).fit(
            x, y, key, trace_dir=d)
    if traces.count != n2:
        raise jl.InvariantViolation(
            f"trace_dir fit retraced the dsvrg epoch scan "
            f"({traces.count - n2} new traces)")
    return ("observe: off-path is the shared no-op; traced sodm fit is "
            "bitwise equal with equal launches and nested spans; dsvrg "
            "stays trace-once")


def _data_stream_loader():
    """The out-of-core data plane's contract: (a) slab contents are a
    pure function of the rows, bitwise invariant to how the source is
    sharded; (b) every shard is read exactly once per pass and the rows
    counter/depth gauge account truthfully (depth never exceeds the
    configured bound); (c) the byte accountant's peak stays below the
    dataset size for a multi-shard source (the loader never materializes
    the whole set); (d) a kill at the ``data.prefetch`` site surfaces
    out of the consuming iteration as Preemption."""
    import numpy as np

    from repro.data import streaming as ds
    from repro.distributed import faults as fm
    from repro.observe import MetricsRegistry

    rng = np.random.default_rng(0)
    M, d, slab = 96, 5, 32
    x = rng.normal(size=(M, d)).astype(np.float32)
    y = np.where(rng.random(M) < 0.5, -1.0, 1.0).astype(np.float32)

    def slabs(shard_rows):
        src = ds.ArraySource(x, y, shard_rows=shard_rows)
        acct = ds.ByteAccountant()
        mets = MetricsRegistry()
        out = [(np.asarray(s.x).copy(), np.asarray(s.y).copy(), s.n_valid)
               for s in ds.iter_slabs(src, slab, depth=2, metrics=mets,
                                      executor=ds.SerialExecutor(),
                                      accountant=acct)]
        return src, acct, mets, out

    src_a, acct, mets, a = slabs(16)
    _, _, _, b = slabs(24)          # misaligned: shards straddle slabs
    for (xa, ya, na), (xb, yb, nb) in zip(a, b, strict=True):
        if not (np.array_equal(xa, xb) and np.array_equal(ya, yb)
                and na == nb):
            raise jl.InvariantViolation(
                "slab contents depend on the shard layout — streaming "
                "results would not be reproducible across re-sharding")
    if src_a.reads != [1] * len(src_a.reads):
        raise jl.InvariantViolation(
            f"one pass must read each shard exactly once: {src_a.reads}")
    snap = mets.snapshot()
    if snap.get("data.rows.count") != M:
        raise jl.InvariantViolation(
            f"rows counter lies: {snap.get('data.rows.count')} != {M}")
    if snap.get("data.prefetch.depth.max", 0) > 2:
        raise jl.InvariantViolation(
            f"prefetch queue exceeded its depth bound: "
            f"{snap['data.prefetch.depth.max']} > 2")
    if snap.get("data.shard.read_s.count") != len(src_a.reads):
        raise jl.InvariantViolation(
            f"shard-read histogram count "
            f"{snap.get('data.shard.read_s.count')} != shard count")
    if not 0 < acct.peak < src_a.total_bytes:
        raise jl.InvariantViolation(
            f"accountant peak {acct.peak} not inside (0, "
            f"{src_a.total_bytes}) — the loader materialized the set")
    plan = fm.FaultPlan().kill("data.prefetch", shard=2)
    src_c = ds.ArraySource(x, y, shard_rows=16)
    try:
        for _ in ds.iter_slabs(src_c, slab, faults=plan,
                               executor=ds.SerialExecutor()):
            pass
    except fm.Preemption as e:
        if e.info.get("shard") != 2:
            raise jl.InvariantViolation(f"kill struck shard {e.info}")
    else:
        raise jl.InvariantViolation(
            "a data.prefetch kill never surfaced from the iteration")
    return ("data: slabs layout-invariant, single-read passes, honest "
            "gauges, bounded resident bytes, kills propagate")


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------

def _declare_builtins() -> None:
    kern = [
        ("kernels.gram.single_launch", "gram",
         "one pallas_call per Gram build", _gram_single_launch),
        ("kernels.gram.vmem_plan", "gram",
         "default (and laplacian) tile plans fit the VMEM budget",
         _gram_vmem),
        ("kernels.gram_matvec.single_launch", "gram_matvec",
         "one pallas_call for all K partition matvecs",
         _gram_matvec_single_launch),
        ("kernels.gram_matvec.vmem_plan", "gram_matvec",
         "default tile plan fits the VMEM budget", _gram_matvec_vmem),
        ("kernels.score.single_launch", "score",
         "one pallas_call per request batch", _score_single_launch),
        ("kernels.score.gather_free", "score",
         "served score paths contain no gather and no host sync",
         _score_gather_free),
        ("kernels.score.vmem_plan", "score",
         "default tile plan fits the VMEM budget", _score_vmem),
        ("kernels.odm_grad.single_launch", "odm_grad",
         "full primal gradient in one pallas_call",
         _odm_grad_single_launch),
        ("kernels.odm_grad.vmem_plan", "odm_grad",
         "_shrink_bm keeps every feature width inside the VMEM budget",
         _odm_grad_vmem),
        ("kernels.odm_svrg_grad.single_launch", "odm_svrg_grad",
         "one pallas_call per DSVRG inner step", _svrg_grad_single_launch),
        ("kernels.odm_svrg_grad.vmem_plan", "odm_svrg_grad",
         "default tile plan fits the VMEM budget", _svrg_grad_vmem),
        ("kernels.fused_cd.single_launch", "fused_cd",
         "one pallas_call per fused sweep, dense and matrix-free",
         _fused_cd_single_launch),
        ("kernels.fused_cd.vmem_plan", "fused_cd",
         "default plans (both sources) fit the VMEM budget",
         _fused_cd_vmem),
        ("kernels.fused_cd.vmem_ceiling", "fused_cd",
         "the m=10^6 partition-resident u_d plan is REJECTED at plan "
         "time with a sizing report", _fused_cd_vmem_ceiling),
    ]
    for name, subject, desc, fn in kern:
        declare(Invariant(name=name, subject=subject, kind="kernel",
                          description=desc, verify=fn))

    for route in ("sodm", "dsvrg", "cascade", "dip", "dc", "svrg",
                  "csvrg"):
        declare(Invariant(
            name=f"routes.{route}.facade_artifact", subject=route,
            kind="route",
            description="ODMEstimator.fit returns a FittedODM with no "
                        "legacy FutureWarning",
            verify=_make_facade_invariant(route)))

    declare(Invariant(
        name="routes.sodm.predict_gather_once", subject="sodm",
        kind="route",
        description="one perm gather per fitted model, zero per predict",
        verify=_sodm_gather_once))
    declare(Invariant(
        name="routes.dsvrg.trace_once", subject="dsvrg", kind="route",
        description="identical re-solve adds zero jit traces",
        verify=_dsvrg_trace_once))
    declare(Invariant(
        name="routes.dsvrg.epoch_scan_shape", subject="dsvrg",
        kind="route",
        description="one epoch scan, no collectives/host-sync in loop "
                    "bodies of the local driver",
        verify=_dsvrg_epoch_scan_shape))
    declare(Invariant(
        name="routes.dsvrg.sharded_gather_hoisted", subject="dsvrg",
        kind="route", slow=True,
        description="serial-schedule slab all-gather count in compiled "
                    "HLO is epoch-count-invariant (hoisted above the "
                    "scan)",
        verify=_dsvrg_sharded_gather_hoisted))

    comp = [
        ("components.faults.deterministic_replay", "faults",
         "fault plans replay deterministically; kills raise, delays "
         "return seconds, counts spend", _faults_deterministic_replay),
        ("components.checkpoint.crash_window", "checkpoint",
         "a kill in the write/rename window keeps the previous step "
         "loadable and the orphan is GC'd on the next save",
         _checkpoint_crash_window),
        ("components.resume.cascade_bit_identical", "resume",
         "kill-mid-cascade + fit(resume=) is bit-identical with fewer "
         "level solves than a cold restart",
         _resume_cascade_bit_identical),
        ("components.resume.dsvrg_segments", "resume",
         "dsvrg segment checkpoints make killed+resumed bitwise equal "
         "to the uninterrupted segmented run", _resume_dsvrg_segments),
        ("components.tracker.level_stream", "tracker",
         "per-level KKT/sweeps/SV/throughput records + fit summary; "
         "jsonl backend is torn-tail-safe", _tracker_level_stream),
        ("components.observe.zero_cost_off", "observe",
         "spans/instruments are no-ops when off; tracing a fit keeps it "
         "bitwise identical, launch-for-launch, and dsvrg trace-once",
         _observe_zero_cost_off),
        ("components.data.stream_loader", "data",
         "slabs are bitwise layout-invariant; one read per shard per "
         "pass; depth/rows instruments honest; resident bytes bounded "
         "below the dataset; prefetch kills propagate",
         _data_stream_loader),
    ]
    for name, subject, desc, fn in comp:
        declare(Invariant(name=name, subject=subject, kind="component",
                          description=desc, verify=fn))


_declare_builtins()
