"""AST lint of repo conventions — stdlib-only, fast enough for CI.

Four rules, each encoding a convention this repo adopted in a specific
PR and has no other machine check for:

* **F001 facade boundary** (PR 5's acceptance rule): outside
  ``src/repro`` internals, training goes through
  ``repro.api.ODMEstimator`` — never the legacy module entry points
  (``sodm.solve/solve_sharded/fit/predict``, ``dsvrg.solve/
  solve_sharded``, ``baselines.*_solve``). Those shims exist for
  back-compat tests only; a benchmark or example calling one silently
  bypasses validation, the registry, and the serving artifact.
* **T001 tile/step literals**: tiling and step knobs (``bm``/``bn``/
  ``bd``/``bt``/``bs``/``bq``/``bk``/``block``/``eta``) are config, not
  call-site magic numbers. A numeric literal bound to one of these
  kwargs at a call site is flagged — EXCEPT when the callee is a config
  constructor (name ending in ``Config``/``Params``/``Spec``) or
  ``dataclasses.replace``, which are exactly where such values belong.
  Function-def defaults are inherently exempt (they ARE the config).
* **W001 warn-once shims**: inside ``src/repro``, deprecation warnings
  go through ``core.deprecation.warn_once`` (one FutureWarning per
  process), never raw ``warnings.warn(..., FutureWarning)`` — a shim on
  a hot path must not warn per call.
* **P001 pallas containment**: ``jax.experimental.pallas`` imports live
  only under ``src/repro/kernels/`` — every other layer consumes kernels
  through ``repro.kernels.ops`` so interpret-mode policy and padding
  stay in one place.

Suppression: append ``# lint: ignore[CODE]`` to a line, or put
``# lint: allow[CODE]`` anywhere in a file to waive that rule file-wide.
``scripts/lint.py`` is the CLI; ``tests/test_analysis.py`` pins that the
seeded fixtures under ``tests/fixtures/lint/`` fail and the real tree
passes.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, Iterator

__all__ = ["LintViolation", "lint_file", "lint_paths", "walk_default",
           "RULES", "TILE_KNOBS", "LEGACY_ENTRY_POINTS"]

#: tiling/step kwargs that must come from config, not call-site literals
TILE_KNOBS = frozenset({"bm", "bn", "bd", "bt", "bs", "bq", "bk",
                        "block", "eta"})

#: legacy attribute entry points per module alias target (F001)
LEGACY_ENTRY_POINTS = {
    "repro.core.sodm": {"solve", "solve_sharded", "fit", "predict"},
    "repro.core.dsvrg": {"solve", "solve_sharded"},
}
_BASELINES_MOD = "repro.core.baselines"

#: callee names whose keywords ARE configuration (T001 exemption)
_CONFIG_CALL_RE = re.compile(r"(Config|Params|Spec)$|^replace$|^create$")

RULES = {
    "F001": "legacy solver entry point called outside src/repro — use "
            "repro.api.ODMEstimator",
    "T001": "hardcoded tile/step size at a call site — move it into a "
            "config dataclass",
    "W001": "raw FutureWarning/DeprecationWarning in src/repro — use "
            "core.deprecation.warn_once",
    "P001": "pallas import outside src/repro/kernels/ — consume kernels "
            "via repro.kernels.ops",
}

_IGNORE_RE = re.compile(r"#\s*lint:\s*ignore\[([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)\]")
_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\[([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)\]")


@dataclasses.dataclass(frozen=True)
class LintViolation:
    file: str
    line: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: {self.code} {self.message}"


def _codes(match: re.Match) -> set[str]:
    return {c.strip() for c in match.group(1).split(",")}


def _suppressions(source: str) -> tuple[dict[int, set[str]], set[str]]:
    per_line: dict[int, set[str]] = {}
    per_file: set[str] = set()
    for i, text in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(text)
        if m:
            per_line.setdefault(i, set()).update(_codes(m))
        m = _ALLOW_RE.search(text)
        if m:
            per_file.update(_codes(m))
    return per_line, per_file


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for an Attribute/Name chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, in_repro: bool, in_kernels: bool,
                 is_deprecation_mod: bool):
        self.path = path
        self.in_repro = in_repro
        self.in_kernels = in_kernels
        self.is_deprecation_mod = is_deprecation_mod
        # local alias -> fully qualified module (F001 tracking)
        self.aliases: dict[str, str] = {}
        # names imported directly from a legacy module: name -> (mod, attr)
        self.direct: dict[str, tuple[str, str]] = {}
        self.out: list[tuple[int, str, str]] = []

    # -- import tracking / P001 -------------------------------------------

    def _note_module(self, fq: str, asname: str, lineno: int) -> None:
        if "pallas" in fq.split(".") and not self.in_kernels:
            self.out.append((lineno, "P001",
                             f"import of {fq!r}: {RULES['P001']}"))
        self.aliases[asname] = fq

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self._note_module(a.name, a.asname or a.name.split(".")[0],
                              node.lineno)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if node.level == 0:
            if "pallas" in mod.split(".") and not self.in_kernels:
                self.out.append((node.lineno, "P001",
                                 f"import from {mod!r}: {RULES['P001']}"))
            for a in node.names:
                fq = f"{mod}.{a.name}" if mod else a.name
                name = a.asname or a.name
                if "pallas" in fq.split(".") and not self.in_kernels:
                    self.out.append((node.lineno, "P001",
                                     f"import of {fq!r}: {RULES['P001']}"))
                # `from repro.core import sodm` binds a legacy module...
                if fq in LEGACY_ENTRY_POINTS or fq == _BASELINES_MOD:
                    self.aliases[name] = fq
                # ...while `from repro.core.sodm import solve` binds the
                # entry point itself
                if (mod in LEGACY_ENTRY_POINTS
                        and a.name in LEGACY_ENTRY_POINTS[mod]):
                    self.direct[name] = (mod, a.name)
                if (mod == _BASELINES_MOD and a.name.endswith("_solve")
                        and not a.name.startswith("_")):
                    self.direct[name] = (mod, a.name)
        self.generic_visit(node)

    # -- call-site rules ---------------------------------------------------

    def _check_facade(self, node: ast.Call) -> None:
        if self.in_repro:
            return
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id in self.direct:
            mod, attr = self.direct[fn.id]
            self.out.append((node.lineno, "F001",
                             f"call to {mod}.{attr}: {RULES['F001']}"))
            return
        if isinstance(fn, ast.Attribute):
            base = _dotted(fn.value)
            if base is None:
                return
            target = self.aliases.get(base, base)
            legacy = LEGACY_ENTRY_POINTS.get(target)
            if legacy is not None and fn.attr in legacy:
                self.out.append((node.lineno, "F001",
                                 f"call to {target}.{fn.attr}: "
                                 f"{RULES['F001']}"))
            elif (target == _BASELINES_MOD and fn.attr.endswith("_solve")
                  and not fn.attr.startswith("_")):
                self.out.append((node.lineno, "F001",
                                 f"call to {target}.{fn.attr}: "
                                 f"{RULES['F001']}"))

    def _check_tile_literals(self, node: ast.Call) -> None:
        callee = None
        if isinstance(node.func, ast.Name):
            callee = node.func.id
        elif isinstance(node.func, ast.Attribute):
            callee = node.func.attr
        if callee is not None and _CONFIG_CALL_RE.search(callee):
            return
        for kw in node.keywords:
            if kw.arg in TILE_KNOBS and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, (int, float)) \
                    and not isinstance(kw.value.value, bool):
                self.out.append((kw.value.lineno, "T001",
                                 f"{kw.arg}={kw.value.value!r} passed to "
                                 f"{callee or 'a call'}(): "
                                 f"{RULES['T001']}"))

    def _check_warn(self, node: ast.Call) -> None:
        if not self.in_repro or self.is_deprecation_mod:
            return
        fn = _dotted(node.func)
        if fn not in ("warnings.warn", "warn"):
            return
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            name = _dotted(arg)
            if name in ("FutureWarning", "DeprecationWarning"):
                self.out.append((node.lineno, "W001", RULES["W001"]))
                return

    def visit_Call(self, node: ast.Call) -> None:
        self._check_facade(node)
        self._check_tile_literals(node)
        self._check_warn(node)
        self.generic_visit(node)


def _classify(path: str) -> tuple[bool, bool, bool]:
    norm = path.replace(os.sep, "/")
    in_repro = "src/repro/" in norm or norm.startswith("repro/")
    in_kernels = "repro/kernels/" in norm
    is_dep = norm.endswith("repro/core/deprecation.py")
    return in_repro, in_kernels, is_dep


def lint_file(path: str, source: str | None = None) -> list[LintViolation]:
    """Lint one file; returns violations after pragma suppression."""
    if source is None:
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [LintViolation(file=path, line=e.lineno or 0, code="E999",
                              message=f"syntax error: {e.msg}")]
    per_line, per_file = _suppressions(source)
    in_repro, in_kernels, is_dep = _classify(path)
    visitor = _Visitor(path, in_repro, in_kernels, is_dep)
    visitor.visit(tree)
    out = []
    for line, code, msg in visitor.out:
        if code in per_file or code in per_line.get(line, set()):
            continue
        out.append(LintViolation(file=path, line=line, code=code,
                                 message=msg))
    return sorted(out, key=lambda v: (v.file, v.line, v.code))


def walk_default(root: str) -> list[str]:
    """The default lint scope: src, benchmarks, examples, scripts —
    everything that ships; tests (and their seeded fixtures) opt in via
    explicit arguments."""
    files: list[str] = []
    for sub in ("src", "benchmarks", "examples", "scripts"):
        base = os.path.join(root, sub)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            files.extend(os.path.join(dirpath, f)
                         for f in sorted(filenames) if f.endswith(".py"))
    return sorted(files)


def lint_paths(paths: Iterable[str]) -> list[LintViolation]:
    out: list[LintViolation] = []
    for p in paths:
        out.extend(lint_file(p))
    return out
