"""Metric instruments: counters, gauges, fixed-bucket histograms.

Dependency-free (stdlib only, no jax import) so the serving hot path can
feed instruments without touching device state. Three instrument kinds:

* :class:`Counter` — monotone event count (requests served, level solves
  launched);
* :class:`Gauge` — last-value-wins scalar (queue depth, current eta);
* :class:`Histogram` — fixed bucket boundaries for cheap distribution
  summaries PLUS the raw observations, so ``p50/p95/p99`` are the exact
  nearest-rank percentiles rather than bucket-midpoint estimates. The
  raw store is capped (``max_samples``, default 65536) with
  skip-the-oldest downsampling beyond the cap.

:class:`MetricsRegistry` is the instrument namespace. It is itself a
:class:`repro.observe.tracker.Tracker` (``log_metrics`` observes every
numeric value into the histogram of the same name), so it composes with
the existing backends — ``CompositeTracker([JsonlTracker(...),
MetricsRegistry()])`` persists the raw stream AND accumulates
distributions — and it *drains* back through the protocol:
``registry.drain(tracker, step)`` emits one flat snapshot record
(``<name>.count``, ``<name>.p99``, ...) to any backend, jsonl and
in-memory included, unchanged. ``snapshot(include_counters=True)`` folds
in the process-wide :mod:`repro.analysis.invariants` counters (pallas
launch counts, level solves, perm gathers), which is how the cascade's
launch accounting reaches the metrics trail without new plumbing.

The shared :func:`percentile` helper is THE nearest-rank definition used
by both the histograms and ``serve.serve_stream`` — the old
``lat[n // 2]`` / ``int(n * 0.95)`` indexing was off-by-one at even and
small n (for n=4, ``lat[2]`` is the 75th percentile, not the median).
"""
from __future__ import annotations

import bisect
import threading
from typing import Mapping, Sequence

__all__ = ["percentile", "Counter", "Gauge", "Histogram",
           "MetricsRegistry", "DEFAULT_BUCKETS"]


def percentile(values: Sequence[float], q: float) -> float:
    """Exact nearest-rank percentile (no interpolation).

    ``q`` in [0, 100]. The nearest-rank definition: the smallest value
    with at least ``ceil(q/100 * n)`` observations at or below it —
    index ``ceil(q/100 * n) - 1`` of the sorted sample, clamped to the
    valid range (q=0 gives the minimum, q=100 the maximum). Sorts a copy
    when the input is unsorted; callers holding an already-sorted list
    pass it straight through cheaply.
    """
    n = len(values)
    if n == 0:
        raise ValueError("percentile of an empty sequence")
    if not 0 <= q <= 100:
        raise ValueError(f"q must be in [0, 100], got {q}")
    vals = list(values)
    if any(vals[i] > vals[i + 1] for i in range(n - 1)):
        vals.sort()
    rank = -(-q * n // 100)            # ceil(q/100 * n) in exact int math
    return vals[max(0, min(n - 1, int(rank) - 1))]


class Counter:
    """Monotone event counter."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self) -> dict:
        return {f"{self.name}.count": self.value}


class Gauge:
    """Last-value-wins scalar with min/max watermarks."""

    def __init__(self, name: str):
        self.name = name
        self.value: float | None = None
        self.min: float | None = None
        self.max: float | None = None
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.value = v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)

    def snapshot(self) -> dict:
        if self.value is None:
            return {}
        return {self.name: self.value, f"{self.name}.min": self.min,
                f"{self.name}.max": self.max}


#: default boundaries — exponential, covering 100µs .. ~100s latencies
#: and small-integer depths/counts alike
DEFAULT_BUCKETS = tuple(10.0 ** (e / 2) for e in range(-8, 5))


class Histogram:
    """Fixed-bucket histogram with exact percentile readout.

    ``buckets`` are the upper bounds of the counting buckets (a final
    +inf bucket is implicit). ``observe`` is O(log buckets); the raw
    sample store backing the exact percentiles is capped at
    ``max_samples`` by keeping every k-th observation once full (the
    bucket counts always remain exact).
    """

    def __init__(self, name: str, buckets: Sequence[float] = DEFAULT_BUCKETS,
                 max_samples: int = 65536):
        self.name = name
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)
        self.n = 0
        self.sum = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self.max_samples = max_samples
        self.samples: list[float] = []
        self._stride = 1
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect.bisect_left(self.buckets, v)] += 1
            self.n += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            if self.n % self._stride == 0:
                self.samples.append(v)
                if len(self.samples) >= self.max_samples:
                    # halve the resident sample set, double the stride
                    self.samples = self.samples[::2]
                    self._stride *= 2

    def percentile(self, q: float) -> float:
        with self._lock:
            sample = list(self.samples)
        return percentile(sample, q)

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0

    def snapshot(self) -> dict:
        if self.n == 0:
            return {f"{self.name}.count": 0}
        return {
            f"{self.name}.count": self.n,
            f"{self.name}.mean": self.mean,
            f"{self.name}.min": self.min,
            f"{self.name}.max": self.max,
            f"{self.name}.p50": self.percentile(50),
            f"{self.name}.p95": self.percentile(95),
            f"{self.name}.p99": self.percentile(99),
        }


class MetricsRegistry:
    """Get-or-create namespace of instruments; a draining Tracker backend.

    As a tracker (``log_metrics``): every numeric metric value is
    observed into the histogram of the same name, so wiring a registry
    into ``ODMEstimator.fit(tracker=...)`` — alone or inside a
    ``CompositeTracker`` — accumulates per-level solve-time / KKT /
    throughput distributions for free.

    As a source (``drain``): one flat snapshot of every instrument is
    emitted through any other tracker, which is how histogram
    percentiles reach jsonl files and ``BENCH_*.json`` records.
    """

    def __init__(self):
        self._instruments: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, *args, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = cls(name, *args, **kw)
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"instrument {name!r} already exists as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, buckets)

    def instruments(self) -> dict[str, object]:
        with self._lock:
            return dict(self._instruments)

    # -- Tracker protocol (accumulating backend) ----------------------------

    def log_metrics(self, step: int, metrics: Mapping[str, object]) -> None:
        del step
        for k, v in metrics.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            self.histogram(k).observe(v)

    # -- draining -----------------------------------------------------------

    def snapshot(self, include_counters: bool = False) -> dict:
        """One flat {name.stat: value} dict over every instrument.

        ``include_counters=True`` folds in the process-wide
        :mod:`repro.analysis.invariants` counters as
        ``counter.<name>.count`` — launch counts, level solves, perm
        gathers — so a drained record carries the structural accounting
        next to the latency distributions.
        """
        out: dict[str, object] = {}
        for inst in self.instruments().values():
            out.update(inst.snapshot())
        if include_counters:
            from repro.analysis import invariants as inv
            for name, c in inv.counters().items():
                out[f"counter.{name}.count"] = c.count
        return out

    def drain(self, tracker, step: int = 0, *,
              include_counters: bool = False) -> dict:
        """Emit :meth:`snapshot` through ``tracker.log_metrics`` (any
        backend of the Tracker protocol); returns the snapshot."""
        snap = self.snapshot(include_counters=include_counters)
        tracker.log_metrics(step, snap)
        return snap
