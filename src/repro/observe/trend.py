"""Perf trajectory: load ``BENCH_*.json`` runs, compare against baselines.

``benchmarks/run.py --out-dir D`` persists one ``BENCH_<name>.json`` per
bench (wall-clock, peak bytes, device kind, output lines, optional
histogram metrics). This module turns a directory of those records into
a *trajectory* and a *gate*:

* :func:`load_dir` — ``{bench_name: record}`` for every BENCH file in a
  directory (schema versions 1 and 2);
* :func:`compare` / :func:`compare_dirs` — current run vs a committed
  baseline, flagging wall-clock and peak-bytes regressions beyond a
  noise band;
* ``scripts/bench_gate.py`` — the CI entry point that exits nonzero on
  any regression, so every PR both leaves a machine-readable perf trail
  and is checked against the last one.

Noise policy: wall clocks are machine- and load-dependent, so a
regression needs BOTH a relative excess (``wall_rtol``, default 1.0 =
2x the baseline) and an absolute excess (``wall_floor_s``) — a 30 ms
quick bench jittering to 70 ms is noise, a 30 s bench hitting 70 s is
not. When the current record's backend/device differs from the
baseline's, timing comparisons are demoted to warnings (cross-hardware
wall clocks are not comparable); structural fields (rows present, bench
still emitted) are always enforced. A bench present in the baseline but
missing from the current run is a failure — a perf trail that silently
goes dark is how trajectories become empty again.

No jax import: the gate must run on any CI box before (or without) the
heavyweight deps.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

__all__ = ["load_bench", "load_dir", "Finding", "compare", "compare_dirs",
           "format_report", "WALL_RTOL", "WALL_FLOOR_S", "BYTES_RTOL",
           "BYTES_FLOOR"]

#: default noise bands (see module docstring); the gate CLI overrides
WALL_RTOL = 1.0          # fail past (1 + rtol) x baseline == 2x
WALL_FLOOR_S = 0.25      # ... and at least this much absolute excess
BYTES_RTOL = 0.25        # peak bytes are deterministic-ish: tighter band
BYTES_FLOOR = 1 << 20    # 1 MiB absolute slack


def load_bench(path: str | os.PathLike) -> dict:
    """Load one BENCH_*.json record (schema 1 or 2)."""
    with open(path) as f:
        rec = json.load(f)
    ver = rec.get("schema_version")
    if ver not in (1, 2):
        raise ValueError(f"{path}: unknown BENCH schema_version {ver!r}")
    return rec


def load_dir(directory: str | os.PathLike) -> dict[str, dict]:
    """All ``BENCH_<name>.json`` records in ``directory``, by bench name."""
    out: dict[str, dict] = {}
    for path in sorted(glob.glob(
            os.path.join(os.fspath(directory), "BENCH_*.json"))):
        rec = load_bench(path)
        out[rec["bench"]] = rec
    return out


@dataclasses.dataclass(frozen=True)
class Finding:
    """One comparison outcome. ``level`` is 'ok' | 'warn' | 'fail'."""

    bench: str
    field: str
    level: str
    baseline: float | None
    current: float | None
    detail: str

    @property
    def regressed(self) -> bool:
        return self.level == "fail"


def _ratio(cur: float, base: float) -> str:
    if base <= 0:
        return "n/a"
    return f"{cur / base:.2f}x"


def compare(current: dict, baseline: dict, *, wall_rtol: float = WALL_RTOL,
            wall_floor_s: float = WALL_FLOOR_S,
            bytes_rtol: float = BYTES_RTOL,
            bytes_floor: int = BYTES_FLOOR) -> list[Finding]:
    """Compare one current record against its baseline record."""
    name = current["bench"]
    out: list[Finding] = []
    same_hw = (current.get("backend") == baseline.get("backend")
               and current.get("device_kind") == baseline.get("device_kind"))

    def check(field: str, cur, base, rtol: float, floor: float,
              unit: str) -> None:
        if base is None or cur is None:
            return
        excess = cur - base * (1.0 + rtol)
        over = excess > 0 and (cur - base) > floor
        if not over:
            out.append(Finding(name, field, "ok", base, cur,
                               f"{cur:.4g}{unit} vs {base:.4g}{unit} "
                               f"({_ratio(cur, base)})"))
            return
        level = "fail" if same_hw else "warn"
        why = "" if same_hw else \
            (f" [hardware differs: {baseline.get('backend')}/"
             f"{baseline.get('device_kind')} -> {current.get('backend')}/"
             f"{current.get('device_kind')}; timing demoted to warning]")
        out.append(Finding(
            name, field, level, base, cur,
            f"{cur:.4g}{unit} vs baseline {base:.4g}{unit} "
            f"({_ratio(cur, base)}, band {1 + rtol:.2f}x + {floor:g}{unit})"
            f"{why}"))

    check("wall_clock_s", current.get("wall_clock_s"),
          baseline.get("wall_clock_s"), wall_rtol, wall_floor_s, "s")
    base_pb = baseline.get("peak_bytes") or 0
    cur_pb = current.get("peak_bytes") or 0
    if base_pb > 0 and cur_pb > 0:        # 0 = backend exposes no stats
        check("peak_bytes", float(cur_pb), float(base_pb), bytes_rtol,
              float(bytes_floor), "B")
    # histogram-derived latency percentiles (schema 2), same noise policy
    # as wall clock — they are wall clocks
    cur_m = current.get("metrics") or {}
    base_m = baseline.get("metrics") or {}
    for key in sorted(set(cur_m) & set(base_m)):
        if key.rsplit(".", 1)[-1] in ("p50", "p95", "p99", "mean"):
            check(f"metrics.{key}", cur_m[key], base_m[key], wall_rtol,
                  wall_floor_s, "s")
    if current.get("rows", 0) <= 0:
        out.append(Finding(name, "rows", "fail", baseline.get("rows"),
                           current.get("rows"),
                           "current run emitted no output lines"))
    return out


def compare_dirs(current_dir: str | os.PathLike,
                 baseline_dir: str | os.PathLike,
                 **kw) -> list[Finding]:
    """Compare every baseline bench against the current run's record."""
    current = load_dir(current_dir)
    baseline = load_dir(baseline_dir)
    if not baseline:
        raise FileNotFoundError(
            f"no BENCH_*.json baselines under {baseline_dir!r}")
    out: list[Finding] = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            out.append(Finding(name, "presence", "fail", None, None,
                               "bench in baseline but missing from the "
                               "current run (perf trail went dark)"))
            continue
        out.extend(compare(cur, base, **kw))
    for name in sorted(set(current) - set(baseline)):
        out.append(Finding(name, "presence", "warn", None, None,
                           "new bench with no committed baseline — add "
                           "one under benchmarks/baselines/"))
    return out


def format_report(findings: list[Finding]) -> str:
    """Human-readable gate report, failures first."""
    order = {"fail": 0, "warn": 1, "ok": 2}
    lines = [f"bench gate: {sum(f.regressed for f in findings)} "
             f"regression(s) in {len(findings)} comparison(s)"]
    for f in sorted(findings, key=lambda f: (order[f.level], f.bench,
                                             f.field)):
        lines.append(f"  [{f.level.upper():4s}] {f.bench}.{f.field}: "
                     f"{f.detail}")
    return "\n".join(lines)
