"""Hierarchical span tracing exported as Chrome-trace / Perfetto JSON.

``span(name, **attrs)`` is a context manager that times a host-side
region of the training or serving path and records it as a complete
("ph": "X") Chrome trace event. Spans nest naturally: each event carries
its thread id and microsecond (ts, dur), and the Perfetto / chrome://
tracing UIs reconstruct the hierarchy by containment per thread — the
cascade's ``fit -> route -> cascade.level`` stack and the server's
``serve.request_batch -> serve.score`` stack need no explicit parent
pointers.

Zero cost when off: with no recorder installed, ``span()`` returns a
shared no-op context manager — no allocation beyond the call, no
timestamps, no locks — so production paths keep the instrumentation
inline unconditionally. The recorder is installed process-wide
(:func:`trace_ctx` / :func:`install`) rather than thread-locally because
instrumented regions span worker threads (the straggler scheduler's
partition attempts, the checkpoint writer); per-thread *nesting* comes
from the per-event ``tid``.

The export sits next to the ``jax.profiler`` traces
(:func:`repro.observe.profiler.profile_ctx`): the profiler sees device
ops, these spans see the host-side orchestration — levels, segments,
checkpoint commits, request batches — that the device timeline cannot
name.
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["Span", "SpanRecorder", "span", "trace_ctx", "install",
           "current_recorder"]


class _NoopSpan:
    """Shared do-nothing context manager returned when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()

#: the process-wide recorder; None means tracing is off (the fast path)
_ACTIVE: "SpanRecorder | None" = None


class Span:
    """One in-flight span; records itself into the recorder on exit."""

    __slots__ = ("recorder", "name", "attrs", "t0")

    def __init__(self, recorder: "SpanRecorder", name: str, attrs: dict):
        self.recorder = recorder
        self.name = name
        self.attrs = attrs
        self.t0 = 0

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self.recorder.add_span(self.name, self.t0 / 1e3,
                               (t1 - self.t0) / 1e3,
                               tid=threading.get_ident(), **self.attrs)
        return False


class SpanRecorder:
    """Collects finished spans as Chrome trace events (thread-safe)."""

    def __init__(self):
        self._events: list[dict] = []
        self._lock = threading.Lock()

    def add_span(self, name: str, ts_us: float, dur_us: float, *,
                 tid: int | str = 0, **attrs) -> None:
        """Append one complete event. ``ts_us``/``dur_us`` are
        microseconds on any monotonic clock base (real spans use
        ``perf_counter``; virtual-clock replays may supply their own)."""
        event = {"name": name, "ph": "X", "ts": ts_us, "dur": dur_us,
                 "pid": os.getpid(), "tid": tid}
        if attrs:
            event["args"] = {k: _jsonable(v) for k, v in attrs.items()}
        with self._lock:
            self._events.append(event)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def spans(self, name: str | None = None) -> list[dict]:
        """Recorded events, optionally filtered by span name."""
        evs = self.events()
        return evs if name is None else [e for e in evs
                                         if e["name"] == name]

    def to_chrome_trace(self) -> dict:
        """The Chrome trace JSON object (load in Perfetto / about:tracing)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str | os.PathLike) -> str:
        """Write the trace JSON; parent directories are created."""
        path = os.fspath(path)
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        os.replace(tmp, path)
        return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool, type(None))):
        return v
    try:
        return float(v)            # jnp/np scalars
    except (TypeError, ValueError):
        return repr(v)


def span(name: str, **attrs):
    """Time a host-side region when a recorder is installed; otherwise a
    shared no-op (the zero-cost-when-off contract)."""
    rec = _ACTIVE
    if rec is None:
        return _NOOP
    return Span(rec, name, attrs)


def current_recorder() -> SpanRecorder | None:
    return _ACTIVE


class install:
    """Install ``recorder`` process-wide for the ``with`` block.

    Re-entrant in the stacking sense: the previous recorder (usually
    None) is restored on exit, so an outer fit trace survives an inner
    scoped one.
    """

    def __init__(self, recorder: SpanRecorder):
        self.recorder = recorder
        self._prev: SpanRecorder | None = None

    def __enter__(self) -> SpanRecorder:
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self.recorder
        return self.recorder

    def __exit__(self, *exc):
        global _ACTIVE
        _ACTIVE = self._prev
        return False


class trace_ctx:
    """Record spans for the block and export ``<trace_dir>/trace.json``.

    No-op when ``trace_dir`` is None (mirrors ``profile_ctx``), so call
    sites can take a ``trace_dir=`` kwarg without branching. The export
    happens even if the block raises — a preempted fit still leaves its
    partial trace on disk.
    """

    FILENAME = "trace.json"

    def __init__(self, trace_dir: str | os.PathLike | None):
        self.trace_dir = trace_dir
        self.recorder: SpanRecorder | None = None
        self._install: install | None = None

    def __enter__(self) -> SpanRecorder | None:
        if self.trace_dir is None:
            return None
        self.recorder = SpanRecorder()
        self._install = install(self.recorder)
        self._install.__enter__()
        return self.recorder

    def __exit__(self, *exc):
        if self._install is not None:
            self._install.__exit__(*exc)
            self.recorder.export(
                os.path.join(os.fspath(self.trace_dir), self.FILENAME))
        return False
