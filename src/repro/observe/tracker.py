"""Metric trackers: the ``log_metrics(step, dict)`` protocol + backends.

Design follows levanter's tracker abstraction: trainers emit flat
``{name: scalar}`` dicts at integer steps and never know where they go.
Backends here are dependency-free — an in-memory list (tests, notebook
inspection) and an append-only jsonl file (survives preemption; each
line is self-delimiting, so a half-written tail line from a killed
process is skipped by ``read_jsonl`` rather than corrupting the
history). ``CompositeTracker`` fans out to several.

Metric values are coerced to plain Python scalars at the logging
boundary (``float(jnp_scalar)`` forces a device sync), so backends never
hold device arrays alive and jsonl output is always serialisable.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, Mapping, Protocol, runtime_checkable


@runtime_checkable
class Tracker(Protocol):
    """Anything with ``log_metrics(step, metrics)`` is a tracker."""

    def log_metrics(self, step: int, metrics: Mapping[str, object]) -> None:
        ...


def _scalarize(value: object) -> object:
    """Coerce metric values to json-safe Python scalars."""
    if isinstance(value, (str, bool, type(None))):
        return value
    if isinstance(value, int):
        return value
    try:
        return float(value)          # jnp/np scalars, python floats
    except (TypeError, ValueError):
        return repr(value)


class InMemoryTracker:
    """Records ``(step, metrics)`` pairs on ``self.steps`` for assertions."""

    def __init__(self):
        self.steps: list[tuple[int, dict]] = []

    def log_metrics(self, step: int, metrics: Mapping[str, object]) -> None:
        self.steps.append(
            (int(step), {k: _scalarize(v) for k, v in metrics.items()}))

    def series(self, name: str) -> list[object]:
        """All logged values of metric ``name``, in step order."""
        return [m[name] for _, m in self.steps if name in m]

    def latest(self) -> dict:
        return self.steps[-1][1] if self.steps else {}


class JsonlTracker:
    """Appends one ``{"step": ..., **metrics}`` json object per line.

    The file handle is opened lazily on the first ``log_metrics`` and
    kept for the tracker's lifetime (the old open-per-call behaviour
    tripled the syscall count on the cascade's per-level stream). The
    durability contract is unchanged: every line is flushed + fsynced
    before ``log_metrics`` returns, so a preempted process loses at most
    its final partial line, which ``read_jsonl`` tolerates. Call
    :meth:`close` (or use the tracker as a context manager) to release
    the handle; a closed tracker reopens transparently if logged to
    again.
    """

    def __init__(self, path: str | os.PathLike):
        self.path = os.fspath(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._file = None

    def log_metrics(self, step: int, metrics: Mapping[str, object]) -> None:
        record = {"step": int(step)}
        record.update({k: _scalarize(v) for k, v in metrics.items()})
        if self._file is None:
            self._file = open(self.path, "a")
        self._file.write(json.dumps(record) + "\n")
        self._file.flush()
        os.fsync(self._file.fileno())

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    def __enter__(self) -> "JsonlTracker":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def __del__(self):  # best-effort; every line is already durable
        self.close()


def read_jsonl(path: str | os.PathLike) -> list[dict]:
    """Load a JsonlTracker file, skipping a torn final line if present."""
    records: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                continue          # torn tail line from a killed writer
    return records


class CompositeTracker:
    """Fans ``log_metrics`` out to several trackers."""

    def __init__(self, trackers: Iterable[Tracker]):
        self.trackers = list(trackers)

    def log_metrics(self, step: int, metrics: Mapping[str, object]) -> None:
        for t in self.trackers:
            t.log_metrics(step, metrics)
