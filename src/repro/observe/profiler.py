"""Opt-in JAX profiler hook.

``profile_ctx(dir)`` wraps a training run in ``jax.profiler.trace`` when
given a directory and is a no-op otherwise, so the estimator can take a
``profile_dir=`` kwarg without branching at every call site. Trace
capture failures degrade to a warning rather than killing training — a
profiler is never worth a failed fit.
"""
from __future__ import annotations

import contextlib
import os
import warnings


@contextlib.contextmanager
def profile_ctx(profile_dir: str | os.PathLike | None):
    """Trace into ``profile_dir`` if set; no-op when ``None``."""
    if profile_dir is None:
        yield
        return
    import jax

    path = os.fspath(profile_dir)
    os.makedirs(path, exist_ok=True)
    try:
        jax.profiler.start_trace(path)
    except Exception as e:  # profiler backends vary by platform
        warnings.warn(
            f"jax profiler trace unavailable ({e}); continuing unprofiled",
            RuntimeWarning, stacklevel=3)
        yield
        return
    try:
        yield
    finally:
        try:
            jax.profiler.stop_trace()
        except Exception as e:
            warnings.warn(f"jax profiler stop_trace failed ({e})",
                          RuntimeWarning, stacklevel=3)
