"""repro.observe — training observability: metric trackers + profiler hook.

The tracker protocol is deliberately tiny (levanter-style): a tracker is
anything with ``log_metrics(step, metrics)``. The estimator feeds it
per-level cascade statistics (KKT residual, objective, support-vector
count, rows/s) and per-segment DSVRG progress, so margin-distribution
training is observable instead of anecdotal.
"""
from repro.observe.tracker import (
    CompositeTracker,
    InMemoryTracker,
    JsonlTracker,
    Tracker,
    read_jsonl,
)
from repro.observe.profiler import profile_ctx

__all__ = [
    "Tracker",
    "InMemoryTracker",
    "JsonlTracker",
    "CompositeTracker",
    "read_jsonl",
    "profile_ctx",
]
