"""repro.observe — telemetry: trackers, spans, instruments, perf trend.

Four legs, all dependency-free on the host side:

* **Trackers** (PR 7): a tracker is anything with
  ``log_metrics(step, metrics)`` (levanter-style). The estimator feeds
  it per-level cascade statistics (KKT residual, objective,
  support-vector count, rows/s) and per-segment DSVRG progress.
* **Spans** (PR 9): ``span(name, **attrs)`` times host-side regions —
  fit → route → cascade level, request batch → score — and
  ``trace_ctx(dir)`` exports them as Chrome-trace/Perfetto JSON next to
  the ``jax.profiler`` device traces. Zero cost when no recorder is
  installed.
* **Instruments** (PR 9): counters, gauges, and fixed-bucket histograms
  with exact nearest-rank p50/p95/p99; ``MetricsRegistry`` is itself a
  tracker and drains back through any tracker backend.
* **Trend** (PR 9): :mod:`repro.observe.trend` compares a directory of
  ``BENCH_*.json`` records against committed baselines;
  ``scripts/bench_gate.py`` turns that into a CI perf gate.
"""
from repro.observe.tracker import (
    CompositeTracker,
    InMemoryTracker,
    JsonlTracker,
    Tracker,
    read_jsonl,
)
from repro.observe.profiler import profile_ctx
from repro.observe.spans import (
    Span,
    SpanRecorder,
    current_recorder,
    install,
    span,
    trace_ctx,
)
from repro.observe.instruments import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
)
from repro.observe import trend

__all__ = [
    "Tracker",
    "InMemoryTracker",
    "JsonlTracker",
    "CompositeTracker",
    "read_jsonl",
    "profile_ctx",
    "Span",
    "SpanRecorder",
    "span",
    "trace_ctx",
    "install",
    "current_recorder",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "percentile",
    "DEFAULT_BUCKETS",
    "trend",
]
