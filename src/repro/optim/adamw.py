"""AdamW with fully sharded state (m, v shard exactly like params).

Pure-function optimizer (init/update) over param pytrees; the state's
logical axes mirror the params' so the FSDP sharding rules apply unchanged
(ZeRO-style: optimizer state is never replicated).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def state_axes(param_axes) -> Any:
    """Logical axes for the state tree (mirrors params)."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return AdamWState(step=(),
                      m=jax.tree.map(lambda a: a, param_axes, is_leaf=is_axes),
                      v=jax.tree.map(lambda a: a, param_axes, is_leaf=is_axes))


def state_shapes(param_shapes) -> AdamWState:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree.map(sds, param_shapes),
                      v=jax.tree.map(sds, param_shapes))


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup then cosine decay to min_lr_frac."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(cfg: AdamWConfig, state: AdamWState, params, grads):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
