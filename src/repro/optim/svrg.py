"""SVRG for network training — the paper's Algorithm 2 lifted to LM heads.

Exact for the convex last-layer / ODM-head case (repro.core.dsvrg is the
faithful convex implementation); for full networks the variance-reduction
correction g(w) - g(anchor) + h is a heuristic (non-convexity breaks the
theory) and is flagged as such. Anchor refresh every ``anchor_every``
steps computes the full gradient over a reference batch set.

Usage: wraps any base optimizer's gradient: the train loop calls
``correct(state, grads, params, anchor_grad_fn)`` before the optimizer
update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SVRGConfig:
    anchor_every: int = 100      # steps between anchor refreshes
    enabled: bool = False


class SVRGState(NamedTuple):
    anchor_params: Any
    anchor_grad: Any             # h = full gradient at the anchor
    age: jax.Array               # steps since refresh


def init(params, grads_like) -> SVRGState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return SVRGState(anchor_params=jax.tree.map(jnp.asarray, params),
                     anchor_grad=jax.tree.map(z, grads_like),
                     age=jnp.zeros((), jnp.int32))


def refresh(state: SVRGState, params, full_grad) -> SVRGState:
    return SVRGState(anchor_params=params, anchor_grad=full_grad,
                     age=jnp.zeros((), jnp.int32))


def correct(state: SVRGState, grads, anchor_batch_grads) -> tuple[Any, SVRGState]:
    """g_vr = g(w) - g(anchor) + h on the same minibatch.

    Pytree-generic (two backward passes feed it). For the convex linear
    ODM head the same direction is available with NO backward passes as
    ONE fused pass over the minibatch — margins for w and the anchor as a
    single MXU op — via ``repro.core.odm.svrg_direction`` (jnp) /
    ``repro.kernels.ops.svrg_grad`` (Pallas); ``repro.core.dsvrg`` is the
    full Algorithm 2 driver built on it.
    """
    out = jax.tree.map(
        lambda g, ga, h: g - ga + h.astype(g.dtype),
        grads, anchor_batch_grads, state.anchor_grad)
    return out, state._replace(age=state.age + 1)
