"""Gradient compression with error feedback (for the DP all-reduce).

Two codecs, both with error-feedback residual accumulation (Seide et al. /
Karimireddy et al.: the compression error is added back to the next
gradient, keeping the method convergent):

* ``topk``  — keep the k largest-magnitude entries per tensor (sparsify);
* ``int8``  — per-tensor symmetric int8 quantization.

Under pjit the DP all-reduce is implicit, so the codec is applied to the
*gradient values* (compress -> decompress) before the optimizer: this is
numerically identical to compressing each DP shard's contribution before
an all-reduce with the same codec, and is how the ablation in EXPERIMENTS
measures accuracy impact without leaving the SPMD programming model. The
wire-bytes saving is reported analytically (codec ratio x gradient bytes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressConfig:
    codec: str = "none"         # none | topk | int8
    topk_frac: float = 0.01     # fraction of entries kept by topk


class EFState(NamedTuple):
    residual: Any               # same pytree as grads


def init(grads_shapes) -> EFState:
    z = lambda s: jnp.zeros(s.shape, jnp.float32)
    return EFState(residual=jax.tree.map(z, grads_shapes))


def _topk_codec(g: Array, frac: float) -> Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return (flat * mask).reshape(g.shape)


def _int8_codec(g: Array) -> Array:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q * scale


def compress(cfg: CompressConfig, state: EFState, grads):
    """Returns (decompressed grads as seen post-all-reduce, new EF state)."""
    if cfg.codec == "none":
        return grads, state

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if cfg.codec == "topk":
            out = _topk_codec(gf, cfg.topk_frac)
        elif cfg.codec == "int8":
            out = _int8_codec(gf)
        else:
            raise ValueError(cfg.codec)
        return out.astype(g.dtype), gf - out

    pairs = jax.tree.map(one, grads, state.residual)
    out = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, EFState(residual=res)


def wire_ratio(cfg: CompressConfig) -> float:
    """Bytes-on-wire ratio vs fp32 all-reduce (analytic)."""
    if cfg.codec == "none":
        return 1.0
    if cfg.codec == "topk":
        # values + indices, both 4 bytes
        return 2.0 * cfg.topk_frac
    if cfg.codec == "int8":
        return 0.25
    raise ValueError(cfg.codec)
