from repro.optim import adamw, compress, svrg

__all__ = ["adamw", "compress", "svrg"]
