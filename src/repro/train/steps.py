"""Train / serve step builders — the functions the launcher jits.

``make_train_step``: value_and_grad over model.loss_fn + AdamW update,
with optional gradient accumulation (scan over microbatches), gradient
compression (error-feedback codec before the update, standing in for a
compressed DP all-reduce), and remat governed by the ArchConfig.

``make_serve_step`` / ``make_prefill``: the decode/prefill entry points
used by the serving example and the decode-shape dry-run cells.

Every builder returns (fn, in_axes, out_axes) where the axes are logical
sharding trees resolvable by repro.sharding — launchers turn them into
in_shardings/out_shardings for jit; smoke tests call fn directly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.optim import adamw, compress as comp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    compression: comp.CompressConfig = dataclasses.field(
        default_factory=comp.CompressConfig)
    grad_accum: int = 1            # microbatches per step
    attn_impl: str = "flash_xla"   # flash_xla | flash_pallas | ref
    aux_weight: float = 0.01


class TrainState:
    """Lightweight pytree: params + optimizer (+ EF residual) + step."""

    # implemented as a plain dict for pytree friendliness
    @staticmethod
    def create(params, use_ef: bool):
        st = {"params": params, "opt": adamw.init(params)}
        if use_ef:
            st["ef"] = comp.init(params)
        return st

    @staticmethod
    def shapes(param_shapes_, use_ef: bool):
        st = {"params": param_shapes_,
              "opt": adamw.state_shapes(param_shapes_)}
        if use_ef:
            f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
            st["ef"] = comp.EFState(residual=jax.tree.map(f32, param_shapes_))
        return st

    @staticmethod
    def axes(param_axes, use_ef: bool):
        st = {"params": param_axes, "opt": adamw.state_axes(param_axes)}
        if use_ef:
            is_axes = lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)
            st["ef"] = comp.EFState(residual=jax.tree.map(
                lambda a: a, param_axes, is_leaf=is_axes))
        return st


def make_train_step(cfg: ArchConfig, tc: TrainConfig):
    """(state, batch) -> (state, metrics)."""
    use_ef = tc.compression.codec != "none"

    def loss(params, batch):
        return M.loss_fn(params, batch, cfg, impl=tc.attn_impl,
                         aux_weight=tc.aux_weight)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def step(state, batch):
        params = state["params"]
        if tc.grad_accum > 1:
            micro = _split_microbatches(batch, tc.grad_accum)

            def acc(carry, mb):
                g_sum, l_sum = carry
                (l, mets), g = grad_fn(params, mb)
                g_sum = jax.tree.map(lambda a, b: a + b, g_sum, g)
                return (g_sum, l_sum + l), mets

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), metss = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / tc.grad_accum, g_sum)
            lval = l_sum / tc.grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metss)
        else:
            (lval, metrics), grads = grad_fn(params, batch)
        if use_ef:
            grads, ef = comp.compress(tc.compression, state["ef"], grads)
        new_params, opt, omets = adamw.update(tc.optimizer, state["opt"],
                                              params, grads)
        out = {"params": new_params, "opt": opt}
        if use_ef:
            out["ef"] = ef
        metrics = {**metrics, **omets, "loss": lval}
        return out, metrics

    return step


def _split_microbatches(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for scan."""
    def sp(x):
        if x.ndim >= 2 and x.shape[0] % n == 0:
            return x.reshape(n, x.shape[0] // n, *x.shape[1:])
        if x.ndim == 3 and x.shape[1] % n == 0:     # pos3 (3, B, S)
            return jnp.moveaxis(
                x.reshape(x.shape[0], n, x.shape[1] // n, x.shape[2]), 1, 0)
        return jnp.broadcast_to(x, (n,) + x.shape)
    return {k: sp(v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ArchConfig):
    """(params, cache, batch) -> (logits, cache). batch per input_specs."""

    def step(params, cache, batch):
        pos3 = batch.get("pos3")
        return M.decode(params, cache, batch["tokens"], batch["pos"], cfg,
                        pos3=pos3)

    return step


def make_prefill(cfg: ArchConfig, max_len: int, attn_impl: str = "flash_xla"):
    def fn(params, batch):
        return M.prefill(params, batch, cfg, max_len=max_len, impl=attn_impl)
    return fn


def greedy_sample(logits: Array) -> Array:
    return jnp.argmax(logits[:, -1], axis=-1)[:, None]


def temperature_sample(key, logits: Array, temp: float = 1.0) -> Array:
    return jax.random.categorical(key, logits[:, -1] / temp, axis=-1)[:, None]
