"""Logical-axis sharding: map semantic array axes onto mesh axes.

Every parameter / activation in the framework is annotated with a tuple of
*logical* axis names (one per array dim, None for unsharded). At lowering
time :func:`logical_to_spec` resolves them to a PartitionSpec under the
active rule set, with a divisibility fallback: if a dim does not divide by
the mesh axis it would shard over, it is replicated instead (e.g.
smollm-135m's 9 heads on a 16-way model axis).

Default rules (ZeRO-3/FSDP flavored, MaxText-style):

  batch    -> ("pod", "data")    activations' batch dim
  embed    -> "data"             d_model param dim (FSDP; XLA all-gathers)
  mlp      -> "model"            d_ff / experts' hidden
  heads    -> "model"            attention heads (q)
  kv_heads -> "model"            attention kv heads
  vocab    -> "model"            embedding/output vocab dim
  experts  -> "model"            MoE expert dim (EP)
  kv_seq   -> "model"            decode KV-cache sequence dim (32k/500k
                                 decode shards the cache by sequence)
  layers / repeats / conv / stack / head_dim / qk / None -> replicated

The rule table is plain data so perf iterations can swap rule sets
(EXPERIMENTS §Perf ablates embed->None vs embed->data, kv_seq->data, ...).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = tuple[Any, ...]       # tuple of logical names (str | None) per dim


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Version-tolerant ``jax.make_mesh``.

    ``axis_types`` (jax.sharding.AxisType) only exists on newer JAX; older
    jaxlibs (<= 0.4.x) reject the kwarg. All our meshes want Auto axes —
    the default on every version — so request it when available and fall
    back cleanly when not.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(tuple(shape), tuple(axes),
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:          # make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "experts": "model",
    "kv_seq": "model",
    "seq": None,
    "layers": None,
    "repeats": None,
    "stack": None,
    "head_dim": None,
    "conv": None,
    "state": None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """A rule table plus the mesh it resolves against."""

    rules: Mapping[str, Any] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))

    def replace(self, **kv) -> "ShardingRules":
        r = dict(self.rules)
        r.update(kv)
        return ShardingRules(rules=r)


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        return mesh.shape.get(mesh_axes, 1)
    n = 1
    for a in mesh_axes:
        n *= mesh.shape.get(a, 1)
    return n


def logical_to_spec(axes: Axes, shape: Sequence[int], mesh: Mesh,
                    rules: ShardingRules | None = None) -> P:
    """Resolve logical axes to a PartitionSpec with divisibility fallback."""
    rules = rules or ShardingRules()
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, axes):
        if name is None:
            parts.append(None)
            continue
        mesh_axes = rules.rules.get(name)
        if mesh_axes is None:
            parts.append(None)
            continue
        tup = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        # only mesh axes that exist on this mesh, are unused so far, and
        # divide the dim
        eff = []
        size = 1
        for a in tup:
            if a in mesh.shape and a not in used:
                eff.append(a)
                size *= mesh.shape[a]
        if eff and dim % size == 0:
            parts.append(tuple(eff) if len(eff) > 1 else eff[0])
            used.update(eff)
        else:
            parts.append(None)       # divisibility / availability fallback
    # strip trailing Nones for cleanliness
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh,
                   rules: ShardingRules | None = None):
    """Map a pytree of logical-axes tuples + matching shapes (or arrays /
    ShapeDtypeStructs) to a pytree of NamedShardings."""
    def one(axes, arr):
        shape = arr.shape if hasattr(arr, "shape") else tuple(arr)
        return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def tree_specs(axes_tree, shape_tree, mesh: Mesh,
               rules: ShardingRules | None = None):
    """Same as tree_shardings but returns raw PartitionSpecs (for in_shardings)."""
    def one(axes, arr):
        shape = arr.shape if hasattr(arr, "shape") else tuple(arr)
        return logical_to_spec(axes, shape, mesh, rules)
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# -- active-mesh context ------------------------------------------------------
# The launcher (repro.launch.*) installs the mesh + rules here; model code
# calls ``constrain`` freely and it is a no-op when no mesh is active (CPU
# smoke tests), so the same model code serves tests and production lowering.

_ACTIVE: dict = {"mesh": None, "rules": None}


def set_mesh(mesh: Mesh | None, rules: ShardingRules | None = None) -> None:
    _ACTIVE["mesh"] = mesh
    _ACTIVE["rules"] = rules


class use_mesh:
    """Context manager: with sharding.use_mesh(mesh, rules): ..."""

    def __init__(self, mesh: Mesh | None, rules: ShardingRules | None = None):
        self._new = (mesh, rules)
        self._old = (None, None)

    def __enter__(self):
        self._old = (_ACTIVE["mesh"], _ACTIVE["rules"])
        set_mesh(*self._new)
        return self

    def __exit__(self, *exc):
        set_mesh(*self._old)
        return False


def constrain(x: jax.Array, axes: Axes,
              rules: ShardingRules | None = None) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op when no active mesh)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    rules = rules or _ACTIVE["rules"]
    spec = logical_to_spec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
