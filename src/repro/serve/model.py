"""Compiled ODM inference artifacts (the deployable model).

The ODM decision function is a kernel expansion over the dual,
f(x) = sum_i y_i (zeta_i - beta_i) kappa(x_i, x). Serving it from the raw
solver output re-reads the *entire* training set per request batch; this
module compiles the expansion ONCE into a :class:`FittedODM`:

* **SV pruning** — complementary slackness puts instances whose margin
  lies inside the [1-theta, 1+theta] band at exactly zero dual, so
  coefficients with |y·(zeta-beta)| <= ``prune_tol`` are dropped and the
  survivors packed into a contiguous (S, d) slab (a single O(M·d) gather
  at compile time, never per request).
* **Linear collapse** — for the linear kernel the expansion telescopes to
  an explicit primal ``w = X_svᵀ coef``: O(d) scoring, no slab at all.
  The DSVRG engine's output is born in this form.
* **Nyström landmark compression** — when the SV slab exceeds a budget,
  the expansion is projected onto ``L`` landmark functions
  kappa(z_l, ·): coefficients c = (K_zz + eps I)⁻¹ K_zs coef. The
  landmarks are picked by :func:`repro.core.partition.select_landmarks`
  — the paper's Eqn. 8 pivoted-Cholesky greedy IS Nyström pivot
  selection (largest posterior variance first), so the partitioning
  machinery doubles as the compression machinery. An optional accuracy
  ``target`` (max |f_compressed − f_exact| over a probe set) grows the
  budget geometrically until met.

Scoring routes through the tiled matrix-free kernel
(:func:`repro.kernels.ops.decision_scores`): one ``pallas_call`` per
request batch, O(B·S_block) memory, never a dense (T, S) Gram.
``save``/``load_model`` persist through
:class:`repro.distributed.checkpoint.CheckpointManager` (atomic commit,
versioned steps), with the kernel spec and compression provenance in the
manifest metadata.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kernel_fns as kf
from repro.core import odm as odm_mod
from repro.core import partition as part_mod
from repro.kernels import ops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FittedODM:
    """A compiled, deployable ODM model.

    Exactly one representation is populated:

    * ``w`` (d,)            — explicit primal weights (linear kernel);
    * ``x_sv`` (S, d) + ``coef`` (S,) — packed kernel expansion.

    ``n_train`` is the source expansion size M, ``compression`` one of
    ``"exact" | "pruned" | "nystrom" | "linear"``, ``gap`` the estimated
    max |f_model − f_exact| over the compile-time probe set (0.0 for the
    lossless routes: exact, pruned-at-zero-tol and linear collapse).
    """

    spec: kf.KernelSpec
    w: Array | None = None
    x_sv: Array | None = None
    coef: Array | None = None
    n_train: int = 0
    compression: str = "exact"
    gap: float = 0.0

    @property
    def n_sv(self) -> int:
        """Support vectors actually scored against (0 for linear w)."""
        return 0 if self.x_sv is None else int(self.x_sv.shape[0])

    # -- scoring ------------------------------------------------------------

    def decision_function(self, x: Array, *, bt: int = 256, bs: int = 256,
                          tiled: bool | None = None) -> Array:
        """f(x) (T,) through the serving path: O(d) matvec for linear,
        the tiled matrix-free scorer otherwise (``tiled`` as in
        :func:`repro.kernels.ops.decision_scores`)."""
        if self.w is not None:
            return x @ self.w
        return ops.decision_scores(x, self.x_sv, self.coef, self.spec,
                                   bt=bt, bs=bs, tiled=tiled)

    def predict(self, x: Array, **kw) -> Array:
        return jnp.sign(self.decision_function(x, **kw))

    # -- persistence --------------------------------------------------------

    def save(self, directory: str) -> str:
        """Atomic versioned save (CheckpointManager step 0)."""
        from repro.distributed.checkpoint import CheckpointManager
        tree = {k: v for k, v in (("w", self.w), ("x_sv", self.x_sv),
                                  ("coef", self.coef)) if v is not None}
        meta = {
            "kind": "fitted_odm",
            "spec": dataclasses.asdict(self.spec),
            "n_train": self.n_train,
            "compression": self.compression,
            "gap": float(self.gap),
        }
        return CheckpointManager(directory, keep=1).save(0, tree, meta)


def load_model(directory: str) -> FittedODM:
    """Exact round-trip of :meth:`FittedODM.save`."""
    from repro.distributed.checkpoint import CheckpointManager
    mgr = CheckpointManager(directory, keep=1)
    manifest = mgr.metadata()
    meta = manifest["metadata"]
    if meta.get("kind") != "fitted_odm":
        raise ValueError(f"{directory!r} does not hold a FittedODM "
                         f"checkpoint (kind={meta.get('kind')!r})")
    template = {k: jax.ShapeDtypeStruct(tuple(v["shape"]), v["dtype"])
                for k, v in manifest["leaves"].items()}
    tree = mgr.restore(template)
    spec = kf.KernelSpec(**meta["spec"])
    return FittedODM(spec=spec, w=tree.get("w"), x_sv=tree.get("x_sv"),
                     coef=tree.get("coef"), n_train=int(meta["n_train"]),
                     compression=meta["compression"],
                     gap=float(meta["gap"]))


# ---------------------------------------------------------------------------
# compilation: solver output -> artifact
# ---------------------------------------------------------------------------

def compile_model(spec: kf.KernelSpec, x_train: Array, y_train: Array,
                  alpha: Array, *, prune_tol: float = 0.0,
                  budget: int | None = None, target: float | None = None,
                  ) -> FittedODM:
    """Compile a dual solution into a deployable :class:`FittedODM`.

    ``alpha`` (2M,) is any solver's [zeta; beta]. ``prune_tol`` drops
    coefficients with |y·(zeta−beta)| <= tol (0.0 prunes the exact zeros
    complementary slackness guarantees — lossless). ``budget``/``target``
    enable Nyström compression of nonlinear kernels (see module docs);
    the linear kernel always collapses to an explicit ``w`` instead.
    """
    M = x_train.shape[0]
    zeta, beta = odm_mod.split_alpha(alpha)
    coef = y_train * (zeta - beta)                          # (M,)
    keep = np.nonzero(np.abs(np.asarray(coef)) > prune_tol)[0]
    if keep.size == 0:
        keep = np.array([0])                 # degenerate: all-zero dual
    idx = jnp.asarray(keep)
    x_sv = jnp.take(x_train, idx, axis=0)
    c_sv = jnp.take(coef, idx)

    if spec.name == "linear":
        # pruning is lossless here whatever the tol: the dropped
        # coefficients are folded into w exactly by re-deriving it from
        # the FULL expansion
        w = x_train.T @ coef if prune_tol > 0.0 else x_sv.T @ c_sv
        return FittedODM(spec=spec, w=w, n_train=M, compression="linear")

    compression = "exact" if keep.size == M and prune_tol == 0.0 \
        else "pruned"
    model = FittedODM(spec=spec, x_sv=x_sv, coef=c_sv, n_train=M,
                      compression=compression)
    if prune_tol > 0.0 and keep.size < M:
        # lossy pruning: measure the decision gap it introduced so the
        # reported provenance (and compress()'s cumulative gap) is honest
        probe = x_train[:_PROBE_CAP]
        full = FittedODM(spec=spec, x_sv=x_train, coef=coef, n_train=M)
        model = dataclasses.replace(
            model, gap=decision_gap(model, full, probe))
    if budget is not None and model.n_sv > budget:
        model = compress(model, budget, target=target)
    return model


def from_sodm(spec: kf.KernelSpec, res, x_train: Array, y_train: Array,
              **kw) -> FittedODM:
    """Compile an ``SODMResult`` — applies ``res.perm`` exactly once."""
    return compile_model(spec, x_train[res.perm], y_train[res.perm],
                         res.alpha, **kw)


def from_dsvrg(res) -> FittedODM:
    """A ``DSVRGResult`` is born compressed: linear kernel, explicit w.

    For direct ``dsvrg.solve`` consumers; the SODM engine route
    (``SODMConfig.engine="dsvrg"``) reaches :func:`from_sodm` through the
    recovered dual and collapses to the identical ``w``.
    """
    return FittedODM(spec=kf.KernelSpec(name="linear"), w=res.w,
                     n_train=int(res.perm.shape[0]), compression="linear")


def from_cascade(spec: kf.KernelSpec, res, **kw) -> FittedODM:
    """Compile a cascade baseline's survivor set (``CascadeResult``)."""
    return compile_model(spec, res.x_sv, res.y_sv, res.alpha, **kw)


# ---------------------------------------------------------------------------
# Nyström landmark compression
# ---------------------------------------------------------------------------

_PROBE_CAP = 512      # decision-gap probe rows (SV subsample)
_JITTER = 1e-8


def _nystrom(spec: kf.KernelSpec, x_sv: Array, coef: Array,
             budget: int) -> tuple[Array, Array]:
    """Project the expansion onto ``budget`` landmark functions.

    min_c ||sum_l c_l k(z_l, ·) − sum_s coef_s k(x_s, ·)||²_RKHS has the
    normal equations K_zz c = K_zs coef; the landmarks are the pivoted-
    Cholesky picks of Eqn. 8 (max posterior variance), the standard
    Nyström pivot rule.
    """
    picks = part_mod.select_landmarks(spec, x_sv, budget)
    z = jnp.take(x_sv, picks, axis=0)
    kzz = kf.gram(spec, z)
    kzs = kf.gram(spec, z, x_sv)
    eye = jnp.eye(budget, dtype=kzz.dtype)
    c = jnp.linalg.solve(kzz + _JITTER * budget * eye, kzs @ coef)
    return z, c


def decision_gap(model: FittedODM, other: FittedODM, probe: Array) -> float:
    """max |f_model(probe) − f_other(probe)| (dense oracle on both sides)."""
    a = model.decision_function(probe, tiled=False)
    b = other.decision_function(probe, tiled=False)
    return float(jnp.max(jnp.abs(a - b)))


def compress(model: FittedODM, budget: int, *, target: float | None = None,
             probe: Array | None = None) -> FittedODM:
    """Nyström-compress an expansion model down to <= ``budget`` landmarks.

    With ``target`` set, the budget is doubled until the decision gap on
    ``probe`` (default: up to 512 SV rows) is <= target or the budget
    reaches the SV count (at which point compression is pointless and the
    input model is returned unchanged).
    """
    if model.x_sv is None:
        return model                       # linear w: already O(d)
    S = model.n_sv
    if budget >= S:
        return model
    if probe is None:
        probe = model.x_sv[:_PROBE_CAP]
    while True:
        z, c = _nystrom(model.spec, model.x_sv, model.coef, budget)
        cand = dataclasses.replace(model, x_sv=z, coef=c,
                                   compression="nystrom")
        gap = decision_gap(cand, model, probe)
        if target is None or gap <= target or budget * 2 >= S:
            break
        budget *= 2
    if target is not None and gap > target and budget * 2 >= S:
        return model                       # budget search exhausted
    return dataclasses.replace(cand, gap=model.gap + gap)
