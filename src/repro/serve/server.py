"""Microbatching throughput scorer for compiled ODM models.

Three layers, composable:

* :class:`MicrobatchScorer` — pads every request batch up to a fixed
  bucket ladder (powers of two by default) so the jit cache is bounded by
  ``len(buckets)`` however many distinct batch sizes traffic produces;
  batches above the top bucket are chunked. ``compiles`` exposes the
  bucket-trace count the tests pin.
* :class:`Batcher` — a deadline microbatcher: requests queue until either
  ``max_batch`` are waiting or the oldest has waited ``max_wait`` seconds,
  then the whole batch is scored in one scorer call. Time is injected
  (``now`` arguments) so tests and replay drivers are deterministic;
  :func:`serve_stream` replays an (arrival_time, x) trace through it and
  reports latency/throughput stats.
* :func:`score_sharded` — slabs a large SV set across the mesh's data
  axis inside ``shard_map``: every device scores the full request batch
  against its local slab of the expansion and a ``psum`` adds the partial
  scores (the decision function is linear in the SV slab). O(S/n_dev)
  model memory per device; linear-collapse models short-circuit to the
  replicated O(d) matvec.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.observe.instruments import MetricsRegistry, percentile
from repro.observe.spans import span as _span
from repro.serve.model import FittedODM

Array = jax.Array


def _bucket_ladder(max_batch: int) -> tuple[int, ...]:
    """1, 2, 4, ... up to (and including) max_batch."""
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


class MicrobatchScorer:
    """Bucket-padded scoring with a bounded jit cache.

    ONE jitted score function per scorer, taking the model arrays as
    *arguments* (not closed-over constants — baking the SV slab into
    every bucket's executable would duplicate a potentially multi-MB slab
    ladder-many times). jit's cache is keyed by the request shape, and
    every request is padded onto the bucket ladder, so the number of
    traces stays <= len(buckets) however many batch sizes traffic sees.
    """

    def __init__(self, model: FittedODM, max_batch: int = 256,
                 buckets: tuple[int, ...] | None = None,
                 metrics: MetricsRegistry | None = None):
        self.model = model
        self.metrics = metrics
        self.buckets = tuple(sorted(buckets or _bucket_ladder(max_batch)))
        self.max_batch = self.buckets[-1]
        self.calls = 0
        self._seen: set[int] = set()
        if model.w is not None:
            self._score = jax.jit(lambda xb, w: xb @ w)
            self._margs = (model.w,)
        else:
            spec = model.spec

            def scores(xb, z, c):
                from repro.kernels import ops
                return ops.decision_scores(xb, z, c, spec)

            self._score = jax.jit(scores)
            self._margs = (model.x_sv, model.coef)

    @property
    def compiles(self) -> int:
        """Distinct bucket shapes traced so far (<= len(buckets) always)."""
        return len(self._seen)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.max_batch

    def score(self, x: Array) -> Array:
        """Decision scores (B,) for any batch size; pads to the bucket,
        chunks batches above the top bucket."""
        B = x.shape[0]
        self.calls += 1
        if B == 0:
            return jnp.zeros((0,), x.dtype)
        t0 = time.perf_counter()
        with _span("serve.score", batch=B):
            outs = []
            off = 0
            while off < B:
                n = min(B - off, self.max_batch)
                bucket = self._bucket_for(n)
                self._seen.add(bucket)
                xb = x[off:off + n]
                if n < bucket:
                    xb = jnp.pad(xb, ((0, bucket - n), (0, 0)))
                o = self._score(xb, *self._margs)
                outs.append(o if n == bucket else o[:n])
                off += n
            out = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
        if self.metrics is not None:
            self.metrics.counter("serve.score.calls").inc()
            self.metrics.histogram("serve.score.wall_s").observe(
                time.perf_counter() - t0)
            self.metrics.histogram("serve.score.batch").observe(B)
        return out

    def predict(self, x: Array) -> Array:
        return jnp.sign(self.score(x))


# ---------------------------------------------------------------------------
# deadline microbatcher
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Pending:
    rid: int
    x: Array            # (d,)
    t_arrival: float


@dataclasses.dataclass
class Completed:
    rid: int
    score: float
    t_arrival: float
    t_done: float

    @property
    def latency(self) -> float:
        return self.t_done - self.t_arrival


class Batcher:
    """Queue/deadline microbatcher over a :class:`MicrobatchScorer`.

    ``submit`` enqueues one request; ``poll(now)`` flushes when the batch
    is full or the oldest request has waited past the deadline. All
    clocks are explicit arguments (``time.monotonic()`` by default) so
    replay is deterministic.
    """

    def __init__(self, scorer: MicrobatchScorer, max_batch: int = 64,
                 max_wait: float = 2e-3, faults=None,
                 metrics: MetricsRegistry | None = None):
        self.scorer = scorer
        self.max_batch = min(max_batch, scorer.max_batch)
        self.max_wait = max_wait
        # instrument registry (repro.observe.MetricsRegistry): per-request
        # latency + queue-wait histograms, queue-depth gauge, request /
        # batch counters. None (default) records nothing.
        self.metrics = metrics
        # fault-injection hook (repro.distributed.faults.FaultPlan): the
        # "serve.flush" site fires before scoring; with a virtual-clock
        # plan (sleeper=None) an injected delay shifts the batch's
        # completion time instead of wall-sleeping, so replay stays
        # deterministic
        self.faults = faults
        self._pending: list[_Pending] = []
        self._next_rid = 0
        self.batches: list[int] = []          # flushed batch sizes

    def submit(self, x: Array, now: float | None = None) -> int:
        now = time.monotonic() if now is None else now
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(_Pending(rid, x, now))
        if self.metrics is not None:
            self.metrics.counter("serve.requests").inc()
            self.metrics.gauge("serve.queue_depth").set(len(self._pending))
        return rid

    def ready(self, now: float | None = None) -> bool:
        if not self._pending:
            return False
        if len(self._pending) >= self.max_batch:
            return True
        now = time.monotonic() if now is None else now
        return now - self._pending[0].t_arrival >= self.max_wait

    def flush(self, now: float | None = None) -> list[Completed]:
        """Score everything pending (at most max_batch) in ONE call."""
        if not self._pending:
            return []
        now = time.monotonic() if now is None else now
        batch, self._pending = (self._pending[:self.max_batch],
                                self._pending[self.max_batch:])
        if self.faults is not None:
            now += self.faults.site("serve.flush", batch=len(batch))
        with _span("serve.request_batch", batch=len(batch)):
            xb = jnp.stack([p.x for p in batch])
            scores = jax.device_get(self.scorer.score(xb))
        self.batches.append(len(batch))
        done = [Completed(p.rid, float(s), p.t_arrival, now)
                for p, s in zip(batch, scores)]
        if self.metrics is not None:
            self.metrics.counter("serve.batches").inc()
            self.metrics.gauge("serve.queue_depth").set(len(self._pending))
            lat_h = self.metrics.histogram("serve.request.latency_s")
            for c in done:
                lat_h.observe(c.latency)
        return done

    def poll(self, now: float | None = None) -> list[Completed]:
        now = time.monotonic() if now is None else now
        out: list[Completed] = []
        while self.ready(now):
            out.extend(self.flush(now))
        return out


def serve_stream(batcher: Batcher, arrivals, *, tick: float | None = None
                 ) -> dict:
    """Replay an iterable of (t_arrival, x) events through the batcher.

    Virtual-clock replay: requests are submitted in arrival order and the
    batcher is polled at each arrival plus one final deadline tick, so
    results are independent of host timing. Returns
    {results, latencies, batches, mean_batch, p50, p95, p99} — the
    percentiles are exact nearest-rank (:func:`repro.observe.percentile`,
    shared with the observe histograms; the old ``lat[n // 2]`` indexing
    over-reported at even/small n).
    """
    results: list[Completed] = []
    t_last = 0.0
    for t, x in arrivals:
        results.extend(batcher.poll(t))
        batcher.submit(x, t)
        t_last = max(t_last, t)
    results.extend(batcher.poll(t_last + batcher.max_wait))
    lat = sorted(r.latency for r in results)
    n = len(lat)
    return {
        "results": results,
        "latencies": lat,
        "batches": list(batcher.batches),
        "mean_batch": (sum(batcher.batches) / len(batcher.batches)
                       if batcher.batches else 0.0),
        "p50": percentile(lat, 50) if n else 0.0,
        "p95": percentile(lat, 95) if n else 0.0,
        "p99": percentile(lat, 99) if n else 0.0,
    }


# ---------------------------------------------------------------------------
# SPMD: SV slab sharded across the mesh
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_scorer(mesh: jax.sharding.Mesh, data_axis: str, spec):
    """One jit(shard_map) per (mesh, axis, kernel spec) — jit's own cache
    handles the (request, slab) shapes, so repeated serving calls never
    retrace."""
    from jax.experimental.shard_map import shard_map

    def body(xb, zs, cs):
        from repro.kernels import ops
        part = ops.decision_scores(xb, zs, cs, spec)
        return jax.lax.psum(part, data_axis)

    return jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(data_axis), P(data_axis)),
        out_specs=P(),
        check_rep=False,
    ))


# padded + device-sharded SV slabs, one per (model slab, mesh, axis):
# re-padding and re-sharding O(S·d) bytes per request batch would defeat
# the O(S/n_dev)-per-device goal. Weakref-keyed (liveness proves the id)
# and FIFO-capped like the sodm predict cache.
_SLAB_CACHE: dict = {}
_SLAB_CACHE_CAP = 8


def _sharded_slab(model: FittedODM, mesh: jax.sharding.Mesh,
                  data_axis: str):
    import weakref
    from jax.sharding import NamedSharding

    key = (id(model.x_sv), mesh, data_axis)
    hit = _SLAB_CACHE.get(key)
    if hit is not None and hit[0]() is model.x_sv:
        return hit[1], hit[2]
    n_dev = mesh.shape[data_axis]
    pad = -model.n_sv % n_dev
    z = jnp.pad(model.x_sv, ((0, pad), (0, 0)))
    c = jnp.pad(model.coef, (0, pad))
    z = jax.device_put(z, NamedSharding(mesh, P(data_axis)))
    c = jax.device_put(c, NamedSharding(mesh, P(data_axis)))
    if len(_SLAB_CACHE) >= _SLAB_CACHE_CAP:
        _SLAB_CACHE.pop(next(iter(_SLAB_CACHE)))
    _SLAB_CACHE[key] = (weakref.ref(model.x_sv), z, c)
    return z, c


def score_sharded(model: FittedODM, x: Array, mesh: jax.sharding.Mesh,
                  data_axis: str = "data") -> Array:
    """Decision scores with the SV slab sharded over ``mesh[data_axis]``.

    The expansion is linear in the SVs, so each device scores the
    (replicated) request batch against its local slab and one ``psum``
    assembles f. The slab is padded to a device multiple with zero
    coefficients (zero coef rows contribute exactly nothing), device_put
    with the data-axis sharding ONCE per (model, mesh), and the
    jit(shard_map) is built once per (mesh, axis, spec) — repeat calls
    pay only the scoring. Linear models score replicated — the w matvec
    is already O(d).
    """
    if model.w is not None:
        return x @ model.w

    z, c = _sharded_slab(model, mesh, data_axis)
    return _sharded_scorer(mesh, data_axis, model.spec)(x, z, c)
