"""ODM serving subsystem: compiled inference artifacts + throughput scoring.

``repro.serve`` turns any solver output (``SODMResult`` / ``DSVRGResult``
/ cascade baselines / a raw dual vector) into a deployable
:class:`FittedODM` artifact — near-zero dual coefficients pruned into a
packed support-vector slab, linear kernels collapsed to an explicit
primal ``w``, optional Nyström landmark compression — and scores it
through the tiled matrix-free decision kernel
(:mod:`repro.kernels.score`) with microbatching, bucketed jit caches and
an SV-sharded SPMD path (:mod:`repro.serve.server`).
"""
from repro.serve.model import (FittedODM, compile_model, compress,
                               from_cascade, from_dsvrg, from_sodm,
                               load_model)
from repro.serve.server import (Batcher, MicrobatchScorer, score_sharded,
                                serve_stream)

__all__ = [
    "FittedODM", "compile_model", "compress", "from_cascade", "from_dsvrg",
    "from_sodm", "load_model", "Batcher", "MicrobatchScorer",
    "score_sharded", "serve_stream",
]
