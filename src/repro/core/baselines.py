"""Baseline scalable QP solvers the paper compares against (Section 4).

All baselines train the *same* ODM dual (so accuracy differences reflect
the partition/merge strategy, exactly the paper's experimental design):

* **Ca-ODM** — Cascade (Graf et al. 2004): binary-tree merge in which each
  node solves its local ODM and forwards only its "support" instances
  (ODM's complementary slackness: duals are nonzero iff the margin falls
  outside the [1-theta, 1+theta] band). Greedy data discarding makes it
  fast but lossy — the paper's Tables 2-3 show exactly that signature.

* **DiP-ODM** — DiP-SVM-style (Singh et al. 2017): k-means clusters in
  input space, each cluster dealt round-robin across partitions (first-
  order distribution preservation, but no RKHS-aware landmark/stratum
  construction), then the same hierarchical merge as SODM.

* **DC-ODM** — DC-SVM-style (Hsieh et al. 2014): each k-means *cluster is
  a partition* (maximally unlike the global distribution), concatenated
  duals warm-start the parent solve, same merge machinery.

* **ODM_svrg** — single-chain SVRG (Johnson & Zhang 2013) on the linear
  primal.

* **ODM_csvrg** — coreset SVRG (Tan et al. 2019): anchor full gradients
  evaluated on a k-center coreset instead of the full set.

Everything reuses repro.core.{dual_cd, sodm, partition, odm} so the only
variable is the strategy under test.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import deprecation as _dep
from repro.core import dual_cd, kernel_fns as kf
from repro.core import partition as part_mod
from repro.core import sodm as sodm_mod
from repro.core.odm import (ODMParams, minibatch_grad, primal_grad,
                            primal_objective)

Array = jax.Array

# Every public *_solve here is a legacy entry point: the supported way to
# train a baseline is the unified API (repro.api.ODMEstimator with
# route="cascade" | "dip" | "dc" | "svrg" | "csvrg"). The shims warn once
# and delegate to the _-prefixed implementations the registry calls.


# ---------------------------------------------------------------------------
# Ca-ODM (Cascade)
# ---------------------------------------------------------------------------

class CascadeResult(NamedTuple):
    x_sv: Array
    y_sv: Array
    alpha: Array
    levels_run: int


def _top_support(x: Array, y: Array, alpha: Array, keep: int,
                 theta_band: float = 1e-8):
    """Keep the `keep` instances with largest dual magnitude |zeta - beta|.

    Static-shape-friendly (top_k); ODM support vectors are margin-band
    violators, which is exactly where |zeta-beta| > 0.
    """
    m = x.shape[0]
    zeta, beta = alpha[:m], alpha[m:]
    mag = jnp.abs(zeta - beta) + jnp.minimum(zeta, beta)   # ~ activity score
    _, idx = jax.lax.top_k(mag, keep)
    return x[idx], y[idx], jnp.concatenate([zeta[idx], beta[idx]])


def cascade_solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
                  levels: int, key: jax.Array, tol: float = 1e-4,
                  max_sweeps: int = 100) -> CascadeResult:
    """Binary cascade: 2^levels leaves; each merge keeps half the instances
    (the classic cascade funnel), solving on survivors only. Legacy entry
    point (see module note)."""
    _dep.warn_once("repro.core.baselines.cascade_solve",
                   "repro.api.ODMEstimator(route='cascade').fit")
    return _cascade_solve(spec, x, y, params, levels, key, tol, max_sweeps)


def _cascade_solve(spec: kf.KernelSpec, x: Array, y: Array,
                   params: ODMParams, levels: int, key: jax.Array,
                   tol: float = 1e-4, max_sweeps: int = 100,
                   perm: Array | None = None) -> CascadeResult:
    M = x.shape[0]
    K = 2 ** levels
    if M % K != 0:
        raise ValueError(f"2^levels={K} must divide M={M}")
    if perm is None:
        perm = part_mod.random_partitions(M, K, key)
    xp, yp = x[perm], y[perm]
    m = M // K
    xs = xp.reshape(K, m, -1)
    ys = yp.reshape(K, m)
    alphas = jnp.zeros((K, 2 * m), x.dtype)

    def make_solve_level(m):
        def solve_level(xs, ys, alphas):
            def one(xk, yk, ak):
                Q = kf.signed_gram(spec, xk, yk)
                res = dual_cd.solve(Q, params, mscale=float(m), alpha0=ak,
                                    tol=tol, max_sweeps=max_sweeps)
                return res.alpha
            return jax.vmap(one)(xs, ys, alphas)
        return jax.jit(solve_level)

    lvl = 0
    while True:
        alphas = make_solve_level(m)(xs, ys, alphas)
        lvl += 1
        if xs.shape[0] == 1:
            break
        # funnel: each node keeps its top m//2 "support" instances, then
        # pairs merge back to (2 * (m//2))-sized problems (handles odd m).
        keep = m // 2
        xk, yk, ak = jax.vmap(
            lambda a, b, c: _top_support(a, b, c, keep))(xs, ys, alphas)
        Kn = xs.shape[0] // 2
        m = 2 * keep
        xs = xk.reshape(Kn, m, -1)
        ys = yk.reshape(Kn, m)
        grouped = ak.reshape(Kn, 2, 2 * keep)
        alphas = jax.vmap(sodm_mod.merge_alphas)(grouped)
    return CascadeResult(x_sv=xs[0], y_sv=ys[0], alpha=alphas[0],
                         levels_run=lvl)


def _cascade_solve_stream(spec: kf.KernelSpec, source, params: ODMParams,
                          levels: int, key: jax.Array | None = None,
                          tol: float = 1e-4, max_sweeps: int = 100, *,
                          faults=None, tracker=None, resume=None,
                          depth: int = 2, executor=None, metrics=None,
                          accountant=None) -> CascadeResult:
    """Out-of-core cascade: level-0 partitions train as shards arrive.

    The dense solver loads all M rows, deals them into 2^levels leaves
    and sweeps the funnel level by level. This driver instead runs the
    cascade as an online binary tournament: each arriving leaf (one
    ``M / 2^levels``-row slab of the stream, cut on global row indices
    by ``iter_slabs``) is solved immediately, and whenever two
    same-level survivors sit on top of the merge stack they funnel the
    instant both exist — keep the top half of each
    (:func:`_top_support`), concatenate, warm-start from the merged
    duals (:func:`repro.core.sodm.merge_alphas`) and re-solve. At most
    ``levels + 1`` partially-merged nodes are ever resident, so host
    memory is O(leaf_rows · levels) whatever M is.

    With the dense solver given ``perm = arange(M)`` the tournament
    pairs exactly the same instances into exactly the same nodes; the
    results differ only by vmap-vs-single solve numerics (the parity
    tests pin ≤ 1e-5). Leaves stream in stream order — ``key`` is
    accepted for signature parity and unused.

    Instrumentation: the ``cascade.shard`` fault site fires per leaf
    (``data.prefetch`` fires underneath, inside the loader), a
    ``cascade.shard`` span wraps each leaf's solve+merge work, the
    tracker logs per-leaf throughput, and ``resume`` (a
    :class:`~repro.distributed.resume.CascadeResumeManager`) checkpoints
    the merge stack after each leaf — a restart re-enters the stream at
    the first unprocessed leaf without re-reading completed shards.
    """
    import time as _time

    from repro.data.streaming import loader as stream_loader
    from repro.observe.spans import span as _span

    M = int(source.n_rows)
    K = 2 ** levels
    if M % K != 0:
        raise ValueError(f"2^levels={K} must divide M={M}")
    del key
    m0 = M // K
    if metrics is None and tracker is not None:
        from repro.observe import MetricsRegistry
        metrics = MetricsRegistry()

    solvers: dict[int, object] = {}

    def solve_node(xn, yn, a0):
        m = int(xn.shape[0])
        if m not in solvers:
            def fn(xn, yn, a0, m=m):
                Q = kf.signed_gram(spec, xn, yn)
                return dual_cd.solve(Q, params, mscale=float(m), alpha0=a0,
                                     tol=tol, max_sweeps=max_sweeps).alpha
            solvers[m] = jax.jit(fn)
        return solvers[m](xn, yn, a0)

    # merge stack: (tier, x (m, d), y (m,), alpha (2m,)) — tier t holds
    # the solved merge of 2^t consecutive leaves
    stack: list[tuple[int, Array, Array, Array]] = []
    start_leaf = 0
    if resume is not None:
        restored = resume.restore_stream()
        if restored is not None:
            start_leaf = restored.leaf
            stack = [(t, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(al))
                     for t, xs, ys, al in restored.stack]

    def funnel():
        while len(stack) >= 2 and stack[-1][0] == stack[-2][0]:
            tier, xb, yb, ab = stack.pop()
            _, xa, ya, aa = stack.pop()
            keep = int(xa.shape[0]) // 2
            xa, ya, aa = _top_support(xa, ya, aa, keep)
            xb, yb, ab = _top_support(xb, yb, ab, keep)
            xm = jnp.concatenate([xa, xb])
            ym = jnp.concatenate([ya, yb])
            am = sodm_mod.merge_alphas(jnp.stack([aa, ab]))
            stack.append((tier + 1, xm, ym, solve_node(xm, ym, am)))

    slabs = stream_loader.iter_slabs(
        source, m0, start_row=start_leaf * m0, depth=depth,
        executor=executor, metrics=metrics, faults=faults,
        accountant=accountant)
    for slab in slabs:
        leaf = slab.start // m0
        if faults is not None:
            faults.site("cascade.shard", shard=leaf)
        t0 = _time.perf_counter()
        with _span("cascade.shard", shard=leaf, rows=m0):
            xl = jnp.asarray(slab.x)
            yl = jnp.asarray(slab.y)
            al = solve_node(xl, yl, jnp.zeros(2 * m0, xl.dtype))
            stack.append((0, xl, yl, al))
            funnel()
        if tracker is not None:
            jax.block_until_ready(stack[-1][3])
            wall = _time.perf_counter() - t0
            tracker.log_metrics(leaf + 1, {
                "route": "cascade", "leaf": leaf, "rows": m0,
                "wall_s": wall, "rows_per_s": m0 / max(wall, 1e-9)})
        if resume is not None:
            resume.save_stream(leaf=leaf + 1, stack=stack)
    if len(stack) != 1:               # K is a power of two: cannot happen
        raise RuntimeError(f"merge stack did not collapse: {len(stack)}")
    if metrics is not None and tracker is not None:
        metrics.drain(tracker, step=K)
    _, x_sv, y_sv, alpha = stack[0]
    return CascadeResult(x_sv=x_sv, y_sv=y_sv, alpha=alpha,
                         levels_run=levels + 1)


def cascade_predict(spec: kf.KernelSpec, res: CascadeResult,
                    x_test: Array) -> Array:
    """Served prediction for the cascade survivor set: compiled FittedODM
    (near-zero duals pruned, linear collapsed to w) through the tiled
    scorer — the dense (T, M) test Gram of the seed path is gone."""
    from repro.serve import model as serve_model
    return serve_model.from_cascade(spec, res).predict(x_test)


# ---------------------------------------------------------------------------
# DiP-ODM / DC-ODM — SODM machinery with rival partition strategies
# ---------------------------------------------------------------------------

def dip_solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
              cfg: sodm_mod.SODMConfig, key: jax.Array) -> sodm_mod.SODMResult:
    """DiP: k-means clusters dealt round-robin across partitions. Legacy
    entry point (see module note)."""
    _dep.warn_once("repro.core.baselines.dip_solve",
                   "repro.api.ODMEstimator(route='dip').fit")
    return _dip_solve(spec, x, y, params, cfg, key)


def _dip_solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
               cfg: sodm_mod.SODMConfig,
               key: jax.Array) -> sodm_mod.SODMResult:
    """Reuses the stratified sampler with *k-means clusters as the strata*
    — the structural difference from SODM is the stratum construction
    (input-space centroids vs RKHS det-max landmarks)."""
    M = x.shape[0]
    K0 = cfg.p ** cfg.levels
    ck, pk = jax.random.split(key)
    # k-means strata
    perm_c = part_mod.cluster_partitions(spec, x, cfg.n_landmarks, ck)
    # recover cluster ids from the sorted permutation layout
    stratum = jnp.zeros(M, jnp.int32).at[perm_c].set(
        jnp.arange(M, dtype=jnp.int32) // (M // cfg.n_landmarks))
    perm = part_mod.stratified_partitions(stratum, K0, pk)
    xp, yp = x[perm], y[perm]
    res = sodm_mod._solve(
        spec, xp, yp, params,
        dataclasses.replace(cfg, partition_strategy="identity"), pk)
    # compose permutations (solve() used identity internally)
    return sodm_mod.SODMResult(alpha=res.alpha, perm=perm[res.perm],
                               levels_run=res.levels_run,
                               sweeps_per_level=res.sweeps_per_level,
                               kkt=res.kkt)


def dc_solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
             cfg: sodm_mod.SODMConfig, key: jax.Array) -> sodm_mod.SODMResult:
    """DC: clusters *are* partitions (cluster_partitions layout). Legacy
    entry point (see module note)."""
    _dep.warn_once("repro.core.baselines.dc_solve",
                   "repro.api.ODMEstimator(route='dc').fit")
    return _dc_solve(spec, x, y, params, cfg, key)


def _dc_solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
              cfg: sodm_mod.SODMConfig,
              key: jax.Array) -> sodm_mod.SODMResult:
    return sodm_mod._solve(
        spec, x, y, params,
        dataclasses.replace(cfg, partition_strategy="cluster"), key)


# ---------------------------------------------------------------------------
# gradient-based baselines (linear kernel)
# ---------------------------------------------------------------------------

class GradResult(NamedTuple):
    w: Array
    history: Array


def svrg_solve(x: Array, y: Array, params: ODMParams, epochs: int,
               eta: float, key: jax.Array, batch: int = 1) -> GradResult:
    """Plain single-machine SVRG (Johnson & Zhang 2013). Legacy entry
    point (see module note)."""
    _dep.warn_once("repro.core.baselines.svrg_solve",
                   "repro.api.ODMEstimator(route='svrg').fit")
    return _svrg_solve(x, y, params, epochs, eta, key, batch)


def _svrg_solve(x: Array, y: Array, params: ODMParams, epochs: int,
                eta: float, key: jax.Array, batch: int = 1) -> GradResult:
    M, d = x.shape
    steps = M // batch

    @jax.jit
    def epoch(w, key):
        anchor = w
        h = primal_grad(anchor, x, y, params)
        idx = jax.random.permutation(key, M)[:steps * batch].reshape(steps, batch)

        def inner(w, ib):
            xb, yb = x[ib], y[ib]
            g_w = minibatch_grad(w, xb, yb, params, M)
            g_a = minibatch_grad(anchor, xb, yb, params, M)
            return w - eta * (g_w - g_a + h), None

        w, _ = jax.lax.scan(inner, w, idx)
        return w, primal_objective(w, x, y, params)

    w = jnp.zeros(d, x.dtype)
    hist = []
    for e in range(epochs):
        w, obj = epoch(w, jax.random.fold_in(key, e))
        hist.append(obj)
    return GradResult(w=w, history=jnp.stack(hist))


def kcenter_coreset(x: Array, n: int) -> Array:
    """Greedy k-center (farthest point) coreset indices."""
    M = x.shape[0]

    def body(s, carry):
        mind2, picks = carry
        i = jnp.where(s == 0, 0, jnp.argmax(mind2))
        picks = picks.at[s].set(i)
        xi = jax.lax.dynamic_slice(x, (i, 0), (1, x.shape[1]))
        d2 = jnp.sum((x - xi) ** 2, axis=1)
        return jnp.minimum(mind2, d2), picks

    mind2 = jnp.full((M,), jnp.inf, x.dtype)
    picks = jnp.zeros((n,), jnp.int32)
    _, picks = jax.lax.fori_loop(0, n, body, (mind2, picks))
    return picks


def csvrg_solve(x: Array, y: Array, params: ODMParams, epochs: int,
                eta: float, key: jax.Array, coreset_frac: float = 0.1,
                batch: int = 1) -> GradResult:
    """Coreset-SVRG (Tan et al. 2019): anchor gradient on a k-center
    coreset. Legacy entry point (see module note)."""
    _dep.warn_once("repro.core.baselines.csvrg_solve",
                   "repro.api.ODMEstimator(route='csvrg').fit")
    return _csvrg_solve(x, y, params, epochs, eta, key, coreset_frac, batch)


def _csvrg_solve(x: Array, y: Array, params: ODMParams, epochs: int,
                 eta: float, key: jax.Array, coreset_frac: float = 0.1,
                 batch: int = 1) -> GradResult:
    M, d = x.shape
    n_core = max(1, int(M * coreset_frac))
    core = kcenter_coreset(x, n_core)
    xc, yc = x[core], y[core]
    steps = M // batch

    @jax.jit
    def epoch(w, key):
        anchor = w
        h = primal_grad(anchor, xc, yc, params)      # coreset anchor (cheap)
        idx = jax.random.permutation(key, M)[:steps * batch].reshape(steps, batch)

        def inner(w, ib):
            xb, yb = x[ib], y[ib]
            g_w = minibatch_grad(w, xb, yb, params, M)
            g_a = minibatch_grad(anchor, xb, yb, params, M)
            return w - eta * (g_w - g_a + h), None

        w, _ = jax.lax.scan(inner, w, idx)
        return w, primal_objective(w, x, y, params)

    w = jnp.zeros(d, x.dtype)
    hist = []
    for e in range(epochs):
        w, obj = epoch(w, jax.random.fold_in(key, e))
        hist.append(obj)
    return GradResult(w=w, history=jnp.stack(hist))
