"""SODM Algorithm 1 — hierarchical partitioned ODM solve with warm starts.

Level l has K_l = p^l partitions of size m_l = M / K_l. Each partition's
local ODM (Eqn. 4 block) is solved by dual coordinate descent; when p
sibling partitions merge, their dual vectors are concatenated as the warm
start of the parent solve (Algorithm 1 line 12). Theorem 1 bounds the gap
between the block-diagonal approximation and the global dual, so the warm
start is already near-optimal and the parent solve converges in a few
sweeps.

Layout note: each local alpha is [zeta_k; beta_k] (2 m_l,). The parent's
alpha is [zeta_all; beta_all] (2 p m_l,), so "concatenation" interleaves:
parent_zeta = concat(zeta_children), parent_beta = concat(beta_children).
``merge_alphas`` implements exactly that.

Scale note: the local dual's diagonal regularizer is m_l·c (Eqn. 4), so
dual magnitudes shrink as partitions grow — at a merge the children's
duals were solved at scale m_l but the parent solves at scale p·m_l, and a
plain concatenation can be up to ~p× too large (its KKT residual is then
*worse* than a cold start's). Every solver engine therefore opens a level
solve with an exact line search along the warm-start ray (the dual
objective is quadratic in t, closed form — see
:func:`repro.core.odm.warm_start_scale`), which lands within a few KKT
digits of the parent optimum in both the regularizer-dominant (t ≈ 1/p)
and the Q-dominant (t ≈ 1) regime and is what makes Algorithm 1's warm
starts actually cut solve passes.

Two execution layouts:

* :func:`solve` — single-process: all partitions of a level advance
  together (levels are a Python loop; shapes are static per level so each
  level compiles once and is reused across calls with the same sizes).

* :func:`solve_sharded` — SPMD: ``shard_map`` over the mesh ``data`` axis.
  While K_l >= n_dev each device sweeps its own slab of partitions with
  **zero** cross-device traffic (the paper's "parallel training" phase);
  when a merge would span devices we all-gather X/y/alpha inside the merge
  group (axis-index arithmetic) — this is the Spark shuffle of the paper
  mapped onto ICI collectives. Once K_l < n_dev the residual levels run
  replicated (at that point the problem is a single in-memory QP anyway).

Solver engines
--------------

HOW each level's K local ODM duals are solved is orthogonal to WHERE they
run, so it is pluggable: ``SODMConfig.engine`` selects a
:class:`repro.core.engines.LocalSolver`:

* ``"scalar"`` (default) — exact Gauss-Seidel dual CD per partition, the
  paper-faithful reference. Latency-bound on accelerators.
* ``"block"``  — pure-jnp block-Gauss-Seidel (exact CD inside VMEM-sized
  tiles, Jacobi across tiles). The XLA oracle of the Pallas path.
* ``"pallas"`` — the greedy block-CD *fused* Pallas pass kernel: one
  ``pallas_call`` per pass runs the whole level's tile sweeps AND the
  cross-tile Gram matvec (no separate per-pass matmul), warm starts
  included; tiles early-exit their sweep at in-tile KKT <= tol (adaptive
  steps_per_pass). Partitions larger than ``SODMConfig.gram_threshold``
  rebuild Gram tiles on the fly from the raw features for every kernel
  family (rbf / laplacian / poly / linear — ``repro.kernels.gram``), so
  per-level memory stays O(m·B) instead of O(m²).

All engines honor Algorithm 1's warm starts (line 12) and report 0
sweeps/passes for an already-converged start (line 5's early stop).

Both layouts checkpoint per level through ``level_callback`` for fault
tolerance (see repro.distributed.checkpoint).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import engines, kernel_fns as kf
from repro.core import partition as part_mod
from repro.core.odm import ODMParams

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SODMConfig:
    """Hyperparameters of the SODM solve."""

    p: int = 2                 # merge factor (partitions merged per level)
    levels: int = 3            # L: start with p^L partitions
    n_landmarks: int = 8       # S strata
    tol: float = 1e-4          # per-solve KKT tolerance
    max_sweeps: int = 100      # CD sweep / outer-pass cap per local solve
    early_stop: bool = True    # Algorithm 1 line 5-6
    partition_strategy: str = "stratified"   # stratified | random | cluster
    engine: str = "scalar"     # scalar | block | pallas (see module docs)
    block: int = 256           # VMEM tile size of the block/pallas engines
    gram_threshold: int = 4096  # pallas: partitions above this rebuild
    #                             Gram tiles on the fly (repro.kernels.gram,
    #                             O(m·B) memory, all kernel families)
    #                             instead of materializing the O(m²) Q
    adaptive: bool = True      # pallas: tiles early-exit their greedy
    #                            sweep at in-tile KKT <= 0.01*tol (never
    #                            changes the outer exact-KKT convergence
    #                            check)


class SODMResult(NamedTuple):
    alpha: Array             # (2M,) global-layout dual solution
    perm: Array              # (M,) partition permutation applied to the data
    levels_run: int
    sweeps_per_level: list   # python list of int sweep counts (max over partitions)
    kkt: Array               # final global KKT residual (if computed) or per-level


def merge_alphas(alphas: Array) -> Array:
    """(K, 2m) per-partition [zeta;beta] -> (2*K*m,) global [zeta_all;beta_all]."""
    K, two_m = alphas.shape
    m = two_m // 2
    zetas = alphas[:, :m].reshape(-1)
    betas = alphas[:, m:].reshape(-1)
    return jnp.concatenate([zetas, betas])


def split_to_partitions(alpha: Array, K: int) -> Array:
    """Inverse of merge_alphas: (2M,) -> (K, 2m)."""
    M = alpha.shape[0] // 2
    m = M // K
    zetas = alpha[:M].reshape(K, m)
    betas = alpha[M:].reshape(K, m)
    return jnp.concatenate([zetas, betas], axis=1)


def solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
          cfg: SODMConfig, key: jax.Array,
          level_callback: Callable[[int, Array], None] | None = None,
          ) -> SODMResult:
    """Single-process SODM (Algorithm 1)."""
    M = x.shape[0]
    K0 = cfg.p ** cfg.levels
    if M % K0 != 0:
        raise ValueError(f"p^L={K0} must divide M={M}")

    if cfg.partition_strategy == "stratified":
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K0, key)
        perm = plan.perm
    elif cfg.partition_strategy == "random":
        perm = part_mod.random_partitions(M, K0, key)
    elif cfg.partition_strategy == "cluster":
        perm = part_mod.cluster_partitions(spec, x, K0, key)
    elif cfg.partition_strategy == "identity":
        perm = jnp.arange(M)       # caller already laid the data out
    else:
        raise ValueError(cfg.partition_strategy)

    xp, yp = x[perm], y[perm]

    K = K0
    m = M // K
    alphas = jnp.zeros((K, 2 * m), x.dtype)
    sweeps_per_level: list = []
    kkt = jnp.array(jnp.inf, x.dtype)

    level = cfg.levels
    solver = engines.make_local_solver(cfg.engine, block=cfg.block,
                                       gram_threshold=cfg.gram_threshold,
                                       adaptive=cfg.adaptive)
    solve_jit = jax.jit(solver,
                        static_argnames=("spec", "params", "tol", "max_sweeps"))
    while True:
        xs = xp.reshape(K, m, -1)
        ys = yp.reshape(K, m)
        alphas, sweeps, kkts = solve_jit(xs, ys, alphas, spec=spec,
                                         params=params, tol=cfg.tol,
                                         max_sweeps=cfg.max_sweeps)
        sweeps_per_level.append(int(jnp.max(sweeps)))
        kkt = jnp.max(kkts)
        if level_callback is not None:
            level_callback(level, alphas)
        # Algorithm 1 line 5: if all local solves already satisfied the
        # warm start (0 sweeps => init was within tol), we are converged.
        converged = cfg.early_stop and int(jnp.max(sweeps)) == 0 and level < cfg.levels
        if K == 1 or level == 0 or converged:
            break
        # merge p siblings: (K, 2m) -> (K/p, 2pm), interleaving zeta/beta
        # (plain concatenation, Algorithm 1 line 12 — the engine rescales
        # the warm start to the parent's regularizer scale, see the
        # module's scale note)
        Kn = K // cfg.p
        grouped = alphas.reshape(Kn, cfg.p, 2 * m)
        merged = jax.vmap(merge_alphas)(grouped)       # (Kn, 2 p m)
        alphas = merged
        K, m = Kn, m * cfg.p
        level -= 1

    alpha = merge_alphas(alphas) if alphas.ndim == 2 and alphas.shape[0] > 1 \
        else alphas.reshape(-1)
    return SODMResult(alpha=alpha, perm=perm,
                      levels_run=len(sweeps_per_level),
                      sweeps_per_level=sweeps_per_level, kkt=kkt)


# ---------------------------------------------------------------------------
# SPMD engine (shard_map over the mesh `data` axis)
# ---------------------------------------------------------------------------

def solve_sharded(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
                  cfg: SODMConfig, key: jax.Array, mesh: jax.sharding.Mesh,
                  data_axis: str = "data") -> SODMResult:
    """SODM with partitions sharded over ``mesh[data_axis]``.

    Preconditions: p^L partitions, n_dev = mesh.shape[data_axis], and
    p^L % n_dev == 0 (each device starts with an equal slab). Levels with
    K_l >= n_dev run with zero communication. Once K_l < n_dev the data
    no longer fills the axis; we gather everything and finish replicated —
    at that point the problem is a single in-memory QP anyway. Every level
    is solved exactly once (no re-solve at the sharded/replicated
    hand-off) and ``levels_run`` reports the true count.
    """
    from jax.experimental.shard_map import shard_map

    M = x.shape[0]
    K0 = cfg.p ** cfg.levels
    n_dev = mesh.shape[data_axis]
    if K0 % n_dev != 0:
        raise ValueError(f"p^L={K0} must be a multiple of data axis {n_dev}")

    if cfg.partition_strategy == "stratified":
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K0, key)
        perm = plan.perm
    else:
        perm = part_mod.random_partitions(M, K0, key)
    xp, yp = x[perm], y[perm]

    K, m = K0, M // K0
    alphas = jnp.zeros((K, 2 * m), x.dtype)
    sweeps_per_level: list = []
    kkt = jnp.array(jnp.inf, x.dtype)
    level = cfg.levels

    solver = engines.make_local_solver(cfg.engine, block=cfg.block,
                                       gram_threshold=cfg.gram_threshold,
                                       adaptive=cfg.adaptive)
    body = partial(solver, spec=spec, params=params, tol=cfg.tol,
                   max_sweeps=cfg.max_sweeps)
    repl_jit = jax.jit(solver,
                      static_argnames=("spec", "params", "tol", "max_sweeps"))

    while True:
        xs = xp.reshape(K, m, -1)
        ys = yp.reshape(K, m)
        if K >= n_dev and K % n_dev == 0 and n_dev > 1:
            # parallel phase: each device sweeps its own slab of partitions
            shmapped = shard_map(
                lambda a, b, c: body(a, b, c),
                mesh=mesh,
                in_specs=(P(data_axis), P(data_axis), P(data_axis)),
                out_specs=(P(data_axis), P(data_axis), P(data_axis)),
                # the per-partition while_loops have no replication rule on
                # this jax version; outputs are fully sharded anyway
                check_rep=False,
            )
            alphas, sweeps, kkts = jax.jit(shmapped)(xs, ys, alphas)
        else:
            # replicated tail: K < n_dev partitions left (tiny residual
            # levels — a single in-memory QP by now)
            alphas, sweeps, kkts = repl_jit(xs, ys, alphas, spec=spec,
                                            params=params, tol=cfg.tol,
                                            max_sweeps=cfg.max_sweeps)
        sweeps_per_level.append(int(jnp.max(sweeps)))
        kkt = jnp.max(kkts)
        converged = cfg.early_stop and int(jnp.max(sweeps)) == 0 \
            and level < cfg.levels
        if K == 1 or converged:
            break
        Kn = K // cfg.p
        grouped = alphas.reshape(Kn, cfg.p, 2 * m)
        alphas = jax.vmap(merge_alphas)(grouped)
        K, m = Kn, m * cfg.p
        level -= 1

    alpha = merge_alphas(alphas) if alphas.ndim == 2 and alphas.shape[0] > 1 \
        else alphas.reshape(-1)
    return SODMResult(alpha=alpha, perm=perm,
                      levels_run=len(sweeps_per_level),
                      sweeps_per_level=sweeps_per_level, kkt=kkt)


# ---------------------------------------------------------------------------
# convenience: fit + predict in original index order
# ---------------------------------------------------------------------------

def fit(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
        cfg: SODMConfig, key: jax.Array) -> tuple[SODMResult, Array, Array]:
    """Returns (result, x_perm, y_perm); alpha is aligned with the permuted data."""
    res = solve(spec, x, y, params, cfg, key)
    return res, x[res.perm], y[res.perm]


def predict(spec: kf.KernelSpec, res: SODMResult, x_train: Array,
            y_train: Array, x_test: Array) -> Array:
    from repro.core import odm
    xp, yp = x_train[res.perm], y_train[res.perm]
    return odm.predict(spec, xp, yp, res.alpha, x_test)
