"""SODM Algorithm 1 — hierarchical partitioned ODM solve with warm starts.

Level l has K_l = p^l partitions of size m_l = M / K_l. Each partition's
local ODM (Eqn. 4 block) is solved by dual coordinate descent; when p
sibling partitions merge, their dual vectors are concatenated as the warm
start of the parent solve (Algorithm 1 line 12). Theorem 1 bounds the gap
between the block-diagonal approximation and the global dual, so the warm
start is already near-optimal and the parent solve converges in a few
sweeps.

Layout note: each local alpha is [zeta_k; beta_k] (2 m_l,). The parent's
alpha is [zeta_all; beta_all] (2 p m_l,), so "concatenation" interleaves:
parent_zeta = concat(zeta_children), parent_beta = concat(beta_children).
``merge_alphas`` implements exactly that.

Scale note: the local dual's diagonal regularizer is m_l·c (Eqn. 4), so
dual magnitudes shrink as partitions grow — at a merge the children's
duals were solved at scale m_l but the parent solves at scale p·m_l, and a
plain concatenation can be up to ~p× too large (its KKT residual is then
*worse* than a cold start's). Every solver engine therefore opens a level
solve with an exact line search along the warm-start ray (the dual
objective is quadratic in t, closed form — see
:func:`repro.core.odm.warm_start_scale`), which lands within a few KKT
digits of the parent optimum in both the regularizer-dominant (t ≈ 1/p)
and the Q-dominant (t ≈ 1) regime and is what makes Algorithm 1's warm
starts actually cut solve passes.

Two execution layouts:

* :func:`solve` — single-process: all partitions of a level advance
  together (levels are a Python loop; shapes are static per level so each
  level compiles once and is reused across calls with the same sizes).

* :func:`solve_sharded` — SPMD: ``shard_map`` over the mesh ``data`` axis.
  While K_l >= n_dev each device sweeps its own slab of partitions with
  **zero** cross-device traffic (the paper's "parallel training" phase);
  when a merge would span devices we all-gather X/y/alpha inside the merge
  group (axis-index arithmetic) — this is the Spark shuffle of the paper
  mapped onto ICI collectives. Once K_l < n_dev the residual levels run
  replicated (at that point the problem is a single in-memory QP anyway).

Solver engines
--------------

HOW each level's K local ODM duals are solved is orthogonal to WHERE they
run, so it is pluggable: ``SODMConfig.engine`` selects a
:class:`repro.core.engines.LocalSolver`:

* ``"scalar"`` (default) — exact Gauss-Seidel dual CD per partition, the
  paper-faithful reference. Latency-bound on accelerators.
* ``"block"``  — pure-jnp block-Gauss-Seidel (exact CD inside VMEM-sized
  tiles, Jacobi across tiles). The XLA oracle of the Pallas path.
* ``"pallas"`` — the greedy block-CD *fused* Pallas pass kernel: one
  ``pallas_call`` per pass runs the whole level's tile sweeps AND the
  cross-tile Gram matvec (no separate per-pass matmul), warm starts
  included; tiles early-exit their sweep at in-tile KKT <= tol (adaptive
  steps_per_pass). Partitions larger than ``SODMConfig.gram_threshold``
  rebuild Gram tiles on the fly from the raw features for every kernel
  family (rbf / laplacian / poly / linear — ``repro.kernels.gram``), so
  per-level memory stays O(m·B) instead of O(m²).
* ``"dsvrg"`` — the paper's linear-kernel path (Algorithm 2): the WHOLE
  problem routes to the communication-efficient primal SVRG solver
  (``repro.core.dsvrg``) instead of the hierarchical dual level loop, and
  the dual alpha is recovered from the primal solution via
  ``odm.alpha_from_w`` so predict/baselines work unchanged. Also selected
  AUTOMATICALLY — only when ``engine`` is left unset (None); an explicit
  scalar/block/pallas choice is always honored — for linear-kernel
  problems with M >= ``SODMConfig.dsvrg_threshold`` (the paper's "when
  linear kernel is applied" dispatch, now owned by
  ``repro.api.registry.resolve_auto``); ``SODMConfig.dsvrg`` carries the
  solver's own epochs/batch/schedule knobs.

``engine=None`` (the default) otherwise behaves exactly like
``"scalar"``.

All level engines honor Algorithm 1's warm starts (line 12) and report 0
sweeps/passes for an already-converged start (line 5's early stop).

Both layouts checkpoint per level through ``level_callback`` for fault
tolerance (see repro.distributed.checkpoint).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import deprecation as _dep
from repro.core import dsvrg as dsvrg_mod
from repro.core import engines, kernel_fns as kf
from repro.core import odm as odm_mod
from repro.core import partition as part_mod
from repro.core.odm import ODMParams
from repro.observe.spans import span as _span

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SODMConfig:
    """Hyperparameters of the SODM solve."""

    p: int = 2                 # merge factor (partitions merged per level)
    levels: int = 3            # L: start with p^L partitions
    n_landmarks: int = 8       # S strata
    tol: float = 1e-4          # per-solve KKT tolerance
    max_sweeps: int = 100      # CD sweep / outer-pass cap per local solve
    early_stop: bool = True    # Algorithm 1 line 5-6
    partition_strategy: str = "stratified"   # stratified | random | cluster
    engine: str | None = None  # None (auto) | scalar | block | pallas |
    #                            dsvrg. None runs the scalar level loop
    #                            EXCEPT for linear-kernel problems with
    #                            M >= dsvrg_threshold, which auto-route to
    #                            dsvrg; an explicitly named engine (scalar
    #                            included) is always honored (module docs)
    block: int = 256           # VMEM tile size of the block/pallas engines
    gram_threshold: int = 4096  # pallas: partitions above this rebuild
    #                             Gram tiles on the fly (repro.kernels.gram,
    #                             O(m·B) memory, all kernel families)
    #                             instead of materializing the O(m²) Q
    adaptive: bool = True      # pallas: tiles early-exit their greedy
    #                            sweep at in-tile KKT <= 0.01*tol (never
    #                            changes the outer exact-KKT convergence
    #                            check)
    dsvrg: dsvrg_mod.DSVRGConfig = dsvrg_mod.DSVRGConfig(epochs=10, batch=64)
    #                            solver knobs of the linear-kernel DSVRG
    #                            route (engine="dsvrg" or auto-dispatch);
    #                            n_partitions is clamped to divide M
    dsvrg_threshold: int = 200_000  # linear-kernel problems at/above this
    #                            many instances auto-route to the DSVRG
    #                            engine (the paper's "when linear kernel
    #                            is applied" dispatch)


class SODMResult(NamedTuple):
    alpha: Array             # (2M,) global-layout dual solution
    perm: Array              # (M,) partition permutation applied to the data
    levels_run: int
    sweeps_per_level: list   # python list of int sweep counts (max over partitions)
    kkt: Array               # final global KKT residual (if computed) or per-level


def merge_alphas(alphas: Array) -> Array:
    """(K, 2m) per-partition [zeta;beta] -> (2*K*m,) global [zeta_all;beta_all]."""
    K, two_m = alphas.shape
    m = two_m // 2
    zetas = alphas[:, :m].reshape(-1)
    betas = alphas[:, m:].reshape(-1)
    return jnp.concatenate([zetas, betas])


def split_to_partitions(alpha: Array, K: int) -> Array:
    """Inverse of merge_alphas: (2M,) -> (K, 2m)."""
    M = alpha.shape[0] // 2
    m = M // K
    zetas = alpha[:M].reshape(K, m)
    betas = alpha[M:].reshape(K, m)
    return jnp.concatenate([zetas, betas], axis=1)


def _solve_dsvrg(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
                 cfg: SODMConfig, key: jax.Array,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data", auto: bool = False, *,
                 faults=None, tracker=None, resume=None,
                 ) -> tuple[SODMResult, dsvrg_mod.DSVRGResult]:
    """Whole-problem linear-kernel route (the registry's dsvrg entry).

    Solves the primal with DSVRG (Algorithm 2) and recovers the dual via
    ``odm.alpha_from_w`` so the result plugs into every alpha consumer;
    the native ``DSVRGResult`` is returned alongside so the unified API
    can report the objective history / eta and compile the artifact from
    the primal ``w`` directly. ``levels_run`` is 1 (a single
    whole-problem solve),
    ``sweeps_per_level`` reports the epoch count, and ``kkt`` is the
    primal gradient infinity norm (the natural stationarity residual of
    the primal path). The outer ``partition_strategy``/``n_landmarks``
    carry over when DSVRG supports the strategy (stratified/random;
    cluster/identity keep ``cfg.dsvrg``'s own setting). The solve is
    epoch-budgeted (``cfg.dsvrg.epochs``) — ``tol``/``max_sweeps`` are
    level-loop knobs and do not apply here; check the returned ``kkt`` if
    a stationarity guarantee is needed. An AUTO-dispatched solve on a
    mesh (``auto=True``) upgrades the default serial schedule to
    ``"parallel"``: the serial chain is replicated compute over an
    all-gathered slab — the right validation tool, but exactly wrong for
    the big-data regime that triggers the auto route. An explicit
    ``engine="dsvrg"`` keeps whatever ``cfg.dsvrg`` says.
    """
    from repro.api import registry
    M = x.shape[0]
    n_dev = mesh.shape[data_axis] if mesh is not None else 1
    K = registry.dsvrg_partition_count(M, cfg.dsvrg.n_partitions, n_dev)
    dcfg = dataclasses.replace(cfg.dsvrg, n_partitions=K)
    if auto and mesh is not None:
        dcfg = dataclasses.replace(dcfg, schedule="parallel")
    if cfg.partition_strategy in ("stratified", "random"):
        dcfg = dataclasses.replace(
            dcfg, partition_strategy=cfg.partition_strategy,
            n_landmarks=cfg.n_landmarks)
    if mesh is not None:
        res = dsvrg_mod._solve_sharded(x, y, params, dcfg, key, mesh,
                                       data_axis=data_axis, faults=faults,
                                       tracker=tracker, resume=resume)
    else:
        res = dsvrg_mod._solve(x, y, params, dcfg, key, faults=faults,
                               tracker=tracker, resume=resume)
    xp, yp = x[res.perm], y[res.perm]
    alpha = odm_mod.alpha_from_w(res.w, xp, yp, params)
    # grad p(w) = w - w_from_alpha(alpha_from_w(w)) exactly (the recovered
    # dual's hinge coefficient is -y⊙(zeta-beta)), so the stationarity
    # residual reuses the alpha pass instead of a second O(M·d) sweep
    kkt = jnp.max(jnp.abs(res.w - odm_mod.w_from_alpha(xp, yp, alpha)))
    return SODMResult(alpha=alpha, perm=res.perm, levels_run=1,
                      sweeps_per_level=[dcfg.epochs], kkt=kkt), res


def _level_loop(run_level, x: Array, y: Array, perm: Array, cfg: SODMConfig,
                *, faults=None, tracker=None, resume=None,
                level_callback: Callable[[int, Array], None] | None = None,
                ) -> SODMResult:
    """The Algorithm-1 level loop, shared by the single-process and SPMD
    drivers (``run_level(xs, ys, alphas, K) -> (alphas, sweeps, kkts)`` is
    the only thing that differs between them).

    Instrumentation seams, all default-off:

    * ``faults`` — a :class:`repro.distributed.faults.FaultPlan`; the
      ``"cascade.level"`` site fires BEFORE each level solve, so a kill at
      level k leaves level k+1's checkpoint as the last committed state
      and a resume restarts exactly the killed solve from the merged
      level-(k+1) duals (Algorithm 1's warm start, recovered from disk).
    * ``tracker`` — per-level KKT / sweeps / SV-count / throughput via
      ``log_metrics(levels_solved, {...})`` (repro.observe).
    * ``resume`` — a :class:`repro.distributed.resume
      .CascadeResumeManager`; every solved level is checkpointed, and a
      non-empty resume directory re-enters the loop at the first unsolved
      level (the restored level is treated as already solved: straight to
      the convergence check and merge). Level solves are deterministic
      pure functions of ``(xs, ys, alphas)`` and the checkpoint round
      trip is bitwise exact, so the resumed result is bit-identical to an
      uninterrupted run's.
    """
    restored = resume.restore() if resume is not None else None
    M = x.shape[0]
    if restored is not None:
        level, K, m = restored.level, restored.K, restored.m
        alphas, perm = restored.alphas, restored.perm
        sweeps_per_level = list(restored.sweeps_per_level)
        kkt = restored.kkt
        pending = False          # the restored level is already solved
    else:
        K = cfg.p ** cfg.levels
        m = M // K
        alphas = jnp.zeros((K, 2 * m), x.dtype)
        sweeps_per_level = []
        kkt = jnp.array(jnp.inf, x.dtype)
        level = cfg.levels
        pending = True
    xp, yp = x[perm], y[perm]

    while True:
        if pending:
            if faults is not None:
                faults.site("cascade.level", level=level, K=K)
            _LEVEL_SOLVE_COUNTER.bump((level, K))
            t0 = time.perf_counter()
            with _span("cascade.level", level=level, K=K, m=m):
                xs = xp.reshape(K, m, -1)
                ys = yp.reshape(K, m)
                alphas, sweeps, kkts = run_level(xs, ys, alphas, K)
                sweeps_per_level.append(int(jnp.max(sweeps)))
                kkt = jnp.max(kkts)
            if tracker is not None:
                jax.block_until_ready(alphas)
                wall = time.perf_counter() - t0
                sv = int(jnp.sum(jnp.abs(alphas[:, :m] - alphas[:, m:]) > 0))
                tracker.log_metrics(len(sweeps_per_level), {
                    "route": "sodm", "level": level, "K": K, "m": m,
                    "sweeps": sweeps_per_level[-1], "kkt": float(kkt),
                    "sv_count": sv, "wall_s": wall,
                    "rows_per_s": M / max(wall, 1e-9)})
            if resume is not None:
                resume.save_level(level=level, K=K, m=m, alphas=alphas,
                                  perm=perm,
                                  sweeps_per_level=sweeps_per_level,
                                  kkt=kkt)
            if level_callback is not None:
                level_callback(level, alphas)
        pending = True
        # Algorithm 1 line 5: if all local solves already satisfied the
        # warm start (0 sweeps => init was within tol), we are converged.
        converged = cfg.early_stop and sweeps_per_level \
            and sweeps_per_level[-1] == 0 and level < cfg.levels
        if K == 1 or level == 0 or converged:
            break
        # merge p siblings: (K, 2m) -> (K/p, 2pm), interleaving zeta/beta
        # (plain concatenation, Algorithm 1 line 12 — the engine rescales
        # the warm start to the parent's regularizer scale, see the
        # module's scale note)
        Kn = K // cfg.p
        grouped = alphas.reshape(Kn, cfg.p, 2 * m)
        alphas = jax.vmap(merge_alphas)(grouped)       # (Kn, 2 p m)
        K, m = Kn, m * cfg.p
        level -= 1

    alpha = merge_alphas(alphas) if alphas.ndim == 2 and alphas.shape[0] > 1 \
        else alphas.reshape(-1)
    return SODMResult(alpha=alpha, perm=perm,
                      levels_run=len(sweeps_per_level),
                      sweeps_per_level=sweeps_per_level, kkt=kkt)


def solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
          cfg: SODMConfig, key: jax.Array,
          level_callback: Callable[[int, Array], None] | None = None,
          ) -> SODMResult:
    """Single-process SODM (Algorithm 1) — legacy entry point; the
    supported front door is ``repro.api.ODMEstimator`` (this shim warns
    once and delegates unchanged). Linear-kernel problems may route to
    the DSVRG primal engine (Algorithm 2) per the registry's dispatch
    policy (``level_callback`` does not fire on that path: there are no
    levels)."""
    _dep.warn_once("repro.core.sodm.solve", "repro.api.ODMEstimator.fit")
    return _solve(spec, x, y, params, cfg, key, level_callback)


def _solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
           cfg: SODMConfig, key: jax.Array,
           level_callback: Callable[[int, Array], None] | None = None,
           *, faults=None, tracker=None, resume=None) -> SODMResult:
    M = x.shape[0]
    if engines.wants_dsvrg(cfg.engine, spec.name, M, cfg.dsvrg_threshold):
        return _solve_dsvrg(spec, x, y, params, cfg, key, faults=faults,
                            tracker=tracker, resume=resume)[0]
    K0 = cfg.p ** cfg.levels
    if M % K0 != 0:
        raise ValueError(f"p^L={K0} must divide M={M}")

    if cfg.partition_strategy == "stratified":
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K0, key)
        perm = plan.perm
    elif cfg.partition_strategy == "random":
        perm = part_mod.random_partitions(M, K0, key)
    elif cfg.partition_strategy == "cluster":
        perm = part_mod.cluster_partitions(spec, x, K0, key)
    elif cfg.partition_strategy == "identity":
        perm = jnp.arange(M)       # caller already laid the data out
    else:
        raise ValueError(cfg.partition_strategy)

    solver = engines.make_local_solver(cfg.engine, block=cfg.block,
                                       gram_threshold=cfg.gram_threshold,
                                       adaptive=cfg.adaptive)
    solve_jit = jax.jit(solver,
                        static_argnames=("spec", "params", "tol", "max_sweeps"))

    def run_level(xs, ys, alphas, K):
        del K
        return solve_jit(xs, ys, alphas, spec=spec, params=params,
                         tol=cfg.tol, max_sweeps=cfg.max_sweeps)

    return _level_loop(run_level, x, y, perm, cfg, faults=faults,
                       tracker=tracker, resume=resume,
                       level_callback=level_callback)


# ---------------------------------------------------------------------------
# SPMD engine (shard_map over the mesh `data` axis)
# ---------------------------------------------------------------------------

def solve_sharded(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
                  cfg: SODMConfig, key: jax.Array, mesh: jax.sharding.Mesh,
                  data_axis: str = "data") -> SODMResult:
    """SODM with partitions sharded over ``mesh[data_axis]`` — legacy
    entry point; the supported front door is ``repro.api.ODMEstimator``
    with ``mesh=`` (this shim warns once and delegates unchanged).

    Preconditions: p^L partitions, n_dev = mesh.shape[data_axis], and
    p^L % n_dev == 0 (each device starts with an equal slab). Levels with
    K_l >= n_dev run with zero communication. Once K_l < n_dev the data
    no longer fills the axis; we gather everything and finish replicated —
    at that point the problem is a single in-memory QP anyway. Every level
    is solved exactly once (no re-solve at the sharded/replicated
    hand-off) and ``levels_run`` reports the true count.
    """
    _dep.warn_once("repro.core.sodm.solve_sharded",
                   "repro.api.ODMEstimator.fit")
    return _solve_sharded(spec, x, y, params, cfg, key, mesh,
                          data_axis=data_axis)


def _solve_sharded(spec: kf.KernelSpec, x: Array, y: Array,
                   params: ODMParams, cfg: SODMConfig, key: jax.Array,
                   mesh: jax.sharding.Mesh, data_axis: str = "data",
                   *, faults=None, tracker=None, resume=None) -> SODMResult:
    from jax.experimental.shard_map import shard_map

    M = x.shape[0]
    if engines.wants_dsvrg(cfg.engine, spec.name, M, cfg.dsvrg_threshold):
        return _solve_dsvrg(spec, x, y, params, cfg, key, mesh=mesh,
                            data_axis=data_axis,
                            auto=cfg.engine != "dsvrg", faults=faults,
                            tracker=tracker, resume=resume)[0]
    K0 = cfg.p ** cfg.levels
    n_dev = mesh.shape[data_axis]
    if K0 % n_dev != 0:
        raise ValueError(f"p^L={K0} must be a multiple of data axis {n_dev}")

    if cfg.partition_strategy == "stratified":
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K0, key)
        perm = plan.perm
    else:
        perm = part_mod.random_partitions(M, K0, key)

    solver = engines.make_local_solver(cfg.engine, block=cfg.block,
                                       gram_threshold=cfg.gram_threshold,
                                       adaptive=cfg.adaptive)
    body = partial(solver, spec=spec, params=params, tol=cfg.tol,
                   max_sweeps=cfg.max_sweeps)
    repl_jit = jax.jit(solver,
                      static_argnames=("spec", "params", "tol", "max_sweeps"))

    def run_level(xs, ys, alphas, K):
        if K >= n_dev and K % n_dev == 0 and n_dev > 1:
            # parallel phase: each device sweeps its own slab of partitions
            shmapped = shard_map(
                lambda a, b, c: body(a, b, c),
                mesh=mesh,
                in_specs=(P(data_axis), P(data_axis), P(data_axis)),
                out_specs=(P(data_axis), P(data_axis), P(data_axis)),
                # the per-partition while_loops have no replication rule on
                # this jax version; outputs are fully sharded anyway
                check_rep=False,
            )
            return jax.jit(shmapped)(xs, ys, alphas)
        # replicated tail: K < n_dev partitions left (tiny residual
        # levels — a single in-memory QP by now)
        return repl_jit(xs, ys, alphas, spec=spec, params=params,
                        tol=cfg.tol, max_sweeps=cfg.max_sweeps)

    return _level_loop(run_level, x, y, perm, cfg, faults=faults,
                       tracker=tracker, resume=resume)


# ---------------------------------------------------------------------------
# convenience: fit + predict in original index order
# ---------------------------------------------------------------------------

# compiled-model cache for the stateless predict() API. The seed-era
# predict re-gathered x_train[res.perm] / y_train[res.perm] — an O(M·d)
# permutation gather plus a fresh (T, M) Gram — on EVERY call; compiling
# the FittedODM once amortizes the gather and SV packing across calls.
# Entries hold WEAK references to their key arrays: a live weakref proves
# the id() key has not been recycled, and a dead one invalidates the
# entry without pinning the (potentially multi-GB) training set in memory
# for the cache's lifetime. FIFO-capped as a second bound.
_MODEL_CACHE: dict = {}
_MODEL_CACHE_CAP = 8

# the gather pin now lives in the invariant registry (one counter store
# for every subsystem); this name is the back-compat alias
from repro.analysis.invariants import counter as _inv_counter  # noqa: E402

_PERM_GATHER_COUNTER = _inv_counter("sodm.perm_gather")

# one bump per level solve actually run (restored levels do NOT bump);
# the resume.cascade_fewer_solves invariant reads deltas of this to prove
# a resumed fit re-runs only the not-yet-solved levels
_LEVEL_SOLVE_COUNTER = _inv_counter("sodm.level_solve")


def level_solve_count() -> int:
    """How many cascade level solves have run in this process — resumed
    fits skip restored levels, so the delta across a resume must be
    smaller than a cold restart's (``resume.cascade_fewer_solves`` in
    ``repro.analysis.invariants``)."""
    return _LEVEL_SOLVE_COUNTER.count


def perm_gather_count() -> int:
    """How many times predict/fit have gathered x_train[res.perm] — the
    per-call-gather pin (``routes.sodm.predict_gather_once`` in
    ``repro.analysis.invariants``) holds this at one per fitted model."""
    return _PERM_GATHER_COUNTER.count


def compile_model(spec: kf.KernelSpec, res: SODMResult, x_train: Array,
                  y_train: Array, **kw):
    """Compile an ``SODMResult`` into a served ``FittedODM`` (the ONE
    place the partition permutation is applied). ``kw`` forwards
    compression knobs (prune_tol / budget / target)."""
    from repro.serve import model as serve_model
    _PERM_GATHER_COUNTER.bump((id(res), x_train.shape))
    return serve_model.from_sodm(spec, res, x_train, y_train, **kw)


def _weakrefs(*arrays):
    import weakref
    try:
        return tuple(weakref.ref(a) for a in arrays)
    except TypeError:                  # non-weakref-able leaf: no liveness
        return None                    # proof => never cache-hit on it


def _cached_model(spec: kf.KernelSpec, res: SODMResult, x_train: Array,
                  y_train: Array):
    key = (id(res.alpha), id(res.perm), id(x_train), id(y_train), spec)
    hit = _MODEL_CACHE.get(key)
    if hit is not None:
        model, refs = hit
        if refs is not None and all(r() is not None for r in refs):
            return model
        del _MODEL_CACHE[key]          # an id was (or could be) recycled
    model = compile_model(spec, res, x_train, y_train)
    if len(_MODEL_CACHE) >= _MODEL_CACHE_CAP:
        _MODEL_CACHE.pop(next(iter(_MODEL_CACHE)))
    _MODEL_CACHE[key] = (model, _weakrefs(res.alpha, res.perm,
                                          x_train, y_train))
    return model


def fit(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
        cfg: SODMConfig, key: jax.Array):
    """Solve + compile in one step: returns ``(SODMResult, FittedODM)``.

    Legacy entry point — the supported training API is
    ``repro.api.ODMEstimator.fit``, which returns ``(FittedODM,
    FitReport)``. THIS shim's tuple shape ``(SODMResult, FittedODM)`` is
    frozen for back-compat (pinned by tests/test_api.py); it warns once
    and delegates unchanged. The artifact is the deployable model — the
    permutation gather and SV packing happen here exactly once, never
    again at predict time.
    """
    _dep.warn_once("repro.core.sodm.fit", "repro.api.ODMEstimator.fit")
    res = _solve(spec, x, y, params, cfg, key)
    return res, _cached_model(spec, res, x, y)


def predict(spec: kf.KernelSpec, res: SODMResult, x_train: Array,
            y_train: Array, x_test: Array) -> Array:
    """Served prediction through a cached compiled model: the permutation
    gather runs once per fitted model (pinned by ``perm_gather_count``),
    and scoring is the tiled matrix-free path — no per-call (T, M) Gram."""
    return _cached_model(spec, res, x_train, y_train).predict(x_test)
