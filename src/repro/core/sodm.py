"""SODM Algorithm 1 — hierarchical partitioned ODM solve with warm starts.

Level l has K_l = p^l partitions of size m_l = M / K_l. Each partition's
local ODM (Eqn. 4 block) is solved by dual coordinate descent; when p
sibling partitions merge, their dual vectors are concatenated as the warm
start of the parent solve (Algorithm 1 line 12). Theorem 1 bounds the gap
between the block-diagonal approximation and the global dual, so the warm
start is already near-optimal and the parent solve converges in a few
sweeps.

Layout note: each local alpha is [zeta_k; beta_k] (2 m_l,). The parent's
alpha is [zeta_all; beta_all] (2 p m_l,), so "concatenation" interleaves:
parent_zeta = concat(zeta_children), parent_beta = concat(beta_children).
``merge_alphas`` implements exactly that.

Two execution engines:

* :func:`solve` — single-process: ``vmap`` over partitions per level
  (levels are a Python loop; shapes are static per level so each level
  compiles once and is reused across calls with the same sizes).

* :func:`solve_sharded` — SPMD: ``shard_map`` over the mesh ``data`` axis.
  While K_l >= n_dev each device sweeps its own slab of partitions with
  **zero** cross-device traffic (the paper's "parallel training" phase);
  when a merge would span devices we all-gather X/y/alpha inside the merge
  group (axis-index arithmetic) — this is the Spark shuffle of the paper
  mapped onto ICI collectives.

Both engines checkpoint per level through ``level_callback`` for fault
tolerance (see repro.distributed.checkpoint).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dual_cd, kernel_fns as kf
from repro.core import partition as part_mod
from repro.core.odm import ODMParams

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SODMConfig:
    """Hyperparameters of the SODM solve."""

    p: int = 2                 # merge factor (partitions merged per level)
    levels: int = 3            # L: start with p^L partitions
    n_landmarks: int = 8       # S strata
    tol: float = 1e-4          # per-solve KKT tolerance
    max_sweeps: int = 100      # CD sweep cap per local solve
    early_stop: bool = True    # Algorithm 1 line 5-6
    partition_strategy: str = "stratified"   # stratified | random | cluster


class SODMResult(NamedTuple):
    alpha: Array             # (2M,) global-layout dual solution
    perm: Array              # (M,) partition permutation applied to the data
    levels_run: int
    sweeps_per_level: list   # python list of int sweep counts (max over partitions)
    kkt: Array               # final global KKT residual (if computed) or per-level


def merge_alphas(alphas: Array) -> Array:
    """(K, 2m) per-partition [zeta;beta] -> (2*K*m,) global [zeta_all;beta_all]."""
    K, two_m = alphas.shape
    m = two_m // 2
    zetas = alphas[:, :m].reshape(-1)
    betas = alphas[:, m:].reshape(-1)
    return jnp.concatenate([zetas, betas])


def split_to_partitions(alpha: Array, K: int) -> Array:
    """Inverse of merge_alphas: (2M,) -> (K, 2m)."""
    M = alpha.shape[0] // 2
    m = M // K
    zetas = alpha[:M].reshape(K, m)
    betas = alpha[M:].reshape(K, m)
    return jnp.concatenate([zetas, betas], axis=1)


def _solve_level(xs: Array, ys: Array, alphas: Array, spec: kf.KernelSpec,
                 params: ODMParams, tol: float, max_sweeps: int):
    """vmap'd local ODM solves: xs (K, m, d), ys (K, m), alphas (K, 2m)."""
    m = xs.shape[1]

    def one(xk, yk, ak):
        Q = kf.signed_gram(spec, xk, yk)
        res = dual_cd.solve(Q, params, mscale=float(m), alpha0=ak,
                            tol=tol, max_sweeps=max_sweeps)
        return res.alpha, res.sweeps, res.kkt

    return jax.vmap(one)(xs, ys, alphas)


def solve(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
          cfg: SODMConfig, key: jax.Array,
          level_callback: Callable[[int, Array], None] | None = None,
          ) -> SODMResult:
    """Single-process SODM (Algorithm 1)."""
    M = x.shape[0]
    K0 = cfg.p ** cfg.levels
    if M % K0 != 0:
        raise ValueError(f"p^L={K0} must divide M={M}")

    if cfg.partition_strategy == "stratified":
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K0, key)
        perm = plan.perm
    elif cfg.partition_strategy == "random":
        perm = part_mod.random_partitions(M, K0, key)
    elif cfg.partition_strategy == "cluster":
        perm = part_mod.cluster_partitions(spec, x, K0, key)
    elif cfg.partition_strategy == "identity":
        perm = jnp.arange(M)       # caller already laid the data out
    else:
        raise ValueError(cfg.partition_strategy)

    xp, yp = x[perm], y[perm]

    K = K0
    m = M // K
    alphas = jnp.zeros((K, 2 * m), x.dtype)
    sweeps_per_level: list = []
    kkt = jnp.array(jnp.inf, x.dtype)

    level = cfg.levels
    solve_jit = jax.jit(_solve_level,
                        static_argnames=("spec", "params", "tol", "max_sweeps"))
    while True:
        xs = xp.reshape(K, m, -1)
        ys = yp.reshape(K, m)
        alphas, sweeps, kkts = solve_jit(xs, ys, alphas, spec=spec,
                                         params=params, tol=cfg.tol,
                                         max_sweeps=cfg.max_sweeps)
        sweeps_per_level.append(int(jnp.max(sweeps)))
        kkt = jnp.max(kkts)
        if level_callback is not None:
            level_callback(level, alphas)
        # Algorithm 1 line 5: if all local solves already satisfied the
        # warm start (0 sweeps => init was within tol), we are converged.
        converged = cfg.early_stop and int(jnp.max(sweeps)) == 0 and level < cfg.levels
        if K == 1 or level == 0 or converged:
            break
        # merge p siblings: (K, 2m) -> (K/p, 2pm), interleaving zeta/beta
        Kn = K // cfg.p
        grouped = alphas.reshape(Kn, cfg.p, 2 * m)
        merged = jax.vmap(merge_alphas)(grouped)       # (Kn, 2 p m)
        alphas = merged
        K, m = Kn, m * cfg.p
        level -= 1

    alpha = merge_alphas(alphas) if alphas.ndim == 2 and alphas.shape[0] > 1 \
        else alphas.reshape(-1)
    return SODMResult(alpha=alpha, perm=perm, levels_run=cfg.levels - level + 1,
                      sweeps_per_level=sweeps_per_level, kkt=kkt)


# ---------------------------------------------------------------------------
# SPMD engine (shard_map over the mesh `data` axis)
# ---------------------------------------------------------------------------

def _level_body_local(xs, ys, alphas, spec, params, tol, max_sweeps, m):
    """Per-device body: solve this device's slab of partitions (k_loc, m, d)."""
    def one(xk, yk, ak):
        Q = kf.signed_gram(spec, xk, yk)
        res = dual_cd.solve(Q, params, mscale=float(m), alpha0=ak,
                            tol=tol, max_sweeps=max_sweeps)
        return res.alpha, res.sweeps, res.kkt
    return jax.vmap(one)(xs, ys, alphas)


def solve_sharded(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
                  cfg: SODMConfig, key: jax.Array, mesh: jax.sharding.Mesh,
                  data_axis: str = "data") -> SODMResult:
    """SODM with partitions sharded over ``mesh[data_axis]``.

    Preconditions: p^L partitions, n_dev = mesh.shape[data_axis], and
    p^L % n_dev == 0 (each device starts with an equal slab). Levels with
    K_l >= n_dev run with zero communication. Once K_l < n_dev the data
    no longer fills the axis; we gather everything and finish replicated —
    at that point the problem is a single in-memory QP anyway.
    """
    from jax.experimental.shard_map import shard_map

    M = x.shape[0]
    K0 = cfg.p ** cfg.levels
    n_dev = mesh.shape[data_axis]
    if K0 % n_dev != 0:
        raise ValueError(f"p^L={K0} must be a multiple of data axis {n_dev}")

    if cfg.partition_strategy == "stratified":
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K0, key)
        perm = plan.perm
    else:
        perm = part_mod.random_partitions(M, K0, key)
    xp, yp = x[perm], y[perm]

    K, m = K0, M // K0
    alphas = jnp.zeros((K, 2 * m), x.dtype)
    sweeps_per_level: list = []
    kkt = jnp.array(jnp.inf, x.dtype)
    level = cfg.levels

    while K >= n_dev:
        xs = xp.reshape(K, m, -1)
        ys = yp.reshape(K, m)

        body = partial(_level_body_local, spec=spec, params=params,
                       tol=cfg.tol, max_sweeps=cfg.max_sweeps, m=m)
        shmapped = shard_map(
            lambda a, b, c: body(a, b, c),
            mesh=mesh,
            in_specs=(P(data_axis), P(data_axis), P(data_axis)),
            out_specs=(P(data_axis), P(data_axis), P(data_axis)),
        )
        alphas, sweeps, kkts = jax.jit(shmapped)(xs, ys, alphas)
        sweeps_per_level.append(int(jnp.max(sweeps)))
        kkt = jnp.max(kkts)
        if K == 1:
            break
        Kn = K // cfg.p
        grouped = alphas.reshape(Kn, cfg.p, 2 * m)
        alphas = jax.vmap(merge_alphas)(grouped)
        K, m = Kn, m * cfg.p
        level -= 1
        if K < n_dev and K >= 1:
            break

    # replicated tail for K < n_dev (tiny residual levels)
    tail_jit = jax.jit(_solve_level,
                       static_argnames=("spec", "params", "tol",
                                        "max_sweeps"))
    while K >= 1:
        xs = xp.reshape(K, m, -1)
        ys = yp.reshape(K, m)
        alphas, sweeps, kkts = tail_jit(xs, ys, alphas, spec=spec,
                                        params=params, tol=cfg.tol,
                                        max_sweeps=cfg.max_sweeps)
        sweeps_per_level.append(int(jnp.max(sweeps)))
        kkt = jnp.max(kkts)
        if K == 1:
            break
        Kn = K // cfg.p
        grouped = alphas.reshape(Kn, cfg.p, 2 * m)
        alphas = jax.vmap(merge_alphas)(grouped)
        K, m = Kn, m * cfg.p
        level -= 1

    alpha = alphas.reshape(-1)
    return SODMResult(alpha=alpha, perm=perm, levels_run=cfg.levels + 1,
                      sweeps_per_level=sweeps_per_level, kkt=kkt)


# ---------------------------------------------------------------------------
# convenience: fit + predict in original index order
# ---------------------------------------------------------------------------

def fit(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
        cfg: SODMConfig, key: jax.Array) -> tuple[SODMResult, Array, Array]:
    """Returns (result, x_perm, y_perm); alpha is aligned with the permuted data."""
    res = solve(spec, x, y, params, cfg, key)
    return res, x[res.perm], y[res.perm]


def predict(spec: kf.KernelSpec, res: SODMResult, x_train: Array,
            y_train: Array, x_test: Array) -> Array:
    from repro.core import odm
    xp, yp = x_train[res.perm], y_train[res.perm]
    return odm.predict(spec, xp, yp, res.alpha, x_test)
