"""Warn-once shims for the legacy per-route training entry points.

Since the unified API (``repro.api``), the supported way to train is the
:class:`repro.api.ODMEstimator` facade backed by the capability-based
solver registry (:mod:`repro.api.registry`). The historical entry points
(``sodm.solve``/``solve_sharded``/``fit``, ``dsvrg.solve``/
``solve_sharded``, ``baselines.*_solve``) keep working unchanged as thin
shims: each warns ONCE per process, then delegates to the private
implementation the registry routes call directly — so training through
the facade never triggers a legacy warning.
"""
from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_once(entry: str, replacement: str) -> None:
    """Emit one ``FutureWarning`` per process for a legacy entry point."""
    if entry in _WARNED:
        return
    _WARNED.add(entry)
    warnings.warn(
        f"{entry} is a legacy entry point kept for back-compat (it "
        f"delegates unchanged); new code should train through "
        f"{replacement} — the repro.api facade over the solver registry.",
        FutureWarning, stacklevel=3)


def reset() -> None:
    """Forget which entries have warned (test hook)."""
    _WARNED.clear()
