"""Evaluate the paper's Theorem 1 / Theorem 2 bounds on concrete problems.

Used by property tests (tests/test_theory.py) and the partition-strategy
ablation benchmark: the theorems must *hold* for any valid inputs, and the
stratified strategy should give a smaller Q-bar (cross-partition kernel
mass) than random/cluster partitions — that is the mechanism behind the
paper's speedup.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dual_cd, kernel_fns as kf
from repro.core.odm import ODMParams, dual_objective

Array = jax.Array


class Theorem1Eval(NamedTuple):
    gap_objective: Array      # d(zeta~*, beta~*) - d(zeta*, beta*)
    gap_solution: Array       # ||alpha~* - alpha*||^2
    bound_objective: Array    # U^2 (Qbar + M (M - m) c)
    bound_solution: Array     # U^2/(M c v) (Qbar + M (M - m) c)
    holds: Array              # both inequalities satisfied (with fp slack)


def solve_global_and_blockwise(spec: kf.KernelSpec, x: Array, y: Array,
                               params: ODMParams, n_partitions: int,
                               tol: float = 1e-7, max_sweeps: int = 2000):
    """Optimal alpha for the global dual and for the block-diagonal
    approximation (Eqn. 4). Data is assumed already laid out in partition
    order (apply the plan's permutation first)."""
    M = x.shape[0]
    m = M // n_partitions
    Q = kf.signed_gram(spec, x, y)
    res_g = dual_cd.solve(Q, params, mscale=float(M), tol=tol,
                          max_sweeps=max_sweeps)
    # block-diagonal problem = K decoupled local solves with mscale=m
    pid = jnp.arange(M) // m
    mask = (pid[:, None] == pid[None, :]).astype(Q.dtype)
    Qt = Q * mask
    res_b = dual_cd.solve(Qt, params, mscale=float(m), tol=tol,
                          max_sweeps=max_sweeps)
    return Q, Qt, res_g.alpha, res_b.alpha


def eval_theorem1(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
                  n_partitions: int, tol: float = 1e-7) -> Theorem1Eval:
    M = x.shape[0]
    m = M // n_partitions
    Q, Qt, a_g, a_b = solve_global_and_blockwise(spec, x, y, params,
                                                 n_partitions, tol=tol)
    d_g = dual_objective(Q, a_g, params, float(M))
    d_b = dual_objective(Q, a_b, params, float(M))     # d() of the approx solution
    gap_obj = d_b - d_g
    gap_sol = jnp.sum((a_b - a_g) ** 2)

    U = jnp.maximum(jnp.max(jnp.abs(a_g)), jnp.max(jnp.abs(a_b)))
    pid = jnp.arange(M) // m
    cross = pid[:, None] != pid[None, :]
    Qbar = jnp.sum(jnp.where(cross, jnp.abs(Q), 0.0))
    c = params.c
    bound_obj = U ** 2 * (Qbar + M * (M - m) * c)
    bound_sol = U ** 2 / (M * c * params.ups) * (Qbar + M * (M - m) * c)
    slack = 1e-6 + 1e-5 * jnp.abs(bound_obj)
    holds = jnp.logical_and(
        jnp.logical_and(gap_obj >= -slack, gap_obj <= bound_obj + slack),
        gap_sol <= bound_sol + slack)
    return Theorem1Eval(gap_objective=gap_obj, gap_solution=gap_sol,
                        bound_objective=bound_obj, bound_solution=bound_sol,
                        holds=holds)


class Theorem2Eval(NamedTuple):
    gap: Array               # d_k(local) - d(global) for the worst k
    bound: Array
    cos_tau: Array
    holds: Array


def eval_theorem2(spec: kf.KernelSpec, x: Array, y: Array, params: ODMParams,
                  stratum: Array, n_partitions: int, perm: Array,
                  tol: float = 1e-7) -> Theorem2Eval:
    """Evaluates the Theorem-2 upper bound for the stratified partitions.

    Requires a shift-invariant kernel (r^2 = kappa(0)); asserts via
    spec.diag_value().
    """
    r2 = spec.diag_value()
    M = x.shape[0]
    m = M // n_partitions
    xp, yp = x[perm], y[perm]
    Q = kf.signed_gram(spec, xp, yp)
    res_g = dual_cd.solve(Q, params, mscale=float(M), tol=tol, max_sweeps=2000)
    d_g = dual_objective(Q, res_g.alpha, params, float(M))

    # worst-k local objective (each local uses mscale=m, objective d_k)
    worst = -jnp.inf
    U = jnp.max(jnp.abs(res_g.alpha))
    for k in range(n_partitions):
        sl = slice(k * m, (k + 1) * m)
        Qk = Q[sl, sl]
        res_k = dual_cd.solve(Qk, params, mscale=float(m), tol=tol,
                              max_sweeps=2000)
        d_k = dual_objective(Qk, res_k.alpha, params, float(m))
        worst = jnp.maximum(worst, d_k - d_g)
        U = jnp.maximum(U, jnp.max(jnp.abs(res_k.alpha)))

    cos_tau = part_cos_tau(spec, x, stratum)
    C = jnp.sum((stratum[:, None] != stratum[None, :]).astype(jnp.float32))
    c = params.c
    bound = (U ** 2 / 2.0 * (M ** 2 * r2 + r2 * cos_tau * (2.0 * C - M ** 2))
             + U ** 2 * M ** 2 * c + 2.0 * U * M)
    slack = 1e-6 + 1e-5 * jnp.abs(bound)
    return Theorem2Eval(gap=worst, bound=bound, cos_tau=cos_tau,
                        holds=worst <= bound + slack)


def part_cos_tau(spec: kf.KernelSpec, x: Array, stratum: Array) -> Array:
    """cos of the minimal principal angle across strata (Theorem 2's tau)."""
    K = kf.gram(spec, x)
    diag = jnp.sqrt(jnp.maximum(kf.gram_diag(spec, x), 1e-12))
    Kn = K / (diag[:, None] * diag[None, :])
    cross = stratum[:, None] != stratum[None, :]
    return jnp.max(jnp.where(cross, Kn, -jnp.inf))
