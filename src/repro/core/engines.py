"""Pluggable solver engines for SODM level solves.

Every level of Algorithm 1 is K independent partition-local ODM duals of
identical size. A :class:`LocalSolver` advances one whole level:

    (xs (K, m, d), ys (K, m), alphas (K, 2m))
        -> (alphas' (K, 2m), sweeps (K,), kkts (K,))

``sweeps`` counts solver iterations (CD sweeps for the scalar engine,
outer Jacobi passes for the block engines); a warm start already within
tol must report 0 so Algorithm 1 line 5's early-stop check keeps working.

Three engines, selected by ``SODMConfig.engine``:

* ``"scalar"`` — exact Gauss-Seidel CD (:func:`repro.core.dual_cd.solve`)
  vmapped over partitions. Faithful to the paper, latency-bound on
  accelerators (a ``fori_loop`` over 2m coordinates per sweep).

* ``"block"`` — pure-jnp block-Gauss-Seidel
  (:func:`repro.core.dual_cd.solve_block`) vmapped over partitions. The
  XLA oracle of the Pallas path; runs anywhere.

* ``"pallas"`` — greedy (Gauss-Southwell) block CD via the *fused* Pallas
  pass kernel (:mod:`repro.kernels.dual_cd_block`): every pass of a level
  is ONE ``pallas_call`` that runs all diagonal-tile sweeps AND the
  cross-tile Gram matvec the line search needs (no separate per-pass XLA
  matmul). When a partition outgrows ``gram_threshold``, the Gram tiles
  are rebuilt on the fly from the raw features for EVERY ``KernelSpec``
  family (rbf / laplacian / poly / linear — see
  :mod:`repro.kernels.gram`), keeping per-level memory O(m·B) instead of
  the O(m²) of a materialized Q. A kernel without a matrix-free lowering
  above the threshold triggers a one-time warning with the memory
  estimate before falling back to a materialized Q — never silently.

A fourth engine name, ``"dsvrg"``, is NOT a level solver: it is the
paper's "when linear kernel is applied" dispatch (Algorithm 2) to the
communication-efficient primal SVRG solver (:mod:`repro.core.dsvrg`).
The dispatch policy lives in the capability-based solver registry
(:func:`repro.api.registry.resolve_auto`); ``sodm.solve``/
``solve_sharded`` consult it BEFORE entering the level loop — explicitly
via ``SODMConfig.engine = "dsvrg"`` (linear kernel required), or
automatically for linear-kernel problems with
M >= ``SODMConfig.dsvrg_threshold`` — and recover the dual alpha from the
primal solution through ``odm.alpha_from_w``, so every dual-alpha consumer
(predict / baselines / benchmarks) reaches it uniformly.
:func:`wants_dsvrg` survives as the legacy boolean form of that policy.

Engines are plain closures so they can be jitted by the caller with
``spec``/``params``/``tol``/``max_sweeps`` static and used unchanged
inside ``shard_map`` bodies.
"""
from __future__ import annotations

import warnings
from typing import Protocol

import jax
import jax.numpy as jnp

from repro.core import dual_cd, kernel_fns as kf
from repro.core import odm
from repro.core.odm import ODMParams

Array = jax.Array

# level solvers (LocalSolver implementations) vs every SODMConfig.engine
# value — "dsvrg" is a whole-problem dispatch, not a level solver
LEVEL_ENGINES = ("scalar", "block", "pallas")
ENGINES = LEVEL_ENGINES + ("dsvrg",)


def wants_dsvrg(engine: str | None, kernel_name: str, M: int,
                threshold: int) -> bool:
    """The paper's linear-kernel dispatch rule (Section 3.3) — LEGACY
    predicate form.

    The policy itself now lives in the capability-based solver registry
    (:func:`repro.api.registry.resolve_auto`, the single source every
    route resolution goes through); this wrapper keeps the historical
    boolean API: True when the whole solve should route to the DSVRG
    primal engine — explicitly (``engine == "dsvrg"``, linear kernel
    required, raises otherwise) or automatically for a linear-kernel
    problem at/above ``threshold`` instances when the engine is left
    UNSET (``None``); any explicitly named engine, scalar included, is
    honored whatever the problem size.
    """
    from repro.api import registry    # deferred: registry imports core
    return registry.resolve_auto(kernel_name, M, engine=engine,
                                 threshold=threshold).name == "dsvrg"

# kernel names already warned about falling back to a materialized Q
_MATERIALIZED_WARNED: set[str] = set()


def _warn_materialized_fallback(name: str, K: int, m: int,
                                itemsize: int) -> None:
    """One-time warning when a kernel has no matrix-free Gram path.

    After the matrix-free Gram subsystem every ``KernelSpec`` family has
    one, so this only fires for kernels added without a tile lowering —
    but it must never be silent: the fallback allocates the full O(m²)
    Gram per partition.
    """
    if name in _MATERIALIZED_WARNED:
        return
    _MATERIALIZED_WARNED.add(name)
    gib = K * m * m * itemsize / 2 ** 30
    warnings.warn(
        f"kernel {name!r} has no matrix-free Gram lowering; the pallas "
        f"engine is materializing K={K} Gram blocks of {m}x{m} "
        f"(~{gib:.2f} GiB) despite gram_threshold — add the kernel to "
        f"repro.kernels.gram or lower gram_threshold expectations.",
        RuntimeWarning, stacklevel=3)


def _rescale_warm_start(Q: Array, ak: Array, params: ODMParams,
                        m: int) -> tuple[Array, Array]:
    """Exact line search along the warm-start ray (see odm.warm_start_scale).

    SODM merges concatenate child duals solved at scale m_child; this
    rescales them to the parent's scale before the solve. No-op (t = 1)
    for cold starts and already-converged starts. Returns the rescaled
    alpha AND its cache u = Q (zeta - beta) — u is linear in alpha, so
    the matvec paid here is handed to the solver instead of recomputed.
    """
    zeta, beta = odm.split_alpha(ak)
    u = Q @ (zeta - beta)
    t = odm.warm_start_scale(u, ak, params, float(m))
    return ak * t, u * t


class LocalSolver(Protocol):
    """Solves all K local ODM duals of one SODM level."""

    def __call__(self, xs: Array, ys: Array, alphas: Array, *,
                 spec: kf.KernelSpec, params: ODMParams, tol: float,
                 max_sweeps: int) -> tuple[Array, Array, Array]:
        ...


# ---------------------------------------------------------------------------
# scalar: exact Gauss-Seidel CD per partition (the paper's Algorithm 1)
# ---------------------------------------------------------------------------

def solve_level_scalar(xs: Array, ys: Array, alphas: Array, *,
                       spec: kf.KernelSpec, params: ODMParams, tol: float,
                       max_sweeps: int) -> tuple[Array, Array, Array]:
    m = xs.shape[1]

    def one(xk, yk, ak):
        Q = kf.signed_gram(spec, xk, yk)
        ak, uk = _rescale_warm_start(Q, ak, params, m)
        res = dual_cd.solve(Q, params, mscale=float(m), alpha0=ak,
                            tol=tol, max_sweeps=max_sweeps, u0=uk)
        return res.alpha, res.sweeps, res.kkt

    return jax.vmap(one)(xs, ys, alphas)


# ---------------------------------------------------------------------------
# block: pure-jnp block-Gauss-Seidel (oracle of the Pallas path)
# ---------------------------------------------------------------------------

def solve_level_block(xs: Array, ys: Array, alphas: Array, *,
                      spec: kf.KernelSpec, params: ODMParams, tol: float,
                      max_sweeps: int,
                      block: int = 256) -> tuple[Array, Array, Array]:
    m = xs.shape[1]
    blk = min(block, m)

    def one(xk, yk, ak):
        Q = kf.signed_gram(spec, xk, yk)
        ak, uk = _rescale_warm_start(Q, ak, params, m)
        res = dual_cd.solve_block(Q, params, mscale=float(m), block=blk,
                                  alpha0=ak, tol=tol, max_outer=max_sweeps,
                                  u0=uk)
        return res.alpha, res.sweeps, res.kkt

    return jax.vmap(one)(xs, ys, alphas)


# ---------------------------------------------------------------------------
# pallas: greedy tile kernel, whole level per pallas_call
# ---------------------------------------------------------------------------

def solve_level_pallas(xs: Array, ys: Array, alphas: Array, *,
                       spec: kf.KernelSpec, params: ODMParams, tol: float,
                       max_sweeps: int, block: int = 256,
                       gram_threshold: int = 4096,
                       adaptive: bool = True) -> tuple[Array, Array, Array]:
    from repro.kernels import dual_cd_block as cdk
    from repro.kernels import gram as gram_mod
    from repro.kernels import ops

    K, m, _ = xs.shape
    B = min(block, m)
    nblk = -(-m // B)
    mp = nblk * B
    pad = mp - m
    valid = (jnp.arange(mp) < m).astype(xs.dtype)

    xp = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
    # padded labels are 0 so the signed matvec y ⊙ (K @ (y ⊙ g)) zeroes
    # padded rows and columns without ever masking a Gram tile
    yp = jnp.pad(ys, ((0, 0), (0, pad)))
    z0, b0 = alphas[:, :m], alphas[:, m:]
    a0 = jnp.concatenate([jnp.pad(z0, ((0, 0), (0, pad))),
                          jnp.pad(b0, ((0, 0), (0, pad)))], axis=1)

    matrix_free = (m > gram_threshold
                   and spec.name in gram_mod.MATRIX_FREE_KERNELS)
    if m > gram_threshold and not matrix_free:
        _warn_materialized_fallback(spec.name, K, mp, xs.dtype.itemsize)
    if matrix_free:
        # diagonal Gram tiles only: (K, nblk, B, B) — O(m·B) per partition;
        # the off-diagonal mass is regenerated tile-by-tile inside the
        # fused pass kernel and never materialized
        x_t = xp.reshape(K * nblk, B, -1)
        y_t = yp.reshape(K * nblk, B)
        qb = jax.vmap(lambda xb, yb: kf.signed_gram(spec, xb, yb))(x_t, y_t)
        qb = qb.reshape(K, nblk, B, B)
        src = gram_mod.make_kernel_source(spec, xp, yp, bm=B, bn=B,
                                          interpret=ops._INTERPRET)
    else:
        Qp = jax.vmap(lambda xk, yk: kf.signed_gram(spec, xk, yk))(xp, yp)
        Qp = Qp * (valid[None, :, None] * valid[None, None, :])
        qb = jax.vmap(lambda q: cdk.extract_diag_blocks(q, B))(Qp)
        src = gram_mod.DenseSource(Qp)

    # warm-start ray rescale, batched over partitions; u is linear in
    # alpha so the rescaled cache rides along to the solver for free
    u0 = src.matvec(a0[:, :mp] - a0[:, mp:])
    t = jax.vmap(lambda u, a: odm.warm_start_scale(u, a, params,
                                                   float(m)))(u0, a0)
    a0 = a0 * t[:, None]
    u0 = u0 * t[:, None]

    out, kkts, passes = cdk.solve_level(
        qb, src, a0, c=params.c, ups=params.ups, theta=params.theta,
        mscale=float(m), n_passes=max_sweeps, tol=tol, valid=valid,
        us0=u0, adaptive=adaptive, interpret=ops._INTERPRET)
    alphas = jnp.concatenate([out[:, :m], out[:, mp:mp + m]], axis=1)
    sweeps = jnp.full((K,), passes, jnp.int32)
    return alphas, sweeps, kkts


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def make_local_solver(engine: str | None = "scalar", block: int = 256,
                      gram_threshold: int = 4096,
                      adaptive: bool = True) -> LocalSolver:
    """Resolve an engine name (``SODMConfig.engine``) to a LocalSolver.

    ``None`` (the config default, meaning "auto") resolves to the scalar
    level solver — the auto DSVRG dispatch happens in ``sodm`` *before*
    the level loop, so by the time a LocalSolver is built the choice is
    between level engines only.
    """
    if engine is None:
        engine = "scalar"
    if engine == "scalar":
        return solve_level_scalar
    if engine == "block":
        def _block(xs, ys, alphas, *, spec, params, tol, max_sweeps):
            return solve_level_block(xs, ys, alphas, spec=spec,
                                     params=params, tol=tol,
                                     max_sweeps=max_sweeps, block=block)
        return _block
    if engine == "pallas":
        def _pallas(xs, ys, alphas, *, spec, params, tol, max_sweeps):
            return solve_level_pallas(xs, ys, alphas, spec=spec,
                                      params=params, tol=tol,
                                      max_sweeps=max_sweeps, block=block,
                                      gram_threshold=gram_threshold,
                                      adaptive=adaptive)
        return _pallas
    if engine == "dsvrg":
        raise ValueError(
            "engine='dsvrg' is a whole-problem primal solver, not a level "
            "solver — sodm.solve/solve_sharded dispatch it before the "
            "level loop (see engines.wants_dsvrg)")
    raise ValueError(
        f"engine must be one of {LEVEL_ENGINES} (or 'dsvrg'/None at the "
        f"SODMConfig level), got {engine!r}")
