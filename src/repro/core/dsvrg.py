"""DSVRG for linear-kernel ODM (paper Algorithm 2, after Lee et al. 2017).

Per epoch:
  1. every node computes the sum of per-instance gradients on its partition;
     one all-reduce produces the full gradient h (the only O(d)
     communication of the epoch besides the iterate hand-off);
  2. nodes run SVRG inner updates
         w <- w - eta * (grad_i(w) - grad_i(w_anchor) + h)
     serially in a round-robin, each consuming its local auxiliary samples
     without replacement and passing w to the next node.

Execution model (the jitted epoch-scan driver): both :func:`solve` and
:func:`solve_sharded` run ALL epochs inside one ``lax.scan`` — each config
traces exactly once (pinned by ``epoch_trace_count`` in the test battery),
the iterate w never round-trips to host between epochs, the per-epoch
objective history is accumulated on device in the scan carry (the sharded
layout reduces it with a ``psum`` of local loss sums instead of
re-evaluating the full objective on host), and the ``auto_eta`` smoothness
step is computed inside the trace (a ``psum`` of E‖x‖² on the mesh) so
sharded and single-process solves always use the same step size. Every
partition is pre-sliced into ceil(m/batch) static minibatches with a
validity mask on the ragged tail, so each sample is consumed exactly once
per epoch (Alg. 2's without-replacement sampling) whatever the batch size.

The inner-step direction g_w − g_a + h is the hot spot; on TPU it runs as
ONE fused Pallas pass over the minibatch (margins for w AND the anchor as
a single MXU op, coefficient difference, back-projection — see
:mod:`repro.kernels.odm_grad`), with the pure-jnp form
(:func:`repro.core.odm.svrg_direction`) as the interpret-mode/CPU
reference (``DSVRGConfig.fused``).

Faithful mode (:func:`solve`) reproduces the serial chain exactly with a
``lax.scan`` over nodes (inner scan over that node's minibatches). SPMD
mode (:func:`solve_sharded`) keeps step 1 as a ``psum`` on the mesh and
offers two inner-phase schedules:

* ``schedule='serial'`` — the faithful round-robin. On an SPMD mesh every
  device executes the same chain over the all-gathered partitions
  (replicated compute, one slab gather per epoch); semantically identical
  to the paper, trivially correct.
* ``schedule='parallel'`` — beyond-paper: all K chains advance in parallel
  from the same anchor and are averaged at epoch end (local-SGD style).
  One extra O(d) all-reduce per epoch; K× less wall-clock per epoch. Lee
  et al.'s sampling-without-replacement analysis covers each chain; the
  averaging step is the standard local-update extension. EXPERIMENTS
  ablates both.

The objective/gradients are the primal ODM of Section 3.3 (see
repro.core.odm.{primal_objective, svrg_direction}).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import odm
from repro.core import partition as part_mod
from repro.core.odm import ODMParams
from repro.observe.spans import span as _span

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DSVRGConfig:
    n_partitions: int = 8
    n_landmarks: int = 8
    epochs: int = 10
    eta: float = 0.0                # <= 0: auto = 0.5 / L_hat (see auto_eta)
    batch: int = 1                  # inner minibatch size (1 = paper-faithful)
    schedule: str = "serial"        # serial | parallel
    partition_strategy: str = "stratified"
    fused: bool | None = None       # None: fused Pallas direction kernel when
    #                                 compiled (TPU), jnp reference under
    #                                 interpret mode / CPU
    coreset_frac: float = 0.1       # anchor-coreset fraction of the csvrg
    #                                 baseline route (ignored elsewhere)
    stream_slab: int = 4096         # rows per host->device slab on the
    #                                 streaming path (_solve_stream); rounded
    #                                 up to a multiple of ``batch``


def auto_eta(x: Array, params: ODMParams, frac: float = 0.5) -> float:
    """Step size from the smoothness of the per-instance objective:
    L_hat = 1 + s * E||x||^2 with s = lam/(1-theta)^2 (the Hessian of the
    quadratic-hinge term is bounded by s x xᵀ; the ridge adds 1).

    Host-side convenience; the solve drivers evaluate the identical
    formula inside the trace (sharded: psum of the local ‖x‖² sums), so a
    solve never pays a host round-trip for it.
    """
    return float(_eta_from_sumsq(jnp.sum(x * x), params, x.shape[0], frac))


def _eta_from_sumsq(sumsq: Array, params: ODMParams, M: int,
                    frac: float = 0.5) -> Array:
    s = params.lam / (1.0 - params.theta) ** 2
    return frac / (1.0 + s * sumsq / M)


class DSVRGResult(NamedTuple):
    w: Array
    history: Array      # (epochs,) primal objective after each epoch
    perm: Array
    eta: Array | float = 0.0   # step size actually used (auto or cfg.eta)


# ---------------------------------------------------------------------------
# trace accounting (compile-count pin for the scan drivers)
# ---------------------------------------------------------------------------

# one append per jit trace of a solve driver (local or sharded). The scan
# body itself is NOT counted — lax.scan legitimately retraces its body for
# abstract eval; what we pin is that a whole solve is one trace per config.
# The store is the invariant registry's counter ("dsvrg.epoch_trace" —
# verified by routes.dsvrg.trace_once); _TRACE_EVENTS aliases the SAME
# list object so existing `_TRACE_EVENTS[-1]` consumers keep working.
from repro.analysis.invariants import counter as _inv_counter  # noqa: E402

_TRACE_EVENTS: list = _inv_counter("dsvrg.epoch_trace").events


def epoch_trace_count() -> int:
    """How many times a DSVRG solve driver has been traced (not dispatched)."""
    return len(_TRACE_EVENTS)


def _resolve_fused(cfg: DSVRGConfig) -> bool:
    if cfg.fused is not None:
        return cfg.fused
    from repro.kernels import ops
    return not ops._INTERPRET


# ---------------------------------------------------------------------------
# batched-epoch building blocks
# ---------------------------------------------------------------------------

def _pad_batches(xs: Array, ys: Array,
                 batch: int) -> tuple[Array, Array, Array]:
    """Pre-slice partitions into static minibatches with a ragged-tail mask.

    xs (K, m, d), ys (K, m) -> xs (K, S, b, d), ys (K, S, b), wts (S, b)
    with S = ceil(m / b); padded rows have x = 0, y = 0, weight 0, so every
    real sample is consumed exactly once per epoch and the tail step's mean
    divides by the true tail size.
    """
    K, m, d = xs.shape
    b = min(batch, m)
    S = -(-m // b)
    pad = S * b - m
    xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
    ys = jnp.pad(ys, ((0, 0), (0, pad)))
    wts = (jnp.arange(S * b) < m).astype(xs.dtype).reshape(S, b)
    return xs.reshape(K, S, b, d), ys.reshape(K, S, b), wts


def _direction(w: Array, anchor: Array, h: Array, xb: Array, yb: Array,
               wb: Array, params: ODMParams, fused: bool) -> Array:
    """One inner step's g_w − g_a + h: fused Pallas pass or jnp reference."""
    if fused:
        from repro.kernels import ops
        return ops.svrg_grad(w, anchor, h, xb, yb, wb, lam=params.lam,
                             theta=params.theta, ups=params.ups)
    return odm.svrg_direction(w, anchor, h, xb, yb, params, wb=wb)


def _loss_grad(anchor: Array, xf: Array, yf: Array, params: ODMParams,
               M: int, fused: bool) -> Array:
    """Hinge part of the full gradient over (possibly padded) rows, scaled
    by the TRUE count M. Padded rows (x = 0, y = 0) contribute nothing.
    The caller adds the ridge term (the anchor itself) after any psum."""
    if fused:
        from repro.kernels import ops
        g = ops.odm_grad(anchor, xf, yf,
                         lam=params.lam * xf.shape[0] / M,
                         theta=params.theta, ups=params.ups)
    else:
        g = odm.primal_grad(anchor, xf, yf, params, total=M)
    return g - anchor


def _epoch_serial(w: Array, xs: Array, ys: Array, wts: Array, anchor: Array,
                  h: Array, eta: Array, params: ODMParams,
                  fused: bool) -> Array:
    """One faithful round-robin epoch. xs: (K, S, b, d) pre-sliced
    minibatches; wts (S, b) masks each step's ragged-tail padding."""

    def node_body(w, xk_yk):
        xk, yk = xk_yk

        def inner(w, sl):
            xb, yb, wb = sl
            return w - eta * _direction(w, anchor, h, xb, yb, wb, params,
                                        fused), None

        w, _ = jax.lax.scan(inner, w, (xk, yk, wts))
        return w, None

    w, _ = jax.lax.scan(node_body, w, (xs, ys))
    return w


def _epoch_parallel(w: Array, xs: Array, ys: Array, wts: Array,
                    anchor: Array, h: Array, eta: Array, params: ODMParams,
                    fused: bool) -> Array:
    """Beyond-paper: K independent chains from the same anchor, averaged."""

    def chain(xk, yk):
        def inner(wk, sl):
            xb, yb, wb = sl
            return wk - eta * _direction(wk, anchor, h, xb, yb, wb, params,
                                         fused), None

        wk, _ = jax.lax.scan(inner, w, (xk, yk, wts))
        return wk

    ws = jax.vmap(chain)(xs, ys)                     # (K, d)
    return jnp.mean(ws, axis=0)


def _flatten(xs: Array, ys: Array, wts: Array):
    """(K, S, b, *) batch layout -> flat padded rows + per-row weights."""
    K, S, b = ys.shape
    xf = xs.reshape(K * S * b, -1)
    yf = ys.reshape(K * S * b)
    wf = jnp.broadcast_to(wts[None], (K, S, b)).reshape(K * S * b)
    return xf, yf, wf


def _partition_perm(x: Array, cfg: DSVRGConfig, K: int,
                    key: jax.Array) -> Array:
    from repro.core import kernel_fns as kf
    M = x.shape[0]
    if cfg.partition_strategy == "identity":
        # stream-order chain: rows stay where they are. This is what the
        # streaming driver implicitly uses (it has no global perm), so
        # the dense-vs-streaming parity tests run the dense solver with
        # this strategy to make the two inner chains comparable.
        return jnp.arange(M)
    if cfg.partition_strategy == "stratified":
        # linear kernel: strata in input space (phi = identity)
        spec = kf.KernelSpec(name="linear")
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K, key)
        return plan.perm
    return part_mod.random_partitions(M, K, key)


# ---------------------------------------------------------------------------
# single-process driver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("params", "cfg", "M"))
def _run(w0: Array, xs: Array, ys: Array, wts: Array, *, params: ODMParams,
         cfg: DSVRGConfig, M: int):
    """All epochs of a single-process solve in one trace (lax.scan)."""
    _TRACE_EVENTS.append(("local", cfg, M))
    fused = _resolve_fused(cfg)
    epoch_fn = _epoch_serial if cfg.schedule == "serial" else _epoch_parallel
    xf, yf, wf = _flatten(xs, ys, wts)
    if cfg.eta > 0:
        eta = jnp.asarray(cfg.eta, xs.dtype)
    else:
        eta = _eta_from_sumsq(jnp.sum(wf * jnp.sum(xf * xf, axis=-1)),
                              params, M).astype(xs.dtype)

    def epoch(w, _):
        anchor = w
        h = anchor + _loss_grad(anchor, xf, yf, params, M, fused)
        w = epoch_fn(w, xs, ys, wts, anchor, h, eta, params, fused)
        return w, odm.primal_objective(w, xf, yf, params, weights=wf,
                                       total=M)

    w, hist = jax.lax.scan(epoch, w0, None, length=cfg.epochs)
    return w, hist, eta


def solve(x: Array, y: Array, params: ODMParams, cfg: DSVRGConfig,
          key: jax.Array, w0: Array | None = None) -> DSVRGResult:
    """Single-process DSVRG (Algorithm 2) — legacy entry point; the
    supported front door is ``repro.api.ODMEstimator`` with
    ``route="dsvrg"`` (this shim warns once and delegates unchanged)."""
    from repro.core import deprecation as _dep
    _dep.warn_once("repro.core.dsvrg.solve",
                   "repro.api.ODMEstimator(route='dsvrg').fit")
    return _solve(x, y, params, cfg, key, w0)


def _solve(x: Array, y: Array, params: ODMParams, cfg: DSVRGConfig,
           key: jax.Array, w0: Array | None = None, *, faults=None,
           tracker=None, resume=None) -> DSVRGResult:
    M, d = x.shape
    K = cfg.n_partitions
    if M % K != 0:
        raise ValueError(f"K={K} must divide M={M}")
    if cfg.schedule not in ("serial", "parallel"):
        raise ValueError(f"unknown schedule {cfg.schedule!r}")

    perm = _partition_perm(x, cfg, K, key)
    xp, yp = x[perm], y[perm]
    xs, ys, wts = _pad_batches(xp.reshape(K, M // K, d),
                               yp.reshape(K, M // K), cfg.batch)
    w0 = jnp.zeros(d, x.dtype) if w0 is None else w0
    if faults is None and tracker is None and resume is None:
        w, hist, eta = _run(w0, xs, ys, wts, params=params, cfg=cfg, M=M)
    else:
        def runner(w, n):
            return _run(w, xs, ys, wts, params=params,
                        cfg=dataclasses.replace(cfg, epochs=n), M=M)

        w, hist, eta = _segmented(runner, w0, cfg, M, perm=perm,
                                  faults=faults, tracker=tracker,
                                  resume=resume)
    return DSVRGResult(w=w, history=hist, perm=perm, eta=eta)


# ---------------------------------------------------------------------------
# streaming driver (out-of-core: consumes a ShardedSource slab by slab)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _make_stream_steps(params: ODMParams, batch: int, fused: bool):
    """The two jitted per-slab kernels of the streaming driver.

    ``stats(anchor, xf, yf, wf, M)`` — one slab's contribution to the
    full-gradient / objective / ‖x‖² reductions of an epoch's anchor
    pass (flat padded rows, scaled by the true global M so partials sum
    to the dense quantities).

    ``inner(w, anchor, h, eta, xs, ys, wts)`` — the SVRG inner chain
    over one slab's pre-sliced (C, b, ·) minibatches: exactly
    ``_epoch_serial``'s inner scan, except a fully-padded minibatch
    (weight-sum 0, which only the zero-padded final slab can produce)
    is masked to a no-op instead of stepping by ``w − anchor + h``.

    Cached per (params, batch, fused) with jit handling shapes, so a
    whole streaming fit is two traces per config — the same trace-once
    discipline as the resident drivers, pinned via ``_TRACE_EVENTS``.
    """

    @functools.partial(jax.jit, static_argnames=("M",))
    def stats(anchor, xf, yf, wf, *, M):
        _TRACE_EVENTS.append(("stream.stats", params, batch, M))
        gpart = _loss_grad(anchor, xf, yf, params, M, fused)
        ridge = 0.5 * anchor @ anchor
        losspart = odm.primal_objective(anchor, xf, yf, params, weights=wf,
                                        total=M) - ridge
        sqpart = jnp.sum(wf * jnp.sum(xf * xf, axis=-1))
        return gpart, losspart, sqpart

    @jax.jit
    def inner(w, anchor, h, eta, xs, ys, wts):
        _TRACE_EVENTS.append(("stream.inner", params, batch))

        def step(w, sl):
            xb, yb, wb = sl
            live = jnp.where(jnp.sum(wb) > 0.0, eta, jnp.zeros_like(eta))
            return w - live * _direction(w, anchor, h, xb, yb, wb, params,
                                         fused), None

        w, _ = jax.lax.scan(step, w, (xs, ys, wts))
        return w

    return stats, inner


def _solve_stream(source, params: ODMParams, cfg: DSVRGConfig,
                  key: jax.Array | None = None, w0: Array | None = None, *,
                  faults=None, tracker=None, resume=None, depth: int = 2,
                  executor=None, metrics=None, accountant=None
                  ) -> tuple[DSVRGResult, Array]:
    """Out-of-core DSVRG: epochs stream ``cfg.stream_slab``-row slabs
    from a :class:`repro.data.streaming.sources.ShardedSource` through
    the prefetch loader; the (M, d) matrix is never resident.

    Per epoch, two passes over the stream: an anchor pass accumulating
    the full gradient h (plus the previous iterate's objective and, on
    the very first pass, the ``auto_eta`` ‖x‖² sum), then the serial
    SVRG inner chain over the global minibatch sequence. Slab
    boundaries are global row indices (``iter_slabs``), so every
    reduction runs in a fixed order — the fitted ``w`` is bitwise
    invariant to how the source is sharded, and a kill/resume replay
    through :class:`~repro.distributed.resume.DsvrgResumeManager` is
    bitwise identical to the uninterrupted run. Relative to the
    resident solver this is the K=1 stream-order chain
    (``partition_strategy="identity"``); ``n_partitions`` /
    ``partition_strategy`` are ignored.

    Returns ``(result, kkt)`` with ``result.perm = None`` (a stream has
    no materialized permutation) and ``kkt = ‖∇p(w)‖∞`` from a terminal
    gradient pass — the primal-stationarity analogue of the dual
    routes' projected-gradient residual.
    """
    from repro.data.streaming import loader as stream_loader

    M, d = int(source.n_rows), int(source.n_features)
    if M <= 0:
        raise ValueError("streaming solve needs a non-empty source")
    if cfg.schedule != "serial":
        raise ValueError(
            "streaming DSVRG supports schedule='serial' only (the "
            "parallel schedule needs all K chains resident at once); "
            f"got {cfg.schedule!r}")
    del key                      # stream order is the partition order
    b = min(cfg.batch, M)
    R = -(-max(cfg.stream_slab, b) // b) * b      # slab rows, multiple of b
    C = R // b
    dtype = jnp.zeros(0, dtype=source.dtype).dtype
    stats_fn, inner_fn = _make_stream_steps(params, b, _resolve_fused(cfg))

    if metrics is None and tracker is not None:
        from repro.observe import MetricsRegistry
        metrics = MetricsRegistry()

    def slabs():
        return stream_loader.iter_slabs(
            source, R, depth=depth, executor=executor, metrics=metrics,
            faults=faults, accountant=accountant)

    def slab_weights(n_valid: int):
        return (jnp.arange(R) < n_valid).astype(dtype)

    def anchor_pass(anchor):
        g = jnp.zeros(d, dtype)
        loss = jnp.zeros((), dtype)
        sq = jnp.zeros((), dtype)
        for slab in slabs():
            gp, lp, sp = stats_fn(anchor, jnp.asarray(slab.x),
                                  jnp.asarray(slab.y),
                                  slab_weights(slab.n_valid), M=M)
            g, loss, sq = g + gp, loss + lp, sq + sp
        return g, loss, sq

    eta_box: list = [jnp.asarray(cfg.eta, dtype) if cfg.eta > 0 else None]
    kkt_box: list = [jnp.zeros((), dtype)]

    def runner(w, n):
        """n epochs from iterate w -> (w', hist_n, eta); the _segmented
        contract. History entry e is obj(w after epoch e), read off the
        next epoch's anchor pass (or a terminal pass for the last one) —
        the streamed anchor pass already evaluates the objective, so no
        extra scan is spent on history except at segment end."""
        if n <= 0:
            eta0 = eta_box[0] if eta_box[0] is not None \
                else jnp.zeros((), dtype)
            return w, jnp.zeros((0,), dtype), eta0
        hist = []
        for e in range(n):
            anchor = w
            g, loss, sq = anchor_pass(anchor)
            if eta_box[0] is None:
                eta_box[0] = _eta_from_sumsq(sq, params, M).astype(dtype)
            if e > 0:
                hist.append(0.5 * anchor @ anchor + loss)
            h = anchor + g
            for slab in slabs():
                xs = jnp.asarray(slab.x).reshape(C, b, d)
                ys = jnp.asarray(slab.y).reshape(C, b)
                wts = slab_weights(slab.n_valid).reshape(C, b)
                w = inner_fn(w, anchor, h, eta_box[0], xs, ys, wts)
        g, loss, _ = anchor_pass(w)
        hist.append(0.5 * w @ w + loss)
        kkt_box[0] = jnp.max(jnp.abs(w + g))
        return w, jnp.stack(hist), eta_box[0]

    w0 = jnp.zeros(d, dtype) if w0 is None else w0
    if faults is None and tracker is None and resume is None:
        w, hist, eta = runner(w0, cfg.epochs)
    else:
        w, hist, eta = _segmented(runner, w0, cfg, M,
                                  perm=jnp.zeros((0,), jnp.int32),
                                  faults=faults, tracker=tracker,
                                  resume=resume)
    if metrics is not None and tracker is not None:
        metrics.drain(tracker, step=cfg.epochs)
    return DSVRGResult(w=w, history=hist, perm=None, eta=eta), kkt_box[0]


# ---------------------------------------------------------------------------
# segmented epoch driver (the instrumented / resumable path)
# ---------------------------------------------------------------------------

def _segmented(runner, w0: Array, cfg: DSVRGConfig, M: int, *, perm: Array,
               faults=None, tracker=None, resume=None):
    """Run ``cfg.epochs`` as checkpointable segments of the epoch scan.

    ``runner(w, n) -> (w', hist_n, eta)`` executes ``n`` epochs from
    iterate ``w`` (one jitted scan per distinct segment length — the
    default single-scan path and its trace-once pin are untouched; this
    driver only exists when faults/tracker/resume are requested). SVRG
    re-anchors at every epoch start, so the iterate ``w`` alone restarts
    the next epoch exactly and splitting the scan never changes the math:
    a resumed run and an uninterrupted run of this driver are
    bit-identical by construction.

    Between segments: the ``"dsvrg.segment"`` fault site fires, the
    tracker logs ``(epoch, objective, throughput)``, and the resume
    manager checkpoints ``{w, history, perm} + {epoch, eta}`` (the
    ``(w, anchor, epoch)`` of the module docs — anchor coincides with
    ``w`` at the boundary).
    """
    w, done, hist = w0, 0, None
    eta = jnp.zeros((), w0.dtype)
    seg = resume.segment if resume is not None else 1
    if resume is not None:
        restored = resume.restore()
        if restored is not None:
            w, done, hist = restored.w, restored.epoch, restored.history
            eta = jnp.asarray(restored.eta, w.dtype)
    while done < cfg.epochs:
        if faults is not None:
            faults.site("dsvrg.segment", epoch=done)
        n = min(seg, cfg.epochs - done)
        t0 = time.perf_counter()
        with _span("dsvrg.segment", epoch=done, epochs=n):
            w, h, eta = runner(w, n)
        hist = h if hist is None else jnp.concatenate([hist, h])
        done += n
        if tracker is not None:
            jax.block_until_ready(w)
            wall = time.perf_counter() - t0
            tracker.log_metrics(done, {
                "route": "dsvrg", "epoch": done,
                "objective": float(h[-1]), "eta": float(eta),
                "wall_s": wall, "rows_per_s": n * M / max(wall, 1e-9)})
        if resume is not None:
            resume.save_segment(epoch=done, w=w, history=hist, perm=perm,
                                eta=eta)
    if hist is None:                   # epochs == 0 and nothing restored
        hist = jnp.zeros((0,), w.dtype)
    return w, hist, eta


# ---------------------------------------------------------------------------
# SPMD engine
# ---------------------------------------------------------------------------

def _gather_slab(xs: Array, ys: Array,
                 data_axis: str) -> tuple[Array, Array]:
    """All-gather the (K, S, b, ·) partition slab for the serial chain."""
    return (jax.lax.all_gather(xs, data_axis, tiled=True),
            jax.lax.all_gather(ys, data_axis, tiled=True))


def _sharded_eta(xs: Array, ys: Array, wts: Array, params: ODMParams,
                 cfg: DSVRGConfig, M: int, data_axis: str,
                 eta: float | None) -> Array:
    """Step size inside the shard_map body. Explicit eta wins; otherwise
    auto_eta from the *sharded* data — a psum of the local ‖x‖² sums, so
    every device (and the single-process driver) lands on the identical
    step size. This replaces the old hardcoded 0.05 fallback."""
    if eta is not None:
        return jnp.asarray(eta, xs.dtype)
    if cfg.eta > 0:
        return jnp.asarray(cfg.eta, xs.dtype)
    xf, _, wf = _flatten(xs, ys, wts)
    sumsq = jax.lax.psum(jnp.sum(wf * jnp.sum(xf * xf, axis=-1)), data_axis)
    return _eta_from_sumsq(sumsq, params, M).astype(xs.dtype)


def _sharded_epoch(w: Array, xs: Array, ys: Array, wts: Array, eta: Array,
                   params: ODMParams, cfg: DSVRGConfig, M: int,
                   data_axis: str, fused: bool,
                   gathered: tuple[Array, Array] | None = None
                   ) -> tuple[Array, Array]:
    """One epoch inside a shard_map body: (w, local slab) -> (w', obj).

    Step 1 (full gradient) is a psum — the paper's single center-node
    reduction. Step 2 follows cfg.schedule (see module docs). The returned
    objective is the GLOBAL primal objective, assembled on device from the
    psum of local loss sums plus one ridge term — no host re-evaluation.
    ``gathered`` lets the epoch-scan driver all-gather the (loop-
    invariant) serial-schedule slab ONCE outside the scan instead of once
    per epoch — XLA does not hoist collectives out of while loops.
    """
    anchor = w
    xf, yf, wf = _flatten(xs, ys, wts)
    g_local = _loss_grad(anchor, xf, yf, params, M, fused)
    h = jax.lax.psum(g_local, data_axis) + anchor

    if cfg.schedule == "parallel":
        wk = _epoch_parallel(w, xs, ys, wts, anchor, h, eta, params, fused)
        w = jax.lax.pmean(wk, data_axis)
    else:
        xg, yg = gathered if gathered is not None else \
            _gather_slab(xs, ys, data_axis)
        w = _epoch_serial(w, xg, yg, wts, anchor, h, eta, params, fused)

    ridge = 0.5 * w @ w
    loss_local = odm.primal_objective(w, xf, yf, params, weights=wf,
                                      total=M) - ridge
    obj = jax.lax.psum(loss_local, data_axis) + ridge
    return w, obj


@functools.lru_cache(maxsize=None)
def _make_sharded_run(mesh: jax.sharding.Mesh, params: ODMParams,
                      cfg: DSVRGConfig, M: int, data_axis: str):
    """jit(shard_map) over ALL epochs: (w0, xs, ys, wts) -> (w, hist, eta).

    Cached per (mesh, params, cfg, M, data_axis) so repeated solves reuse
    one trace; the epoch loop is a lax.scan with the on-device objective
    history in the scanned carry.
    """
    from jax.experimental.shard_map import shard_map

    fused = _resolve_fused(cfg)

    def run(w0, xs, ys, wts):
        eta = _sharded_eta(xs, ys, wts, params, cfg, M, data_axis, None)
        # the serial chain consumes the full slab every epoch — gather it
        # once here, not once per scan iteration
        gathered = _gather_slab(xs, ys, data_axis) \
            if cfg.schedule == "serial" else None

        def epoch(w, _):
            return _sharded_epoch(w, xs, ys, wts, eta, params, cfg, M,
                                  data_axis, fused, gathered=gathered)

        w, hist = jax.lax.scan(epoch, w0, None, length=cfg.epochs)
        return w, hist, eta

    shm = shard_map(
        run, mesh=mesh,
        in_specs=(P(), P(data_axis), P(data_axis), P()),
        out_specs=(P(), P(), P()),
        check_rep=False,     # the SVRG carry w becomes data-varying inside
    )

    def traced(w0, xs, ys, wts):
        _TRACE_EVENTS.append(("sharded", cfg, M))
        return shm(w0, xs, ys, wts)

    return jax.jit(traced)


def make_sharded_epoch(mesh: jax.sharding.Mesh, params: ODMParams,
                       cfg: DSVRGConfig, M: int, data_axis: str = "data",
                       eta: float | None = None):
    """Builds a jit'd SPMD *single*-epoch function over partitions sharded
    on ``data_axis``: (w, xs, ys) -> (w', obj_global). Validation helper —
    production solves go through the epoch-scan driver (solve_sharded),
    which never hands w back to host between epochs.

    When ``eta`` is omitted and ``cfg.eta <= 0`` the step size is the
    ``auto_eta`` smoothness step computed from the sharded data (psum of
    the local ‖x‖² sums) — identical to the single-process step size.
    """
    from jax.experimental.shard_map import shard_map

    fused = _resolve_fused(cfg)

    def epoch(w, xs, ys):
        # xs: (K_loc, m, d) local slab on each device
        xsb, ysb, wts = _pad_batches(xs, ys, cfg.batch)
        eta_v = _sharded_eta(xsb, ysb, wts, params, cfg, M, data_axis, eta)
        return _sharded_epoch(w, xsb, ysb, wts, eta_v, params, cfg, M,
                              data_axis, fused)

    return jax.jit(shard_map(
        epoch, mesh=mesh,
        in_specs=(P(), P(data_axis), P(data_axis)),
        out_specs=(P(), P()),
        check_rep=False,     # the SVRG carry w becomes data-varying inside
    ))


def solve_sharded(x: Array, y: Array, params: ODMParams, cfg: DSVRGConfig,
                  key: jax.Array, mesh: jax.sharding.Mesh,
                  data_axis: str = "data",
                  w0: Array | None = None) -> DSVRGResult:
    """SPMD DSVRG — legacy entry point; the supported front door is
    ``repro.api.ODMEstimator`` with ``route="dsvrg"`` and ``mesh=`` (this
    shim warns once and delegates unchanged)."""
    from repro.core import deprecation as _dep
    _dep.warn_once("repro.core.dsvrg.solve_sharded",
                   "repro.api.ODMEstimator(route='dsvrg').fit")
    return _solve_sharded(x, y, params, cfg, key, mesh, data_axis, w0)


def _solve_sharded(x: Array, y: Array, params: ODMParams, cfg: DSVRGConfig,
                   key: jax.Array, mesh: jax.sharding.Mesh,
                   data_axis: str = "data",
                   w0: Array | None = None, *, faults=None, tracker=None,
                   resume=None) -> DSVRGResult:
    M, d = x.shape
    K = cfg.n_partitions
    n_dev = mesh.shape[data_axis]
    if M % K != 0:
        raise ValueError(f"K={K} must divide M={M}")
    if K % n_dev != 0:
        raise ValueError(f"K={K} must be a multiple of data axis size {n_dev}")
    if cfg.schedule not in ("serial", "parallel"):
        raise ValueError(f"unknown schedule {cfg.schedule!r}")

    perm = _partition_perm(x, cfg, K, key)
    xp, yp = x[perm], y[perm]
    xs, ys, wts = _pad_batches(xp.reshape(K, M // K, d),
                               yp.reshape(K, M // K), cfg.batch)

    w0 = jnp.zeros(d, x.dtype) if w0 is None else w0
    if faults is None and tracker is None and resume is None:
        run = _make_sharded_run(mesh, params, cfg, M, data_axis)
        w, hist, eta = run(w0, xs, ys, wts)
    else:
        def runner(w, n):
            run = _make_sharded_run(mesh, params,
                                    dataclasses.replace(cfg, epochs=n),
                                    M, data_axis)
            return run(w, xs, ys, wts)

        w, hist, eta = _segmented(runner, w0, cfg, M, perm=perm,
                                  faults=faults, tracker=tracker,
                                  resume=resume)
    return DSVRGResult(w=w, history=hist, perm=perm, eta=eta)
