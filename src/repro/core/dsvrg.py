"""DSVRG for linear-kernel ODM (paper Algorithm 2, after Lee et al. 2017).

Per epoch:
  1. every node computes the sum of per-instance gradients on its partition;
     one all-reduce produces the full gradient h (the only O(d)
     communication of the epoch besides the iterate hand-off);
  2. nodes run SVRG inner updates
         w <- w - eta * (grad_i(w) - grad_i(w_anchor) + h)
     serially in a round-robin, each consuming its local auxiliary samples
     without replacement and passing w to the next node.

Faithful mode (:func:`solve`) reproduces the serial chain exactly with a
``lax.scan`` over nodes (inner scan over that node's samples). SPMD mode
(:func:`solve_sharded`) keeps step 1 as a ``psum`` on the mesh and offers
two inner-phase schedules:

* ``schedule='serial'`` — the faithful round-robin. On an SPMD mesh every
  device executes the same chain (replicated compute, zero extra comm);
  semantically identical to the paper, trivially correct.
* ``schedule='parallel'`` — beyond-paper: all K chains advance in parallel
  from the same anchor and are averaged at epoch end (local-SGD style).
  One extra O(d) all-reduce per epoch; K× less wall-clock per epoch. Lee
  et al.'s sampling-without-replacement analysis covers each chain; the
  averaging step is the standard local-update extension. EXPERIMENTS
  ablates both.

The objective/gradients are the primal ODM of Section 3.3 (see
repro.core.odm.{primal_objective, minibatch_grad}).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import partition as part_mod
from repro.core.odm import ODMParams, minibatch_grad, primal_grad, primal_objective

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DSVRGConfig:
    n_partitions: int = 8
    n_landmarks: int = 8
    epochs: int = 10
    eta: float = 0.0                # <= 0: auto = 0.5 / L_hat (see below)
    batch: int = 1                  # inner minibatch size (1 = paper-faithful)
    schedule: str = "serial"        # serial | parallel
    partition_strategy: str = "stratified"


def auto_eta(x: Array, params: ODMParams, frac: float = 0.5) -> float:
    """Step size from the smoothness of the per-instance objective:
    L_hat = 1 + s * E||x||^2 with s = lam/(1-theta)^2 (the Hessian of the
    quadratic-hinge term is bounded by s x xᵀ; the ridge adds 1)."""
    s = params.lam / (1.0 - params.theta) ** 2
    l_hat = 1.0 + s * float(jnp.mean(jnp.sum(x * x, axis=1)))
    return frac / l_hat


class DSVRGResult(NamedTuple):
    w: Array
    history: Array      # (epochs,) primal objective after each epoch
    perm: Array


def _epoch_serial(w: Array, xs: Array, ys: Array, anchor: Array, h: Array,
                  eta: float, batch: int, params: ODMParams, M: int) -> Array:
    """One faithful round-robin epoch. xs: (K, m, d) permuted partitions."""
    K, m, d = xs.shape
    steps = m // batch

    def node_body(w, xk_yk):
        xk, yk = xk_yk

        def inner(w, sl):
            xb = jax.lax.dynamic_slice(xk, (sl * batch, 0), (batch, d))
            yb = jax.lax.dynamic_slice(yk, (sl * batch,), (batch,))
            g_w = minibatch_grad(w, xb, yb, params, M)
            g_a = minibatch_grad(anchor, xb, yb, params, M)
            return w - eta * (g_w - g_a + h), None

        w, _ = jax.lax.scan(inner, w, jnp.arange(steps))
        return w, None

    w, _ = jax.lax.scan(node_body, w, (xs, ys))
    return w


def _epoch_parallel(w: Array, xs: Array, ys: Array, anchor: Array, h: Array,
                    eta: float, batch: int, params: ODMParams, M: int) -> Array:
    """Beyond-paper: K independent chains from the same anchor, averaged."""
    K, m, d = xs.shape
    steps = m // batch

    def chain(xk, yk):
        def inner(wk, sl):
            xb = jax.lax.dynamic_slice(xk, (sl * batch, 0), (batch, d))
            yb = jax.lax.dynamic_slice(yk, (sl * batch,), (batch,))
            g_w = minibatch_grad(wk, xb, yb, params, M)
            g_a = minibatch_grad(anchor, xb, yb, params, M)
            return wk - eta * (g_w - g_a + h), None
        wk, _ = jax.lax.scan(inner, w, jnp.arange(steps))
        return wk

    ws = jax.vmap(chain)(xs, ys)                     # (K, d)
    return jnp.mean(ws, axis=0)


def solve(x: Array, y: Array, params: ODMParams, cfg: DSVRGConfig,
          key: jax.Array, w0: Array | None = None) -> DSVRGResult:
    """Single-process DSVRG (Algorithm 2)."""
    from repro.core import kernel_fns as kf
    M, d = x.shape
    K = cfg.n_partitions
    if M % K != 0:
        raise ValueError(f"K={K} must divide M={M}")

    if cfg.partition_strategy == "stratified":
        # linear kernel: strata in input space (phi = identity)
        spec = kf.KernelSpec(name="linear")
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K, key)
        perm = plan.perm
    else:
        perm = part_mod.random_partitions(M, K, key)
    xp, yp = x[perm], y[perm]
    xs = xp.reshape(K, M // K, d)
    ys = yp.reshape(K, M // K)

    w = jnp.zeros(d, x.dtype) if w0 is None else w0
    epoch_fn = _epoch_serial if cfg.schedule == "serial" else _epoch_parallel
    eta = cfg.eta if cfg.eta > 0 else auto_eta(x, params)

    @jax.jit
    def one_epoch(w):
        anchor = w
        h = primal_grad(anchor, xp, yp, params)      # full gradient (Alg.2 l.7-9)
        w = epoch_fn(w, xs, ys, anchor, h, eta, cfg.batch, params, M)
        return w, primal_objective(w, xp, yp, params)

    hist = []
    for _ in range(cfg.epochs):
        w, obj = one_epoch(w)
        hist.append(obj)
    return DSVRGResult(w=w, history=jnp.stack(hist), perm=perm)


# ---------------------------------------------------------------------------
# SPMD engine
# ---------------------------------------------------------------------------

def make_sharded_epoch(mesh: jax.sharding.Mesh, params: ODMParams,
                       cfg: DSVRGConfig, M: int, data_axis: str = "data",
                       eta: float | None = None):
    """Builds a jit'd SPMD epoch function over partitions sharded on
    ``data_axis``: (w, xs, ys) -> (w', local_obj_sum).

    Step 1 (full gradient) is a ``psum`` — the paper's single center-node
    reduction. Step 2 follows cfg.schedule:
      * 'parallel': each device advances the chains of its local partitions
        and a final ``pmean`` averages — total 2 all-reduces of O(d)/epoch.
      * 'serial': every device runs the full serial chain over the
        *gathered* partitions (one all-gather of the data slab; exact
        paper semantics, used for validation at small scale).
    """
    from jax.experimental.shard_map import shard_map

    eta_v = eta if eta is not None else (cfg.eta if cfg.eta > 0 else 0.05)

    def epoch(w, xs, ys):
        # xs: (K_loc, m, d) local slab on each device
        anchor = w
        K_loc, m, d = xs.shape
        xf = xs.reshape(K_loc * m, d)
        yf = ys.reshape(K_loc * m)
        # local sum of per-instance gradients; psum -> full gradient.
        # primal_grad averages internally over its rows, so rescale to the
        # global mean: local_mean * (local_count / M) summed over devices.
        g_local = primal_grad(anchor, xf, yf, params) - anchor
        g_local = g_local * (xf.shape[0] / M)
        h = jax.lax.psum(g_local, data_axis) + anchor

        if cfg.schedule == "parallel":
            wk = _epoch_parallel(w, xs, ys, anchor, h, eta_v, cfg.batch,
                                 params, M)
            w = jax.lax.pmean(wk, data_axis)
        else:
            xg = jax.lax.all_gather(xs, data_axis, tiled=True)   # (K, m, d)
            yg = jax.lax.all_gather(ys, data_axis, tiled=True)
            w = _epoch_serial(w, xg, yg, anchor, h, eta_v, cfg.batch,
                              params, M)
        obj_local = primal_objective(w, xf, yf, params)
        return w, obj_local

    return jax.jit(shard_map(
        epoch, mesh=mesh,
        in_specs=(P(), P(data_axis), P(data_axis)),
        out_specs=(P(), P()),
        check_rep=False,     # the SVRG carry w becomes data-varying inside
    ))


def solve_sharded(x: Array, y: Array, params: ODMParams, cfg: DSVRGConfig,
                  key: jax.Array, mesh: jax.sharding.Mesh,
                  data_axis: str = "data") -> DSVRGResult:
    from repro.core import kernel_fns as kf
    M, d = x.shape
    K = cfg.n_partitions
    n_dev = mesh.shape[data_axis]
    if K % n_dev != 0:
        raise ValueError(f"K={K} must be a multiple of data axis size {n_dev}")

    spec = kf.KernelSpec(name="linear")
    if cfg.partition_strategy == "stratified":
        plan = part_mod.make_plan(spec, x, cfg.n_landmarks, K, key)
        perm = plan.perm
    else:
        perm = part_mod.random_partitions(M, K, key)
    xp, yp = x[perm], y[perm]
    xs = xp.reshape(K, M // K, d)
    ys = yp.reshape(K, M // K)

    eta = cfg.eta if cfg.eta > 0 else auto_eta(x, params)
    epoch_fn = make_sharded_epoch(mesh, params, cfg, M, data_axis, eta=eta)
    w = jnp.zeros(d, x.dtype)
    hist = []
    for _ in range(cfg.epochs):
        w, _ = epoch_fn(w, xs, ys)
        hist.append(primal_objective(w, xp, yp, params))
    return DSVRGResult(w=w, history=jnp.stack(hist), perm=perm)
