"""The paper's contribution: ODM / SODM solvers (Wang et al., IJCAI 2023).

Public surface:
  kernel_fns  — KernelSpec + gram computations
  odm         — primal/dual objectives, gradients, prediction
  dual_cd     — dual coordinate descent (exact + block-Gauss-Seidel)
  partition   — Section 3.2 distribution-aware partitioning (Eqn. 7-8)
  sodm        — Algorithm 1 (hierarchical merge, warm starts, shard_map)
  dsvrg       — Algorithm 2 (communication-efficient SVRG, linear kernel)
  baselines   — Ca-ODM / DiP-ODM / DC-ODM / SVRG / CSVRG rivals
  theory      — Theorem 1/2 bound evaluation
"""
from repro.core import (baselines, dsvrg, dual_cd, kernel_fns, odm, partition,
                        sodm, theory)

__all__ = ["baselines", "dsvrg", "dual_cd", "kernel_fns", "odm", "partition",
           "sodm", "theory"]
