"""SODM distribution-aware partition strategy (paper Section 3.2).

Three steps, all jit-safe:

1. **Landmark selection** (Eqn. 8): z_1 = x_1; then greedily
   z_{s+1} = argmin_z  K_{s,z}^T K_{s,s}^{-1} K_{s,z}
   over the data set, which maximizes the Gram determinant of the landmark
   set (Schur complement) and hence the minimal principal angle tau between
   strata. We solve the argmin exactly over all candidates each round —
   O(S * M * s^2) with tiny s, the "computationally efficient" claim of the
   paper — using a Cholesky of K_ss that is updated incrementally.

2. **Stratum assignment** (Eqn. 7): phi(i) = argmin_s ||phi(x_i) - phi(z_s)||
   = argmax_s kappa(x_i, z_s) for shift-invariant kernels (||phi|| = r const),
   and we use the general form -2k(x,z)+k(z,z) otherwise.

3. **Stratified partitioning**: each stratum is split into K equal pieces by
   random sampling without replacement; partition k takes piece k of every
   stratum, so every partition preserves the global distribution.

The output is a permutation ``perm`` of [M] such that instances
perm[k*m:(k+1)*m] form partition k — downstream code (sodm.py) applies the
permutation once and then works on contiguous slabs, which is exactly the
layout shard_map wants.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernel_fns as kf

Array = jax.Array


class PartitionPlan(NamedTuple):
    perm: Array          # (M,) permutation: partition k = perm[k*m:(k+1)*m]
    landmarks: Array     # (S,) indices of the landmark points
    stratum: Array       # (M,) stratum index of each ORIGINAL instance
    n_partitions: int    # static K


# ---------------------------------------------------------------------------
# landmark selection (Eqn. 8)
# ---------------------------------------------------------------------------

def select_landmarks(spec: kf.KernelSpec, x: Array, n_landmarks: int,
                     jitter: float = 1e-6) -> Array:
    """Greedy determinant-maximizing landmark indices (Eqn. 8).

    Equivalent to greedy MAP inference of a DPP / pivoted-Cholesky column
    selection: the Schur complement r^2 - K_sz^T K_ss^-1 K_sz is exactly the
    *residual diagonal* of the pivoted Cholesky, so we select the argmax
    residual each round and update the residual in O(M) — total O(S M d)
    for the kernel columns plus O(S^2 M) updates.
    """
    M = x.shape[0]
    diag = kf.gram_diag(spec, x)                       # (M,) kappa(x_i, x_i)
    # residual diagonal of the pivoted Cholesky of the full Gram
    resid = diag
    # L factors against chosen pivots: rows (s, M) built incrementally
    L = jnp.zeros((n_landmarks, M), x.dtype)
    picks = jnp.zeros((n_landmarks,), jnp.int32)

    def body(s, carry):
        resid, L, picks = carry
        # paper: z_1 = x_1 ("any choice makes no difference"); then greedy.
        i = jnp.where(s == 0, 0, jnp.argmax(resid))
        picks = picks.at[s].set(i)
        kcol = kf.gram(spec, x, jax.lax.dynamic_slice(x, (i, 0), (1, x.shape[1])))[:, 0]
        # ell = (k(:, i) - L[:s].T @ L[:s, i]) / sqrt(resid[i])
        proj = L.T @ L[:, i]                           # (M,) uses only rows < s (others are 0)
        denom = jnp.sqrt(jnp.maximum(resid[i], jitter))
        ell = (kcol - proj) / denom
        L = L.at[s].set(ell)
        resid = jnp.maximum(resid - ell * ell, 0.0)
        # never re-pick: zero the residual at i
        resid = resid.at[i].set(0.0)
        return resid, L, picks

    _, _, picks = jax.lax.fori_loop(0, n_landmarks, body, (resid, L, picks))
    return picks


# ---------------------------------------------------------------------------
# stratum assignment (Eqn. 7)
# ---------------------------------------------------------------------------

def assign_strata(spec: kf.KernelSpec, x: Array, landmark_idx: Array) -> Array:
    """phi(i) = argmin_s ||phi(x_i) - phi(z_s)||^2 in the RKHS.

    ||phi(x)-phi(z)||^2 = k(x,x) - 2 k(x,z) + k(z,z); k(x,x) is constant in
    s so the argmin needs only the last two terms.
    """
    z = x[landmark_idx]                                 # (S, d)
    kxz = kf.gram(spec, x, z)                           # (M, S)
    kzz = kf.gram_diag(spec, z)                         # (S,)
    d2 = kzz[None, :] - 2.0 * kxz
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# stratified partition construction
# ---------------------------------------------------------------------------

def stratified_partitions(stratum: Array, n_partitions: int,
                          key: jax.Array) -> Array:
    """Permutation placing a proportional random slice of every stratum in
    each partition.

    Implementation trick (fully vectorized, no ragged loops): sort instances
    by (stratum, random tiebreak); within each stratum the order is uniform;
    then assign instance ranked r *within its stratum* to partition
    r mod K — a perfect round-robin deal that splits every stratum into K
    near-equal pieces. Finally sort by (partition, random) to produce the
    contiguous-slab permutation. Partition sizes differ by at most S when
    stratum sizes are not multiples of K; we rebalance to exactly M/K by a
    final round-robin of the overflow, preserving per-stratum proportions
    up to +-1.
    """
    M = stratum.shape[0]
    K = n_partitions
    k1, k2 = jax.random.split(key)
    tie = jax.random.uniform(k1, (M,))
    # rank of each instance within its stratum
    order = jnp.lexsort((tie, stratum))                 # sorted by stratum then tie
    # position within stratum: index along sorted order minus start of stratum
    sorted_stratum = stratum[order]
    is_start = jnp.concatenate([jnp.ones(1, jnp.int32),
                                (sorted_stratum[1:] != sorted_stratum[:-1]).astype(jnp.int32)])
    seg_id = jnp.cumsum(is_start) - 1                   # dense stratum id along order
    pos_global = jnp.arange(M)
    seg_start = jnp.zeros(M, jnp.int32).at[seg_id].max(
        jnp.where(is_start == 1, pos_global, 0).astype(jnp.int32))
    # within-stratum rank
    rank = pos_global - seg_start[seg_id]
    part_of_sorted = (rank % K).astype(jnp.int32)
    # scatter back to original order
    part = jnp.zeros(M, jnp.int32).at[order].set(part_of_sorted)

    # rebalance to exact size m = M // K (assumes K | M, enforced by caller):
    # sort by (partition, random); oversized partitions' tail spills into
    # undersized ones by re-assigning global rank r -> r // m.
    tie2 = jax.random.uniform(k2, (M,))
    order2 = jnp.lexsort((tie2, part))
    m = M // K
    final_part_sorted = (jnp.arange(M) // m).astype(jnp.int32)
    del final_part_sorted  # implicit: position r in order2 goes to partition r//m
    return order2


def make_plan(spec: kf.KernelSpec, x: Array, n_landmarks: int,
              n_partitions: int, key: jax.Array) -> PartitionPlan:
    """Full Section-3.2 pipeline: landmarks -> strata -> partitions."""
    M = x.shape[0]
    if M % n_partitions != 0:
        raise ValueError(f"K={n_partitions} must divide M={M} "
                         "(pad or trim the data set first)")
    landmarks = select_landmarks(spec, x, n_landmarks)
    stratum = assign_strata(spec, x, landmarks)
    perm = stratified_partitions(stratum, n_partitions, key)
    return PartitionPlan(perm=perm, landmarks=landmarks, stratum=stratum,
                         n_partitions=n_partitions)


# ---------------------------------------------------------------------------
# rival partition strategies (for ablation / baselines)
# ---------------------------------------------------------------------------

def random_partitions(M: int, n_partitions: int, key: jax.Array) -> Array:
    """Uniform random permutation — the strawman SODM improves on."""
    return jax.random.permutation(key, M)


def cluster_partitions(spec: kf.KernelSpec, x: Array, n_partitions: int,
                       key: jax.Array, iters: int = 10) -> Array:
    """Kernel k-means-style clusters-as-partitions (DC-SVM / DiP-SVM style).

    Lloyd's algorithm in input space (the common practical surrogate), then
    *clusters become partitions*: sort by cluster and deal contiguous slabs.
    Cluster sizes are forced to M/K by ranking within cluster and spilling
    the tail round-robin (same rebalance trick as above) so downstream code
    sees equal slabs; this mirrors how DC-SVM pads/limits cluster sizes.
    """
    M, d = x.shape
    K = n_partitions
    init = jax.random.choice(key, M, (K,), replace=False)
    cent = x[init]

    def step(cent, _):
        d2 = (jnp.sum(x * x, 1)[:, None] + jnp.sum(cent * cent, 1)[None, :]
              - 2.0 * x @ cent.T)
        a = jnp.argmin(d2, 1)
        onehot = jax.nn.one_hot(a, K, dtype=x.dtype)
        counts = jnp.maximum(onehot.sum(0), 1.0)
        cent = (onehot.T @ x) / counts[:, None]
        return cent, a

    cent, assigns = jax.lax.scan(step, cent, None, length=iters)
    a = assigns[-1]
    tie = jax.random.uniform(jax.random.fold_in(key, 1), (M,))
    order = jnp.lexsort((tie, a))
    return order


# ---------------------------------------------------------------------------
# diagnostics used by theory tests and EXPERIMENTS
# ---------------------------------------------------------------------------

def offdiag_mass(spec: kf.KernelSpec, x: Array, y: Array, perm: Array,
                 n_partitions: int) -> Array:
    """Q-bar of Theorem 1: sum of |Q_ij| over cross-partition pairs.

    O(M^2) — used on small/medium synthetic sets in tests and benches only.
    """
    xp, yp = x[perm], y[perm]
    Q = kf.signed_gram(spec, xp, yp)
    M = x.shape[0]
    m = M // n_partitions
    pid = jnp.arange(M) // m
    cross = pid[:, None] != pid[None, :]
    return jnp.sum(jnp.where(cross, jnp.abs(Q), 0.0))


def min_principal_angle(spec: kf.KernelSpec, x: Array, stratum: Array,
                        n_landmarks: int) -> Array:
    """cos(tau) estimate: max cross-stratum normalized kernel value."""
    K = kf.gram(spec, x)
    diag = jnp.sqrt(jnp.maximum(kf.gram_diag(spec, x), 1e-12))
    Kn = K / (diag[:, None] * diag[None, :])
    cross = stratum[:, None] != stratum[None, :]
    return jnp.max(jnp.where(cross, Kn, -jnp.inf))
