"""Dual coordinate descent for the ODM box-constrained QP (Eqn. 3).

The univariate subproblem for coordinate i has the closed form

    alpha_i <- max(alpha_i - grad_i / H_ii, 0)

We maintain the cache ``u = Q (zeta - beta)`` so each coordinate update is
O(m) (one row of Q) instead of O(m^2). Two execution styles are provided:

* :func:`solve` — epoch-based ``lax.while_loop`` over full sweeps; each
  sweep is a ``fori_loop`` over the 2m coordinates (exact Gauss-Seidel).
  This is the faithful reference solver used by SODM level solves on CPU
  and inside shard_map per-partition.

* :func:`solve_block` — block-Gauss-Seidel: exact CD *within* a tile that
  fits VMEM, Jacobi across tiles. This mirrors the Pallas kernel in
  ``repro.kernels.dual_cd_block`` and is its pure-jnp oracle.

Both operate on a *precomputed* Gram matrix Q (signed: Q_ij = y_i y_j k_ij).
For problems too large to materialize Q, SODM never needs to — it only ever
solves partition-sized subproblems (that is the point of the paper).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.odm import ODMParams, dual_grad_from_u, dual_objective, split_alpha

Array = jax.Array


class CDResult(NamedTuple):
    alpha: Array        # (2m,) final dual variables
    u: Array            # (m,) final cache Q (zeta - beta)
    sweeps: Array       # () int32 number of sweeps executed
    kkt: Array          # () final projected-gradient infinity norm


def _coord_update(i, state, Q, q_diag, params: ODMParams, mscale):
    """One exact CD step on coordinate i (i < m: zeta_i; else beta_{i-m})."""
    alpha, u = state
    m = Q.shape[0]
    is_zeta = i < m
    row = i - jnp.where(is_zeta, 0, m)          # index into [m]
    # gradient of coordinate i given the cache u
    g_zeta = u[row] + mscale * params.c * params.ups * alpha[i] + (params.theta - 1.0)
    g_beta = -u[row] + mscale * params.c * alpha[i] + (params.theta + 1.0)
    g = jnp.where(is_zeta, g_zeta, g_beta)
    h_zeta = q_diag[row] + mscale * params.c * params.ups
    h_beta = q_diag[row] + mscale * params.c
    h = jnp.where(is_zeta, h_zeta, h_beta)
    new = jnp.maximum(alpha[i] - g / h, 0.0)
    delta = new - alpha[i]
    # u tracks Q (zeta - beta): zeta moves add +delta * Q[:, row], beta -delta
    sign = jnp.where(is_zeta, 1.0, -1.0)
    u = u + (sign * delta) * Q[:, row]
    alpha = alpha.at[i].set(new)
    return alpha, u


def sweep(Q: Array, q_diag: Array, alpha: Array, u: Array,
          params: ODMParams, mscale: float) -> tuple[Array, Array]:
    """One full Gauss-Seidel sweep over all 2m coordinates."""
    m = Q.shape[0]

    def body(i, st):
        return _coord_update(i, st, Q, q_diag, params, mscale)

    return jax.lax.fori_loop(0, 2 * m, body, (alpha, u))


def kkt_from_u(u: Array, alpha: Array, params: ODMParams, mscale: float) -> Array:
    g = dual_grad_from_u(u, alpha, params, mscale)
    proj = jnp.where(alpha > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
    return jnp.max(proj)


def solve(Q: Array, params: ODMParams, mscale: float,
          alpha0: Array | None = None, tol: float = 1e-5,
          max_sweeps: int = 200, u0: Array | None = None) -> CDResult:
    """Run CD sweeps until the projected KKT residual drops below tol.

    ``alpha0`` is the warm start (SODM Algorithm 1 line 12 concatenates the
    child solutions here); defaults to zeros. ``u0`` is the optional
    precomputed cache Q (zeta0 - beta0) — u is linear in alpha, so callers
    that already paid the matvec (e.g. a warm-start rescale) pass the
    scaled cache and skip recomputing it.
    """
    m = Q.shape[0]
    q_diag = jnp.diagonal(Q)
    alpha = jnp.zeros(2 * m, Q.dtype) if alpha0 is None else alpha0
    if u0 is None:
        zeta, beta = split_alpha(alpha)
        u = Q @ (zeta - beta)
    else:
        u = u0

    def cond(carry):
        alpha, u, s, kkt = carry
        return jnp.logical_and(s < max_sweeps, kkt > tol)

    def body(carry):
        alpha, u, s, _ = carry
        alpha, u = sweep(Q, q_diag, alpha, u, params, mscale)
        return alpha, u, s + 1, kkt_from_u(u, alpha, params, mscale)

    # evaluate KKT at the warm start so an already-optimal init runs zero
    # sweeps (Algorithm 1 line 5's convergence check reads this)
    init = (alpha, u, jnp.int32(0), kkt_from_u(u, alpha, params, mscale))
    alpha, u, s, kkt = jax.lax.while_loop(cond, body, init)
    return CDResult(alpha=alpha, u=u, sweeps=s, kkt=kkt)


# ---------------------------------------------------------------------------
# block-Gauss-Seidel variant (oracle for the Pallas kernel)
# ---------------------------------------------------------------------------

def solve_block(Q: Array, params: ODMParams, mscale: float,
                block: int = 256, alpha0: Array | None = None,
                tol: float = 1e-5, max_outer: int = 200,
                u0: Array | None = None) -> CDResult:
    """Exact CD within each (block,)-sized tile, Jacobi across tiles.

    The per-tile solve only touches the diagonal Gram block (resident in
    VMEM on TPU); cross-tile coupling enters through the cache u, which is
    refreshed once per outer iteration (one Q @ gamma matmul — MXU work).
    Each Jacobi pass is safeguarded by an exact line search along the
    joint step (f is quadratic along it, and u moves linearly, so the
    optimal damping costs no extra matvec): undamped simultaneous tile
    solves can diverge when the off-diagonal mass beats the M·c·I shift
    (e.g. small c = weak regularization), while the damped pass is
    monotone for any Q.
    """
    m = Q.shape[0]
    nblk = -(-m // block)
    mp = nblk * block
    # zero-pad to a multiple of the block size; padded rows have Q=0, and a
    # padded coordinate's update is max(0 - (theta-1)/h, 0) > 0 for zeta...
    # so mask them explicitly instead.
    pad = mp - m
    Qp = jnp.pad(Q, ((0, pad), (0, pad)))
    q_diag = jnp.diagonal(Qp)
    valid = jnp.arange(mp) < m

    alpha = jnp.zeros(2 * mp, Q.dtype)
    if alpha0 is not None:
        z0, b0 = split_alpha(alpha0)
        alpha = alpha.at[:m].set(z0).at[mp:mp + m].set(b0)

    def tile_solve(qblk, dblk, ablk, ublk, vblk):
        """Exact Gauss-Seidel inside one tile: ablk (2*block,), ublk (block,)."""
        def body(i, st):
            a, u = st
            is_zeta = i < block
            row = i - jnp.where(is_zeta, 0, block)
            gz = u[row] + mscale * params.c * params.ups * a[i] + (params.theta - 1.0)
            gb = -u[row] + mscale * params.c * a[i] + (params.theta + 1.0)
            g = jnp.where(is_zeta, gz, gb)
            hz = dblk[row] + mscale * params.c * params.ups
            hb = dblk[row] + mscale * params.c
            h = jnp.where(is_zeta, hz, hb)
            new = jnp.maximum(a[i] - g / h, 0.0)
            new = jnp.where(vblk[row], new, 0.0)
            delta = new - a[i]
            sign = jnp.where(is_zeta, 1.0, -1.0)
            u = u + (sign * delta) * qblk[:, row]
            return a.at[i].set(new), u
        ablk, _ = jax.lax.fori_loop(0, 2 * block, body, (ablk, ublk))
        return ablk

    def outer(carry):
        alpha, u, it, kkt = carry
        zeta, beta = alpha[:mp], alpha[mp:]
        # process all tiles (Jacobi across tiles, each uses the same u snapshot
        # but exact updates within the tile via the diag block)
        def tile_body(b, acc):
            z, bta = acc
            idx = b * block
            qblk = jax.lax.dynamic_slice(
                Qp, (idx, idx), (block, block))
            dblk = jax.lax.dynamic_slice(q_diag, (idx,), (block,))
            vblk = jax.lax.dynamic_slice(valid, (idx,), (block,))
            zblk = jax.lax.dynamic_slice(z, (idx,), (block,))
            bblk = jax.lax.dynamic_slice(bta, (idx,), (block,))
            ublk = jax.lax.dynamic_slice(u, (idx,), (block,))
            # ublk = external contribution + in-tile contribution; the
            # external part is frozen for this tile solve (Jacobi across
            # tiles) and the in-tile part is tracked incrementally by
            # tile_solve's rank-1 updates, so ublk is the right init.
            ablk = jnp.concatenate([zblk, bblk])
            ablk = tile_solve(qblk, dblk, ablk, ublk, vblk)
            z = jax.lax.dynamic_update_slice(z, ablk[:block], (idx,))
            bta = jax.lax.dynamic_update_slice(bta, ablk[block:], (idx,))
            return z, bta
        z_new, b_new = jax.lax.fori_loop(0, nblk, tile_body, (zeta, beta))
        # exact line search along the joint Jacobi step: f(alpha + t*d) is
        # quadratic in t and u moves linearly, so the optimal damping is
        # closed-form and reuses the one matvec this pass needs anyway.
        # t = 1 when tiles don't conflict; t < 1 tames off-diagonal mass
        # that would otherwise make simultaneous tile updates diverge.
        dz, db = z_new - zeta, b_new - beta
        u_d = Qp @ (dz - db)
        gz = u + mscale * params.c * params.ups * zeta + (params.theta - 1.0)
        gb = -u + mscale * params.c * beta + (params.theta + 1.0)
        gdot = gz @ dz + gb @ db
        quad = (dz - db) @ u_d + mscale * params.c * (
            params.ups * dz @ dz + db @ db)
        t = jnp.where(quad > 0.0,
                      jnp.clip(-gdot / jnp.maximum(quad, 1e-30), 0.0, 1.0),
                      1.0)
        zeta, beta = zeta + t * dz, beta + t * db
        alpha = jnp.concatenate([zeta, beta])
        u = u + t * u_d
        kkt = _kkt_padded(u, alpha, valid, params, mscale, mp)
        return alpha, u, it + 1, kkt

    def cond(carry):
        _, _, it, kkt = carry
        return jnp.logical_and(it < max_outer, kkt > tol)

    # evaluate KKT at the warm start so an already-optimal init runs zero
    # outer passes (Algorithm 1 line 5's convergence check reads this).
    # u0 is (m,) from the caller (u is linear in alpha, so a rescaled warm
    # start's cache comes for free); padded rows of Qp are zero => pad u
    # with zeros.
    if u0 is None:
        u0 = Qp @ (alpha[:mp] - alpha[mp:])
    else:
        u0 = jnp.pad(u0, (0, pad))
    init = (alpha, u0, jnp.int32(0), _kkt_padded(u0, alpha, valid, params,
                                                 mscale, mp))
    alpha, u, it, kkt = jax.lax.while_loop(cond, lambda c: outer(c), init)
    zeta, beta = alpha[:mp], alpha[mp:]
    out = jnp.concatenate([zeta[:m], beta[:m]])
    u = Q @ (zeta[:m] - beta[:m])
    return CDResult(alpha=out, u=u, sweeps=it, kkt=kkt)


def _kkt_padded(u, alpha, valid, params, mscale, mp):
    zeta, beta = alpha[:mp], alpha[mp:]
    gz = u + mscale * params.c * params.ups * zeta + (params.theta - 1.0)
    gb = -u + mscale * params.c * beta + (params.theta + 1.0)
    g = jnp.concatenate([gz, gb])
    a = jnp.concatenate([zeta, beta])
    v2 = jnp.concatenate([valid, valid])
    proj = jnp.where(a > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
    return jnp.max(jnp.where(v2, proj, 0.0))


def objective(Q: Array, alpha: Array, params: ODMParams, mscale: float) -> Array:
    return dual_objective(Q, alpha, params, mscale)
