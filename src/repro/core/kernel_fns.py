"""Kernel functions for ODM / SODM.

Everything is pure jnp and jit-safe. Kernels are exposed both as
``KernelSpec`` (a small pytree-friendly description that can be threaded
through shard_map'd code) and as plain functions.

The Gram computation is the nonlinear-kernel hot spot of the paper; the
tiled matrix-free Pallas lowering of every family here lives in
``repro.kernels.gram`` and is validated against these pure-jnp grams.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Static description of a positive-definite kernel.

    Attributes:
      name:  one of 'linear' | 'rbf' | 'laplacian' | 'poly'.
      gamma: bandwidth for rbf/laplacian, scale for poly.
      degree: polynomial degree (poly only).
      coef0: polynomial offset (poly only).
    """

    name: str = "rbf"
    gamma: float = 1.0
    degree: int = 3
    coef0: float = 1.0

    def is_shift_invariant(self) -> bool:
        return self.name in ("rbf", "laplacian")

    def family(self) -> str:
        """Accumulation family of the matrix-free Gram lowering.

        ``"l2"`` kernels (rbf/poly/linear) build their tiles from the
        ``x @ z.T`` cross term on the MXU; ``"l1"`` kernels (laplacian)
        need a tiled L1 reduction on the VPU (no matmul form exists).
        Delegates to :mod:`repro.kernels.gram` (the lowering itself) so
        there is exactly one registry of the split.
        """
        from repro.kernels import gram  # deferred: core must stay
        #                                 importable without kernels
        if self.name in gram.L1_KERNELS:
            return "l1"
        if self.name in gram.MATRIX_FREE_KERNELS:
            return "l2"
        raise ValueError(f"no matrix-free lowering for {self.name!r}")

    def diag_value(self) -> float:
        """kappa(x, x) for shift-invariant kernels (the r^2 of Theorem 2)."""
        if self.name in ("rbf", "laplacian"):
            return 1.0
        raise ValueError(f"diag_value undefined for kernel {self.name!r}")


# ---------------------------------------------------------------------------
# pairwise distances / inner products
# ---------------------------------------------------------------------------

def sq_dists(x: Array, z: Array) -> Array:
    """Pairwise squared euclidean distances, (m, n) for x:(m,d), z:(n,d).

    Uses the expanded form so the cross term is a single matmul (MXU-bound
    on TPU); clamps tiny negatives introduced by cancellation.
    """
    xx = jnp.sum(x * x, axis=-1)[:, None]
    zz = jnp.sum(z * z, axis=-1)[None, :]
    cross = x @ z.T
    return jnp.maximum(xx + zz - 2.0 * cross, 0.0)


def l1_dists(x: Array, z: Array) -> Array:
    """Pairwise L1 distances (m, n). O(m n d) memory-bound; used by laplacian."""
    return jnp.sum(jnp.abs(x[:, None, :] - z[None, :, :]), axis=-1)


# ---------------------------------------------------------------------------
# gram matrices
# ---------------------------------------------------------------------------

def linear_gram(x: Array, z: Array) -> Array:
    return x @ z.T


def rbf_gram(x: Array, z: Array, gamma: float) -> Array:
    return jnp.exp(-gamma * sq_dists(x, z))


def laplacian_gram(x: Array, z: Array, gamma: float) -> Array:
    return jnp.exp(-gamma * l1_dists(x, z))


def poly_gram(x: Array, z: Array, gamma: float, degree: int, coef0: float) -> Array:
    return (gamma * (x @ z.T) + coef0) ** degree


def gram(spec: KernelSpec, x: Array, z: Array | None = None) -> Array:
    """Gram matrix K[i, j] = kappa(x_i, z_j). z defaults to x."""
    z = x if z is None else z
    if spec.name == "linear":
        return linear_gram(x, z)
    if spec.name == "rbf":
        return rbf_gram(x, z, spec.gamma)
    if spec.name == "laplacian":
        return laplacian_gram(x, z, spec.gamma)
    if spec.name == "poly":
        return poly_gram(x, z, spec.gamma, spec.degree, spec.coef0)
    raise ValueError(f"unknown kernel {spec.name!r}")


def gram_diag(spec: KernelSpec, x: Array) -> Array:
    """diag(K(x, x)) without forming the full gram."""
    if spec.name == "linear":
        return jnp.sum(x * x, axis=-1)
    if spec.name in ("rbf", "laplacian"):
        return jnp.ones(x.shape[0], x.dtype)
    if spec.name == "poly":
        return (spec.gamma * jnp.sum(x * x, axis=-1) + spec.coef0) ** spec.degree
    raise ValueError(f"unknown kernel {spec.name!r}")


def signed_gram(spec: KernelSpec, x: Array, y: Array,
                xz: Array | None = None, yz: Array | None = None) -> Array:
    """Q[i, j] = y_i y_j kappa(x_i, z_j) — the ODM dual Hessian block."""
    xz = x if xz is None else xz
    yz = y if yz is None else yz
    return (y[:, None] * yz[None, :]) * gram(spec, x, xz)


def kernel_fn(spec: KernelSpec) -> Callable[[Array, Array], Array]:
    """Returns a closed-over gram function (for APIs wanting a callable)."""
    return partial(gram, spec)


def median_gamma(x: Array, sample: int = 256) -> float:
    """Median-distance heuristic: gamma = 1 / median(||x_i - x_j||^2).

    The standard bandwidth rule for RBF kernels on normalized data; used
    by the benchmark harnesses so one setting works across the paper's
    eight data sets.
    """
    xs = x[:sample]
    d2 = sq_dists(xs, xs)
    iu = jnp.triu_indices(xs.shape[0], 1)
    med = jnp.median(d2[iu])
    return float(1.0 / jnp.maximum(med, 1e-6))


# Registry used by configs / CLI flags.
KERNELS = ("linear", "rbf", "laplacian", "poly")


def make_spec(name: str, gamma: float = 1.0, degree: int = 3,
              coef0: float = 1.0) -> KernelSpec:
    if name not in KERNELS:
        raise ValueError(f"kernel must be one of {KERNELS}, got {name!r}")
    return KernelSpec(name=name, gamma=gamma, degree=degree, coef0=coef0)
