"""Optimal margin Distribution Machine (ODM) — primal and dual forms.

Paper: Zhang & Zhou 2019 (ODM); Wang et al. IJCAI 2023 (SODM) Eqns. (1)-(3).

Primal (Eqn. 9 of the appendix):

    min_w  p(w) = 1/2 ||w||^2 + lam/(2 M (1-theta)^2) * sum_i (xi_i^2 + ups*eps_i^2)
    s.t.   1 - theta - xi_i <= y_i w^T phi(x_i) <= 1 + theta + eps_i

Dual (Eqn. 1/2), alpha = [zeta; beta] in R^{2M}_+:

    min_alpha f(alpha) = 1/2 alpha^T H alpha + b^T alpha
    H = [[Q + M c ups I, -Q], [-Q, Q + M c I]]
    b = [(theta-1) 1_M ; (theta+1) 1_M],   c = (1-theta)^2 / (lam ups)

Strong duality holds with p(w*) = -f(alpha*).

Everything here is pure jnp so it can run inside jit / shard_map / scan.
The *scale* of the regularizer (the "M" multiplying c) is an explicit
argument ``mscale`` because SODM's local subproblems use m = M/K in that
slot (Eqn. 4) while keeping the same c.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernel_fns as kf

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ODMParams:
    """Hyperparameters of ODM. ``ups`` is the paper's upsilon (v)."""

    lam: float = 1.0
    theta: float = 0.1
    ups: float = 0.5

    @property
    def c(self) -> float:
        """c = (1-theta)^2 / (lam * ups), constant in the dual Hessian."""
        return (1.0 - self.theta) ** 2 / (self.lam * self.ups)


class DualState(NamedTuple):
    """State threaded through dual coordinate descent.

    alpha:  (2m,) dual variables [zeta; beta] >= 0.
    u:      (m,) maintained product Q @ (zeta - beta)  (gradient cache).
    """

    alpha: Array
    u: Array


# ---------------------------------------------------------------------------
# dual form
# ---------------------------------------------------------------------------

def split_alpha(alpha: Array) -> tuple[Array, Array]:
    m = alpha.shape[0] // 2
    return alpha[:m], alpha[m:]


def dual_objective(Q: Array, alpha: Array, params: ODMParams,
                   mscale: float) -> Array:
    """f(alpha) = 1/2 a^T H a + b^T a with explicit regularizer scale."""
    zeta, beta = split_alpha(alpha)
    gam = zeta - beta
    quad = 0.5 * gam @ (Q @ gam)
    reg = 0.5 * mscale * params.c * (params.ups * zeta @ zeta + beta @ beta)
    lin = (params.theta - 1.0) * jnp.sum(zeta) + (params.theta + 1.0) * jnp.sum(beta)
    return quad + reg + lin


def dual_grad(Q: Array, alpha: Array, params: ODMParams,
              mscale: float) -> Array:
    """grad f(alpha) = H alpha + b, computed via u = Q (zeta-beta)."""
    zeta, beta = split_alpha(alpha)
    u = Q @ (zeta - beta)
    return dual_grad_from_u(u, alpha, params, mscale)


def dual_grad_from_u(u: Array, alpha: Array, params: ODMParams,
                     mscale: float) -> Array:
    """Gradient given the cached u = Q (zeta - beta)."""
    zeta, beta = split_alpha(alpha)
    gz = u + mscale * params.c * params.ups * zeta + (params.theta - 1.0)
    gb = -u + mscale * params.c * beta + (params.theta + 1.0)
    return jnp.concatenate([gz, gb])


def warm_start_scale(u: Array, alpha: Array, params: ODMParams,
                     mscale: float) -> Array:
    """Optimal scalar t for a warm start: argmin_t f(t · alpha).

    f is quadratic along the ray, f(t·a) = t²·(½ aᵀH a) + t·(bᵀa), so
    t* = -bᵀa / (aᵀH a), clipped to t ≥ 0 (box constraint). SODM merges
    concatenate child duals solved at regularizer scale m into a parent
    solve at scale p·m; the right correction is ≈1/p when the m·c·I term
    dominates H and ≈1 when Q dominates — this line search lands on the
    optimum in either regime for one cached matvec (``u = Q (zeta-beta)``,
    which the solvers need anyway). t = 1 for a zero (cold) start.
    """
    zeta, beta = split_alpha(alpha)
    gam = zeta - beta
    quad = gam @ u + mscale * params.c * (
        params.ups * zeta @ zeta + beta @ beta)
    lin = (params.theta - 1.0) * jnp.sum(zeta) \
        + (params.theta + 1.0) * jnp.sum(beta)
    return jnp.where(quad > 0.0, jnp.maximum(-lin / quad, 0.0), 1.0)


def hess_diag(q_diag: Array, params: ODMParams, mscale: float) -> Array:
    """diag(H) = [Q_ii + M c ups; Q_ii + M c]."""
    hz = q_diag + mscale * params.c * params.ups
    hb = q_diag + mscale * params.c
    return jnp.concatenate([hz, hb])


def kkt_residual(Q: Array, alpha: Array, params: ODMParams,
                 mscale: float) -> Array:
    """Projected-gradient infinity norm for the box constraint alpha >= 0.

    At optimum: grad_i >= 0 where alpha_i = 0, grad_i = 0 where alpha_i > 0.
    """
    g = dual_grad(Q, alpha, params, mscale)
    proj = jnp.where(alpha > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
    return jnp.max(proj)


# ---------------------------------------------------------------------------
# primal form (linear kernel)
# ---------------------------------------------------------------------------

def margins(w: Array, x: Array, y: Array) -> Array:
    """y_i w^T x_i, shape (M,)."""
    return y * (x @ w)


def _hinge_coef(m: Array, y: Array, params: ODMParams) -> Array:
    """Per-instance quadratic-hinge coefficient s·(lo + ups·hi)·y.

    s = lam/(1-theta)² is the per-instance scale (no 1/M); every gradient
    form below divides by its own instance count. Rows with y = 0
    (padding) get coefficient exactly 0.
    """
    s = params.lam / (1.0 - params.theta) ** 2
    lo = jnp.where(m < 1.0 - params.theta, m + params.theta - 1.0, 0.0)
    hi = jnp.where(m > 1.0 + params.theta, m - params.theta - 1.0, 0.0)
    return s * (lo + params.ups * hi) * y


def primal_objective(w: Array, x: Array, y: Array, params: ODMParams,
                     weights: Array | None = None,
                     total: int | None = None) -> Array:
    """p(w). ``weights`` masks rows (padded rows get 0); ``total`` is the
    true instance count when ``x`` carries padding rows — the loss is
    normalized by it, so a sharded caller can recover the global objective
    as ``ridge + psum(local - ridge)`` with every shard passing the global
    M as ``total``."""
    m = margins(w, x, y)
    xi = jnp.maximum(0.0, (1.0 - params.theta) - m)
    eps = jnp.maximum(0.0, m - (1.0 + params.theta))
    terms = xi * xi + params.ups * (eps * eps)
    if weights is not None:
        terms = weights * terms
    M = x.shape[0] if total is None else total
    loss = jnp.sum(terms) * params.lam / (2.0 * M * (1.0 - params.theta) ** 2)
    return 0.5 * w @ w + loss


def primal_grad(w: Array, x: Array, y: Array, params: ODMParams,
                total: int | None = None) -> Array:
    """Full-batch grad p(w); matches the mean of per-instance grads below.

    ``total`` is the true instance count when ``x`` carries padding rows —
    padded rows must have y = 0 (their coefficient is then exactly 0).
    """
    M = x.shape[0] if total is None else total
    coef = _hinge_coef(margins(w, x, y), y, params)      # (M,)
    return w + (x.T @ coef) / M


def per_instance_grad(w: Array, x_i: Array, y_i: Array, params: ODMParams,
                      M: int) -> Array:
    """The paper's nabla p_i(w) (Section 3.3) — unbiased: E_i[...] = grad p.

    The paper's per-instance loss term carries no 1/M (it is M times the
    instance's 1/M share of the empirical loss), so a uniformly sampled i
    gives an unbiased estimator of the full gradient. ``M`` is accepted for
    signature parity with :func:`minibatch_grad` but unused.
    """
    del M
    m = y_i * (x_i @ w)
    return w + _hinge_coef(m, y_i, params) * x_i


def minibatch_grad(w: Array, xb: Array, yb: Array, params: ODMParams,
                   M: int) -> Array:
    """Mean over the batch of the paper's per-instance gradients.

    E_batch[minibatch_grad] = primal_grad when instances are drawn uniformly,
    because each per-instance grad is w + M * (its 1/M loss-grad share).
    ``M`` is accepted for signature parity but unused.
    """
    del M
    coef = _hinge_coef(yb * (xb @ w), yb, params)     # (B,)
    # mean_i [ w + coef_i x_i ] = w + (1/B) X^T coef
    return w + (xb.T @ coef) / xb.shape[0]


def svrg_direction(w: Array, anchor: Array, h: Array, xb: Array, yb: Array,
                   params: ODMParams, wb: Array | None = None) -> Array:
    """DSVRG inner-step direction  g_w − g_a + h  on one minibatch.

    Expanding both :func:`minibatch_grad` terms, the ridge parts cancel to
    ``w − anchor`` and the hinge parts share the same X, so the direction is

        (w − anchor + h) + Xᵀ(coef_w − coef_a) / n_valid

    — one pass over the batch instead of two independent gradients. ``wb``
    masks ragged-tail padding rows (0 ⇒ excluded from both the coefficient
    and the mean divisor); omitted means all rows count. This is the pure
    jnp reference of the fused Pallas kernel
    (:func:`repro.kernels.ops.svrg_grad`).
    """
    mm = yb[:, None] * (xb @ jnp.stack([w, anchor], axis=1))   # (B, 2)
    dcoef = _hinge_coef(mm[:, 0], yb, params) \
        - _hinge_coef(mm[:, 1], yb, params)
    if wb is None:
        n = xb.shape[0]
    else:
        dcoef = wb * dcoef
        n = jnp.maximum(jnp.sum(wb), 1.0)
    return (w - anchor + h) + (xb.T @ dcoef) / n


# ---------------------------------------------------------------------------
# primal <-> dual bridges and prediction
# ---------------------------------------------------------------------------

def w_from_alpha(x: Array, y: Array, alpha: Array) -> Array:
    """KKT: w = X Y (zeta - beta) — linear kernel only."""
    zeta, beta = split_alpha(alpha)
    return x.T @ (y * (zeta - beta))


def alpha_from_w(w: Array, x: Array, y: Array, params: ODMParams) -> Array:
    """Inverse KKT map: dual [zeta; beta] from a primal solution w.

    At a primal stationary point the complementary-slackness conditions
    give zeta_i = s·xi_i and beta_i = s·ups·eps_i with
    s = lam/(M(1-theta)²) — substituting back,
    w = Xᵀ(y ⊙ (zeta − beta)) recovers w exactly. Used by the DSVRG
    solver engine so a primal linear solve plugs into every dual-alpha
    consumer (predict / dual_objective / SODMResult). Exact only at
    stationarity; mid-optimization it is the dual of the *projected*
    primal point.
    """
    m = margins(w, x, y)
    xi = jnp.maximum(0.0, (1.0 - params.theta) - m)
    eps = jnp.maximum(0.0, m - (1.0 + params.theta))
    s = params.lam / (x.shape[0] * (1.0 - params.theta) ** 2)
    return jnp.concatenate([s * xi, s * params.ups * eps])


def decision_function(spec: kf.KernelSpec, x_train: Array, y_train: Array,
                      alpha: Array, x_test: Array) -> Array:
    """f(x) = sum_i y_i (zeta_i - beta_i) kappa(x_i, x).

    Dense oracle: materializes the full (T, M) test Gram. Kept as the
    exact-expansion reference the serving subsystem is validated against;
    production scoring goes through :func:`predict` / ``repro.serve``
    (compiled artifact + tiled matrix-free scorer, no (T, M) block).
    """
    zeta, beta = split_alpha(alpha)
    coef = y_train * (zeta - beta)
    return kf.gram(spec, x_test, x_train) @ coef


def predict(spec: kf.KernelSpec, x_train: Array, y_train: Array,
            alpha: Array, x_test: Array) -> Array:
    """Served prediction: compiles the dual into a ``FittedODM`` (exact-
    zero coefficients pruned, linear kernels collapsed to w) and scores
    through the tiled matrix-free kernel — O(T·B) memory instead of the
    dense (T, M) Gram of :func:`decision_function`. Host-side API (the
    compile step gathers); call ``FittedODM.predict`` directly inside jit.
    """
    from repro.serve import model as serve_model   # deferred: serving layer
    m = serve_model.compile_model(spec, x_train, y_train, alpha)
    return m.predict(x_test)


def accuracy(y_true: Array, y_pred: Array) -> Array:
    return jnp.mean((y_true * y_pred) > 0.0)
