"""``ProblemSpec`` — the one validated description of an ODM problem.

Every training route needs the same two things: the kernel
(:class:`repro.core.kernel_fns.KernelSpec`) and the ODM hyperparameters
(:class:`repro.core.odm.ODMParams`). Before the unified API each route
re-validated them independently (or not at all — a mislabeled ``y``
reached the solver and produced a silently wrong model). ``ProblemSpec``
fuses both into one frozen object with EAGER validation:

* hyperparameter sanity at construction (``__post_init__``): kernel name
  registered, positive bandwidth/degree where the family uses them,
  ``lam``/``ups`` positive, ``theta`` in [0, 1) — the dual constant
  c = (1-theta)^2/(lam·ups) must exist and be positive;
* data checks at :meth:`validate` (called once by
  ``ODMEstimator.fit``): 2-D features, 1-D labels of matching length,
  labels exactly ±1 (the dual layout [zeta; beta] and every margin
  formula assume it), labels cast to the feature dtype.

Kernel-family × solver compatibility is the *registry's* half of
validation (:func:`repro.api.registry.resolve`) — a spec only says what
the problem IS, the registry says who can solve it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import kernel_fns as kf
from repro.core.odm import ODMParams

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """A validated (kernel, hyperparameters) pair. Hashable and static —
    safe to close over in jitted code, like its two components."""

    kernel: kf.KernelSpec = kf.KernelSpec()
    params: ODMParams = ODMParams()

    def __post_init__(self):
        k, p = self.kernel, self.params
        if k.name not in kf.KERNELS:
            raise ValueError(
                f"kernel must be one of {kf.KERNELS}, got {k.name!r}")
        if k.name in ("rbf", "laplacian", "poly") and not k.gamma > 0.0:
            raise ValueError(
                f"kernel {k.name!r} needs gamma > 0, got {k.gamma}")
        if k.name == "poly" and k.degree < 1:
            raise ValueError(f"poly degree must be >= 1, got {k.degree}")
        if not p.lam > 0.0:
            raise ValueError(f"lam must be > 0, got {p.lam}")
        if not p.ups > 0.0:
            raise ValueError(f"ups must be > 0, got {p.ups}")
        if not 0.0 <= p.theta < 1.0:
            raise ValueError(
                f"theta must be in [0, 1) (c = (1-theta)^2/(lam*ups) "
                f"degenerates at 1), got {p.theta}")

    @classmethod
    def create(cls, kernel: str = "rbf", *, gamma: float = 1.0,
               degree: int = 3, coef0: float = 1.0, lam: float = 1.0,
               theta: float = 0.1, ups: float = 0.5) -> "ProblemSpec":
        """Flat-kwargs convenience constructor (quickstart-friendly)."""
        return cls(kernel=kf.KernelSpec(name=kernel, gamma=gamma,
                                        degree=degree, coef0=coef0),
                   params=ODMParams(lam=lam, theta=theta, ups=ups))

    # -- data validation ----------------------------------------------------

    def validate(self, x: Array, y: Array) -> tuple[Array, Array]:
        """Shape/label checks every route used to re-do (or skip).

        Returns ``(x, y)`` as jnp arrays with ``y`` cast to ``x``'s dtype
        (integer ±1 labels are accepted and converted). Raises
        ``ValueError`` with the offending shape/count otherwise.
        """
        x = jnp.asarray(x)
        y = jnp.asarray(y)
        if x.ndim != 2:
            raise ValueError(f"x must be (M, d), got shape {x.shape}")
        if y.ndim != 1:
            raise ValueError(f"y must be (M,), got shape {y.shape}")
        if x.shape[0] != y.shape[0]:
            raise ValueError(
                f"x and y disagree on M: {x.shape[0]} vs {y.shape[0]}")
        if x.shape[0] == 0:
            raise ValueError("empty training set")
        bad = int(jnp.sum(jnp.abs(y.astype(jnp.float32)) != 1.0))
        if bad:
            raise ValueError(
                f"labels must be exactly +1/-1 (the dual layout and every "
                f"margin formula assume it); {bad} of {y.shape[0]} rows "
                f"are not")
        return x, y.astype(x.dtype)

    def validate_source(self, source) -> None:
        """Structural checks for a streaming fit's ShardedSource.

        Cheap metadata-only validation — per-shard label checks happen
        as shards stream through the loader (``iter_slabs``), not here;
        a source's whole point is that nobody reads all of it up front.
        """
        n_rows = int(getattr(source, "n_rows"))
        n_features = int(getattr(source, "n_features"))
        if n_rows <= 0:
            raise ValueError(f"empty training source (n_rows={n_rows})")
        if n_features < 1:
            raise ValueError(
                f"source must have >= 1 feature, got {n_features}")
        sizes = tuple(source.shard_sizes())
        if sum(sizes) != n_rows:
            raise ValueError(
                f"source shard sizes sum to {sum(sizes)} but n_rows is "
                f"{n_rows} — the source is inconsistent")
