"""repro.api — the unified front door over every training route.

    from repro.api import ODMEstimator, ProblemSpec

    est = ODMEstimator(ProblemSpec.create("rbf", gamma=0.5, lam=100.0))
    model, report = est.fit(x, y, key)        # always a servable artifact
    acc = est.score(x_test, y_test)

Pieces (each module's docstring has the full story):

* :class:`ProblemSpec` — kernel + hyperparameters, eagerly validated.
* :mod:`repro.api.registry` — capability-based solver registry; one
  ``resolve`` policy replaces the ad-hoc per-module dispatch.
* :class:`ODMEstimator` — fit / predict / score / save / load facade.
* :class:`FitReport` — the uniform training report (route, engine,
  history, passes, eta, SV count, wall-clock; native result in ``raw``).
"""
from repro.api import registry
from repro.api.estimator import ODMEstimator
from repro.api.registry import SolverEntry, resolve
from repro.api.report import FitReport
from repro.api.spec import ProblemSpec

__all__ = ["ODMEstimator", "ProblemSpec", "FitReport", "SolverEntry",
           "registry", "resolve"]
