"""``ODMEstimator`` — the one front door for training and serving ODMs.

    est = ODMEstimator(ProblemSpec.create("rbf", gamma=0.5, lam=100.0))
    model, report = est.fit(x, y, jax.random.PRNGKey(0))
    est.predict(x_test)              # or model.predict(...)
    est.save("/tmp/model"); ODMEstimator.load("/tmp/model")

One estimator covers every training route in the solver registry
(:mod:`repro.api.registry`): the paper's two regimes (Alg. 1 partitioned
dual solves, Alg. 2 linear-kernel DSVRG) and the Section-4 baselines.
``fit`` validates the data once (:meth:`ProblemSpec.validate`), resolves
the route (explicit ``route=`` always wins; otherwise the registry's auto
policy — the paper's linear-kernel dispatch), runs it, and ALWAYS returns
a deployable :class:`repro.serve.model.FittedODM` plus a uniform
:class:`repro.api.report.FitReport` — fixing the old asymmetry where only
``sodm.fit`` compiled an artifact and every other route handed back raw
solver state.

Persistence delegates to the serving subsystem: :meth:`save` writes the
compiled artifact through ``CheckpointManager`` (atomic, versioned) and
:meth:`load` restores an estimator that scores without refitting.
"""
from __future__ import annotations

import time

import jax

from repro.api import registry
from repro.api.report import FitReport
from repro.api.spec import ProblemSpec
from repro.core import kernel_fns as kf
from repro.core import odm as odm_mod
from repro.core.sodm import SODMConfig
from repro.observe import profile_ctx, span, trace_ctx
from repro.serve import model as serve_model

Array = jax.Array


class ODMEstimator:
    """Facade over the solver registry with sklearn-flavored verbs.

    Parameters
    ----------
    problem: what to solve — a :class:`ProblemSpec` (a bare ``KernelSpec``
        is accepted and wrapped with default ``ODMParams``); ``None``
        means the default rbf problem.
    route: registry route name, or ``None`` for the auto policy
        (:func:`repro.api.registry.resolve`). Unknown names fail HERE,
        not at fit time.
    cfg: one ``SODMConfig`` configures every route — the hierarchical
        routes read p/levels/tol/engine/..., the gradient routes read
        ``cfg.dsvrg`` (epochs/batch/eta/coreset_frac), cascade reads
        levels/tol/max_sweeps.
    mesh / data_axis: SPMD placement for the mesh-aware routes.
    prune_tol / budget / target: artifact compression knobs forwarded to
        ``serve.compile_model`` (SV pruning + Nyström landmark budget).
    """

    def __init__(self, problem: ProblemSpec | kf.KernelSpec | None = None,
                 *, route: str | None = None,
                 cfg: SODMConfig | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data", prune_tol: float = 0.0,
                 budget: int | None = None, target: float | None = None):
        if problem is None:
            problem = ProblemSpec()
        elif isinstance(problem, kf.KernelSpec):
            problem = ProblemSpec(kernel=problem)
        self.problem = problem
        if route is not None:
            registry.get(route)            # unknown route: fail eagerly
        self.route = route
        self.cfg = cfg if cfg is not None else SODMConfig()
        self.mesh = mesh
        self.data_axis = data_axis
        self.compile_kw = {"prune_tol": prune_tol, "budget": budget,
                           "target": target}
        self.model_: serve_model.FittedODM | None = None
        self.report_: FitReport | None = None

    # -- training -----------------------------------------------------------

    #: routes with a resume/faults/tracker seam (the paper's two regimes;
    #: the Section-4 baselines have no mid-solve state worth persisting)
    INSTRUMENTED_ROUTES = ("dsvrg", "sodm")
    #: same seam on the streaming path — the cascade gains it there (its
    #: merge stack checkpoints per leaf as shards arrive)
    STREAM_INSTRUMENTED_ROUTES = ("dsvrg", "cascade")

    def fit(self, x, y: Array | None = None, key: jax.Array | None = None,
            *, resume=None, faults=None, tracker=None, profile_dir=None,
            trace_dir=None,
            **fit_kw) -> tuple[serve_model.FittedODM, FitReport]:
        """Train through the resolved route; returns (artifact, report).

        ``x`` is either a dense ``(M, d)`` feature matrix with ``y`` its
        ±1 labels, or a :class:`repro.data.streaming.ShardedSource` (with
        ``y`` omitted — a source carries its own labels). A source
        streams through an out-of-core route (dsvrg for linear kernels,
        cascade otherwise; see ``registry.streaming_routes``) without
        ever materializing the (M, d) matrix.

        Preemption-proofing and observability (sodm / dsvrg routes only —
        other routes raise rather than silently ignore these):

        resume: a directory (or :class:`repro.distributed.resume
            .ResumeConfig`) holding mid-solve checkpoints. A fresh
            directory is populated as the solve progresses (per cascade
            level / per DSVRG epoch segment); a directory left behind by
            a preempted fit restarts at the first unsolved level, and the
            result is bit-identical to an uninterrupted run. Provenance
            (kernel/params/cfg/data/key) is fingerprinted — resuming
            against a different problem raises.
        faults: a :class:`repro.distributed.faults.FaultPlan` for
            deterministic chaos testing (kill-at-level-k,
            kill-mid-checkpoint, ...).
        tracker: anything with ``log_metrics(step, dict)`` (see
            :mod:`repro.observe`); receives per-level / per-segment
            training metrics plus one final fit summary.
        profile_dir: write a JAX profiler trace of the solve there.
        trace_dir: record host-side spans (fit → route → cascade.level /
            dsvrg.segment, checkpoint commits) and export Chrome-trace
            JSON to ``<trace_dir>/trace.json`` — open it in Perfetto.
            Unlike resume/faults/tracker this works on every route (it
            only wraps host code).

        Remaining ``fit_kw`` forward route-specific hooks (currently
        ``level_callback`` for the sodm route's legacy per-level
        checkpointing seam).
        """
        from repro.data.streaming import is_source
        streaming = is_source(x)
        if streaming:
            if y is not None:
                raise ValueError(
                    "fit(source) carries its own labels — passing y "
                    "alongside a ShardedSource is ambiguous; drop y")
            self.problem.validate_source(x)
            M = int(x.n_rows)
        else:
            x, y = self.problem.validate(x, y)
            M = int(x.shape[0])
        key = jax.random.PRNGKey(0) if key is None else key
        entry = registry.resolve(self.problem, M, mesh=self.mesh,
                                 route=self.route, cfg=self.cfg,
                                 streaming=streaming)
        instrumented = self.STREAM_INSTRUMENTED_ROUTES if streaming \
            else self.INSTRUMENTED_ROUTES
        if entry.name not in instrumented:
            bad = [n for n, v in (("resume", resume), ("faults", faults),
                                  ("tracker", tracker)) if v is not None]
            if bad:
                raise ValueError(
                    f"route {entry.name!r} has no {'/'.join(bad)} seam — "
                    f"instrumented routes: {list(instrumented)}")
        if not streaming:
            loader_kw = [k for k in ("depth", "executor", "metrics",
                                     "accountant") if k in fit_kw]
            if loader_kw:
                raise ValueError(
                    f"{'/'.join(loader_kw)} are streaming loader knobs — "
                    f"they only apply to fit(source); a dense fit has no "
                    f"prefetch loader to configure")
        if resume is not None:
            fit_kw["resume"] = self._resume_manager(entry.name, resume,
                                                    x, y, key, faults,
                                                    streaming=streaming)
        if faults is not None:
            fit_kw["faults"] = faults
        if tracker is not None:
            fit_kw["tracker"] = tracker
        # the schedule-upgrade rule only applies to AUTO dsvrg dispatch
        # (an explicit choice keeps whatever cfg.dsvrg says)
        auto = (entry.name == "dsvrg" and self.route is None
                and self.cfg.engine != "dsvrg")
        t0 = time.perf_counter()
        with trace_ctx(trace_dir), profile_ctx(profile_dir), \
                span("fit", route=entry.name, n_train=M,
                     streaming=streaming):
            with span(f"route.{entry.name}", engine=self.cfg.engine):
                out = entry.fit(self.problem, x, y, key, cfg=self.cfg,
                                mesh=self.mesh, data_axis=self.data_axis,
                                auto=auto, compile_kw=dict(self.compile_kw),
                                fit_kw=fit_kw)
            with span("fit.block_until_ready"):
                jax.block_until_ready(
                    out.model.w if out.model.w is not None
                    else out.model.coef)
        wall = time.perf_counter() - t0
        report = FitReport(
            route=entry.name, engine=out.engine, algorithm=entry.algorithm,
            n_train=M, n_sv=out.model.n_sv,
            compression=out.model.compression, wall_clock=wall,
            passes=out.passes, kkt=out.kkt, eta=out.eta,
            history=out.history, gap=out.model.gap, raw=out.raw)
        if tracker is not None:
            final = out.passes[0] if entry.name == "dsvrg" \
                else len(out.passes)
            tracker.log_metrics(final, {
                "route": entry.name, "engine": out.engine, "fit_done": True,
                "n_train": M, "n_sv": out.model.n_sv, "kkt": out.kkt,
                "wall_clock": wall,
                "rows_per_s": M / max(wall, 1e-9)})
        self.model_, self.report_ = out.model, report
        return out.model, report

    def _resume_manager(self, route: str, resume, x: Array, y: Array,
                        key: jax.Array, faults, streaming: bool = False):
        """Build the route's resume manager, fingerprinting THIS fit's
        (kernel, params, cfg, data, key) so a stale directory is rejected
        instead of splicing foreign duals into the solve. A streaming fit
        fingerprints the *source* (``source.fingerprint()``) instead of
        summing data nobody wants resident."""
        from repro.distributed import resume as resume_mod
        rc = resume_mod.ResumeConfig.of(resume)
        if streaming:
            prov = resume_mod.provenance_source(self.problem.kernel,
                                                self.problem.params,
                                                self.cfg, x, key)
        else:
            prov = resume_mod.provenance(self.problem.kernel,
                                         self.problem.params, self.cfg,
                                         x, y, key)
        cls = (resume_mod.DsvrgResumeManager if route == "dsvrg"
               else resume_mod.CascadeResumeManager)
        return cls(rc, prov, faults=faults)

    # -- scoring ------------------------------------------------------------

    def _fitted(self) -> serve_model.FittedODM:
        if self.model_ is None:
            raise ValueError(
                "this ODMEstimator is not fitted — call fit(x, y) first "
                "(or load() a saved artifact)")
        return self.model_

    def decision_function(self, x: Array, **kw) -> Array:
        """f(x) (T,) through the served scoring path."""
        return self._fitted().decision_function(x, **kw)

    def predict(self, x: Array, **kw) -> Array:
        """sign(f(x)) in {-1, +1}."""
        return self._fitted().predict(x, **kw)

    def score(self, x: Array, y: Array) -> float:
        """Accuracy of :meth:`predict` against ±1 labels."""
        return float(odm_mod.accuracy(y, self.predict(x)))

    # -- persistence --------------------------------------------------------

    def save(self, directory: str) -> str:
        """Persist the fitted artifact (atomic versioned checkpoint)."""
        return self._fitted().save(directory)

    @classmethod
    def load(cls, directory: str, *,
             problem: ProblemSpec | None = None) -> "ODMEstimator":
        """Restore an estimator that scores immediately (no refit).

        The artifact stores the kernel spec but not the training
        hyperparameters; pass ``problem`` to set them for a later refit,
        otherwise defaults are assumed.
        """
        model = serve_model.load_model(directory)
        est = cls(problem if problem is not None
                  else ProblemSpec(kernel=model.spec))
        est.model_ = model
        return est
