"""``ODMEstimator`` — the one front door for training and serving ODMs.

    est = ODMEstimator(ProblemSpec.create("rbf", gamma=0.5, lam=100.0))
    model, report = est.fit(x, y, jax.random.PRNGKey(0))
    est.predict(x_test)              # or model.predict(...)
    est.save("/tmp/model"); ODMEstimator.load("/tmp/model")

One estimator covers every training route in the solver registry
(:mod:`repro.api.registry`): the paper's two regimes (Alg. 1 partitioned
dual solves, Alg. 2 linear-kernel DSVRG) and the Section-4 baselines.
``fit`` validates the data once (:meth:`ProblemSpec.validate`), resolves
the route (explicit ``route=`` always wins; otherwise the registry's auto
policy — the paper's linear-kernel dispatch), runs it, and ALWAYS returns
a deployable :class:`repro.serve.model.FittedODM` plus a uniform
:class:`repro.api.report.FitReport` — fixing the old asymmetry where only
``sodm.fit`` compiled an artifact and every other route handed back raw
solver state.

Persistence delegates to the serving subsystem: :meth:`save` writes the
compiled artifact through ``CheckpointManager`` (atomic, versioned) and
:meth:`load` restores an estimator that scores without refitting.
"""
from __future__ import annotations

import time

import jax

from repro.api import registry
from repro.api.report import FitReport
from repro.api.spec import ProblemSpec
from repro.core import kernel_fns as kf
from repro.core import odm as odm_mod
from repro.core.sodm import SODMConfig
from repro.serve import model as serve_model

Array = jax.Array


class ODMEstimator:
    """Facade over the solver registry with sklearn-flavored verbs.

    Parameters
    ----------
    problem: what to solve — a :class:`ProblemSpec` (a bare ``KernelSpec``
        is accepted and wrapped with default ``ODMParams``); ``None``
        means the default rbf problem.
    route: registry route name, or ``None`` for the auto policy
        (:func:`repro.api.registry.resolve`). Unknown names fail HERE,
        not at fit time.
    cfg: one ``SODMConfig`` configures every route — the hierarchical
        routes read p/levels/tol/engine/..., the gradient routes read
        ``cfg.dsvrg`` (epochs/batch/eta/coreset_frac), cascade reads
        levels/tol/max_sweeps.
    mesh / data_axis: SPMD placement for the mesh-aware routes.
    prune_tol / budget / target: artifact compression knobs forwarded to
        ``serve.compile_model`` (SV pruning + Nyström landmark budget).
    """

    def __init__(self, problem: ProblemSpec | kf.KernelSpec | None = None,
                 *, route: str | None = None,
                 cfg: SODMConfig | None = None,
                 mesh: jax.sharding.Mesh | None = None,
                 data_axis: str = "data", prune_tol: float = 0.0,
                 budget: int | None = None, target: float | None = None):
        if problem is None:
            problem = ProblemSpec()
        elif isinstance(problem, kf.KernelSpec):
            problem = ProblemSpec(kernel=problem)
        self.problem = problem
        if route is not None:
            registry.get(route)            # unknown route: fail eagerly
        self.route = route
        self.cfg = cfg if cfg is not None else SODMConfig()
        self.mesh = mesh
        self.data_axis = data_axis
        self.compile_kw = {"prune_tol": prune_tol, "budget": budget,
                           "target": target}
        self.model_: serve_model.FittedODM | None = None
        self.report_: FitReport | None = None

    # -- training -----------------------------------------------------------

    def fit(self, x: Array, y: Array, key: jax.Array | None = None,
            **fit_kw) -> tuple[serve_model.FittedODM, FitReport]:
        """Train through the resolved route; returns (artifact, report).

        ``fit_kw`` forwards route-specific hooks (currently
        ``level_callback`` for the single-process sodm route's per-level
        checkpointing; routes ignore hooks they have no seam for).
        """
        x, y = self.problem.validate(x, y)
        key = jax.random.PRNGKey(0) if key is None else key
        M = int(x.shape[0])
        entry = registry.resolve(self.problem, M, mesh=self.mesh,
                                 route=self.route, cfg=self.cfg)
        # the schedule-upgrade rule only applies to AUTO dsvrg dispatch
        # (an explicit choice keeps whatever cfg.dsvrg says)
        auto = (entry.name == "dsvrg" and self.route is None
                and self.cfg.engine != "dsvrg")
        t0 = time.perf_counter()
        out = entry.fit(self.problem, x, y, key, cfg=self.cfg,
                        mesh=self.mesh, data_axis=self.data_axis,
                        auto=auto, compile_kw=dict(self.compile_kw),
                        fit_kw=fit_kw)
        jax.block_until_ready(
            out.model.w if out.model.w is not None else out.model.coef)
        wall = time.perf_counter() - t0
        report = FitReport(
            route=entry.name, engine=out.engine, algorithm=entry.algorithm,
            n_train=M, n_sv=out.model.n_sv,
            compression=out.model.compression, wall_clock=wall,
            passes=out.passes, kkt=out.kkt, eta=out.eta,
            history=out.history, gap=out.model.gap, raw=out.raw)
        self.model_, self.report_ = out.model, report
        return out.model, report

    # -- scoring ------------------------------------------------------------

    def _fitted(self) -> serve_model.FittedODM:
        if self.model_ is None:
            raise ValueError(
                "this ODMEstimator is not fitted — call fit(x, y) first "
                "(or load() a saved artifact)")
        return self.model_

    def decision_function(self, x: Array, **kw) -> Array:
        """f(x) (T,) through the served scoring path."""
        return self._fitted().decision_function(x, **kw)

    def predict(self, x: Array, **kw) -> Array:
        """sign(f(x)) in {-1, +1}."""
        return self._fitted().predict(x, **kw)

    def score(self, x: Array, y: Array) -> float:
        """Accuracy of :meth:`predict` against ±1 labels."""
        return float(odm_mod.accuracy(y, self.predict(x)))

    # -- persistence --------------------------------------------------------

    def save(self, directory: str) -> str:
        """Persist the fitted artifact (atomic versioned checkpoint)."""
        return self._fitted().save(directory)

    @classmethod
    def load(cls, directory: str, *,
             problem: ProblemSpec | None = None) -> "ODMEstimator":
        """Restore an estimator that scores immediately (no refit).

        The artifact stores the kernel spec but not the training
        hyperparameters; pass ``problem`` to set them for a later refit,
        otherwise defaults are assumed.
        """
        model = serve_model.load_model(directory)
        est = cls(problem if problem is not None
                  else ProblemSpec(kernel=model.spec))
        est.model_ = model
        return est
