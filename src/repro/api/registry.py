"""Capability-based solver registry — every training route behind one door.

The paper presents SODM as ONE method with two regimes: hierarchical
partitioned dual solves for nonlinear kernels (Algorithm 1) and a
communication-efficient SVRG for the linear kernel (Algorithm 2). The
repo's Section-4 baselines add five more strategies. Each route registers
a :class:`SolverEntry` here with *declared capabilities* — supported
kernel families, mesh-awareness, matrix-free-ness, scale band — and one
:func:`resolve` policy turns (problem, M, mesh[, route/config]) into the
entry that trains it:

* an EXPLICIT choice always wins: ``resolve(..., route=name)`` returns
  that entry or raises a ``ValueError`` listing its capabilities when the
  problem is outside them (never a silent fallback — the old
  ``engines.wants_dsvrg`` fell through to the scalar loop);
* the AUTO policy (``route=None``) is the paper's dispatch, identical to
  the PR 3 behavior it replaces (property-tested in
  ``tests/test_api.py``): a ``SODMConfig.engine`` pinned to a level
  engine stays on the ``sodm`` route whatever the problem size;
  ``engine="dsvrg"`` demands the dsvrg route (linear kernel required);
  an unset engine routes linear-kernel problems with
  M >= ``dsvrg_threshold`` to ``dsvrg`` and everything else to ``sodm``.

Routes (see also the README table):

====== ===================================================== =========
name   strategy                                              kernels
====== ===================================================== =========
sodm   Alg. 1 hierarchical partitioned dual CD               all
dsvrg  Alg. 2 communication-efficient primal SVRG            linear
cascade Graf et al. 2004 binary-funnel cascade (Ca-ODM)      all
dip    DiP-SVM-style round-robin k-means strata (DiP-ODM)    all
dc     DC-SVM-style cluster-per-partition (DC-ODM)           all
svrg   single-chain SVRG (Johnson & Zhang 2013)              linear
csvrg  coreset-anchor SVRG (Tan et al. 2019)                 linear
====== ===================================================== =========

Every ``fit`` callable has the uniform signature

    fit(problem, x, y, key, *, cfg, mesh, data_axis, auto,
        compile_kw, fit_kw) -> RouteOutput

and returns a compiled, deployable :class:`repro.serve.model.FittedODM`
plus the report fields — training output is ALWAYS a servable artifact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax

from repro.core import baselines as baselines_mod
from repro.core import dsvrg as dsvrg_mod
from repro.core import sodm as sodm_mod
from repro.serve import model as serve_model

Array = jax.Array

#: auto-dispatch threshold of Algorithm 2 ("when linear kernel is
#: applied ... we extend a communication efficient SVRG method") — read
#: off ``SODMConfig.dsvrg_threshold``'s default so bare registry
#: resolution and config-carrying resolution can never disagree.
DSVRG_AUTO_THRESHOLD = sodm_mod.SODMConfig.dsvrg_threshold


class RouteOutput(NamedTuple):
    """What a route's ``fit`` hands back to the estimator."""

    model: serve_model.FittedODM
    raw: object                       # the route's native result
    engine: str
    passes: tuple[int, ...]
    kkt: float | None = None
    eta: float | None = None
    history: tuple[float, ...] | None = None


@dataclasses.dataclass(frozen=True)
class SolverEntry:
    """One registered training route and its declared capabilities."""

    name: str
    fit: Callable[..., RouteOutput]
    algorithm: str                     # paper algorithm / citation
    kernels: frozenset[str] | None = None   # None = every KernelSpec family
    mesh_aware: bool = False           # has an SPMD (shard_map) driver
    matrix_free: bool = False          # never materializes O(m^2) state
    streaming: bool = False            # consumes a ShardedSource out-of-core
    scale_min: int = 0                 # auto-dispatch band (advisory)
    scale_max: int | None = None
    description: str = ""

    def capabilities(self) -> str:
        """Human-readable capability line (used by every resolve error)."""
        kern = "all kernels" if self.kernels is None \
            else "kernels {" + ", ".join(sorted(self.kernels)) + "}"
        band = f"M in [{self.scale_min}, " + \
            (f"{self.scale_max}]" if self.scale_max is not None else "inf)")
        return (f"{self.name}: {self.algorithm}; {kern}; "
                f"mesh_aware={self.mesh_aware}; "
                f"matrix_free={self.matrix_free}; "
                f"streaming={self.streaming}; {band}")

    def check(self, kernel_name: str, M: int,
              mesh: jax.sharding.Mesh | None = None,
              streaming: bool = False) -> None:
        """Raise ``ValueError`` (listing capabilities) on incompatibility."""
        if self.kernels is not None and kernel_name not in self.kernels:
            raise ValueError(
                f"route {self.name!r} does not support kernel "
                f"{kernel_name!r} — its capabilities: {self.capabilities()}."
                f" Routes supporting {kernel_name!r}: "
                f"{supporting(kernel_name)}")
        if mesh is not None and not self.mesh_aware:
            raise ValueError(
                f"route {self.name!r} has no SPMD driver but a mesh was "
                f"given — its capabilities: {self.capabilities()}. "
                f"Mesh-aware routes: "
                f"{[e.name for e in _REGISTRY.values() if e.mesh_aware]}")
        if streaming and not self.streaming:
            raise ValueError(
                f"route {self.name!r} cannot train from a ShardedSource — "
                f"its capabilities: {self.capabilities()}. Streaming routes: "
                f"{streaming_routes()}")
        if streaming and mesh is not None:
            raise ValueError(
                "streaming fits have no SPMD driver yet (ROADMAP open "
                "item 2: mesh-sharded shard ingestion) — drop the mesh or "
                "materialize the source")


_REGISTRY: dict[str, SolverEntry] = {}


def register(entry: SolverEntry) -> SolverEntry:
    """Add a route. Duplicate names raise (no silent shadowing)."""
    if entry.name in _REGISTRY:
        raise ValueError(
            f"route {entry.name!r} is already registered "
            f"({_REGISTRY[entry.name].capabilities()}); unregister it "
            f"first or pick another name. Registered routes: {routes()}")
    _REGISTRY[entry.name] = entry
    return entry


def unregister(name: str) -> None:
    """Remove a route (plugin/test hook)."""
    _REGISTRY.pop(name, None)


def get(name: str) -> SolverEntry:
    """Look a route up by name; unknown names raise listing the options."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown route {name!r}; registered routes: {routes()}"
        ) from None


def routes() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def supporting(kernel_name: str) -> list[str]:
    """Route names whose capabilities cover ``kernel_name``."""
    return [e.name for e in _REGISTRY.values()
            if e.kernels is None or kernel_name in e.kernels]


def streaming_routes() -> list[str]:
    """Route names that can consume a ShardedSource out-of-core."""
    return [e.name for e in _REGISTRY.values() if e.streaming]


def capability_table() -> str:
    """All routes, one capability line each (README / error helper)."""
    return "\n".join(_REGISTRY[n].capabilities() for n in routes())


# ---------------------------------------------------------------------------
# resolution policy
# ---------------------------------------------------------------------------

def resolve(problem, M: int, mesh: jax.sharding.Mesh | None = None,
            route: str | None = None, cfg=None,
            streaming: bool = False) -> SolverEntry:
    """The one dispatch policy: explicit route wins, else the paper's auto
    rule. ``problem`` is a :class:`repro.api.spec.ProblemSpec` (or a bare
    ``KernelSpec``); ``cfg`` an optional ``SODMConfig`` supplying the
    ``engine`` pin and ``dsvrg_threshold``; ``streaming`` marks a fit fed
    by a ShardedSource (routes without an out-of-core driver refuse).
    """
    kernel_name = getattr(getattr(problem, "kernel", problem), "name")
    if route is not None:
        entry = get(route)
        if entry.name != "dsvrg" and getattr(cfg, "engine", None) == "dsvrg":
            raise ValueError(
                f"route={route!r} with SODMConfig.engine='dsvrg' is "
                f"contradictory — use route='dsvrg', or leave route unset "
                f"(the resolve policy honors the engine pin)")
        entry.check(kernel_name, M, mesh, streaming)
        return entry
    engine = getattr(cfg, "engine", None)
    threshold = getattr(cfg, "dsvrg_threshold", DSVRG_AUTO_THRESHOLD)
    return resolve_auto(kernel_name, M, engine=engine, threshold=threshold,
                        mesh=mesh, streaming=streaming)


def resolve_auto(kernel_name: str, M: int, *, engine: str | None = None,
                 threshold: int = DSVRG_AUTO_THRESHOLD,
                 mesh: jax.sharding.Mesh | None = None,
                 streaming: bool = False) -> SolverEntry:
    """The paper's linear-kernel dispatch (Section 3.3), PR 3 semantics.

    ``engine="dsvrg"`` demands the dsvrg route (raises for nonlinear
    kernels, listing capabilities); any other explicitly named engine —
    scalar included — pins the sodm level loop whatever the problem size;
    an unset engine (``None``) routes linear-kernel problems with
    M >= ``threshold`` to dsvrg and everything else to sodm. Replaces
    ``engines.wants_dsvrg`` as the single source of this rule.

    Streaming fits narrow the menu to the out-of-core drivers: linear
    kernels (or an explicit dsvrg engine pin) stream through dsvrg —
    a source is by definition past the threshold regime — and every
    other kernel streams through the cascade.
    """
    if streaming:
        if engine == "dsvrg" or kernel_name == "linear":
            entry = get("dsvrg")
        else:
            entry = get("cascade")
    elif engine == "dsvrg":
        entry = get("dsvrg")
    elif engine is None and kernel_name == "linear" and M >= threshold:
        entry = get("dsvrg")
    else:
        entry = get("sodm")
    entry.check(kernel_name, M, mesh, streaming)
    return entry


def dsvrg_partition_count(M: int, want: int, n_dev: int = 1) -> int:
    """Largest K <= ``want`` that divides M and is a multiple of ``n_dev``
    (the dsvrg route's partition clamp, shared by every caller)."""
    K = max(want - want % n_dev, n_dev)
    while K >= n_dev:
        if M % K == 0:
            return K
        K -= n_dev
    raise ValueError(
        f"no DSVRG partition count <= {want} divides M={M} and is a "
        f"multiple of the data axis size {n_dev}")


# ---------------------------------------------------------------------------
# route implementations (uniform fit signature)
# ---------------------------------------------------------------------------

def _pin_level_engine(cfg, route: str):
    """An explicit route choice must never be re-routed by the level
    loop's own auto dispatch: ``engine=None`` behaves exactly like
    ``"scalar"`` inside the loop, so pin it there — and the contradictory
    ``engine="dsvrg"`` combo fails loudly instead of silently training
    a different algorithm than the requested route."""
    if cfg.engine == "dsvrg":
        raise ValueError(
            f"route={route!r} with SODMConfig.engine='dsvrg' is "
            f"contradictory — use route='dsvrg', or leave route unset "
            f"(the resolve policy honors the engine pin)")
    if cfg.engine is None:
        return dataclasses.replace(cfg, engine="scalar")
    return cfg


def _hooks(fit_kw) -> dict:
    """The preemption/observability seams every instrumented route takes
    (repro.distributed.faults / repro.observe / repro.distributed.resume),
    forwarded from ``ODMEstimator.fit(faults=, tracker=, resume=)``."""
    return {k: fit_kw[k] for k in ("faults", "tracker", "resume")
            if fit_kw.get(k) is not None}


def _stream_hooks(fit_kw) -> dict:
    """:func:`_hooks` plus the loader knobs only the streaming drivers
    take: prefetch ``depth``, injected ``executor``/``metrics`` (chaos
    and instrument tests), and the resident-byte ``accountant``."""
    kw = _hooks(fit_kw)
    kw.update({k: fit_kw[k]
               for k in ("depth", "executor", "metrics", "accountant")
               if fit_kw.get(k) is not None})
    return kw


def _fit_sodm(problem, x, y, key, *, cfg, mesh, data_axis, auto,
              compile_kw, fit_kw) -> RouteOutput:
    del auto
    cfg = _pin_level_engine(cfg, "sodm")
    if mesh is None:
        res = sodm_mod._solve(problem.kernel, x, y, problem.params, cfg,
                              key, fit_kw.get("level_callback"),
                              **_hooks(fit_kw))
    else:
        res = sodm_mod._solve_sharded(problem.kernel, x, y, problem.params,
                                      cfg, key, mesh, data_axis=data_axis,
                                      **_hooks(fit_kw))
    model = serve_model.from_sodm(problem.kernel, res, x, y, **compile_kw)
    return RouteOutput(model=model, raw=res, engine=cfg.engine,
                       passes=tuple(res.sweeps_per_level),
                       kkt=float(res.kkt))


def _fit_dsvrg(problem, x, y, key, *, cfg, mesh, data_axis, auto,
               compile_kw, fit_kw) -> RouteOutput:
    if y is None:                      # x is a ShardedSource (streaming fit)
        del mesh, data_axis, auto, compile_kw
        source = x
        dres, kkt = dsvrg_mod._solve_stream(source, problem.params,
                                            cfg.dsvrg, key,
                                            **_stream_hooks(fit_kw))
        # the dual-recovery pass of the resident path is O(M) host state —
        # a streaming fit compiles the artifact straight from the primal w
        model = serve_model.FittedODM(spec=problem.kernel, w=dres.w,
                                      n_train=int(source.n_rows),
                                      compression="linear")
        return RouteOutput(model=model, raw=dres, engine="dsvrg",
                           passes=(len(dres.history),), kkt=float(kkt),
                           eta=float(dres.eta),
                           history=tuple(float(h) for h in dres.history))
    res, dres = sodm_mod._solve_dsvrg(problem.kernel, x, y, problem.params,
                                      cfg, key, mesh=mesh,
                                      data_axis=data_axis, auto=auto,
                                      **_hooks(fit_kw))
    # the artifact comes straight from the primal w (born compressed, and
    # bit-identical to a direct dsvrg.solve consumer's model); the
    # recovered-dual SODMResult rides along as the stationarity check
    model = dataclasses.replace(serve_model.from_dsvrg(dres),
                                spec=problem.kernel)
    return RouteOutput(model=model, raw=dres, engine="dsvrg",
                       passes=(len(dres.history),), kkt=float(res.kkt),
                       eta=float(dres.eta),
                       history=tuple(float(h) for h in dres.history))


def _fit_cascade(problem, x, y, key, *, cfg, mesh, data_axis, auto,
                 compile_kw, fit_kw) -> RouteOutput:
    del mesh, data_axis, auto
    if y is None:                      # x is a ShardedSource (streaming fit)
        res = baselines_mod._cascade_solve_stream(
            problem.kernel, x, problem.params, levels=cfg.levels, key=key,
            tol=cfg.tol, max_sweeps=cfg.max_sweeps, **_stream_hooks(fit_kw))
    else:
        del fit_kw
        res = baselines_mod._cascade_solve(problem.kernel, x, y,
                                           problem.params, levels=cfg.levels,
                                           key=key, tol=cfg.tol,
                                           max_sweeps=cfg.max_sweeps)
    model = serve_model.from_cascade(problem.kernel, res, **compile_kw)
    return RouteOutput(model=model, raw=res, engine="scalar",
                       passes=(res.levels_run,))


def _fit_dip(problem, x, y, key, *, cfg, mesh, data_axis, auto,
             compile_kw, fit_kw) -> RouteOutput:
    del mesh, data_axis, auto, fit_kw
    cfg = _pin_level_engine(cfg, "dip")
    res = baselines_mod._dip_solve(problem.kernel, x, y, problem.params,
                                   cfg, key)
    model = serve_model.from_sodm(problem.kernel, res, x, y, **compile_kw)
    return RouteOutput(model=model, raw=res, engine=cfg.engine,
                       passes=tuple(res.sweeps_per_level),
                       kkt=float(res.kkt))


def _fit_dc(problem, x, y, key, *, cfg, mesh, data_axis, auto,
            compile_kw, fit_kw) -> RouteOutput:
    del mesh, data_axis, auto, fit_kw
    cfg = _pin_level_engine(cfg, "dc")
    res = baselines_mod._dc_solve(problem.kernel, x, y, problem.params,
                                  cfg, key)
    model = serve_model.from_sodm(problem.kernel, res, x, y, **compile_kw)
    return RouteOutput(model=model, raw=res, engine=cfg.engine,
                       passes=tuple(res.sweeps_per_level),
                       kkt=float(res.kkt))


def _grad_eta(x, cfg, params) -> float:
    d = cfg.dsvrg
    return d.eta if d.eta > 0 else dsvrg_mod.auto_eta(x, params)


def _fit_svrg(problem, x, y, key, *, cfg, mesh, data_axis, auto,
              compile_kw, fit_kw) -> RouteOutput:
    del mesh, data_axis, auto, compile_kw, fit_kw
    d = cfg.dsvrg
    eta = _grad_eta(x, cfg, problem.params)
    res = baselines_mod._svrg_solve(x, y, problem.params, epochs=d.epochs,
                                    eta=eta, key=key, batch=d.batch)
    model = serve_model.FittedODM(spec=problem.kernel, w=res.w,
                                  n_train=int(x.shape[0]),
                                  compression="linear")
    return RouteOutput(model=model, raw=res, engine="svrg",
                       passes=(d.epochs,), eta=float(eta),
                       history=tuple(float(h) for h in res.history))


def _fit_csvrg(problem, x, y, key, *, cfg, mesh, data_axis, auto,
               compile_kw, fit_kw) -> RouteOutput:
    del mesh, data_axis, auto, compile_kw, fit_kw
    d = cfg.dsvrg
    eta = _grad_eta(x, cfg, problem.params)
    res = baselines_mod._csvrg_solve(x, y, problem.params, epochs=d.epochs,
                                     eta=eta, key=key,
                                     coreset_frac=d.coreset_frac,
                                     batch=d.batch)
    model = serve_model.FittedODM(spec=problem.kernel, w=res.w,
                                  n_train=int(x.shape[0]),
                                  compression="linear")
    return RouteOutput(model=model, raw=res, engine="csvrg",
                       passes=(d.epochs,), eta=float(eta),
                       history=tuple(float(h) for h in res.history))


# ---------------------------------------------------------------------------
# the built-in routes
# ---------------------------------------------------------------------------

_LINEAR = frozenset({"linear"})

register(SolverEntry(
    name="sodm", fit=_fit_sodm,
    algorithm="Alg. 1 (hierarchical partitioned dual CD)",
    kernels=None, mesh_aware=True, matrix_free=True,
    description="stratified partitions, warm-started level merges; level "
                "engines scalar | block | pallas"))
register(SolverEntry(
    name="dsvrg", fit=_fit_dsvrg,
    algorithm="Alg. 2 (communication-efficient SVRG)",
    kernels=_LINEAR, mesh_aware=True, matrix_free=True, streaming=True,
    scale_min=DSVRG_AUTO_THRESHOLD,
    description="primal round-robin SVRG; dual recovered via "
                "odm.alpha_from_w; auto-selected for big linear problems; "
                "accepts a ShardedSource (out-of-core epochs)"))
register(SolverEntry(
    name="cascade", fit=_fit_cascade,
    algorithm="Ca-ODM (Graf et al. 2004 cascade)",
    kernels=None, mesh_aware=False, matrix_free=False, streaming=True,
    description="binary support-vector funnel; fast but lossy baseline; "
                "accepts a ShardedSource (leaves train as shards arrive)"))
register(SolverEntry(
    name="dip", fit=_fit_dip,
    algorithm="DiP-ODM (Singh et al. 2017)",
    kernels=None, mesh_aware=False, matrix_free=False,
    description="k-means strata dealt round-robin, then the SODM merge"))
register(SolverEntry(
    name="dc", fit=_fit_dc,
    algorithm="DC-ODM (Hsieh et al. 2014)",
    kernels=None, mesh_aware=False, matrix_free=False,
    description="k-means clusters as partitions, then the SODM merge"))
register(SolverEntry(
    name="svrg", fit=_fit_svrg,
    algorithm="single-chain SVRG (Johnson & Zhang 2013)",
    kernels=_LINEAR, mesh_aware=False, matrix_free=False,
    description="gradient baseline; eta <= 0 takes the auto smoothness "
                "step"))
register(SolverEntry(
    name="csvrg", fit=_fit_csvrg,
    algorithm="coreset SVRG (Tan et al. 2019)",
    kernels=_LINEAR, mesh_aware=False, matrix_free=False,
    description="anchor gradients on a k-center coreset "
                "(DSVRGConfig.coreset_frac)"))
