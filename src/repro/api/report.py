"""``FitReport`` — the uniform training report every route returns.

Before the unified API each route returned its own grab-bag
(``SODMResult`` with sweeps/KKT, ``DSVRGResult`` with history/eta,
``CascadeResult`` with a survivor slab, bare ``GradResult`` tuples), so
benchmarks and examples each re-derived "what happened" differently.
``FitReport`` is the one shape: route chosen, engine used, objective
history, pass/epoch counts, step size where applicable, SV count,
wall-clock. The route's native result survives untouched in ``raw`` for
consumers that need route-specific fields (e.g. ``SODMResult.perm``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FitReport:
    """What one ``ODMEstimator.fit`` did, uniformly across routes.

    ``passes`` is the route's native progress counter — CD sweeps per
    level for the sodm/dip/dc level loops (one entry per level, coarsest
    last), ``(epochs,)`` for the gradient routes, and ``(levels_run,)``
    for the cascade funnel (its result reports no per-level sweep
    counts). ``history`` / ``eta`` are ``None`` where the route has no
    objective trace / step size (the dual-CD level loop tracks a KKT
    residual instead — ``kkt``).
    """

    route: str                            # registry route that trained
    engine: str                           # solver engine underneath
    algorithm: str                        # paper algorithm it implements
    n_train: int                          # instances trained on
    n_sv: int                             # SVs in the compiled artifact
    compression: str                      # FittedODM.compression
    wall_clock: float                     # fit seconds (solve + compile)
    passes: tuple[int, ...] = ()          # sweeps per level / (epochs,)
    kkt: float | None = None              # final KKT residual (dual routes)
    eta: float | None = None              # step size used (gradient routes)
    history: tuple[float, ...] | None = None   # per-epoch objective
    gap: float = 0.0                      # compile-time decision gap
    raw: object = None                    # the route's native result

    def summary(self) -> str:
        """One readable line for logs and examples."""
        bits = [f"route={self.route}", f"engine={self.engine}",
                f"M={self.n_train}", f"sv={self.n_sv}",
                f"passes={list(self.passes)}"]
        if self.kkt is not None:
            bits.append(f"kkt={self.kkt:.2e}")
        if self.eta is not None:
            bits.append(f"eta={self.eta:.4g}")
        if self.history:
            bits.append(f"obj={self.history[-1]:.5f}")
        bits.append(f"{self.wall_clock:.2f}s")
        return "FitReport(" + " ".join(bits) + ")"
