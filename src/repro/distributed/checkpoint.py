"""Sharded, versioned, atomic checkpoints with async write + retention.

Layout:   <dir>/step_<n>/manifest.json + arrays.npz       (committed)
          <dir>/step_<n>.tmp.<pid>/...                    (in flight)

* **Atomic commit**: everything is written into a tmp dir, fsync'd, then
  os.rename'd — a crash never leaves a half-readable step visible.
* **Async**: ``save_async`` snapshots to host memory (device_get) on the
  caller thread — the cheap part — and runs serialization on a background
  thread so the train loop is not blocked by disk.
* **Elastic restore**: the manifest stores *logical axes* per leaf, not
  device assignments; ``restore`` re-resolves shardings against whatever
  mesh is active (a checkpoint written on (2,16,16) restores onto (16,16)
  or (8,16) — tested in tests/test_checkpoint.py).
* **Retention**: keep the most recent ``keep`` steps, delete older.

Data cursor convention: train loops store {"step": int} metadata; the data
pipeline (repro.data.lm) is stateless given the step, so restore resumes
the exact stream position.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.observe.spans import span as _span

SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _unflatten_into(template, flat: dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths[0]:
        key = SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, faults=None):
        self.dir = directory
        self.keep = keep
        # fault-injection hook (repro.distributed.faults.FaultPlan): fires
        # the "checkpoint.pre_rename" site inside the crash window — after
        # the fsync'd temp write, before the atomic rename
        self.faults = faults
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write ---------------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[dict] = None) -> str:
        """Synchronous checkpoint."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host, metadata or {})

    def save_async(self, step: int, tree, metadata: Optional[dict] = None):
        """Snapshot now, serialize in the background."""
        self.wait()                      # one in flight at a time
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        md = dict(metadata or {})

        def run():
            try:
                self._write(step, host, md)
            except BaseException as e:     # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree, metadata: dict) -> str:
        with _span("checkpoint.commit", step=step):
            return self._write_inner(step, host_tree, metadata)

    def _write_inner(self, step: int, host_tree, metadata: dict) -> str:
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + f".tmp.{os.getpid()}"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        # numpy cannot natively persist ml_dtypes (bfloat16 etc.); store a
        # same-width unsigned view and record the true dtype in the manifest
        savable = {}
        dtypes = {}
        for k, v in flat.items():
            arr = np.asarray(v)
            dtypes[k] = str(arr.dtype)
            if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
                arr = arr.view(_uint_of_width(arr.dtype.itemsize))
            savable[k] = arr
        np.savez(os.path.join(tmp, "arrays.npz"), **savable)
        manifest = {
            "step": step,
            "metadata": metadata,
            "leaves": {k: {"shape": list(np.shape(v)),
                           "dtype": dtypes[k]}
                       for k, v in flat.items()},
            "format": 1,
        }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if self.faults is not None:
            # the crash window: a kill here leaves an orphaned tmp dir and
            # must NOT disturb the previously committed step
            self.faults.site("checkpoint.pre_rename", step=step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)
        # orphaned in-flight dirs left by a writer killed inside the crash
        # window. Safe under the manager's one-write-in-flight discipline
        # (_gc only runs after our own rename committed, so any tmp dir
        # still present belongs to a dead writer); concurrent unmanaged
        # writers to the same directory are not supported.
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp." in name:
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp." not in name:
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def metadata(self, step: Optional[int] = None) -> dict:
        step = self.latest_step() if step is None else step
        path = os.path.join(self.dir, f"step_{step:010d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template`` (arrays or
        ShapeDtypeStructs). If ``shardings`` (matching pytree of
        NamedShardings) is given, leaves are device_put accordingly —
        this is the elastic-resharding path: the mesh inside the
        shardings can differ from the mesh at save time.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        manifest = self.metadata(step)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {}
            for k in z.files:
                arr = z[k]
                true_dtype = manifest["leaves"][k]["dtype"]
                if str(arr.dtype) != true_dtype:
                    import ml_dtypes
                    arr = arr.view(np.dtype(getattr(
                        ml_dtypes, true_dtype, true_dtype)))
                flat[k] = arr
        tree = _unflatten_into(template, flat)
        # dtype-cast to the template's dtypes (bf16 is stored as its view)
        def cast(t, x):
            want = t.dtype if hasattr(t, "dtype") else None
            arr = jnp.asarray(x)
            return arr.astype(want) if want is not None else arr
        tree = jax.tree.map(cast, template, tree)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree


def _uint_of_width(itemsize: int):
    return {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}[itemsize]
