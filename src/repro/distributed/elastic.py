"""Elastic resharding: move a job between meshes without conversion tools.

The framework's state (params, optimizer, SODM solver state) is always
saved as *full logical arrays* plus logical-axis annotations — never as
device-local shards with baked-in device ids. Rescaling is therefore just
re-resolving shardings against the new mesh and device_put'ing:

    old job on (pod=2, data=16, model=16)   -> checkpoint
    new job on (data=16, model=16)          -> restore(..., mesh=new_mesh)

``reshard`` also covers live resharding (array already on devices), which
XLA implements as the minimal collective permute.

Divisibility fallbacks in repro.sharding make this safe for *any* target
mesh: a dim that no longer divides simply replicates.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro import sharding as shd


def reshard(tree, axes_tree, mesh: Mesh,
            rules: shd.ShardingRules | None = None):
    """device_put every leaf to its sharding under the (new) mesh."""
    shardings = shd.tree_shardings(axes_tree, tree, mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def restore_elastic(manager, template, axes_tree, mesh: Mesh,
                    step=None, rules: shd.ShardingRules | None = None):
    """CheckpointManager.restore + resharding onto ``mesh`` in one call."""
    shardings = shd.tree_shardings(axes_tree, template, mesh, rules)
    return manager.restore(template, step=step, shardings=shardings)


def validate_resharding(tree_a, tree_b) -> bool:
    """Value equality across meshes (used by tests)."""
    import jax.numpy as jnp
    ok = jax.tree.map(
        lambda a, b: bool(jnp.array_equal(jax.device_get(a),
                                          jax.device_get(b))),
        tree_a, tree_b)
    return all(jax.tree.leaves(ok))
