"""Straggler mitigation for the SODM partition scheduler.

The SODM level solve is embarrassingly parallel and *idempotent*: each
partition solve is a pure function of (X_k, y_k, alpha_init). On a real
cluster some workers straggle (bad host, thermal throttling, preemption),
so the scheduler:

  1. dispatches all partition solves to the worker pool;
  2. watches completion; once ``spec_quantile`` of tasks finished, starts a
     deadline = ``spec_factor`` x median completion time;
  3. past the deadline, re-dispatches still-running tasks to idle workers
     (speculative duplicates); first completion wins, losers are ignored
     (pure function => identical results, no coordination needed).

For the SPMD LM train loop stragglers are a non-issue by construction
(synchronous XLA collectives gate every step), so mitigation there lives
at the checkpoint/elastic level — see DESIGN.md §6.

On this single-node container the pool is threads and "stragglers" are
simulated in tests by sleeping tasks; the scheduler logic (quantile
tracking, deadline, duplicate dispatch, first-wins) is exactly what a
multi-host dispatcher would run.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
import time
from typing import Any, Callable, Sequence

from repro.observe.spans import span as _span


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    max_workers: int = 8
    spec_quantile: float = 0.75    # fraction done before arming the deadline
    spec_factor: float = 2.0       # deadline = factor x median duration
    max_duplicates: int = 2        # per task
    poll_s: float = 0.005


class SpeculativeScheduler:
    def __init__(self, cfg: SpecConfig = SpecConfig()):
        self.cfg = cfg

    def run(self, tasks: Sequence[Callable[[], Any]],
            faults=None) -> list[Any]:
        """Execute all tasks; returns results in task order.

        Each task may be re-submitted up to max_duplicates extra times once
        the speculation deadline passes; the first completed attempt's
        result is kept.

        ``faults`` (a :class:`repro.distributed.faults.FaultPlan`) fires
        the ``"cascade.partition"`` site at the start of every attempt:
        a ``delay`` rule makes that partition straggle (speculation under
        test), a ``kill`` rule fails the attempt — idempotent tasks mean
        the scheduler just re-dispatches it, which is the worker-loss
        recovery path this instrument exists to prove.
        """
        n = len(tasks)
        results: list[Any] = [None] * n
        done = [False] * n
        attempts = [0] * n
        durations: list[float] = []
        lock = threading.Lock()

        # NOT a `with` block: first-completion-wins means losers may still
        # be running when all results are in; shutdown(wait=False) lets us
        # return immediately instead of joining abandoned duplicates.
        pool = cf.ThreadPoolExecutor(max_workers=self.cfg.max_workers)
        try:
            futures: dict[cf.Future, int] = {}

            def submit(i):
                t0 = time.monotonic()
                attempts[i] += 1
                att = attempts[i]

                def wrapped():
                    with _span("straggler.attempt", partition=i,
                               attempt=att):
                        if faults is not None:
                            faults.site("cascade.partition", partition=i,
                                        attempt=att)
                        out = tasks[i]()
                    return out, time.monotonic() - t0

                futures[pool.submit(wrapped)] = i

            for i in range(n):
                submit(i)

            armed_at = None
            while True:
                with lock:
                    if all(done):
                        break
                finished, _ = cf.wait(list(futures),
                                      timeout=self.cfg.poll_s,
                                      return_when=cf.FIRST_COMPLETED)
                for f in finished:
                    i = futures.pop(f)
                    try:
                        out, dt = f.result()
                    except Exception:
                        # failed attempt: re-dispatch unconditionally
                        if not done[i]:
                            submit(i)
                        continue
                    with lock:
                        if not done[i]:
                            results[i] = out
                            done[i] = True
                            durations.append(dt)
                # arm speculation once the quantile completed
                frac = sum(done) / n
                if armed_at is None and frac >= self.cfg.spec_quantile \
                        and durations:
                    med = sorted(durations)[len(durations) // 2]
                    armed_at = time.monotonic() + \
                        max(self.cfg.spec_factor * med, 0.01)
                if armed_at is not None and time.monotonic() > armed_at:
                    for i in range(n):
                        if not done[i] and attempts[i] <= self.cfg.max_duplicates:
                            submit(i)
                    med = sorted(durations)[len(durations) // 2] \
                        if durations else 0.05
                    armed_at = time.monotonic() + \
                        max(self.cfg.spec_factor * med, 0.01)
        finally:
            pool.shutdown(wait=False, cancel_futures=True)
        return results
