from repro.distributed import checkpoint, elastic, faults, resume, straggler

__all__ = ["checkpoint", "elastic", "faults", "resume", "straggler"]
