from repro.distributed import checkpoint, elastic, straggler

__all__ = ["checkpoint", "elastic", "straggler"]
