"""Deterministic fault injection — the chaos-test substrate.

Distributed kernel-machine practice treats worker loss as the common
case, not the exception: a long-running cascade solve WILL be preempted,
a checkpoint writer WILL die between the temp write and the atomic
rename, and one partition WILL straggle. Proving the recovery paths work
requires *injecting* those faults deterministically, from tests, without
subprocess gymnastics (killing real processes is slow, flaky, and hides
the failure point).

The production loops are instrumented with named **sites** — points where
a preemption or delay can strike:

====================== ====================================================
site                   where it fires
====================== ====================================================
``cascade.level``      top of each SODM level solve (``level=``, ``K=``)
``cascade.partition``  before each straggler-scheduler partition attempt
                       (``partition=``, ``attempt=``)
``dsvrg.segment``      before each DSVRG epoch segment (``epoch=``)
``checkpoint.pre_rename``  inside ``CheckpointManager._write``, between
                       the fsync'd temp write and the atomic rename —
                       the crash window (``step=``)
``serve.flush``        before a ``Batcher`` flush scores (``batch=``)
``data.prefetch``      inside the streaming ``PrefetchLoader``, before a
                       shard read starts (``shard=``) — a kill surfaces
                       out of the loader's iteration, a delay simulates
                       slow storage
``cascade.shard``      before the streaming cascade consumes an arrived
                       level-0 leaf (``shard=`` — the leaf index)
====================== ====================================================

A :class:`FaultPlan` holds match rules against those sites:

    plan = FaultPlan().kill_at_level(2)          # die solving level 2
    plan = FaultPlan().kill_mid_checkpoint()     # die in the crash window
    plan = FaultPlan().delay_partition(3, 0.05)  # partition 3 straggles

``site()`` is called by the instrumented loop with the site name and
keyword facts; a matching ``kill`` rule raises :class:`Preemption` (the
simulated SIGKILL — it propagates out of ``fit`` exactly like a driver
death), a matching ``delay`` rule sleeps through the plan's injected
``sleeper`` (or, with ``sleeper=None``, just *returns* the delay seconds
so virtual-clock consumers like ``serve_stream`` can add it to their
clock instead of wall-sleeping). Rules carry a fire ``count`` and are
spent after it — a killed-and-retried attempt succeeds, which is exactly
the recovery semantics under test. Everything is deterministic: the same
plan against the same loop fires at the same site every time, and
``plan.fired`` records what struck where.

``None`` (no plan) is the production default everywhere; instrumentation
costs one ``is None`` check per site.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable


class Preemption(RuntimeError):
    """The simulated driver/worker death raised by a ``kill`` rule."""

    def __init__(self, site: str, info: dict):
        self.site = site
        self.info = dict(info)
        super().__init__(f"injected preemption at site {site!r} ({info})")


@dataclasses.dataclass
class _Rule:
    site: str
    match: tuple[tuple[str, object], ...]   # (key, value) facts, all must hold
    action: str                             # "kill" | "delay"
    seconds: float = 0.0
    remaining: int = 1                      # fires left; spent at 0

    def matches(self, site: str, info: dict) -> bool:
        if self.remaining <= 0 or site != self.site:
            return False
        return all(info.get(k) == v for k, v in self.match)


class FaultPlan:
    """A deterministic schedule of injected faults (see module docs).

    ``sleeper`` implements delay rules — ``time.sleep`` by default,
    ``None`` for virtual-clock consumers (no wall sleep; ``site()``
    returns the delay seconds either way so callers can advance their
    own clocks).
    """

    def __init__(self, sleeper: Callable[[float], None] | None = time.sleep):
        self.sleeper = sleeper
        self.rules: list[_Rule] = []
        self.fired: list[tuple[str, str, dict]] = []   # (action, site, info)

    # -- rule construction (chainable) --------------------------------------

    def kill(self, site: str, *, count: int = 1, **match) -> "FaultPlan":
        """Raise :class:`Preemption` the first ``count`` matching visits."""
        self.rules.append(_Rule(site=site, match=tuple(sorted(match.items())),
                                action="kill", remaining=count))
        return self

    def delay(self, site: str, seconds: float, *, count: int = 1,
              **match) -> "FaultPlan":
        """Stall ``seconds`` on the first ``count`` matching visits."""
        self.rules.append(_Rule(site=site, match=tuple(sorted(match.items())),
                                action="delay", seconds=float(seconds),
                                remaining=count))
        return self

    # the ISSUE's three chaos verbs, spelled out

    def kill_at_level(self, level: int, *, count: int = 1) -> "FaultPlan":
        """Preempt the driver while it is solving cascade level ``level``."""
        return self.kill("cascade.level", level=level, count=count)

    def kill_mid_checkpoint(self, *, count: int = 1) -> "FaultPlan":
        """Preempt inside the checkpoint crash window (post-write,
        pre-rename) — the previously committed step must survive."""
        return self.kill("checkpoint.pre_rename", count=count)

    def delay_partition(self, partition: int, seconds: float, *,
                        count: int = 1) -> "FaultPlan":
        """Make one partition solve straggle (speculation-trigger test)."""
        return self.delay("cascade.partition", seconds, partition=partition,
                          count=count)

    def kill_at_epoch(self, epoch: int, *, count: int = 1) -> "FaultPlan":
        """Preempt the DSVRG driver before the segment starting at
        ``epoch``."""
        return self.kill("dsvrg.segment", epoch=epoch, count=count)

    def kill_at_shard(self, shard: int, *, count: int = 1) -> "FaultPlan":
        """Preempt the streaming cascade before it consumes leaf
        ``shard`` (mid-stream driver death)."""
        return self.kill("cascade.shard", shard=shard, count=count)

    def delay_shard_read(self, shard: int, seconds: float, *,
                         count: int = 1) -> "FaultPlan":
        """Make one shard read straggle inside the prefetch loader
        (slow-storage simulation)."""
        return self.delay("data.prefetch", seconds, shard=shard,
                          count=count)

    # -- the hook the instrumented loops call --------------------------------

    def site(self, name: str, **info) -> float:
        """Visit site ``name``; returns total injected delay seconds.

        Matching rules fire in declaration order, decrement their
        ``remaining`` budget, and are recorded in ``fired``. A ``kill``
        raises after recording (so post-mortem inspection sees it)."""
        delay = 0.0
        for rule in self.rules:
            if not rule.matches(name, info):
                continue
            rule.remaining -= 1
            self.fired.append((rule.action, name, dict(info)))
            if rule.action == "kill":
                raise Preemption(name, info)
            delay += rule.seconds
            if self.sleeper is not None:
                self.sleeper(rule.seconds)
        return delay

    def __repr__(self) -> str:
        live = sum(1 for r in self.rules if r.remaining > 0)
        return (f"FaultPlan({len(self.rules)} rules, {live} armed, "
                f"{len(self.fired)} fired)")
