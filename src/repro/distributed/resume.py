"""Mid-solve resume: per-level cascade checkpoints + DSVRG segments.

A preempted ``ODMEstimator.fit`` used to restart from scratch — every
already-solved cascade level thrown away. This module makes the solve
state durable through :class:`repro.distributed.checkpoint
.CheckpointManager` (atomic, versioned, retention-managed) so
``fit(resume=dir)`` restarts a killed level-k solve from the merged
level-(k−1) duals instead.

File layout (one resume directory per fit)::

    <dir>/step_0000000001/manifest.json   # after the 1st level solve
                          arrays.npz      #   {alphas (K, 2m), perm (M,)}
    <dir>/step_0000000002/...             # after the 2nd, and so on

The manifest metadata carries everything the loop needs to re-enter at
the right place — ``level``/``K``/``m``, the sweeps-per-level history,
the running KKT residual — plus a **provenance** block fingerprinting
(kernel, params, cfg, data, PRNG key). Restore refuses (or, with
``strict=False``, warns and cold-starts) when the provenance does not
match: resuming level-k duals against different data or a different
partition key would silently train a wrong model.

The DSVRG route checkpoints ``{w, history, perm}`` + ``{epoch, eta}``
between scan segments (the anchor coincides with ``w`` at every epoch
boundary, so ``w`` alone restarts the next epoch exactly).

The *streaming* cascade (``fit(source)``) checkpoints its binary-counter
merge stack after each consumed level-0 leaf (``mode="stream"`` in the
manifest; one ``s{i}_x/s{i}_y/s{i}_alpha`` triple per stack entry), so a
mid-stream kill re-enters at the first unprocessed shard without
re-reading completed ones. Dense level checkpoints and stream leaf
checkpoints refuse to resume each other.

Checkpoint steps count *completed work* (levels solved / epochs run), so
they are strictly increasing whatever direction the cascade's level
index runs. All saves are synchronous: a cascade level is coarse-grained
enough that async buys nothing, and a synchronous write is what lets the
fault layer's kill-mid-checkpoint strike on the caller thread.

Bit-identical guarantee (pinned by tests/test_resume.py and the
``resume.*`` invariants): level solves are deterministic pure functions
of ``(xs, ys, alphas)`` and the npz round trip is bitwise exact, so a
resumed fit returns the same ``SODMResult`` — and compiles the same
``FittedODM`` — as the uninterrupted one, with only the not-yet-solved
levels re-run.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.checkpoint import CheckpointManager


class ProvenanceError(ValueError):
    """Resume directory belongs to a different problem/data/key."""


def _key_fingerprint(key) -> list[int]:
    try:
        data = jax.random.key_data(key)
    except Exception:                     # raw uint32 key array
        data = key
    return [int(v) for v in np.asarray(data).reshape(-1)]


def provenance(kernel, params, cfg, x, y, key) -> dict:
    """Fingerprint of everything a resumed solve must agree on.

    reprs of the (frozen, nested) config dataclasses are deterministic;
    the data fingerprint is shape/dtype plus two exact float32 sums
    (JSON round-trips binary64 exactly, and float32 sums promoted to
    python floats are representable), so a changed dataset is caught
    without hashing O(M·d) bytes.
    """
    return {
        "format": 1,
        "kernel": repr(kernel),
        "params": repr(params),
        "cfg": repr(cfg),
        "data": {
            "shape": [int(s) for s in x.shape],
            "dtype": str(x.dtype),
            "x_sum": float(jnp.sum(x)),
            "y_sum": float(jnp.sum(y)),
        },
        "key": _key_fingerprint(key),
    }


def provenance_source(kernel, params, cfg, source, key) -> dict:
    """Streaming-fit provenance: fingerprint the *source*, not the rows.

    A streaming fit never holds the (M, d) matrix, so summing it here
    would defeat the point. ``source.fingerprint()`` is each source's
    own cheap identity (paths + shard sizes for file-backed shards,
    generator seed + shape for synthetic ones, exact sums for in-memory
    arrays) — good enough to catch "resumed against different data"
    without a full scan.
    """
    return {
        "format": 1,
        "kernel": repr(kernel),
        "params": repr(params),
        "cfg": repr(cfg),
        "data": source.fingerprint(),
        "key": _key_fingerprint(key),
    }


def _check_provenance(saved: dict, want: dict, strict: bool,
                      directory: str) -> bool:
    """True if compatible; raise (strict) or warn+False otherwise."""
    if saved == want:
        return True
    diff = [k for k in want if saved.get(k) != want.get(k)]
    msg = (f"resume directory {directory!r} was written by a different "
           f"run (mismatched: {diff}); refusing to splice its duals into "
           f"this solve")
    if strict:
        raise ProvenanceError(msg)
    warnings.warn(msg + " — cold-starting instead", RuntimeWarning,
                  stacklevel=4)
    return False


def _template_from_manifest(manifest: dict) -> dict:
    """Rebuild the flat-dict save tree's template from manifest leaves."""
    return {k: jax.ShapeDtypeStruct(tuple(leaf["shape"]),
                                    jnp.dtype(leaf["dtype"]))
            for k, leaf in manifest["leaves"].items()}


@dataclasses.dataclass(frozen=True)
class ResumeConfig:
    """User-facing ``fit(resume=...)`` value (a bare path also works).

    ``segment`` is the DSVRG checkpoint cadence in epochs; the cascade
    route checkpoints every level regardless. ``strict`` controls the
    provenance mismatch behavior (raise vs warn + cold start). ``keep``
    is the checkpoint retention depth — 0 keeps every step (a resumed
    run then replays to completion with zero new solves on re-entry).
    """

    directory: str
    keep: int = 3
    strict: bool = True
    segment: int = 1

    @staticmethod
    def of(value) -> "ResumeConfig":
        if isinstance(value, ResumeConfig):
            return value
        return ResumeConfig(directory=os.fspath(value))


class RestoredCascade(NamedTuple):
    level: int               # the level whose solve this state COMPLETED
    K: int
    m: int
    alphas: jax.Array        # (K, 2m) post-solve duals of that level
    perm: jax.Array          # (M,) partition permutation
    sweeps_per_level: list
    kkt: jax.Array


class RestoredStream(NamedTuple):
    leaf: int                # level-0 leaves fully consumed so far
    stack: list              # [(tier, x (m, d), y (m,), alpha (2m,)), ...]


class RestoredSegments(NamedTuple):
    epoch: int               # epochs completed
    w: jax.Array
    history: jax.Array       # (epoch,) objective after each epoch
    perm: jax.Array
    eta: float


class CascadeResumeManager:
    """Per-level checkpoints of the Algorithm-1 level loop."""

    route = "cascade"

    def __init__(self, cfg: ResumeConfig, prov: dict, faults=None):
        self.cfg = cfg
        self.prov = prov
        self.ckpt = CheckpointManager(cfg.directory, keep=cfg.keep,
                                      faults=faults)

    def save_level(self, *, level: int, K: int, m: int, alphas, perm,
                   sweeps_per_level: list, kkt) -> None:
        step = len(sweeps_per_level)          # levels solved so far
        self.ckpt.save(step, {"alphas": alphas, "perm": perm}, metadata={
            "route": self.route,
            "level": int(level), "K": int(K), "m": int(m),
            "sweeps_per_level": [int(s) for s in sweeps_per_level],
            "kkt": float(kkt),
            "provenance": self.prov,
        })

    def restore(self) -> RestoredCascade | None:
        md, manifest, step = self._latest("level")
        if md is None:
            return None
        tree = self.ckpt.restore(_template_from_manifest(manifest), step)
        return RestoredCascade(
            level=int(md["level"]), K=int(md["K"]), m=int(md["m"]),
            alphas=tree["alphas"], perm=tree["perm"],
            sweeps_per_level=list(md["sweeps_per_level"]),
            kkt=jnp.asarray(md["kkt"], tree["alphas"].dtype))

    # -- streaming cascade: merge-stack checkpoints per consumed leaf --------

    def save_stream(self, *, leaf: int, stack) -> None:
        """Checkpoint the binary-counter merge stack after leaf ``leaf``.

        The stack entries have data-dependent (but per-tier fixed) row
        counts, so each entry is saved under its own ``s{i}_*`` keys and
        the tier list rides in the metadata — ``_template_from_manifest``
        rebuilds the exact shapes on restore.
        """
        tree = {}
        for i, (_, xs, ys, alpha) in enumerate(stack):
            tree[f"s{i}_x"] = xs
            tree[f"s{i}_y"] = ys
            tree[f"s{i}_alpha"] = alpha
        self.ckpt.save(leaf, tree, metadata={
            "route": self.route,
            "mode": "stream",
            "leaf": int(leaf),
            "tiers": [int(t) for t, *_ in stack],
            "provenance": self.prov,
        })

    def restore_stream(self) -> RestoredStream | None:
        md, manifest, step = self._latest("stream")
        if md is None:
            return None
        tree = self.ckpt.restore(_template_from_manifest(manifest), step)
        stack = [(int(t), tree[f"s{i}_x"], tree[f"s{i}_y"],
                  tree[f"s{i}_alpha"])
                 for i, t in enumerate(md["tiers"])]
        return RestoredStream(leaf=int(md["leaf"]), stack=stack)

    def _latest(self, mode: str):
        """Latest checkpoint's (metadata, manifest, step) — or
        ``(None,)*3`` for an empty/cold directory. Raises when the
        directory holds another route's state or the other cascade
        flavor's (dense level vs stream leaf checkpoints don't splice)."""
        step = self.ckpt.latest_step()
        if step is None:
            return None, None, None
        manifest = self.ckpt.metadata(step)
        md = manifest["metadata"]
        if md.get("route") != self.route:
            raise ProvenanceError(
                f"resume directory {self.cfg.directory!r} holds "
                f"{md.get('route')!r} checkpoints, not cascade state")
        saved_mode = md.get("mode", "level")
        if saved_mode != mode:
            raise ProvenanceError(
                f"resume directory {self.cfg.directory!r} holds cascade "
                f"{saved_mode!r} checkpoints but this fit runs in "
                f"{mode!r} mode — a dense level solve and a streaming "
                f"merge stack cannot resume each other")
        if not _check_provenance(md.get("provenance", {}), self.prov,
                                 self.cfg.strict, self.cfg.directory):
            return None, None, None
        return md, manifest, step


class DsvrgResumeManager:
    """Between-segment checkpoints of the Algorithm-2 epoch scan."""

    route = "dsvrg"

    def __init__(self, cfg: ResumeConfig, prov: dict, faults=None):
        self.cfg = cfg
        self.prov = prov
        self.ckpt = CheckpointManager(cfg.directory, keep=cfg.keep,
                                      faults=faults)

    @property
    def segment(self) -> int:
        return max(1, self.cfg.segment)

    def save_segment(self, *, epoch: int, w, history, perm, eta) -> None:
        self.ckpt.save(epoch, {"w": w, "history": history, "perm": perm},
                       metadata={
            "route": self.route,
            "epoch": int(epoch),
            "eta": float(eta),
            "provenance": self.prov,
        })

    def restore(self) -> RestoredSegments | None:
        step = self.ckpt.latest_step()
        if step is None:
            return None
        manifest = self.ckpt.metadata(step)
        md = manifest["metadata"]
        if md.get("route") != self.route:
            raise ProvenanceError(
                f"resume directory {self.cfg.directory!r} holds "
                f"{md.get('route')!r} checkpoints, not dsvrg state")
        if not _check_provenance(md.get("provenance", {}), self.prov,
                                 self.cfg.strict, self.cfg.directory):
            return None
        tree = self.ckpt.restore(_template_from_manifest(manifest), step)
        return RestoredSegments(
            epoch=int(md["epoch"]), w=tree["w"], history=tree["history"],
            perm=tree["perm"], eta=float(md["eta"]))
