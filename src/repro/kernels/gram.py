"""Matrix-free multi-kernel Gram subsystem: tiled Pallas Gram/matvec kernels.

Lowers every ``KernelSpec`` family from the ODM paper (Zhang & Zhou, 2016)
— ``rbf``, ``laplacian``, ``poly``, ``linear`` — to tiled TPU kernels that
share ONE accumulation skeleton (:func:`accum_tile` / :func:`finalize_tile`):

* **L2 family** (``rbf``, ``poly``, ``linear``): the pairwise cross term
  ``x @ z.T`` is accumulated over feature blocks on the MXU
  (``dot_general`` with an fp32 scratch accumulator); the kernel transform
  (``exp``, integer power, identity) runs on the VPU over the finished
  tile. Squared row norms for rbf are precomputed on host (O(Md),
  negligible) and streamed as (1, bm)-shaped scalars-per-row.

* **L1 family** (``laplacian``): there is no matmul form of the L1
  distance, so the tile is built by a tiled VPU reduction — a
  ``fori_loop`` over ``_L1_CHUNK``-wide feature slabs, each contributing
  ``sum_d |x_id - z_jd|`` via an (bm, bn, chunk) broadcast. Peak extra
  VMEM is ``bm * bn * _L1_CHUNK`` fp32 (256x256x8 => 2 MB), so laplacian
  tiles respect the same budget as the MXU path at the default blocks.

Three consumers share the skeleton:

1. :func:`gram`        — materialize a (signed) Gram tile grid, (M, N).
2. :func:`gram_matvec` — batched u[k] = K_k @ g[k] with no (M, N) Gram
   ever leaving VMEM (O(m*B) memory per partition however large the full
   Gram would be).
3. ``repro.kernels.dual_cd_block``'s fused CD pass — the same tile
   accumulation feeding an in-kernel accumulating matvec, one
   ``pallas_call`` per solver pass.

VMEM budget per grid step (fp32):
  L2 gram:    bm*bd + bn*bd (operands) + bm*bn (acc); defaults
              (256, 256, 512) => 1 MB + 0.25 MB — far under the ~16 MB/core
              budget, leaving room for double buffering.
  L1 gram:    bm*bd + bn*bd + bm*bn + bm*bn*_L1_CHUNK transient => ~3.3 MB
              at the same defaults.
  matvec:     the gram-step budget + bn (g tile) + bm (u accumulator).

``gram_threshold`` semantics (see ``SODMConfig``): SODM level solves with
partition size m <= gram_threshold materialize the O(m^2) signed Gram once
(cheaper when it fits — tiles are reused every pass); above the threshold
all four kernel families switch to these matrix-free tiles, so per-level
memory stays O(m*B) and the threshold is purely a speed/memory trade, not
a capability cliff. :data:`MATRIX_FREE_KERNELS` lists the families with a
matrix-free lowering; ``repro.core.engines`` warns (once, with a memory
estimate) if any other kernel is asked to solve above the threshold.

MXU alignment: bm, bn, bd multiples of 128 on real TPUs (the ops.py
wrappers pad); the D sweep is the innermost grid axis so the fp32
accumulator scratch lives across it and each output tile is written once.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# kernel families with a matrix-free tile lowering (all of KernelSpec's);
# L1_KERNELS is the single source of the l1-vs-l2 accumulation split —
# KernelSpec.family() and the tile skeleton both dispatch on it
MATRIX_FREE_KERNELS = ("linear", "rbf", "laplacian", "poly")
L1_KERNELS = ("laplacian",)

# feature-slab width of the laplacian L1 reduction: bounds the transient
# (bm, bn, chunk) broadcast to bm*bn*8 fp32 (2 MB at 256x256 tiles)
_L1_CHUNK = 8


# ---------------------------------------------------------------------------
# the shared accumulation skeleton
# ---------------------------------------------------------------------------

def accum_tile(kind: str, acc: Array, x: Array, z: Array) -> Array:
    """acc (bm, bn) += one feature slab's pairwise contribution.

    L2 family: the ``x @ z.T`` cross term on the MXU. L1 family
    (laplacian): partial L1 distance via chunked VPU broadcasts. ``kind``
    is static, so each kernel compiles exactly one of the two paths.
    """
    if kind in L1_KERNELS:
        bd = x.shape[-1]
        xf = x.astype(jnp.float32)
        zf = z.astype(jnp.float32)
        nfull = bd // _L1_CHUNK

        def body(c, a):
            xs = jax.lax.dynamic_slice_in_dim(xf, c * _L1_CHUNK, _L1_CHUNK, 1)
            zs = jax.lax.dynamic_slice_in_dim(zf, c * _L1_CHUNK, _L1_CHUNK, 1)
            return a + jnp.sum(jnp.abs(xs[:, None, :] - zs[None, :, :]),
                               axis=-1)

        acc = jax.lax.fori_loop(0, nfull, body, acc)
        if bd % _L1_CHUNK:
            xs = xf[:, nfull * _L1_CHUNK:]
            zs = zf[:, nfull * _L1_CHUNK:]
            acc = acc + jnp.sum(jnp.abs(xs[:, None, :] - zs[None, :, :]),
                                axis=-1)
        return acc
    return acc + jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def finalize_tile(kind: str, acc: Array, xx: Array, zz: Array, *,
                  gamma: float, degree: int, coef0: float) -> Array:
    """Finished accumulator -> kernel tile, on the VPU.

    ``acc`` is the L2 cross term (L2 family) or the full L1 distance
    (laplacian). ``xx``/``zz`` are the (bm,)/(bn,) squared row norms —
    only rbf reads them; the others accept them for a uniform signature.
    """
    if kind == "rbf":
        d2 = xx[:, None] + zz[None, :] - 2.0 * acc
        return jnp.exp(-gamma * jnp.maximum(d2, 0.0))
    if kind == "laplacian":
        return jnp.exp(-gamma * acc)
    if kind == "poly":
        return (gamma * acc + coef0) ** degree
    if kind == "linear":
        return acc
    raise ValueError(f"no matrix-free lowering for kernel {kind!r}; "
                     f"supported: {MATRIX_FREE_KERNELS}")


def row_norms(x: Array) -> Array:
    """Squared L2 row norms in fp32, batched over leading axes."""
    return jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)


# ---------------------------------------------------------------------------
# gram: (M, N) tile grid
# ---------------------------------------------------------------------------

def _gram_kernel(xx_ref, zz_ref, yx_ref, yz_ref, x_ref, z_ref, out_ref,
                 acc_ref, *, kind: str, gamma: float, degree: int,
                 coef0: float, signed: bool, n_d_steps: int):
    """One (bm, bn) tile, accumulating over D blocks (innermost grid axis).

    xx/zz: (1, bm)/(1, bn) squared row norms; yx/yz: labels (only read when
    signed). x (bm, bd), z (bn, bd). acc: (bm, bn) fp32 scratch.
    """
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = accum_tile(kind, acc_ref[...], x_ref[...], z_ref[...])

    @pl.when(kd == n_d_steps - 1)
    def _finalize():
        k = finalize_tile(kind, acc_ref[...], xx_ref[0, :], zz_ref[0, :],
                          gamma=gamma, degree=degree, coef0=coef0)
        if signed:
            k = (yx_ref[0, :][:, None] * yz_ref[0, :][None, :]) * k
        out_ref[...] = k.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "kind", "gamma", "degree", "coef0", "signed", "bm", "bn", "bd",
    "interpret"))
def gram(x: Array, z: Array, yx: Array | None = None,
         yz: Array | None = None, *, kind: str = "rbf", gamma: float = 1.0,
         degree: int = 3, coef0: float = 1.0, signed: bool = False,
         bm: int = 256, bn: int = 256, bd: int = 512,
         interpret: bool = False) -> Array:
    """K (or Q if signed) of shape (M, N) for any supported kernel family.

    Shapes must tile evenly; the ops.py wrapper pads and unpads arbitrary
    shapes. Grid (M/bm, N/bn, D/bd) with D innermost (see module docs).
    """
    M, D = x.shape
    N = z.shape[0]
    assert M % bm == 0 and N % bn == 0 and D % bd == 0, (M, N, D, bm, bn, bd)
    if yx is None:
        yx = jnp.ones((M,), x.dtype)
    if yz is None:
        yz = jnp.ones((N,), x.dtype)
    n_d_steps = D // bd

    grid = (M // bm, N // bn, n_d_steps)
    xx = row_norms(x)[None, :]                                   # (1, M)
    zz = row_norms(z)[None, :]                                   # (1, N)

    kernel = functools.partial(_gram_kernel, kind=kind, gamma=gamma,
                               degree=degree, coef0=coef0, signed=signed,
                               n_d_steps=n_d_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j, k: (0, i)),       # xx
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),       # zz
            pl.BlockSpec((1, bm), lambda i, j, k: (0, i)),       # yx
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),       # yz
            pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),      # x
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),      # z
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[_scratch((bm, bn))],
        interpret=interpret,
    )(xx, zz, yx[None, :], yz[None, :], x, z)


# ---------------------------------------------------------------------------
# gram_matvec: batched u = K @ g, tile never leaves VMEM
# ---------------------------------------------------------------------------

def _gram_matvec_kernel(xx_ref, zz_ref, g_ref, x_ref, z_ref, out_ref,
                        acc_ref, u_ref, *, kind: str, gamma: float,
                        degree: int, coef0: float, n_j: int, n_d: int):
    """One (bm,) slice of u = K(x, z) @ g, accumulated over (j, d) tiles.

    Grid (K, M/bm, N/bn, D/bd). The (bm, bn) Gram tile is formed in the
    acc scratch across the D sweep exactly like :func:`_gram_kernel`, then
    immediately contracted against the matching g tile into the (bm, 1)
    u scratch — the tile never leaves VMEM, so memory stays O(m·B) however
    large the partition's full Gram would be.
    """
    kj = pl.program_id(2)
    kd = pl.program_id(3)

    @pl.when(jnp.logical_and(kj == 0, kd == 0))
    def _init_u():
        u_ref[...] = jnp.zeros_like(u_ref)

    @pl.when(kd == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = accum_tile(kind, acc_ref[...], x_ref[0], z_ref[0])

    @pl.when(kd == n_d - 1)
    def _contract():
        k = finalize_tile(kind, acc_ref[...], xx_ref[0, 0, :],
                          zz_ref[0, 0, :], gamma=gamma, degree=degree,
                          coef0=coef0)
        g = g_ref[0, 0, :]                     # (bn,)
        u_ref[...] += jax.lax.dot_general(     # (bm, bn) @ (bn, 1)
            k, g[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(kj == n_j - 1, kd == n_d - 1))
    def _finalize():
        out_ref[...] = u_ref[...].astype(out_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=(
    "kind", "gamma", "degree", "coef0", "bm", "bn", "bd", "interpret"))
def gram_matvec(x: Array, z: Array, g: Array, *, kind: str = "rbf",
                gamma: float = 1.0, degree: int = 3, coef0: float = 1.0,
                bm: int = 256, bn: int = 256, bd: int = 512,
                interpret: bool = False) -> Array:
    """u[k] = K(x[k], z[k]) @ g[k] without materializing any (M, N) Gram.

    Batched over a leading partition axis so one SODM level's u refresh is
    a single pallas_call: x (K, M, D), z (K, N, D), g (K, N) -> u (K, M).
    Shapes must tile evenly; the ops.py wrapper pads arbitrary shapes. For
    the *signed* product Q @ g = y ⊙ (K @ (y ⊙ g)) fold the labels into g
    and the result (the ops wrapper does).
    """
    K, M, D = x.shape
    N = z.shape[1]
    assert M % bm == 0 and N % bn == 0 and D % bd == 0, (M, N, D, bm, bn, bd)
    n_j, n_d = N // bn, D // bd
    grid = (K, M // bm, n_j, n_d)
    xx = row_norms(x)[:, None, :]                               # (K, 1, M)
    zz = row_norms(z)[:, None, :]                               # (K, 1, N)

    kernel = functools.partial(_gram_matvec_kernel, kind=kind, gamma=gamma,
                               degree=degree, coef0=coef0, n_j=n_j, n_d=n_d)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm), lambda k, i, j, d: (k, 0, i)),   # xx
            pl.BlockSpec((1, 1, bn), lambda k, i, j, d: (k, 0, j)),   # zz
            pl.BlockSpec((1, 1, bn), lambda k, i, j, d: (k, 0, j)),   # g
            pl.BlockSpec((1, bm, bd), lambda k, i, j, d: (k, i, d)),  # x
            pl.BlockSpec((1, bn, bd), lambda k, i, j, d: (k, j, d)),  # z
        ],
        out_specs=pl.BlockSpec((1, bm, 1), lambda k, i, j, d: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, M, 1), x.dtype),
        scratch_shapes=[_scratch((bm, bn)), _scratch((bm, 1))],
        interpret=interpret,
    )(xx, zz, g[:, None, :], x, z)
    return out[:, :, 0]


# ---------------------------------------------------------------------------
# gram sources: how a solver pass reaches the off-diagonal mass
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DenseSource:
    """Materialized signed Gram Q (K, mp, mp) — below ``gram_threshold``.

    Padded rows/columns must already be masked to zero. ``matvec`` is a
    plain batched matmul; the fused CD pass streams (B, B) tiles of ``q``
    straight from HBM.
    """

    q: Array                   # (K, mp, mp) signed, padding masked

    def matvec(self, g: Array) -> Array:
        return jnp.einsum("kij,kj->ki", self.q, g)


@dataclasses.dataclass
class KernelSource:
    """On-the-fly Gram tiles from the raw features — above ``gram_threshold``.

    ``x`` (K, mp, Dp) is row- and feature-padded (pads zero); ``y`` (K, mp)
    carries 0 labels on padded rows so the signed product
    y ⊙ (K @ (y ⊙ g)) zeroes padded rows and columns without ever masking
    a Gram tile. ``kind``/``gamma``/``degree``/``coef0`` mirror KernelSpec.
    """

    kind: str
    x: Array                   # (K, mp, Dp)
    y: Array                   # (K, mp), 0.0 on padded rows
    gamma: float = 1.0
    degree: int = 3
    coef0: float = 1.0
    bm: int = 256
    bn: int = 256
    bd: int = 512
    interpret: bool = False

    def matvec(self, g: Array) -> Array:
        u = gram_matvec(self.x, self.x, self.y * g, kind=self.kind,
                        gamma=self.gamma, degree=self.degree,
                        coef0=self.coef0, bm=self.bm, bn=self.bn,
                        bd=self.bd, interpret=self.interpret)
        return self.y * u


def make_kernel_source(spec, x: Array, y: Array, *, bm: int, bn: int,
                       bd: int = 512, interpret: bool = False
                       ) -> KernelSource:
    """Build a :class:`KernelSource` from a KernelSpec-like object.

    ``x`` (K, mp, D) must already be row-padded to the tile multiple; the
    feature axis is padded here (zero features shift no distance and no
    inner product). ``spec`` is duck-typed (name/gamma/degree/coef0) so
    this module never imports repro.core.
    """
    if spec.name not in MATRIX_FREE_KERNELS:
        raise ValueError(f"no matrix-free lowering for {spec.name!r}")
    D = x.shape[-1]
    bd = min(bd, max(8, D))
    target = -(-D // bd) * bd
    if target != D:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, target - D)))
    return KernelSource(kind=spec.name, x=x, y=y, gamma=spec.gamma,
                        degree=spec.degree, coef0=spec.coef0, bm=bm, bn=bn,
                        bd=bd, interpret=interpret)


def _scratch(shape: tuple[int, ...]):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:                          # pragma: no cover
        return pl.VMEM(shape, jnp.float32)
