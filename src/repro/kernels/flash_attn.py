"""Pallas TPU kernel: flash attention (causal / sliding-window, GQA).

Online-softmax attention for training and 32k prefill. Tiling follows the
canonical TPU flash pattern:

  grid = (batch, q_heads, T/bq, S/bk)   — kv axis innermost so the running
  (m, l, acc) statistics live in VMEM scratch across the kv sweep and the
  (bq, dh) output tile is written once on the last kv step.

GQA is handled in the k/v BlockSpec index_map (kv head = q head // group),
so no repeated-KV materialization ever touches HBM. The causal and
sliding-window masks are applied per-tile with iota arithmetic; fully
masked tiles still execute (XLA grid is static) but short-circuit the
exp/matmul via `pl.when` on a tile-level bound check — on real TPU this
skips ~half the work for causal training.

VMEM per step: bq·dh (q) + 2·bk·dh (k,v) + bq·bk (logits) + bq·dh (acc).
Defaults bq=bk=512, dh=128 → ≈ 0.9 MB fp32: safely double-bufferable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, n_kv_steps: int, q_offset: int):
    """One (bq, dh) output tile; kv axis is grid dim 3 (innermost)."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile-level skip test: queries span q0..q0+bq-1 (global positions
    # offset by q_offset = S - T), kv span k0..k0+bk-1.
    q0 = iq * bq + q_offset
    k0 = ik * bk
    # any work iff min_kpos <= max_qpos (causal) and max_kpos > min_qpos - window
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k0 <= q0 + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k0 + bk - 1 > q0 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0]                                 # (bq, dh)
        k = k_ref[0, 0]                                 # (bk, dh)
        v = v_ref[0, 0]                                 # (bk, dh)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                     # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv_steps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> Array:
    """q (B, Hq, T, D); k/v (B, Hkv, S, D) with Hq % Hkv == 0. Returns
    (B, Hq, T, D). T % bq == 0 and S % bk == 0 (ops.py pads)."""
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0, (T, S, bq, bk)
    n_kv = S // bk
    q_offset = S - T          # queries sit at the end of the kv history

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv_steps=n_kv, q_offset=q_offset)

    grid = (B, Hq, T // bq, n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),     # running max m
            _vmem((bq, 1), jnp.float32),     # running denom l
            _vmem((bq, D), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
