"""Compatibility shim: the tiled RBF Gram kernels now live in
:mod:`repro.kernels.gram`, which lowers the full ODM kernel family
(rbf / laplacian / poly / linear) through one shared accumulation
skeleton. These wrappers pin ``kind="rbf"`` and keep the original
signatures for existing callers and kernel tests; like the other legacy
entry points they warn ONCE per process (``core.deprecation``).
"""
from __future__ import annotations

import jax

from repro.kernels import gram as _gram

Array = jax.Array


def _warn(entry: str, replacement: str) -> None:
    # function-level import: kernels/ never imports repro.core at module
    # scope (the dependency points the other way)
    from repro.core import deprecation as _dep
    _dep.warn_once(entry, replacement)


def rbf_gram(x: Array, z: Array, yx: Array | None = None,
             yz: Array | None = None, *, gamma: float = 1.0,
             signed: bool = False, bm: int = 256, bn: int = 256,
             bd: int = 512, interpret: bool = False) -> Array:
    """K (or Q if signed) of shape (M, N). See :func:`repro.kernels.gram.gram`."""
    _warn("repro.kernels.rbf_gram.rbf_gram", "repro.kernels.ops.gram")
    return _gram.gram(x, z, yx, yz, kind="rbf", gamma=gamma, signed=signed,
                      bm=bm, bn=bn, bd=bd, interpret=interpret)


def rbf_gram_matvec(x: Array, z: Array, g: Array, *, gamma: float = 1.0,
                    bm: int = 256, bn: int = 256, bd: int = 512,
                    interpret: bool = False) -> Array:
    """u[k] = K(x[k], z[k]) @ g[k]. See :func:`repro.kernels.gram.gram_matvec`."""
    _warn("repro.kernels.rbf_gram.rbf_gram_matvec",
          "repro.kernels.ops.gram_matvec")
    return _gram.gram_matvec(x, z, g, kind="rbf", gamma=gamma, bm=bm, bn=bn,
                             bd=bd, interpret=interpret)
