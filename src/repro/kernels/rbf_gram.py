"""Pallas TPU kernel: tiled (signed) RBF Gram matrix.

The nonlinear-kernel hot spot of SODM: every local ODM solve needs
Q_ij = y_i y_j exp(-gamma ||x_i - x_j||^2) for its partition. The expanded
form puts the -2 x zᵀ cross term on the MXU; row norms are precomputed on
host (O(Md), negligible) and streamed as (1, bm)-shaped scalars-per-row.

Tiling: grid (M/bm, N/bn, D/bd). The feature dimension D is the innermost
(fastest-varying) grid axis so the fp32 accumulator scratch lives across
the D sweep and the (bm, bn) output tile is written once, on the last D
step — classic matmul accumulation pattern. VMEM per step:
bm*bd + bn*bd (operands) + bm*bn (acc) floats; defaults (256, 256, 512)
=> 0.75 MB operands + 0.25 MB acc in fp32, far under the ~16 MB/core VMEM
budget, leaving room for double buffering.

MXU alignment: bm, bn, bd all multiples of 128 (the MXU systolic dim) and
the exp() runs on the VPU over the finished tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _rbf_gram_kernel(xx_ref, zz_ref, yx_ref, yz_ref, x_ref, z_ref,
                     out_ref, acc_ref, *, gamma: float, signed: bool,
                     n_d_steps: int):
    """One (bm, bn) tile, accumulating the cross term over D blocks.

    xx/zz: (1, bm)/(1, bn) squared row norms; yx/yz: labels (only read when
    signed). x (bm, bd), z (bn, bd). acc: (bm, bn) fp32 scratch.
    """
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    z = z_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kd == n_d_steps - 1)
    def _finalize():
        xx = xx_ref[0, :]                      # (bm,)
        zz = zz_ref[0, :]                      # (bn,)
        d2 = xx[:, None] + zz[None, :] - 2.0 * acc_ref[...]
        k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))
        if signed:
            k = (yx_ref[0, :][:, None] * yz_ref[0, :][None, :]) * k
        out_ref[...] = k.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gamma", "signed", "bm", "bn",
                                             "bd", "interpret"))
def rbf_gram(x: Array, z: Array, yx: Array | None = None,
             yz: Array | None = None, *, gamma: float = 1.0,
             signed: bool = False, bm: int = 256, bn: int = 256,
             bd: int = 512, interpret: bool = False) -> Array:
    """K (or Q if signed) of shape (M, N). Shapes must tile evenly; the
    ops.py wrapper pads and unpads arbitrary shapes."""
    M, D = x.shape
    N = z.shape[0]
    assert M % bm == 0 and N % bn == 0 and D % bd == 0, (M, N, D, bm, bn, bd)
    if yx is None:
        yx = jnp.ones((M,), x.dtype)
    if yz is None:
        yz = jnp.ones((N,), x.dtype)
    n_d_steps = D // bd

    grid = (M // bm, N // bn, n_d_steps)
    xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[None, :]   # (1, M)
    zz = jnp.sum(z.astype(jnp.float32) ** 2, axis=-1)[None, :]   # (1, N)

    kernel = functools.partial(_rbf_gram_kernel, gamma=gamma, signed=signed,
                               n_d_steps=n_d_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j, k: (0, i)),       # xx
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),       # zz
            pl.BlockSpec((1, bm), lambda i, j, k: (0, i)),       # yx
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),       # yz
            pl.BlockSpec((bm, bd), lambda i, j, k: (i, k)),      # x
            pl.BlockSpec((bn, bd), lambda i, j, k: (j, k)),      # z
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[_acc_scratch(bm, bn)],
        interpret=interpret,
    )(xx, zz, yx[None, :], yz[None, :], x, z)


def _rbf_matvec_kernel(xx_ref, zz_ref, g_ref, x_ref, z_ref, out_ref,
                       acc_ref, u_ref, *, gamma: float, n_j: int, n_d: int):
    """One (bm,) slice of u = K(x, z) @ g, accumulated over (j, d) tiles.

    Grid (K, M/bm, N/bn, D/bd). The (bm, bn) Gram tile is formed in the
    acc scratch across the D sweep exactly like _rbf_gram_kernel, then
    immediately contracted against the matching g tile into the (bm, 1)
    u scratch — the tile never leaves VMEM, so memory stays O(m·B) however
    large the partition's full Gram would be.
    """
    kj = pl.program_id(2)
    kd = pl.program_id(3)

    @pl.when(jnp.logical_and(kj == 0, kd == 0))
    def _init_u():
        u_ref[...] = jnp.zeros_like(u_ref)

    @pl.when(kd == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], z_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kd == n_d - 1)
    def _contract():
        xx = xx_ref[0, 0, :]                   # (bm,)
        zz = zz_ref[0, 0, :]                   # (bn,)
        d2 = xx[:, None] + zz[None, :] - 2.0 * acc_ref[...]
        k = jnp.exp(-gamma * jnp.maximum(d2, 0.0))
        g = g_ref[0, 0, :]                     # (bn,)
        u_ref[...] += jax.lax.dot_general(     # (bm, bn) @ (bn, 1)
            k, g[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(kj == n_j - 1, kd == n_d - 1))
    def _finalize():
        out_ref[...] = u_ref[...].astype(out_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("gamma", "bm", "bn", "bd",
                                             "interpret"))
def rbf_gram_matvec(x: Array, z: Array, g: Array, *, gamma: float = 1.0,
                    bm: int = 256, bn: int = 256, bd: int = 512,
                    interpret: bool = False) -> Array:
    """u[k] = K(x[k], z[k]) @ g[k] without materializing any (M, N) Gram.

    Batched over a leading partition axis so one SODM level's u refresh is
    a single pallas_call: x (K, M, D), z (K, N, D), g (K, N) -> u (K, M).
    Shapes must tile evenly; the ops.py wrapper pads arbitrary shapes. For
    the *signed* product Q @ g = y ⊙ (K @ (y ⊙ g)) fold the labels into g
    and the result (the ops wrapper does).
    """
    K, M, D = x.shape
    N = z.shape[1]
    assert M % bm == 0 and N % bn == 0 and D % bd == 0, (M, N, D, bm, bn, bd)
    n_j, n_d = N // bn, D // bd
    grid = (K, M // bm, n_j, n_d)
    xx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)[:, None, :]  # (K, 1, M)
    zz = jnp.sum(z.astype(jnp.float32) ** 2, axis=-1)[:, None, :]  # (K, 1, N)

    kernel = functools.partial(_rbf_matvec_kernel, gamma=gamma, n_j=n_j,
                               n_d=n_d)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm), lambda k, i, j, d: (k, 0, i)),   # xx
            pl.BlockSpec((1, 1, bn), lambda k, i, j, d: (k, 0, j)),   # zz
            pl.BlockSpec((1, 1, bn), lambda k, i, j, d: (k, 0, j)),   # g
            pl.BlockSpec((1, bm, bd), lambda k, i, j, d: (k, i, d)),  # x
            pl.BlockSpec((1, bn, bd), lambda k, i, j, d: (k, j, d)),  # z
        ],
        out_specs=pl.BlockSpec((1, bm, 1), lambda k, i, j, d: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, M, 1), x.dtype),
        scratch_shapes=[_acc_scratch(bm, bn), _u_scratch(bm)],
        interpret=interpret,
    )(xx, zz, g[:, None, :], x, z)
    return out[:, :, 0]


def _u_scratch(bm: int):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM((bm, 1), jnp.float32)
    except Exception:                          # pragma: no cover
        return pl.VMEM((bm, 1), jnp.float32)


def _acc_scratch(bm: int, bn: int):
    from jax.experimental import pallas as pl  # local to keep import cheap
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM((bm, bn), jnp.float32)
    except Exception:                          # pragma: no cover
        return pl.VMEM((bm, bn), jnp.float32)
