"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are tested against
(tests/test_kernels.py sweeps shapes & dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# rbf_gram
# ---------------------------------------------------------------------------

def rbf_gram(x: Array, z: Array, gamma: float) -> Array:
    """K[i,j] = exp(-gamma ||x_i - z_j||^2)."""
    xx = jnp.sum(x * x, axis=-1)[:, None]
    zz = jnp.sum(z * z, axis=-1)[None, :]
    cross = x @ z.T
    return jnp.exp(-gamma * jnp.maximum(xx + zz - 2.0 * cross, 0.0))


def signed_rbf_gram(x: Array, z: Array, yx: Array, yz: Array,
                    gamma: float) -> Array:
    """Q[i,j] = y_i y_j exp(-gamma ||x_i - z_j||^2) — the ODM dual block."""
    return (yx[:, None] * yz[None, :]) * rbf_gram(x, z, gamma)


# ---------------------------------------------------------------------------
# dual_cd_block — Gauss-Southwell (greedy) CD within a VMEM-resident tile
# ---------------------------------------------------------------------------

def cd_tile_sweep(qblk: Array, alpha: Array, u: Array, *, c: float,
                  ups: float, theta: float, mscale: float,
                  n_steps: int) -> tuple[Array, Array]:
    """Greedy coordinate descent on one diagonal tile.

    qblk:  (B, B) diagonal Gram block (signed).
    alpha: (2B,) [zeta; beta] for the tile's coordinates.
    u:     (B,) cache Q(zeta - beta) restricted to the tile's rows
           (external contribution included; it stays constant here).

    Each step picks the coordinate with the largest projected-gradient
    violation (Gauss-Southwell rule) and applies the exact univariate
    update. All ops are vectorized (argmax + one-hot) — the TPU-friendly
    formulation the Pallas kernel mirrors exactly.
    """
    B = qblk.shape[0]
    q_diag = jnp.diagonal(qblk)

    def step(carry, _):
        alpha, u = carry
        zeta, beta = alpha[:B], alpha[B:]
        gz = u + mscale * c * ups * zeta + (theta - 1.0)
        gb = -u + mscale * c * beta + (theta + 1.0)
        g = jnp.concatenate([gz, gb])
        # projected violation for the box alpha >= 0
        viol = jnp.where(alpha > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
        i = jnp.argmax(viol)
        hz = q_diag + mscale * c * ups
        hb = q_diag + mscale * c
        h = jnp.concatenate([hz, hb])
        new_i = jnp.maximum(alpha[i] - g[i] / h[i], 0.0)
        delta = new_i - alpha[i]
        alpha = alpha.at[i].set(new_i)
        row = jnp.where(i < B, i, i - B)
        sign = jnp.where(i < B, 1.0, -1.0).astype(u.dtype)
        onehot = (jnp.arange(B) == row).astype(u.dtype)
        u = u + (sign * delta) * (qblk @ onehot)
        return (alpha, u), None

    (alpha, u), _ = jax.lax.scan(step, (alpha, u), None, length=n_steps)
    return alpha, u


def cd_block_sweep(q_blocks: Array, alphas: Array, us: Array, *, c: float,
                   ups: float, theta: float, mscale: float,
                   n_steps: int) -> tuple[Array, Array]:
    """vmap of cd_tile_sweep over the leading tile axis.

    q_blocks (nblk, B, B), alphas (nblk, 2B), us (nblk, B).
    """
    fn = lambda q, a, u: cd_tile_sweep(q, a, u, c=c, ups=ups, theta=theta,
                                       mscale=mscale, n_steps=n_steps)
    return jax.vmap(fn)(q_blocks, alphas, us)


# ---------------------------------------------------------------------------
# odm_grad — fused linear-kernel primal ODM gradient
# ---------------------------------------------------------------------------

def odm_grad(w: Array, x: Array, y: Array, *, lam: float, theta: float,
             ups: float) -> Array:
    """grad p(w) = w + (lam / (M (1-theta)^2)) X^T [(lo + ups*hi) * y]."""
    M = x.shape[0]
    m = y * (x @ w)
    s = lam / (M * (1.0 - theta) ** 2)
    lo = jnp.where(m < 1.0 - theta, m + theta - 1.0, 0.0)
    hi = jnp.where(m > 1.0 + theta, m - theta - 1.0, 0.0)
    coef = s * (lo + ups * hi) * y
    return w + x.T @ coef


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window, GQA)
# ---------------------------------------------------------------------------

def mha(q: Array, k: Array, v: Array, *, causal: bool = True,
        window: int | None = None, scale: float | None = None) -> Array:
    """Reference attention. q (B, Hq, T, D), k/v (B, Hkv, S, D).

    GQA: Hq % Hkv == 0; query head h attends to kv head h // (Hq // Hkv).
    window: if set, query position t attends only to kv in
    (t - window, t] (causal sliding window, Gemma/recurrentgemma style).
    """
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q, kq) * scale
    # positions: queries occupy the last T slots of the S-long history
    qpos = jnp.arange(T) + (S - T)
    kpos = jnp.arange(S)
    mask = jnp.ones((T, S), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows give nan; zero them (cannot happen for causal+window>=1)
    probs = jnp.nan_to_num(probs)
    return jnp.einsum("bhts,bhsd->bhtd", probs, vq)
