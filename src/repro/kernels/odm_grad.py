"""Pallas TPU kernel: fused linear-kernel primal ODM gradient.

grad p(w) = w + s · Xᵀ[(lo + ups·hi) ⊙ y],  s = lam / (M (1-θ)²)

where lo/hi are the two-sided margin residuals (Section 3.3). XLA lowers
the naive expression as two passes over X (one for the margins X w, one
for the back-projection Xᵀ coef). For DSVRG the gradient is the inner-loop
hot spot and X is the dominant operand, so fusing both matvecs into a
single HBM pass halves the memory traffic — the op is memory-bound
(arithmetic intensity ≈ 2 flops/byte either way), so that is a ~2× win.

Tiling: grid (M/bm,), sequential on TPU, so all cells accumulate into the
same (1, d) output block; cell i loads its (bm, d) X slab once, computes
margins m = X_i w (MXU), coefficients (VPU), and the partial Xᵀ coef
(MXU), adding into the accumulator. Cell 0 initializes the accumulator
with w (the ridge term). VMEM: bm·d + 2·d + O(bm) floats; defaults
(bm=512, d≤8192) ≈ 16 MB fp32 upper bound — the wrapper halves bm when
bm·d would exceed the budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _odm_grad_kernel(w_ref, x_ref, y_ref, out_ref, *, s: float, theta: float,
                     ups: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = w_ref[...]

    x = x_ref[...]                              # (bm, d)
    w = w_ref[0, :]                             # (d,)
    y = y_ref[0, :]                             # (bm,)
    m = y * jax.lax.dot_general(x, w[:, None], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)[:, 0]
    lo = jnp.where(m < 1.0 - theta, m + theta - 1.0, 0.0)
    hi = jnp.where(m > 1.0 + theta, m - theta - 1.0, 0.0)
    coef = (s * (lo + ups * hi) * y).astype(x.dtype)        # (bm,)
    part = jax.lax.dot_general(coef[None, :], x, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (1, d)
    out_ref[...] += part.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lam", "theta", "ups", "bm",
                                             "interpret"))
def odm_grad(w: Array, x: Array, y: Array, *, lam: float = 1.0,
             theta: float = 0.1, ups: float = 0.5, bm: int = 512,
             interpret: bool = False) -> Array:
    """Full-batch grad p(w). Shapes must tile evenly (ops.py pads)."""
    M, d = x.shape
    assert M % bm == 0, (M, bm)
    s = lam / (M * (1.0 - theta) ** 2)
    kernel = functools.partial(_odm_grad_kernel, s=s, theta=theta, ups=ups)
    out = pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),      # w
            pl.BlockSpec((bm, d), lambda i: (i, 0)),     # x
            pl.BlockSpec((1, bm), lambda i: (0, i)),     # y
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), w.dtype),
        interpret=interpret,
    )(w[None, :], x, y[None, :])
    return out[0]
