"""Pallas TPU kernels: fused linear-kernel primal ODM gradients.

Two fused passes share the layout:

* :func:`odm_grad` — full-batch anchor gradient

      grad p(w) = w + s · Xᵀ[(lo + ups·hi) ⊙ y],  s = lam / (M (1-θ)²)

  where lo/hi are the two-sided margin residuals (Section 3.3). XLA
  lowers the naive expression as two passes over X (one for the margins
  X w, one for the back-projection Xᵀ coef). For DSVRG the gradient is
  the hot spot and X is the dominant operand, so fusing both matvecs into
  a single HBM pass halves the memory traffic — the op is memory-bound
  (arithmetic intensity ≈ 2 flops/byte either way), so that is a ~2× win.

* :func:`odm_svrg_grad` — the DSVRG inner-step direction

      g_w − g_a + h = (w − a + h) + Xᵀ[(coef_w − coef_a) ⊙ wt] / n_valid

  The naive form is FOUR passes over the minibatch (margins + back-
  projection for each of w and the anchor a); the fused kernel loads each
  X slab once, computes BOTH margin products as a single (bm, 2) MXU op
  against the stacked [w; a] (the ``gram.py`` accumulation skeleton's
  cross-term, :func:`repro.kernels.gram.accum_tile`), forms the
  coefficient difference on the VPU, and back-projects — a ~4× traffic
  cut on the epoch's dominant operand. ``wt`` masks ragged-tail padding
  rows; ``inv_n`` (host-precomputed 1/n_valid) keeps the mean exact for
  partial tails.

Tiling (both): grid (M/bm,), sequential on TPU, so all cells accumulate
into the same (1, d) output block; cell i loads its (bm, d) X slab once,
cell 0 initializes the accumulator with the ridge/variance-reduction term.
VMEM: bm·d + O(d) + O(bm) floats; defaults (bm=512, d≤8192) ≈ 16 MB fp32
upper bound — the ops.py wrappers halve bm when bm·d would exceed the
budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gram import accum_tile

Array = jax.Array


def _hinge_coef(m: Array, y: Array, *, s: float, theta: float,
                ups: float) -> Array:
    """VPU per-instance coefficient s·(lo + ups·hi)·y (odm._hinge_coef)."""
    lo = jnp.where(m < 1.0 - theta, m + theta - 1.0, 0.0)
    hi = jnp.where(m > 1.0 + theta, m - theta - 1.0, 0.0)
    return s * (lo + ups * hi) * y


def _odm_grad_kernel(w_ref, x_ref, y_ref, out_ref, *, s: float, theta: float,
                     ups: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = w_ref[...]

    x = x_ref[...]                              # (bm, d)
    w = w_ref[0, :]                             # (d,)
    y = y_ref[0, :]                             # (bm,)
    m = y * jax.lax.dot_general(x, w[:, None], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)[:, 0]
    coef = _hinge_coef(m, y, s=s, theta=theta, ups=ups).astype(x.dtype)
    part = jax.lax.dot_general(coef[None, :], x, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (1, d)
    out_ref[...] += part.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lam", "theta", "ups", "bm",
                                             "interpret"))
def odm_grad(w: Array, x: Array, y: Array, *, lam: float = 1.0,
             theta: float = 0.1, ups: float = 0.5, bm: int = 512,
             interpret: bool = False) -> Array:
    """Full-batch grad p(w). Shapes must tile evenly (ops.py pads)."""
    M, d = x.shape
    assert M % bm == 0, (M, bm)
    s = lam / (M * (1.0 - theta) ** 2)
    kernel = functools.partial(_odm_grad_kernel, s=s, theta=theta, ups=ups)
    out = pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),      # w
            pl.BlockSpec((bm, d), lambda i: (i, 0)),     # x
            pl.BlockSpec((1, bm), lambda i: (0, i)),     # y
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), w.dtype),
        interpret=interpret,
    )(w[None, :], x, y[None, :])
    return out[0]


# ---------------------------------------------------------------------------
# fused DSVRG inner-step direction
# ---------------------------------------------------------------------------

def _svrg_grad_kernel(wa_ref, h_ref, inv_ref, x_ref, y_ref, wt_ref, out_ref,
                      *, s: float, theta: float, ups: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # variance-reduction base (w - a + h): ridge terms of g_w - g_a
        # cancel to w - a, then the anchor full gradient h rides on top
        out_ref[...] = (wa_ref[0, :] - wa_ref[1, :] + h_ref[0, :])[None, :]

    x = x_ref[...]                              # (bm, d)
    y = y_ref[0, :]                             # (bm,)
    # both margin products in ONE MXU op: x @ [w; a]ᵀ via the shared Gram
    # cross-term skeleton -> (bm, 2) columns [x·w, x·a]
    mm = y[:, None] * accum_tile(
        "linear", jnp.zeros((x.shape[0], 2), jnp.float32), x, wa_ref[...])
    dcoef = _hinge_coef(mm[:, 0], y, s=s, theta=theta, ups=ups) \
        - _hinge_coef(mm[:, 1], y, s=s, theta=theta, ups=ups)
    dcoef = (dcoef * wt_ref[0, :] * inv_ref[0, 0]).astype(x.dtype)  # (bm,)
    part = jax.lax.dot_general(dcoef[None, :], x, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (1, d)
    out_ref[...] += part.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("s", "theta", "ups", "bm",
                                             "interpret"))
def odm_svrg_grad(w: Array, anchor: Array, h: Array, x: Array, y: Array,
                  wt: Array, inv_n: Array, *, s: float, theta: float = 0.1,
                  ups: float = 0.5, bm: int = 512,
                  interpret: bool = False) -> Array:
    """Fused g_w − g_a + h on one (possibly masked) minibatch.

    x (B, d) with B % bm == 0 (ops.py pads); wt (B,) 1.0 on real rows and
    0.0 on padding; inv_n a (1, 1) array holding 1/n_valid (host-side, so
    the masked mean stays exact whatever the tail size). ``s`` is the
    per-instance hinge scale lam/(1-θ)² — no 1/M, the division is inv_n.
    """
    B, d = x.shape
    assert B % bm == 0, (B, bm)
    kernel = functools.partial(_svrg_grad_kernel, s=s, theta=theta, ups=ups)
    out = pl.pallas_call(
        kernel,
        grid=(B // bm,),
        in_specs=[
            pl.BlockSpec((2, d), lambda i: (0, 0)),      # [w; anchor]
            pl.BlockSpec((1, d), lambda i: (0, 0)),      # h
            pl.BlockSpec((1, 1), lambda i: (0, 0)),      # 1/n_valid
            pl.BlockSpec((bm, d), lambda i: (i, 0)),     # x
            pl.BlockSpec((1, bm), lambda i: (0, i)),     # y
            pl.BlockSpec((1, bm), lambda i: (0, i)),     # wt
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, d), w.dtype),
        interpret=interpret,
    )(jnp.stack([w, anchor]), h[None, :], inv_n, x, y[None, :], wt[None, :])
    return out[0]
