"""Public jit'd entry points for the Pallas kernels.

These wrappers make the kernels shape-agnostic (pad to tile multiples,
unpad the result), pick block sizes that respect the VMEM budget, and fall
back to the pure-jnp reference on hosts where Mosaic is unavailable
(interpret=True runs the kernel body in Python — used by all CPU tests).

Use these from framework code; use the <name>.py modules directly only in
kernel tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dual_cd_block as _cd
from repro.kernels import flash_attn as _fa
from repro.kernels import gram as _gram
from repro.kernels import odm_grad as _og
from repro.kernels import ref

Array = jax.Array

# interpret=True on CPU hosts (tests / this container); False on real TPU.
_INTERPRET = jax.default_backend() != "tpu"


def _pad_to(a: Array, axis: int, mult: int, value=0.0) -> tuple[Array, int]:
    n = a.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return a, n
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(a, pad, constant_values=value), n


# ---------------------------------------------------------------------------
# gram (all KernelSpec families; rbf_* kept as pinned-kernel conveniences)
# ---------------------------------------------------------------------------

def gram(x: Array, z: Array, spec, *, yx: Array | None = None,
         yz: Array | None = None, bm: int = 256, bn: int = 256,
         bd: int = 512) -> Array:
    """(Signed) Gram for arbitrary shapes and any ``KernelSpec`` family.

    ``spec`` is KernelSpec-like (name/gamma/degree/coef0). Pads to tile
    multiples and unpads the result; zero feature pads shift neither the
    L2 cross term nor the L1 distance, so padding is transparent.
    """
    M, D = x.shape
    N = z.shape[0]
    bm = min(bm, max(8, M))
    bn = min(bn, max(8, N))
    bd = min(bd, max(8, D))
    xp, _ = _pad_to(x, 0, bm)
    zp, _ = _pad_to(z, 0, bn)
    xp, _ = _pad_to(xp, 1, bd)
    zp, _ = _pad_to(zp, 1, bd)
    signed = yx is not None
    yxp = yzp = None
    if signed:
        yxp, _ = _pad_to(yx, 0, bm)
        yzp, _ = _pad_to(yz if yz is not None else yx, 0, bn)
    out = _gram.gram(xp, zp, yxp, yzp, kind=spec.name, gamma=spec.gamma,
                     degree=spec.degree, coef0=spec.coef0, signed=signed,
                     bm=bm, bn=bn, bd=bd, interpret=_INTERPRET)
    return out[:M, :N]


def rbf_gram(x: Array, z: Array, gamma: float, *, yx: Array | None = None,
             yz: Array | None = None, bm: int = 256, bn: int = 256,
             bd: int = 512) -> Array:
    """(Signed) RBF Gram for arbitrary shapes; pads to tile multiples."""
    return gram(x, z, _RbfSpec(gamma), yx=yx, yz=yz, bm=bm, bn=bn, bd=bd)


class _RbfSpec:
    """Minimal KernelSpec stand-in so kernels/ never imports repro.core."""

    name = "rbf"
    degree = 3
    coef0 = 1.0

    def __init__(self, gamma: float):
        self.gamma = gamma


# ---------------------------------------------------------------------------
# block dual CD
# ---------------------------------------------------------------------------

def dual_cd_solve(Q: Array, *, c: float, ups: float, theta: float,
                  mscale: float, block: int = 256, n_passes: int = 50,
                  tol: float = 1e-5, steps_per_pass: int | None = None,
                  alpha0: Array | None = None,
                  adaptive: bool = True) -> tuple[Array, Array, Array]:
    """Solve the ODM dual with the fused Pallas pass kernel. Pads M to the
    block.

    ``alpha0`` (2M,) is the warm start (SODM Algorithm 1 line 12); zeros
    when omitted. Padded coordinates are masked inside the tile kernel
    (frozen at zero, excluded from the KKT residual), so padding neither
    moves spurious coordinates nor delays the 0-pass warm-start exit.
    ``adaptive`` enables the in-tile early exit (see
    :func:`repro.kernels.dual_cd_block.solve_level`).
    """
    M = Q.shape[0]
    block = min(block, M)
    Qp, _ = _pad_to(Q, 0, block)
    Qp, _ = _pad_to(Qp, 1, block)
    Mp = Qp.shape[0]
    a0 = None
    if alpha0 is not None:
        a0 = jnp.zeros(2 * Mp, Q.dtype) \
            .at[:M].set(alpha0[:M]).at[Mp:Mp + M].set(alpha0[M:])
    valid = (jnp.arange(Mp) < M).astype(Q.dtype) if Mp != M else None
    alpha, kkt, passes = _cd.solve(
        Qp, c=c, ups=ups, theta=theta, mscale=mscale, block=block,
        n_passes=n_passes, tol=tol, steps_per_pass=steps_per_pass,
        alpha0=a0, valid=valid, adaptive=adaptive, interpret=_INTERPRET)
    zeta, beta = alpha[:Mp], alpha[Mp:]
    return jnp.concatenate([zeta[:M], beta[:M]]), kkt, passes


def gram_matvec(x: Array, g: Array, spec, *, y: Array | None = None,
                bm: int = 256, bn: int = 256, bd: int = 512) -> Array:
    """u[k] = Q_k @ g[k] for any ``KernelSpec`` family, never materialized.

    x (K, m, d) batched partitions, g (K, m); y (K, m) labels make it the
    signed product Q = y yᵀ ⊙ K via u = y ⊙ (K @ (y ⊙ g)). Pads m and d to
    tile multiples — padded g entries are zero so padded rows contribute
    nothing, and padded outputs are sliced off. Per-partition memory is
    O(m·B) (one Gram tile), not O(m²).
    """
    K, M, D = x.shape
    bm = min(bm, max(8, M))
    bn = min(bn, max(8, M))
    bd = min(bd, max(8, D))
    gs = g if y is None else y * g
    xp, _ = _pad_to(x, 1, max(bm, bn))
    xp, _ = _pad_to(xp, 2, bd)
    gp, _ = _pad_to(gs, 1, max(bm, bn))
    u = _gram.gram_matvec(xp, xp, gp, kind=spec.name, gamma=spec.gamma,
                          degree=spec.degree, coef0=spec.coef0, bm=bm,
                          bn=bn, bd=bd, interpret=_INTERPRET)[:, :M]
    return u if y is None else y * u


def rbf_gram_matvec(x: Array, g: Array, *, gamma: float,
                    y: Array | None = None, bm: int = 256, bn: int = 256,
                    bd: int = 512) -> Array:
    """RBF-pinned convenience over :func:`gram_matvec`."""
    return gram_matvec(x, g, _RbfSpec(gamma), y=y, bm=bm, bn=bn, bd=bd)


# ---------------------------------------------------------------------------
# serving: tiled decision-function scores
# ---------------------------------------------------------------------------

def decision_scores(x: Array, z: Array, coef: Array, spec, *,
                    bt: int = 256, bs: int = 256, bd: int = 512,
                    tiled: bool | None = None) -> Array:
    """f (T,) = K(x, z) @ coef for arbitrary shapes — the serving hot path.

    ``z`` (S, d) is the packed support-vector slab, ``coef`` (S,) its dual
    coefficients y ⊙ (ζ − β); ``spec`` is KernelSpec-like. Pads every axis
    to tile multiples (padded coef entries are 0 so padded SV rows add
    nothing; padded request rows are sliced off) and never materializes
    the (T, S) Gram: ``tiled=None`` auto-picks the Pallas kernel when
    compiled (TPU) and the O(bt·S) jnp streaming scorer under interpret
    mode, where unrolling the tile grid into the trace would bloat CPU
    compile time (same policy as ``DSVRGConfig.fused``/``solve_level``).
    ``tiled=True`` forces the kernel (tests), ``tiled=False`` the dense
    reference oracle.
    """
    from repro.kernels import score as _score
    T, D = x.shape
    S = z.shape[0]
    if tiled is False:
        return _score.score_ref(x, z, coef, kind=spec.name, gamma=spec.gamma,
                                degree=spec.degree, coef0=spec.coef0)
    bt = min(bt, max(8, T))
    xp, _ = _pad_to(x, 0, bt)
    if tiled is None and _INTERPRET:
        out = _score.score_blocked(xp, z, coef, kind=spec.name,
                                   gamma=spec.gamma, degree=spec.degree,
                                   coef0=spec.coef0, bt=bt)
        return out[:T]
    bs = min(bs, max(8, S))
    bd = min(bd, max(8, D))
    zp, _ = _pad_to(z, 0, bs)
    xp, _ = _pad_to(xp, 1, bd)
    zp, _ = _pad_to(zp, 1, bd)
    cp, _ = _pad_to(coef, 0, bs)
    out = _score.score_tiles(xp, zp, cp, kind=spec.name, gamma=spec.gamma,
                             degree=spec.degree, coef0=spec.coef0, bt=bt,
                             bs=bs, bd=bd, interpret=_INTERPRET)
    return out[:T]


# ---------------------------------------------------------------------------
# fused ODM gradient
# ---------------------------------------------------------------------------

def _shrink_bm(bm: int, M: int, d: int) -> int:
    """Shrink the row-tile so the (bm, d) fp32 slab stays STRICTLY under
    the ~8 MB single-copy VMEM budget (shared policy of the fused ODM
    gradient kernels). Strict: at exactly 8 MB the slab alone consumes
    the whole budget and the resident w/out rows push the launch over —
    pinned by the ``kernels.odm_grad.vmem_plan`` invariant."""
    bm_eff = min(bm, M)
    while bm_eff > 8 and bm_eff * d * 4 >= 8 * 2 ** 20:
        bm_eff //= 2
    return bm_eff


def odm_grad(w: Array, x: Array, y: Array, *, lam: float = 1.0,
             theta: float = 0.1, ups: float = 0.5, bm: int = 512) -> Array:
    """Fused primal gradient; pads M (zero rows have margin 0 -> inside the
    band only if theta >= 1, so we pad y with +1 labels and w·0 = 0 margin
    => lo = theta - 1 < 0 contributes coef on a zero row: harmless since
    the x row is zero => contributes nothing to Xᵀcoef)."""
    M, d = x.shape
    bm_eff = _shrink_bm(bm, M, d)
    xp, _ = _pad_to(x, 0, bm_eff)
    yp, _ = _pad_to(y, 0, bm_eff, value=1.0)
    # padded rows are all-zero in x => contribute nothing; but they do not
    # change s either (s uses the true M), so pass lam scaled to true M.
    out = _og.odm_grad(w, xp, yp, lam=lam * xp.shape[0] / M, theta=theta,
                       ups=ups, bm=bm_eff, interpret=_INTERPRET)
    return out


def svrg_grad(w: Array, anchor: Array, h: Array, x: Array, y: Array,
              wt: Array | None = None, *, lam: float = 1.0,
              theta: float = 0.1, ups: float = 0.5, bm: int = 512) -> Array:
    """Fused DSVRG inner-step direction g_w − g_a + h (see odm_grad.py).

    ``wt`` (B,) masks ragged-tail padding rows (0 ⇒ excluded from the
    coefficient and the mean divisor); the wrapper's own batch padding is
    folded into the same mask. Semantically identical to the pure-jnp
    reference ``repro.core.odm.svrg_direction``.
    """
    B, d = x.shape
    bm_eff = _shrink_bm(bm, B, d)
    if wt is None:
        wt = jnp.ones((B,), x.dtype)
    xp, _ = _pad_to(x, 0, bm_eff)
    yp, _ = _pad_to(y, 0, bm_eff)
    wtp, _ = _pad_to(wt, 0, bm_eff)
    inv_n = (1.0 / jnp.maximum(jnp.sum(wt), 1.0)).reshape(1, 1)
    s = lam / (1.0 - theta) ** 2
    return _og.odm_svrg_grad(w, anchor, h, xp, yp, wtp,
                             inv_n.astype(w.dtype), s=s, theta=theta,
                             ups=ups, bm=bm_eff, interpret=_INTERPRET)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None, scale: float | None = None,
                    bq: int = 512, bk: int = 512) -> Array:
    """Flash attention with T/S padding. Padded kv positions are masked by
    the causal bound (they sit beyond the last real query's reach) when
    causal=True; for non-causal we pad k with -inf-like zeros and rely on
    the caller to not use non-causal with ragged S (asserted)."""
    B, Hq, T, D = q.shape
    S = k.shape[2]
    bq = min(bq, T)
    bk = min(bk, S)
    if T % bq == 0 and S % bk == 0:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale, bq=bq, bk=bk,
                                   interpret=_INTERPRET)
    if not causal or T != S:
        # ragged non-self-attention: padding shifts the causal alignment
        # (q_offset = S - T must be preserved); use the reference — this
        # path only occurs for tiny smoke shapes, never in production
        # configs (which are tile-aligned by construction).
        return ref.mha(q, k, v, causal=causal, window=window, scale=scale)
    bq = bk = min(bq, bk)
    qp, _ = _pad_to(q, 2, bq)
    kp, _ = _pad_to(k, 2, bk)
    vp, _ = _pad_to(v, 2, bk)
    # equal pads on q and kv keep q_offset = 0; padded kv positions sit
    # beyond every real query's causal reach, so they are masked out.
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              scale=scale, bq=bq, bk=bk,
                              interpret=_INTERPRET)
    return out[:, :, :T, :]


# ---------------------------------------------------------------------------
# introspection
# ---------------------------------------------------------------------------

def count_pallas_calls(fn) -> int:
    """Trace ``fn()`` (zero-arg, no execution) and count ``pallas_call``s.

    Used by the kernels benchmark and the engine tests to pin per-pass
    kernel-launch counts (e.g. the fused CD pass must be exactly one).
    Delegates to the jaxpr walker in :mod:`repro.analysis.jaxpr_lint`,
    which recurses into jitted constituents' sub-jaxprs — unlike the old
    ``pl.pallas_call`` monkeypatch it cannot undercount on a warm trace
    cache, so no ``clear_cache()`` discipline is needed."""
    from repro.analysis import jaxpr_lint as _jl
    return _jl.count_primitive(fn, "pallas_call")


# re-export oracles for convenience
reference = ref
