"""Pallas TPU kernel: block-Gauss-Seidel dual coordinate descent tile solve.

TPU adaptation of the paper's scalar dual CD (Eqn. 3). Scalar cyclic CD is
latency-bound on TPU (one f32 op per cycle vs a 8x128 VPU), so inside each
VMEM-resident diagonal tile we run *Gauss-Southwell* (greedy) CD: every
step computes the full projected-gradient vector for the tile's 2B
coordinates (vectorized), picks the worst violator (argmax), and applies
the exact univariate update via a one-hot masked rank-1 update of the
cache u. Each step is O(B) VPU work + one (B,B)x(B,) product — fully
vectorized, no scalar HBM round-trips. Cross-tile coupling is handled by
the caller refreshing u = Q gamma with an MXU matmul between passes
(Jacobi across tiles), mirroring repro.core.dual_cd.solve_block.

Memory: only the (B, B) *diagonal* Gram blocks enter the kernel —
O(nblk·B²) = O(M·B) bytes instead of the full O(M²) Gram; the off-diagonal
mass is only ever touched through the u refresh matmul, which itself can
use an on-the-fly Gram (rbf_gram kernel) for memory-free operation.

Grid: (nblk,). VMEM per step: B² + 4B floats (B=256 → 260 KB fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _cd_tile_kernel(q_ref, alpha_ref, u_ref, alpha_out, u_out, *,
                    c: float, ups: float, theta: float, mscale: float,
                    n_steps: int):
    B = q_ref.shape[1]
    qblk = q_ref[0]                       # (B, B)
    q_diag = jnp.diagonal(qblk)
    hz = q_diag + mscale * c * ups
    hb = q_diag + mscale * c
    h = jnp.concatenate([hz, hb])

    def step(t, carry):
        alpha, u = carry
        zeta, beta = alpha[:B], alpha[B:]
        gz = u + mscale * c * ups * zeta + (theta - 1.0)
        gb = -u + mscale * c * beta + (theta + 1.0)
        g = jnp.concatenate([gz, gb])
        viol = jnp.where(alpha > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
        i = jnp.argmax(viol)
        sel = (jnp.arange(2 * B) == i).astype(alpha.dtype)        # one-hot 2B
        a_i = jnp.sum(alpha * sel)
        g_i = jnp.sum(g * sel)
        h_i = jnp.sum(h * sel)
        new_i = jnp.maximum(a_i - g_i / h_i, 0.0)
        delta = new_i - a_i
        alpha = alpha + delta * sel
        row_oh = sel[:B] - sel[B:]        # +1 for zeta coord, -1 for beta
        u = u + delta * (qblk @ row_oh)
        return alpha, u

    alpha, u = jax.lax.fori_loop(0, n_steps,
                                 step, (alpha_ref[0], u_ref[0]))
    alpha_out[0] = alpha
    u_out[0] = u


@functools.partial(jax.jit, static_argnames=("c", "ups", "theta", "mscale",
                                             "n_steps", "interpret"))
def cd_block_sweep(q_blocks: Array, alphas: Array, us: Array, *, c: float,
                   ups: float, theta: float, mscale: float, n_steps: int,
                   interpret: bool = False) -> tuple[Array, Array]:
    """Run n_steps greedy-CD updates inside every diagonal tile.

    q_blocks (nblk, B, B), alphas (nblk, 2B), us (nblk, B) ->
    (alphas', us').
    """
    nblk, B, _ = q_blocks.shape
    kernel = functools.partial(_cd_tile_kernel, c=c, ups=ups, theta=theta,
                               mscale=mscale, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 2 * B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2 * B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(alphas.shape, alphas.dtype),
            jax.ShapeDtypeStruct(us.shape, us.dtype),
        ],
        interpret=interpret,
    )(q_blocks, alphas, us)


def extract_diag_blocks(Q: Array, block: int) -> Array:
    """(M, M) -> (M/block, block, block) diagonal blocks."""
    M = Q.shape[0]
    nblk = M // block
    idx = jnp.arange(nblk)
    return jax.vmap(lambda b: jax.lax.dynamic_slice(
        Q, (b * block, b * block), (block, block)))(idx)


def solve(Q: Array, *, c: float, ups: float, theta: float, mscale: float,
          block: int = 256, steps_per_pass: int | None = None,
          n_passes: int = 30, tol: float = 1e-5,
          interpret: bool = False) -> tuple[Array, Array, Array]:
    """Full block-CD solve driven by the Pallas tile kernel.

    Outer loop (lax.while_loop): refresh u = Q gamma (MXU matmul), run the
    tile kernel on all diagonal blocks, check the global projected-KKT
    residual. Returns (alpha, kkt, passes).
    """
    M = Q.shape[0]
    assert M % block == 0, (M, block)
    nblk = M // block
    n_steps = 2 * block if steps_per_pass is None else steps_per_pass
    qb = extract_diag_blocks(Q, block)

    def kkt(alpha, u):
        zeta, beta = alpha[:M], alpha[M:]
        gz = u + mscale * c * ups * zeta + (theta - 1.0)
        gb = -u + mscale * c * beta + (theta + 1.0)
        g = jnp.concatenate([gz, gb])
        a = jnp.concatenate([zeta, beta])
        return jnp.max(jnp.where(a > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0)))

    def body(carry):
        alpha, _, it = carry
        zeta, beta = alpha[:M], alpha[M:]
        u = Q @ (zeta - beta)
        a_t = jnp.concatenate([zeta.reshape(nblk, block),
                               beta.reshape(nblk, block)], axis=1)
        u_t = u.reshape(nblk, block)
        a_t, _ = cd_block_sweep(qb, a_t, u_t, c=c, ups=ups, theta=theta,
                                mscale=mscale, n_steps=n_steps,
                                interpret=interpret)
        zeta = a_t[:, :block].reshape(M)
        beta = a_t[:, block:].reshape(M)
        alpha = jnp.concatenate([zeta, beta])
        u = Q @ (zeta - beta)
        return alpha, kkt(alpha, u), it + 1

    def cond(carry):
        _, r, it = carry
        return jnp.logical_and(it < n_passes, r > tol)

    alpha0 = jnp.zeros(2 * M, Q.dtype)
    alpha, r, it = jax.lax.while_loop(
        cond, body, (alpha0, jnp.array(jnp.inf, Q.dtype), jnp.int32(0)))
    return alpha, r, it
