"""Pallas TPU kernel: block-Gauss-Seidel dual coordinate descent tile solve.

TPU adaptation of the paper's scalar dual CD (Eqn. 3). Scalar cyclic CD is
latency-bound on TPU (one f32 op per cycle vs a 8x128 VPU), so inside each
VMEM-resident diagonal tile we run *Gauss-Southwell* (greedy) CD: every
step computes the full projected-gradient vector for the tile's 2B
coordinates (vectorized), picks the worst violator (argmax), and applies
the exact univariate update via a one-hot masked rank-1 update of the
cache u. Each step is O(B) VPU work + one (B,B)x(B,) product — fully
vectorized, no scalar HBM round-trips. Cross-tile coupling is handled by
the caller refreshing u = Q gamma with an MXU matmul between passes
(Jacobi across tiles), mirroring repro.core.dual_cd.solve_block.

Memory: only the (B, B) *diagonal* Gram blocks enter the kernel —
O(nblk·B²) = O(M·B) bytes instead of the full O(M²) Gram; the off-diagonal
mass is only ever touched through the u refresh matmul, which itself can
use an on-the-fly Gram (rbf_gram kernel) for memory-free operation.

Grid: (nblk,) — or (K·nblk,) via :func:`solve_level`, which advances all K
partitions of one SODM level in a single pallas_call per pass with
warm-start support (Algorithm 1 line 12) and masked padding for
non-tile-multiple partitions. VMEM per step: B² + 5B floats (B=256 →
261 KB fp32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _cd_tile_kernel(q_ref, alpha_ref, u_ref, valid_ref, alpha_out, u_out, *,
                    c: float, ups: float, theta: float, mscale: float,
                    n_steps: int):
    B = q_ref.shape[1]
    qblk = q_ref[0]                       # (B, B)
    q_diag = jnp.diagonal(qblk)
    hz = q_diag + mscale * c * ups
    hb = q_diag + mscale * c
    h = jnp.concatenate([hz, hb])
    # padded coordinates (valid = 0) are frozen at zero: their violation is
    # masked so greedy never selects them and they never perturb u
    valid2 = jnp.concatenate([valid_ref[0], valid_ref[0]])

    def step(t, carry):
        alpha, u = carry
        zeta, beta = alpha[:B], alpha[B:]
        gz = u + mscale * c * ups * zeta + (theta - 1.0)
        gb = -u + mscale * c * beta + (theta + 1.0)
        g = jnp.concatenate([gz, gb])
        viol = jnp.where(alpha > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
        viol = jnp.where(valid2 > 0.0, viol, 0.0)
        i = jnp.argmax(viol)
        sel = (jnp.arange(2 * B) == i).astype(alpha.dtype)        # one-hot 2B
        a_i = jnp.sum(alpha * sel)
        g_i = jnp.sum(g * sel)
        h_i = jnp.sum(h * sel)
        v_i = jnp.sum(valid2 * sel)
        new_i = jnp.maximum(a_i - g_i / h_i, 0.0)
        delta = (new_i - a_i) * v_i
        alpha = alpha + delta * sel
        row_oh = sel[:B] - sel[B:]        # +1 for zeta coord, -1 for beta
        u = u + delta * (qblk @ row_oh)
        return alpha, u

    alpha, u = jax.lax.fori_loop(0, n_steps,
                                 step, (alpha_ref[0], u_ref[0]))
    alpha_out[0] = alpha
    u_out[0] = u


@functools.partial(jax.jit, static_argnames=("c", "ups", "theta", "mscale",
                                             "n_steps", "interpret"))
def cd_block_sweep(q_blocks: Array, alphas: Array, us: Array, *, c: float,
                   ups: float, theta: float, mscale: float, n_steps: int,
                   valids: Array | None = None,
                   interpret: bool = False) -> tuple[Array, Array]:
    """Run n_steps greedy-CD updates inside every diagonal tile.

    q_blocks (nblk, B, B), alphas (nblk, 2B), us (nblk, B) ->
    (alphas', us'). ``valids`` (nblk, B) marks real coordinates (1.0) vs
    padding (0.0); padded coordinates are frozen at zero. Defaults to all
    valid.
    """
    nblk, B, _ = q_blocks.shape
    if valids is None:
        valids = jnp.ones((nblk, B), q_blocks.dtype)
    kernel = functools.partial(_cd_tile_kernel, c=c, ups=ups, theta=theta,
                               mscale=mscale, n_steps=n_steps)
    return pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 2 * B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2 * B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(alphas.shape, alphas.dtype),
            jax.ShapeDtypeStruct(us.shape, us.dtype),
        ],
        interpret=interpret,
    )(q_blocks, alphas, us, valids)


def extract_diag_blocks(Q: Array, block: int) -> Array:
    """(M, M) -> (M/block, block, block) diagonal blocks."""
    M = Q.shape[0]
    nblk = M // block
    idx = jnp.arange(nblk)
    return jax.vmap(lambda b: jax.lax.dynamic_slice(
        Q, (b * block, b * block), (block, block)))(idx)


def solve_level(q_blocks: Array, matvec, alphas0: Array, *, c: float,
                ups: float, theta: float, mscale: float,
                steps_per_pass: int | None = None, n_passes: int = 30,
                tol: float = 1e-5, valid: Array | None = None,
                us0: Array | None = None,
                interpret: bool = False) -> tuple[Array, Array, Array]:
    """Block-CD solve of K same-size partitions, one ``pallas_call`` per pass.

    This is SODM's per-level engine: all K local ODM duals of one level are
    advanced together — the tile kernel runs over a flat (K * nblk,) grid so
    a whole level is a single kernel launch per pass, and the u refresh is
    one batched matmul (or on-the-fly Gram matvec) supplied by ``matvec``.

    Args:
      q_blocks: (K, nblk, B, B) diagonal Gram blocks of each partition.
      matvec:   callable (K, m) -> (K, m) computing per-partition Q_k @ g_k.
                Supplied by the caller so the off-diagonal mass can live in a
                materialized Q or be generated on the fly (rbf_gram kernel).
      alphas0:  (K, 2m) warm starts — Algorithm 1 line 12 passes the merged
                child solutions here; zeros give a cold start.
      valid:    (m,) mask of real vs padded coordinates, shared by all
                partitions (they are equal-sized). Padded coordinates stay
                frozen at zero and are excluded from the KKT residual, so
                padding never delays convergence or fakes violations.
      us0:      optional (K, m) precomputed matvec(zeta0 - beta0) — u is
                linear in alpha, so callers that already paid the matvec
                (e.g. for a warm-start rescale) pass the scaled cache here
                and skip the init matvec.

    The outer while_loop is shared across partitions (Jacobi): it stops when
    the *worst* partition's projected-KKT residual drops below tol. The KKT
    of the warm start is evaluated before the first pass so an
    already-optimal init returns 0 passes (Algorithm 1 line 5's early-stop
    convergence check reads this).

    Returns (alphas (K, 2m), kkts (K,), passes ()).
    """
    K, nblk, B, _ = q_blocks.shape
    m = nblk * B
    qb = q_blocks.reshape(K * nblk, B, B)
    n_steps = 2 * B if steps_per_pass is None else steps_per_pass
    if valid is None:
        valid = jnp.ones((m,), q_blocks.dtype)
    valid = valid.astype(q_blocks.dtype)
    valids = jnp.tile(valid.reshape(nblk, B), (K, 1))      # (K*nblk, B)
    valid2 = jnp.concatenate([valid, valid])[None, :]      # (1, 2m)

    def kkt(alphas, us):
        zetas, betas = alphas[:, :m], alphas[:, m:]
        gz = us + mscale * c * ups * zetas + (theta - 1.0)
        gb = -us + mscale * c * betas + (theta + 1.0)
        g = jnp.concatenate([gz, gb], axis=1)
        viol = jnp.where(alphas > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
        return jnp.max(jnp.where(valid2 > 0.0, viol, 0.0), axis=1)   # (K,)

    def body(carry):
        alphas, us, _, it = carry
        zetas, betas = alphas[:, :m], alphas[:, m:]
        a_t = jnp.concatenate([zetas.reshape(K, nblk, B),
                               betas.reshape(K, nblk, B)],
                              axis=2).reshape(K * nblk, 2 * B)
        a_t, _ = cd_block_sweep(qb, a_t, us.reshape(K * nblk, B), c=c,
                                ups=ups, theta=theta, mscale=mscale,
                                n_steps=n_steps, valids=valids,
                                interpret=interpret)
        a_t = a_t.reshape(K, nblk, 2 * B)
        z_new = a_t[:, :, :B].reshape(K, m)
        b_new = a_t[:, :, B:].reshape(K, m)
        # exact line search along each partition's joint Jacobi step:
        # f(alpha + t·d) is quadratic in t and u moves linearly, so the
        # optimal damping is closed-form and reuses this pass's one
        # matvec. t = 1 when tiles don't conflict; t < 1 tames
        # off-diagonal mass that would otherwise make simultaneous tile
        # updates diverge (weakly regularized / Q-dominant duals).
        dz, db = z_new - zetas, b_new - betas
        u_d = matvec(dz - db)
        gz = us + mscale * c * ups * zetas + (theta - 1.0)
        gb = -us + mscale * c * betas + (theta + 1.0)
        gdot = jnp.sum(gz * dz + gb * db, axis=1)
        quad = jnp.sum((dz - db) * u_d, axis=1) + mscale * c * jnp.sum(
            ups * dz * dz + db * db, axis=1)
        t = jnp.where(quad > 0.0,
                      jnp.clip(-gdot / jnp.maximum(quad, 1e-30), 0.0, 1.0),
                      1.0)[:, None]
        zetas, betas = zetas + t * dz, betas + t * db
        alphas = jnp.concatenate([zetas, betas], axis=1)
        us = us + t * u_d
        return alphas, us, kkt(alphas, us), it + 1

    def cond(carry):
        _, _, r, it = carry
        return jnp.logical_and(it < n_passes, jnp.max(r) > tol)

    if us0 is None:
        zetas0, betas0 = alphas0[:, :m], alphas0[:, m:]
        us0 = matvec(zetas0 - betas0)
    init = (alphas0, us0, kkt(alphas0, us0), jnp.int32(0))
    alphas, _, r, it = jax.lax.while_loop(cond, body, init)
    return alphas, r, it


def solve(Q: Array, *, c: float, ups: float, theta: float, mscale: float,
          block: int = 256, steps_per_pass: int | None = None,
          n_passes: int = 30, tol: float = 1e-5, alpha0: Array | None = None,
          valid: Array | None = None,
          interpret: bool = False) -> tuple[Array, Array, Array]:
    """Full block-CD solve driven by the Pallas tile kernel.

    Outer loop (lax.while_loop): refresh u = Q gamma (MXU matmul), run the
    tile kernel on all diagonal blocks, check the global projected-KKT
    residual. ``alpha0`` is the warm start (defaults to zeros); a
    warm start already within tol returns 0 passes. ``valid`` marks real
    vs padded coordinates (see :func:`solve_level`). Returns
    (alpha, kkt, passes).
    """
    M = Q.shape[0]
    assert M % block == 0, (M, block)
    qb = extract_diag_blocks(Q, block)[None]               # (1, nblk, B, B)
    a0 = jnp.zeros(2 * M, Q.dtype) if alpha0 is None else alpha0
    alphas, r, it = solve_level(
        qb, lambda g: g @ Q, a0[None], c=c, ups=ups, theta=theta,
        mscale=mscale, steps_per_pass=steps_per_pass, n_passes=n_passes,
        tol=tol, valid=valid, interpret=interpret)
    return alphas[0], r[0], it
