"""Pallas TPU kernel: block-Gauss-Seidel dual coordinate descent tile solve.

TPU adaptation of the paper's scalar dual CD (Eqn. 3). Scalar cyclic CD is
latency-bound on TPU (one f32 op per cycle vs a 8x128 VPU), so inside each
VMEM-resident diagonal tile we run *Gauss-Southwell* (greedy) CD: every
step computes the full projected-gradient vector for the tile's 2B
coordinates (vectorized), picks the worst violator (argmax), and applies
the exact univariate update via a one-hot masked rank-1 update of the
cache u. Each step is O(B) VPU work + one (B,B)x(B,) product — fully
vectorized, no scalar HBM round-trips. A tile *early-exits* its sweep once
its in-tile projected-KKT residual drops below the solver tolerance
(adaptive steps_per_pass), so greedy CD stops wasting steps on converged
tiles; convergence itself is still decided by the exact full-problem KKT
residual in the outer pass loop, never by the in-tile exit.

Fused pass (:func:`fused_cd_pass`): one ``pallas_call`` advances a whole
SODM level — every diagonal tile's greedy sweep AND the cross-tile Gram
matvec u_d = Q (dz - db) needed by the Jacobi line search. The grid is
(K, nblk_i, nblk_j[, n_d]): for each CD tile i (outer), the sweep runs
once (at j = 0) and its step d_i is held in VMEM scratch while the j sweep
streams Gram tiles — materialized (B, B) blocks of Q (DenseSource) or
on-the-fly tiles built from the raw features with the shared accumulation
skeleton in :mod:`repro.kernels.gram` (KernelSource) — and accumulates
K(j, i) @ d_i straight into the resident (1, mp) u_d output block. The
Gram tile never leaves VMEM and the separate per-pass XLA matmul (or
second matvec kernel launch) of the unfused path disappears: HBM traffic
per pass drops from (kernel read + matmul read) to one streamed read.

Memory: only the (B, B) *diagonal* Gram blocks and O(m)-sized vectors
(alpha, u, u_d, labels) are resident — O(nblk·B²) = O(m·B) bytes instead
of the full O(m²) Gram on the matrix-free path. VMEM per grid step:
B² (diag tile) + B² (gram acc) + 2·B·bd (feature slabs) + ~6m/nblk·B
floats, plus the (1, mp) u_d/label blocks (4·m bytes fp32 — 4 MB at
m = 10⁶, documented ceiling of the fused layout).

:func:`solve_level` drives the pass loop with warm-start support
(Algorithm 1 line 12) and masked padding for non-tile-multiple partitions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import gram as gram_mod

Array = jax.Array


def _greedy_tile_sweep(qblk: Array, alpha: Array, u: Array, valid2: Array,
                       *, c: float, ups: float, theta: float, mscale: float,
                       n_steps: int, exit_tol: float) -> tuple[Array, Array]:
    """Greedy (Gauss-Southwell) CD on one diagonal tile, with early exit.

    qblk (B, B) diagonal Gram block; alpha (2B,) [zeta; beta]; u (B,) cache
    restricted to the tile's rows (external contribution frozen — Jacobi);
    valid2 (2B,) marks real coordinates. Runs until ``n_steps`` updates
    have been applied or the in-tile projected-KKT residual (measured at
    the start of a step, so the exit lags one cheap update) drops to
    ``exit_tol``. ``exit_tol = 0.0`` reproduces the fixed-step sweep.
    """
    B = qblk.shape[0]
    q_diag = jnp.diagonal(qblk)
    hz = q_diag + mscale * c * ups
    hb = q_diag + mscale * c
    h = jnp.concatenate([hz, hb])

    def cond(carry):
        _, _, t, vmax = carry
        return jnp.logical_and(t < n_steps, vmax > exit_tol)

    def step(carry):
        alpha, u, t, _ = carry
        zeta, beta = alpha[:B], alpha[B:]
        gz = u + mscale * c * ups * zeta + (theta - 1.0)
        gb = -u + mscale * c * beta + (theta + 1.0)
        g = jnp.concatenate([gz, gb])
        viol = jnp.where(alpha > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
        viol = jnp.where(valid2 > 0.0, viol, 0.0)
        i = jnp.argmax(viol)
        sel = (jnp.arange(2 * B) == i).astype(alpha.dtype)        # one-hot 2B
        a_i = jnp.sum(alpha * sel)
        g_i = jnp.sum(g * sel)
        h_i = jnp.sum(h * sel)
        v_i = jnp.sum(valid2 * sel)
        new_i = jnp.maximum(a_i - g_i / h_i, 0.0)
        delta = (new_i - a_i) * v_i
        alpha = alpha + delta * sel
        row_oh = sel[:B] - sel[B:]        # +1 for zeta coord, -1 for beta
        u = u + delta * (qblk @ row_oh)
        return alpha, u, t + 1, jnp.max(viol)

    big = jnp.asarray(jnp.finfo(alpha.dtype).max, alpha.dtype)
    alpha, u, _, _ = jax.lax.while_loop(
        cond, step, (alpha, u, jnp.int32(0), big))
    return alpha, u


def _cd_tile_kernel(q_ref, alpha_ref, u_ref, valid_ref, alpha_out, u_out, *,
                    c: float, ups: float, theta: float, mscale: float,
                    n_steps: int, exit_tol: float):
    """One (bm=B, bn=B) diagonal tile of the standalone sweep kernel.

    Padded coordinates (valid = 0) are frozen at zero: their violation is
    masked so greedy never selects them and they never perturb u.
    """
    valid2 = jnp.concatenate([valid_ref[0], valid_ref[0]])
    alpha, u = _greedy_tile_sweep(q_ref[0], alpha_ref[0], u_ref[0], valid2,
                                  c=c, ups=ups, theta=theta, mscale=mscale,
                                  n_steps=n_steps, exit_tol=exit_tol)
    alpha_out[0] = alpha
    u_out[0] = u


@functools.partial(jax.jit, static_argnames=("c", "ups", "theta", "mscale",
                                             "n_steps", "exit_tol",
                                             "interpret"))
def cd_block_sweep(q_blocks: Array, alphas: Array, us: Array, *, c: float,
                   ups: float, theta: float, mscale: float, n_steps: int,
                   valids: Array | None = None, exit_tol: float = 0.0,
                   interpret: bool = False) -> tuple[Array, Array]:
    """Run up to n_steps greedy-CD updates inside every diagonal tile.

    q_blocks (nblk, B, B), alphas (nblk, 2B), us (nblk, B) ->
    (alphas', us'). ``valids`` (nblk, B) marks real coordinates (1.0) vs
    padding (0.0); padded coordinates are frozen at zero. Defaults to all
    valid. ``exit_tol > 0`` lets a tile stop its sweep once its in-tile
    KKT residual drops below it (adaptive steps_per_pass).
    """
    nblk, B, _ = q_blocks.shape
    if valids is None:
        valids = jnp.ones((nblk, B), q_blocks.dtype)
    kernel = functools.partial(_cd_tile_kernel, c=c, ups=ups, theta=theta,
                               mscale=mscale, n_steps=n_steps,
                               exit_tol=exit_tol)
    return pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B, B), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 2 * B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 2 * B), lambda b: (b, 0)),
            pl.BlockSpec((1, B), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(alphas.shape, alphas.dtype),
            jax.ShapeDtypeStruct(us.shape, us.dtype),
        ],
        interpret=interpret,
    )(q_blocks, alphas, us, valids)


def extract_diag_blocks(Q: Array, block: int) -> Array:
    """(M, M) -> (M/block, block, block) diagonal blocks."""
    M = Q.shape[0]
    nblk = M // block
    idx = jnp.arange(nblk)
    return jax.vmap(lambda b: jax.lax.dynamic_slice(
        Q, (b * block, b * block), (block, block)))(idx)


# ---------------------------------------------------------------------------
# fused pass: tile sweeps + accumulating Gram matvec, one pallas_call
# ---------------------------------------------------------------------------

def _fused_dense_kernel(qb_ref, a_ref, u_ref, v_ref, q_ref, a_out, ud_out,
                        d_ref, *, c: float, ups: float, theta: float,
                        mscale: float, n_steps: int, exit_tol: float,
                        B: int):
    """Fused pass over a materialized signed Q. Grid (K, nblk_i, nblk_j).

    At j = 0 the CD sweep for tile i runs and its Jacobi step
    d_i = dz_i - db_i is parked in scratch; every j then streams the
    (B, B) block Q[jB:, iB:] and accumulates Q(j, i) @ d_i into the
    partition-resident (1, mp) u_d output block.
    """
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _zero_ud():
        ud_out[...] = jnp.zeros_like(ud_out)

    @pl.when(j == 0)
    def _sweep():
        a_old = a_ref[0, 0]
        valid2 = jnp.concatenate([v_ref[0, 0], v_ref[0, 0]])
        a_new, _ = _greedy_tile_sweep(qb_ref[0, 0], a_old, u_ref[0, 0],
                                      valid2, c=c, ups=ups, theta=theta,
                                      mscale=mscale, n_steps=n_steps,
                                      exit_tol=exit_tol)
        a_out[0, 0] = a_new
        d = (a_new[:B] - a_old[:B]) - (a_new[B:] - a_old[B:])
        d_ref[...] = d.astype(jnp.float32)[:, None]

    contrib = jax.lax.dot_general(                 # Q(j, i) @ d_i: (B, 1)
        q_ref[0].astype(jnp.float32), d_ref[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    sl = pl.ds(j * B, B)
    ud_out[0, sl] = ud_out[0, sl] + contrib[:, 0].astype(ud_out.dtype)


def _fused_mf_kernel(qb_ref, a_ref, u_ref, v_ref, y_ref, xxr_ref, xxc_ref,
                     xr_ref, xc_ref, a_out, ud_out, acc_ref, d_ref, *,
                     kind: str, gamma: float, degree: int, coef0: float,
                     c: float, ups: float, theta: float, mscale: float,
                     n_steps: int, exit_tol: float, n_d: int, B: int):
    """Matrix-free fused pass. Grid (K, nblk_i, nblk_j, n_d).

    Identical control flow to the dense variant, but the Gram tile
    K(j, i) is rebuilt in the acc scratch from feature slabs with the
    shared skeleton (:mod:`repro.kernels.gram`) across the innermost D
    sweep. Labels fold in as Q = y yᵀ ⊙ K: the parked step is
    d_i ⊙ y_i and each row contribution is scaled by y_j, so padded rows
    (label 0) vanish without masking any tile.
    """
    i = pl.program_id(1)
    j = pl.program_id(2)
    kd = pl.program_id(3)

    @pl.when(jnp.logical_and(i == 0, jnp.logical_and(j == 0, kd == 0)))
    def _zero_ud():
        ud_out[...] = jnp.zeros_like(ud_out)

    @pl.when(jnp.logical_and(j == 0, kd == 0))
    def _sweep():
        a_old = a_ref[0, 0]
        valid2 = jnp.concatenate([v_ref[0, 0], v_ref[0, 0]])
        a_new, _ = _greedy_tile_sweep(qb_ref[0, 0], a_old, u_ref[0, 0],
                                      valid2, c=c, ups=ups, theta=theta,
                                      mscale=mscale, n_steps=n_steps,
                                      exit_tol=exit_tol)
        a_out[0, 0] = a_new
        d = (a_new[:B] - a_old[:B]) - (a_new[B:] - a_old[B:])
        yi = y_ref[0, pl.ds(i * B, B)]
        d_ref[...] = (yi * d).astype(jnp.float32)[:, None]

    @pl.when(kd == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = gram_mod.accum_tile(kind, acc_ref[...], xr_ref[0],
                                       xc_ref[0])

    @pl.when(kd == n_d - 1)
    def _contract():
        k = gram_mod.finalize_tile(kind, acc_ref[...], xxr_ref[0, 0, :],
                                   xxc_ref[0, 0, :], gamma=gamma,
                                   degree=degree, coef0=coef0)
        contrib = jax.lax.dot_general(             # K(j, i) @ (y_i ⊙ d_i)
            k, d_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        yj = y_ref[0, pl.ds(j * B, B)]
        sl = pl.ds(j * B, B)
        ud_out[0, sl] = ud_out[0, sl] + (yj * contrib).astype(ud_out.dtype)


def fused_cd_pass(q_blocks: Array, src, alphas: Array, us: Array,
                  valids: Array, *, c: float, ups: float, theta: float,
                  mscale: float, n_steps: int, exit_tol: float,
                  interpret: bool = False) -> tuple[Array, Array]:
    """One fused Jacobi pass for a whole level: ONE ``pallas_call``.

    q_blocks (K, nblk, B, B) diagonal blocks; ``src`` a
    :class:`~repro.kernels.gram.DenseSource` or
    :class:`~repro.kernels.gram.KernelSource` supplying the off-diagonal
    mass; alphas (K, nblk, 2B) per-tile [zeta; beta]; us (K, nblk, B);
    valids (K, nblk, B). Returns (alphas' (K, nblk, 2B),
    u_d (K, m) = Q (dz - db)) — everything the caller's exact line search
    needs, with no separate matvec.
    """
    K, nblk, B, _ = q_blocks.shape
    m = nblk * B
    cd = dict(c=c, ups=ups, theta=theta, mscale=mscale, n_steps=n_steps,
              exit_tol=exit_tol)
    out_shape = [
        jax.ShapeDtypeStruct(alphas.shape, alphas.dtype),
        jax.ShapeDtypeStruct((K, m), us.dtype),
    ]
    cd_specs = [
        pl.BlockSpec((1, 1, B, B), lambda k, i, j, *d: (k, i, 0, 0)),  # qb
        pl.BlockSpec((1, 1, 2 * B), lambda k, i, j, *d: (k, i, 0)),    # a
        pl.BlockSpec((1, 1, B), lambda k, i, j, *d: (k, i, 0)),        # u
        pl.BlockSpec((1, 1, B), lambda k, i, j, *d: (k, i, 0)),        # v
    ]
    out_specs = [
        pl.BlockSpec((1, 1, 2 * B), lambda k, i, j, *d: (k, i, 0)),    # a'
        pl.BlockSpec((1, m), lambda k, i, j, *d: (k, 0)),              # u_d
    ]
    if isinstance(src, gram_mod.DenseSource):
        kernel = functools.partial(_fused_dense_kernel, B=B, **cd)
        return pl.pallas_call(
            kernel,
            grid=(K, nblk, nblk),
            in_specs=cd_specs + [
                pl.BlockSpec((1, B, B), lambda k, i, j: (k, j, i)),    # Q
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=[gram_mod._scratch((B, 1))],
            interpret=interpret,
        )(q_blocks, alphas, us, valids, src.q)

    bd = src.bd
    n_d = src.x.shape[-1] // bd
    xx = gram_mod.row_norms(src.x)[:, None, :].astype(src.x.dtype)  # (K,1,m)
    kernel = functools.partial(_fused_mf_kernel, kind=src.kind,
                               gamma=src.gamma, degree=src.degree,
                               coef0=src.coef0, n_d=n_d, B=B, **cd)
    return pl.pallas_call(
        kernel,
        grid=(K, nblk, nblk, n_d),
        in_specs=cd_specs + [
            pl.BlockSpec((1, m), lambda k, i, j, d: (k, 0)),           # y
            pl.BlockSpec((1, 1, B), lambda k, i, j, d: (k, 0, j)),     # xx_j
            pl.BlockSpec((1, 1, B), lambda k, i, j, d: (k, 0, i)),     # xx_i
            pl.BlockSpec((1, B, bd), lambda k, i, j, d: (k, j, d)),    # x_j
            pl.BlockSpec((1, B, bd), lambda k, i, j, d: (k, i, d)),    # x_i
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[gram_mod._scratch((B, B)), gram_mod._scratch((B, 1))],
        interpret=interpret,
    )(q_blocks, alphas, us, valids, src.y, xx, xx, src.x, src.x)


# ---------------------------------------------------------------------------
# level solve: fused pass loop + exact line search + exact KKT stop
# ---------------------------------------------------------------------------

def solve_level(q_blocks: Array, src, alphas0: Array, *, c: float,
                ups: float, theta: float, mscale: float,
                steps_per_pass: int | None = None, n_passes: int = 30,
                tol: float = 1e-5, valid: Array | None = None,
                us0: Array | None = None, adaptive: bool = True,
                fused: bool | None = None,
                interpret: bool = False) -> tuple[Array, Array, Array]:
    """Block-CD solve of K same-size partitions, one ``pallas_call`` per pass.

    This is SODM's per-level engine: all K local ODM duals of one level are
    advanced together by :func:`fused_cd_pass` — tile sweeps AND the
    cross-tile Gram matvec in a single kernel launch per pass, for any
    supported gram source.

    Args:
      q_blocks: (K, nblk, B, B) diagonal Gram blocks of each partition.
      src:      gram source for the off-diagonal mass —
                :class:`~repro.kernels.gram.DenseSource` (materialized Q)
                or :class:`~repro.kernels.gram.KernelSource` (on-the-fly
                tiles, O(m·B) memory).
      alphas0:  (K, 2m) warm starts — Algorithm 1 line 12 passes the merged
                child solutions here; zeros give a cold start.
      valid:    (m,) mask of real vs padded coordinates, shared by all
                partitions (they are equal-sized). Padded coordinates stay
                frozen at zero and are excluded from the KKT residual, so
                padding never delays convergence or fakes violations.
      us0:      optional (K, m) precomputed matvec(zeta0 - beta0) — u is
                linear in alpha, so callers that already paid the matvec
                (e.g. for a warm-start rescale) pass the scaled cache here
                and skip the init matvec.
      adaptive: early-exit each tile's greedy sweep once its in-tile KKT
                residual drops below 0.01·tol (never changes the
                convergence criterion — the outer stop is always the
                exact full-problem KKT residual).
      fused:    run each pass as ONE :func:`fused_cd_pass` launch (sweeps
                + in-kernel Gram matvec). Default (None) picks fused when
                compiled and the mathematically identical two-launch
                layout (sweep kernel + ``src.matvec``) under interpret
                mode: the interpreter unrolls the grid into the trace, so
                the fused nblk² grid would bloat CPU compile time
                quadratically while the win it buys (one kernel launch,
                halved HBM round-trips) only exists on real hardware.

    The outer while_loop is shared across partitions (Jacobi): it stops when
    the *worst* partition's projected-KKT residual drops below tol. Each
    pass is safeguarded by an exact line search along the joint Jacobi step
    (f(alpha + t·d) is quadratic in t and u moves linearly, so the optimal
    damping is closed-form and reuses the fused pass's matvec): t = 1 when
    tiles don't conflict; t < 1 tames off-diagonal mass that would
    otherwise make simultaneous tile updates diverge (weakly regularized /
    Q-dominant duals). The KKT of the warm start is evaluated before the
    first pass so an already-optimal init returns 0 passes (Algorithm 1
    line 5's early-stop convergence check reads this).

    Returns (alphas (K, 2m), kkts (K,), passes ()).
    """
    K, nblk, B, _ = q_blocks.shape
    m = nblk * B
    n_steps = 2 * B if steps_per_pass is None else steps_per_pass
    if fused is None:
        fused = not interpret
    # the in-tile exit is two decades tighter than the outer stop so an
    # exited tile is converged *relative to* the full-problem check — the
    # adaptive path then never pays extra outer passes for the steps the
    # fixed sweep would have spent polishing an already-converged tile
    exit_tol = 0.01 * tol if adaptive else 0.0
    if valid is None:
        valid = jnp.ones((m,), q_blocks.dtype)
    valid = valid.astype(q_blocks.dtype)
    valids = jnp.broadcast_to(valid.reshape(1, nblk, B), (K, nblk, B))
    valid2 = jnp.concatenate([valid, valid])[None, :]      # (1, 2m)

    def kkt(alphas, us):
        zetas, betas = alphas[:, :m], alphas[:, m:]
        gz = us + mscale * c * ups * zetas + (theta - 1.0)
        gb = -us + mscale * c * betas + (theta + 1.0)
        g = jnp.concatenate([gz, gb], axis=1)
        viol = jnp.where(alphas > 0.0, jnp.abs(g), jnp.maximum(-g, 0.0))
        return jnp.max(jnp.where(valid2 > 0.0, viol, 0.0), axis=1)   # (K,)

    def body(carry):
        alphas, us, _, it = carry
        zetas, betas = alphas[:, :m], alphas[:, m:]
        a_t = jnp.concatenate([zetas.reshape(K, nblk, B),
                               betas.reshape(K, nblk, B)], axis=2)
        if fused:
            a_t, u_d = fused_cd_pass(q_blocks, src, a_t,
                                     us.reshape(K, nblk, B), valids, c=c,
                                     ups=ups, theta=theta, mscale=mscale,
                                     n_steps=n_steps, exit_tol=exit_tol,
                                     interpret=interpret)
            z_new = a_t[:, :, :B].reshape(K, m)
            b_new = a_t[:, :, B:].reshape(K, m)
            dz, db = z_new - zetas, b_new - betas
        else:
            # two-launch layout: same sweep helper, same math — the Gram
            # matvec just rides a second launch (src.matvec) instead of
            # accumulating inside the sweep kernel
            a2, _ = cd_block_sweep(
                q_blocks.reshape(K * nblk, B, B),
                a_t.reshape(K * nblk, 2 * B),
                us.reshape(K * nblk, B), c=c, ups=ups, theta=theta,
                mscale=mscale, n_steps=n_steps, exit_tol=exit_tol,
                valids=valids.reshape(K * nblk, B), interpret=interpret)
            a2 = a2.reshape(K, nblk, 2 * B)
            z_new = a2[:, :, :B].reshape(K, m)
            b_new = a2[:, :, B:].reshape(K, m)
            dz, db = z_new - zetas, b_new - betas
            u_d = src.matvec(dz - db)
        # exact line search along each partition's joint Jacobi step; the
        # matvec u_d = Q (dz - db) it needs came out of the fused pass
        gz = us + mscale * c * ups * zetas + (theta - 1.0)
        gb = -us + mscale * c * betas + (theta + 1.0)
        gdot = jnp.sum(gz * dz + gb * db, axis=1)
        quad = jnp.sum((dz - db) * u_d, axis=1) + mscale * c * jnp.sum(
            ups * dz * dz + db * db, axis=1)
        t = jnp.where(quad > 0.0,
                      jnp.clip(-gdot / jnp.maximum(quad, 1e-30), 0.0, 1.0),
                      1.0)[:, None]
        zetas, betas = zetas + t * dz, betas + t * db
        alphas = jnp.concatenate([zetas, betas], axis=1)
        us = us + t * u_d
        return alphas, us, kkt(alphas, us), it + 1

    def cond(carry):
        _, _, r, it = carry
        return jnp.logical_and(it < n_passes, jnp.max(r) > tol)

    if us0 is None:
        zetas0, betas0 = alphas0[:, :m], alphas0[:, m:]
        us0 = src.matvec(zetas0 - betas0)
    init = (alphas0, us0, kkt(alphas0, us0), jnp.int32(0))
    alphas, _, r, it = jax.lax.while_loop(cond, body, init)
    return alphas, r, it


def solve(Q: Array, *, c: float, ups: float, theta: float, mscale: float,
          block: int = 256, steps_per_pass: int | None = None,
          n_passes: int = 30, tol: float = 1e-5, alpha0: Array | None = None,
          valid: Array | None = None, adaptive: bool = True,
          fused: bool | None = None,
          interpret: bool = False) -> tuple[Array, Array, Array]:
    """Full block-CD solve driven by the fused Pallas pass kernel.

    Outer loop (lax.while_loop): one fused pass (tile sweeps + Gram
    matvec), exact line search, global projected-KKT check. ``alpha0`` is
    the warm start (defaults to zeros); a warm start already within tol
    returns 0 passes. ``valid`` marks real vs padded coordinates (see
    :func:`solve_level`). Returns (alpha, kkt, passes).
    """
    M = Q.shape[0]
    assert M % block == 0, (M, block)
    qb = extract_diag_blocks(Q, block)[None]               # (1, nblk, B, B)
    a0 = jnp.zeros(2 * M, Q.dtype) if alpha0 is None else alpha0
    alphas, r, it = solve_level(
        qb, gram_mod.DenseSource(Q[None]), a0[None], c=c, ups=ups,
        theta=theta, mscale=mscale, steps_per_pass=steps_per_pass,
        n_passes=n_passes, tol=tol, valid=valid, adaptive=adaptive,
        fused=fused, interpret=interpret)
    return alphas[0], r[0], it
