"""Tiled matrix-free decision-function (serving) kernel.

Inference for a kernel expansion is  f(x_t) = sum_s coef_s kappa(x_s, x_t)
— a Gram-times-vector product against the packed support-vector slab. The
seed-era path materialized the dense (T, S) test Gram for every predict
call; this kernel reuses the :mod:`repro.kernels.gram` accumulation
skeleton (:func:`accum_tile` / :func:`finalize_tile`: MXU cross term for
the L2 family, chunked VPU L1 reduction for laplacian) to contract each
(bt, bs) kernel tile against its coef tile *inside VMEM*, so one request
batch is ONE ``pallas_call`` and peak memory is O(B·S_block) — the (T, S)
Gram never exists, however many support vectors the model keeps.

Three entry points:

* :func:`score_tiles`   — the Pallas kernel (tile-aligned shapes; the
  ops.py wrapper pads arbitrary shapes).
* :func:`score_ref`     — dense pure-jnp oracle (materializes (T, S));
  the parity target of the kernel tests, exactly like ``odm_grad``'s
  reference.
* :func:`score_blocked` — jnp row-block streaming fallback used under
  interpret mode (CPU hosts), where unrolling the (T/bt)·(S/bs) grid into
  the trace would bloat compile time: a ``lax.map`` over (bt, d) request
  chunks keeps the same O(bt·S) memory bound at XLA speed.

Grid (T/bt, S/bs, D/bd), D innermost so the fp32 cross-term accumulator
scratch lives across the feature sweep; the (bt, 1) score accumulator
lives across the S sweep. VMEM per step (fp32, defaults bt=bs=256,
bd=512): operands bt·bd + bs·bd = 1 MB, acc bt·bs = 0.25 MB, scores bt —
same budget as the gram matvec kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gram import (accum_tile, finalize_tile, row_norms,
                                _scratch, L1_KERNELS)

Array = jax.Array


def _score_kernel(xx_ref, zz_ref, c_ref, x_ref, z_ref, out_ref, acc_ref,
                  u_ref, *, kind: str, gamma: float, degree: int,
                  coef0: float, n_j: int, n_d: int):
    """One (bt,) slice of f = K(x, z) @ coef, accumulated over (j, d) tiles.

    x (bt, bd) request rows, z (bs, bd) SV rows, c (1, bs) coef tile.
    acc (bt, bs) fp32 Gram-tile scratch (across the D sweep), u (bt, 1)
    fp32 score scratch (across the S sweep). The kernel tile is contracted
    against the coef tile the moment it is finished — it never leaves VMEM.
    """
    kj = pl.program_id(1)
    kd = pl.program_id(2)

    @pl.when(jnp.logical_and(kj == 0, kd == 0))
    def _init_u():
        u_ref[...] = jnp.zeros_like(u_ref)

    @pl.when(kd == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] = accum_tile(kind, acc_ref[...], x_ref[...], z_ref[...])

    @pl.when(kd == n_d - 1)
    def _contract():
        k = finalize_tile(kind, acc_ref[...], xx_ref[0, :], zz_ref[0, :],
                          gamma=gamma, degree=degree, coef0=coef0)
        c = c_ref[0, :]                        # (bs,)
        u_ref[...] += jax.lax.dot_general(     # (bt, bs) @ (bs, 1)
            k, c[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(kj == n_j - 1, kd == n_d - 1))
    def _finalize():
        out_ref[...] = u_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "kind", "gamma", "degree", "coef0", "bt", "bs", "bd", "interpret"))
def score_tiles(x: Array, z: Array, coef: Array, *, kind: str = "rbf",
                gamma: float = 1.0, degree: int = 3, coef0: float = 1.0,
                bt: int = 256, bs: int = 256, bd: int = 512,
                interpret: bool = False) -> Array:
    """f (T,) = K(x, z) @ coef in ONE pallas_call; shapes must tile evenly
    (the ops.py wrapper pads — padded coef entries are zero so padded SV
    rows contribute nothing, padded request rows are sliced off)."""
    T, D = x.shape
    S = z.shape[0]
    assert T % bt == 0 and S % bs == 0 and D % bd == 0, (T, S, D, bt, bs, bd)
    n_j, n_d = S // bs, D // bd
    grid = (T // bt, n_j, n_d)
    xx = row_norms(x)[None, :]                                  # (1, T)
    zz = row_norms(z)[None, :]                                  # (1, S)

    kernel = functools.partial(_score_kernel, kind=kind, gamma=gamma,
                               degree=degree, coef0=coef0, n_j=n_j, n_d=n_d)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt), lambda i, j, d: (0, i)),      # xx
            pl.BlockSpec((1, bs), lambda i, j, d: (0, j)),      # zz
            pl.BlockSpec((1, bs), lambda i, j, d: (0, j)),      # coef
            pl.BlockSpec((bt, bd), lambda i, j, d: (i, d)),     # x
            pl.BlockSpec((bs, bd), lambda i, j, d: (j, d)),     # z
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda i, j, d: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 1), x.dtype),
        scratch_shapes=[_scratch((bt, bs)), _scratch((bt, 1))],
        interpret=interpret,
    )(xx, zz, coef[None, :], x, z)
    return out[:, 0]


# ---------------------------------------------------------------------------
# pure-jnp oracle + streaming fallback
# ---------------------------------------------------------------------------

def _dense_gram(x: Array, z: Array, *, kind: str, gamma: float, degree: int,
                coef0: float) -> Array:
    """Dense (T, S) kernel block via the same accumulate/finalize math."""
    if kind in L1_KERNELS:
        acc = jnp.sum(jnp.abs(x[:, None, :] - z[None, :, :]), axis=-1)
    else:
        acc = x.astype(jnp.float32) @ z.astype(jnp.float32).T
    return finalize_tile(kind, acc, row_norms(x), row_norms(z),
                         gamma=gamma, degree=degree, coef0=coef0)


def score_ref(x: Array, z: Array, coef: Array, *, kind: str = "rbf",
              gamma: float = 1.0, degree: int = 3,
              coef0: float = 1.0) -> Array:
    """Dense oracle: materializes the (T, S) block. Parity target only —
    production paths go through :func:`score_tiles` / :func:`score_blocked`."""
    k = _dense_gram(x, z, kind=kind, gamma=gamma, degree=degree, coef0=coef0)
    return (k @ coef.astype(jnp.float32)).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=(
    "kind", "gamma", "degree", "coef0", "bt"))
def score_blocked(x: Array, z: Array, coef: Array, *, kind: str = "rbf",
                  gamma: float = 1.0, degree: int = 3, coef0: float = 1.0,
                  bt: int = 256) -> Array:
    """Streaming jnp scorer: lax.map over (bt, d) request chunks.

    Numerically identical to :func:`score_ref` but peak memory is
    O(bt · S) — one kernel block per chunk, never the full (T, S). The
    interpret-mode (CPU) production path; T must be a bt multiple (the
    ops.py wrapper pads).
    """
    T, D = x.shape
    assert T % bt == 0, (T, bt)
    chunks = x.reshape(T // bt, bt, D)

    def one(xc):
        k = _dense_gram(xc, z, kind=kind, gamma=gamma, degree=degree,
                        coef0=coef0)
        return (k @ coef.astype(jnp.float32)).astype(x.dtype)

    return jax.lax.map(one, chunks).reshape(T)
