"""Pallas TPU kernels for the perf-critical hot spots.

  gram          — matrix-free multi-kernel Gram subsystem: tiled (signed)
                  Gram + batched matvec for every KernelSpec family
                  (rbf / laplacian / poly / linear), one shared
                  accumulation skeleton (SODM nonlinear-kernel hot spot)
  rbf_gram      — compatibility shim pinning gram to kind="rbf"
  dual_cd_block — VMEM-tile Gauss-Southwell dual CD (TPU adaptation of
                  Eqn. 3) + the fused pass kernel (tile sweeps and the
                  cross-tile Gram matvec in one pallas_call per pass)
  odm_grad      — fused single-pass linear primal ODM gradient (DSVRG)
  flash_attn    — causal/sliding-window GQA flash attention (LM substrate)

Use :mod:`repro.kernels.ops` from framework code (padding + fallbacks);
:mod:`repro.kernels.ref` holds the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
