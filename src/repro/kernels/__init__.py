"""Pallas TPU kernels for the perf-critical hot spots.

  rbf_gram      — tiled (signed) RBF Gram (SODM nonlinear-kernel hot spot)
  dual_cd_block — VMEM-tile Gauss-Southwell dual CD (TPU adaptation of Eqn. 3)
  odm_grad      — fused single-pass linear primal ODM gradient (DSVRG)
  flash_attn    — causal/sliding-window GQA flash attention (LM substrate)

Use :mod:`repro.kernels.ops` from framework code (padding + fallbacks);
:mod:`repro.kernels.ref` holds the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
