"""Layer stacks: pattern-aware scan super-blocks for all 10 architectures.

Every architecture reduces to a *pattern* ``(unit, reps, tail)`` from
ArchConfig.layer_pattern():

  dense / moe / vlm      unit=("attn",)                     reps=n_layers
  llama4-scout (iRoPE)   unit=("attn_window",)*3+("attn_global",)  reps=12
  falcon-mamba           unit=("ssm",)                      reps=64
  recurrentgemma         unit=("rec","rec","attn")          reps=12, tail=(rec,rec)

Parameters of each unit position are stacked across reps on a leading
"repeats" axis and consumed by one ``lax.scan`` (MaxText-style: compile
time is O(|unit|), not O(n_layers)). The remainder ``tail`` is unrolled.
Remat wraps the scan body per cfg.remat.

The same machinery runs three modes:
  train/``forward``  — full sequence, no caches;
  ``prefill``        — full sequence, returns per-layer caches (stacked);
  ``decode``         — one token against the stacked caches.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import attention, layers as L, mamba, moe as moe_mod, rglru

Array = jax.Array


# ---------------------------------------------------------------------------
# single-layer init / apply
# ---------------------------------------------------------------------------

def init_layer(key, kind: str, cfg: ArchConfig, dtype, cross: bool = False):
    """One layer's params+axes for the given kind."""
    ks = jax.random.split(key, 4)
    p: dict = {}
    a: dict = {}
    p["ln1"], a["ln1"] = L.norm_init(cfg.d_model, cfg.norm_kind, dtype)
    if kind in ("attn", "attn_window", "attn_global"):
        p["attn"], a["attn"] = attention.init(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["ssm"], a["ssm"] = mamba.init(ks[0], cfg, dtype)
        return p, a                     # mamba block: no separate MLP
    elif kind == "rec":
        p["rec"], a["rec"] = rglru.init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cross:
        p["lnx"], a["lnx"] = L.norm_init(cfg.d_model, cfg.norm_kind, dtype)
        p["cross"], a["cross"] = attention.init(ks[2], cfg, dtype, cross=True)
    p["ln2"], a["ln2"] = L.norm_init(cfg.d_model, cfg.norm_kind, dtype)
    if cfg.moe is not None and kind.startswith("attn"):
        p["moe"], a["moe"] = moe_mod.init(ks[1], cfg, dtype)
    else:
        p["mlp"], a["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.act, dtype)
    return p, a


def _kind_attn_opts(kind: str, cfg: ArchConfig):
    """(window, use_rope) per layer kind."""
    if kind == "attn_window":
        return cfg.attn_window, True
    if kind == "attn_global":
        return None, False              # llama4 NoPE global layers
    if kind == "attn" and cfg.rglru is not None:
        return cfg.rglru.window, True   # recurrentgemma local attention
    return None, True


def apply_layer(p, x: Array, kind: str, cfg: ArchConfig, *, pos: Array,
                pos3: Optional[Array] = None, memory: Optional[Array] = None,
                causal: bool = True, impl: str = "flash_xla",
                compute_dtype=jnp.bfloat16):
    """Train/prefill-mode layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(p["ln1"], x, cfg.norm_kind)
    if kind == "ssm":
        x = x + L.precision_boundary(
            mamba.forward(p["ssm"], h, cfg, compute_dtype))
        return x, aux
    if kind == "rec":
        x = x + L.precision_boundary(
            rglru.forward(p["rec"], h, cfg, compute_dtype))
    else:
        window, use_rope = _kind_attn_opts(kind, cfg)
        y = attention.forward(p["attn"], h, cfg, pos=pos, causal=causal,
                              window=window, use_rope=use_rope,
                              pos3=pos3, impl=impl,
                              compute_dtype=compute_dtype)
        from jax.ad_checkpoint import checkpoint_name
        y = checkpoint_name(L.precision_boundary(y), "attn_out")
        x = x + y
    if "cross" in p and memory is not None:
        hx = L.apply_norm(p["lnx"], x, cfg.norm_kind)
        x = x + L.precision_boundary(
            attention.forward(p["cross"], hx, cfg, pos=pos, causal=False,
                              memory=memory, use_rope=False,
                              impl=impl, compute_dtype=compute_dtype))
    h2 = L.apply_norm(p["ln2"], x, cfg.norm_kind)
    if "moe" in p:
        y, aux = moe_mod.forward(p["moe"], h2, cfg, compute_dtype)
        x = x + L.precision_boundary(y)
    else:
        x = x + L.precision_boundary(
            L.apply_mlp(p["mlp"], h2, cfg.act, compute_dtype))
    return x, aux


# ---------------------------------------------------------------------------
# caches (per layer kind)
# ---------------------------------------------------------------------------

def layer_cache_shape(kind: str, cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, cross_len: int = 0):
    """ShapeDtypeStruct cache pytree + logical axes for one layer."""
    if kind == "ssm":
        return mamba.state_shape(cfg, batch, dtype)
    if kind == "rec":
        return rglru.state_shape(cfg, batch, dtype)
    window, _ = _kind_attn_opts(kind, cfg)
    c, a = attention.cache_shape(cfg, batch, max_len, window, dtype)
    if cross_len:
        sds = jax.ShapeDtypeStruct((batch, cross_len, cfg.n_kv_heads, cfg.dh),
                                   dtype)
        c = {**c, "xk": sds, "xv": sds}
        a = {**a, "xk": ("batch", None, "kv_heads", None),
             "xv": ("batch", None, "kv_heads", None)}
    return c, a


def init_layer_cache(kind: str, cfg: ArchConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16, cross_len: int = 0):
    shp, _ = layer_cache_shape(kind, cfg, batch, max_len, dtype, cross_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shp)


def apply_layer_decode(p, cache, x: Array, kind: str, cfg: ArchConfig, *,
                       pos: Array, pos3: Optional[Array] = None,
                       compute_dtype=jnp.bfloat16):
    """One-token decode through a layer. Returns (x, new_cache)."""
    h = L.apply_norm(p["ln1"], x, cfg.norm_kind)
    if kind == "ssm":
        y, cache = mamba.decode_step(p["ssm"], cache, h, cfg, compute_dtype)
        return x + y, cache
    if kind == "rec":
        y, cache = rglru.decode_step(p["rec"], cache, h, cfg, compute_dtype)
        x = x + y
    else:
        window, use_rope = _kind_attn_opts(kind, cfg)
        kv_cache = {"k": cache["k"], "v": cache["v"]}
        y, kv_cache = attention.decode_step(
            p["attn"], kv_cache, h, cfg, pos=pos, window=window,
            use_rope=use_rope, pos3=pos3, compute_dtype=compute_dtype)
        cache = {**cache, **kv_cache}
        x = x + y
    if "cross" in p and "xk" in cache:
        hx = L.apply_norm(p["lnx"], x, cfg.norm_kind)
        y = _cross_decode(p["cross"], cache["xk"], cache["xv"], hx, cfg,
                          compute_dtype)
        x = x + y
    h2 = L.apply_norm(p["ln2"], x, cfg.norm_kind)
    if "moe" in p:
        y, _ = moe_mod.forward(p["moe"], h2, cfg, compute_dtype,
                               full_capacity=True)
        x = x + y
    else:
        x = x + L.apply_mlp(p["mlp"], h2, cfg.act, compute_dtype)
    return x, cache


def _cross_decode(p, xk, xv, x, cfg, compute_dtype):
    """Cross-attention for one decoder token against static encoder kv."""
    B = x.shape[0]
    dh = cfg.dh
    q = L.apply_dense(p["wq"], x, compute_dtype).reshape(B, 1, cfg.n_heads, dh)
    KV = cfg.n_kv_heads
    G = cfg.n_heads // KV
    qg = q.reshape(B, 1, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    logits = jnp.einsum("btkgd,bskd->btkgs", qg, xk.astype(jnp.float32))
    prob = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", prob, xv.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * dh).astype(compute_dtype)
    return L.apply_dense(p["wo"], o, compute_dtype)


# ---------------------------------------------------------------------------
# prefill-mode layer: full sequence + cache production
# ---------------------------------------------------------------------------

def apply_layer_prefill(p, x: Array, kind: str, cfg: ArchConfig, *,
                        pos: Array, max_len: int,
                        pos3: Optional[Array] = None,
                        memory: Optional[Array] = None,
                        impl: str = "flash_xla",
                        compute_dtype=jnp.bfloat16):
    """Full-sequence forward that also emits the layer's decode cache."""
    B, T, D = x.shape
    h = L.apply_norm(p["ln1"], x, cfg.norm_kind)
    if kind == "ssm":
        di = mamba.d_inner(cfg)
        xz = L.apply_dense(p["ssm"]["in_proj"], h, compute_dtype)
        xb, z = jnp.split(xz, 2, axis=-1)
        xc = mamba._causal_conv(xb, p["ssm"]["conv"], compute_dtype)
        xc = jax.nn.silu(xc)
        h0 = jnp.zeros((B, di, cfg.ssm.state), jnp.float32)
        y, h_fin = mamba.scan_sequence(p["ssm"], xc, cfg, h0)
        y = y * jax.nn.silu(z)
        out = L.apply_dense(p["ssm"]["out_proj"], y, compute_dtype)
        K = cfg.ssm.conv
        cache = {"h": h_fin,
                 "conv": _tail_pad(xb, K - 1).astype(jnp.bfloat16)}
        return x + out, cache
    if kind == "rec":
        w = rglru.width(cfg)
        xb = L.apply_dense(p["rec"]["in_x"], h, compute_dtype)
        g = jax.nn.gelu(L.apply_dense(p["rec"]["in_gate"], h, compute_dtype))
        xc = rglru._causal_conv(xb, p["rec"]["conv"], compute_dtype)
        a, b = rglru._lru_coeffs(p["rec"], xc)

        def op(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, ar * bl + br

        _, hs = jax.lax.associative_scan(op, (a, b), axis=1)
        y = hs.astype(compute_dtype) * g
        out = L.apply_dense(p["rec"]["out"], y, compute_dtype)
        K = cfg.rglru.conv
        cache = {"h": hs[:, -1],
                 "conv": _tail_pad(xb, K - 1).astype(jnp.bfloat16)}
        x = x + out
    else:
        window, use_rope = _kind_attn_opts(kind, cfg)
        dh = cfg.dh
        q = L.apply_dense(p["attn"]["wq"], h, compute_dtype).reshape(
            B, T, cfg.n_heads, dh)
        k = L.apply_dense(p["attn"]["wk"], h, compute_dtype).reshape(
            B, T, cfg.n_kv_heads, dh)
        v = L.apply_dense(p["attn"]["wv"], h, compute_dtype).reshape(
            B, T, cfg.n_kv_heads, dh)
        if "qknorm" in p["attn"]:
            q = L.apply_head_rmsnorm(q, p["attn"]["qknorm"]["q_scale"])
            k = L.apply_head_rmsnorm(k, p["attn"]["qknorm"]["k_scale"])
        if use_rope:
            if cfg.rope_kind == "mrope" and pos3 is not None:
                q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
                k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
            elif cfg.rope_kind != "none":
                q = L.apply_rope(q, pos, cfg.rope_theta)
                k = L.apply_rope(k, pos, cfg.rope_theta)
        o = attention.attend(q, k, v, causal=True, window=window, impl=impl)
        o = o.reshape(B, T, cfg.n_heads * dh)
        x = x + L.apply_dense(p["attn"]["wo"], o, compute_dtype)
        cache = _fill_kv_cache(k, v, window, max_len)
    if "cross" in p and memory is not None:
        hx = L.apply_norm(p["lnx"], x, cfg.norm_kind)
        x = x + attention.forward(p["cross"], hx, cfg, pos=pos, causal=False,
                                  memory=memory, use_rope=False, impl=impl,
                                  compute_dtype=compute_dtype)
        xk = L.apply_dense(p["cross"]["wk"], memory, compute_dtype).reshape(
            B, memory.shape[1], cfg.n_kv_heads, cfg.dh)
        xv = L.apply_dense(p["cross"]["wv"], memory, compute_dtype).reshape(
            B, memory.shape[1], cfg.n_kv_heads, cfg.dh)
        cache = {**cache, "xk": xk.astype(jnp.bfloat16),
                 "xv": xv.astype(jnp.bfloat16)}
    h2 = L.apply_norm(p["ln2"], x, cfg.norm_kind)
    if "moe" in p:
        # inference semantics: dropless (consistent with the decode path)
        y, _ = moe_mod.forward(p["moe"], h2, cfg, compute_dtype,
                               full_capacity=True)
        x = x + y
    else:
        x = x + L.apply_mlp(p["mlp"], h2, cfg.act, compute_dtype)
    return x, cache


def _tail_pad(x: Array, n: int) -> Array:
    """Last n positions of (B, T, d) (left-padded with zeros if T < n)."""
    B, T, d = x.shape
    if T >= n:
        return x[:, T - n:]
    return jnp.pad(x, ((0, 0), (n - T, 0), (0, 0)))


def _fill_kv_cache(k: Array, v: Array, window: Optional[int],
                   max_len: int):
    """Static cache from prefill kv. k/v (B, T, KV, dh); T <= max_len.

    Global layers: cache size max_len, prompt occupies [0, T).
    Window layers: ring buffer of W slots; slot t%W holds position t for
    the last min(W, T) positions.
    """
    B, T, KV, dh = k.shape
    if window is None:
        pad = max_len - T
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return {"k": ck.astype(jnp.bfloat16), "v": cv.astype(jnp.bfloat16)}
    W = min(window, max_len)
    keep = min(W, T)
    kt = k[:, T - keep:]
    vt = v[:, T - keep:]
    # absolute positions of kept entries: [T-keep, T); ring slot = pos % W
    slots = (jnp.arange(T - keep, T)) % W
    ck = jnp.zeros((B, W, KV, dh), jnp.bfloat16)
    cv = jnp.zeros((B, W, KV, dh), jnp.bfloat16)
    ck = ck.at[:, slots].set(kt.astype(jnp.bfloat16))
    cv = cv.at[:, slots].set(vt.astype(jnp.bfloat16))
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# the stack: scan super-blocks + unrolled tail
# ---------------------------------------------------------------------------

def init_stack(key, cfg: ArchConfig, dtype, cross: bool = False):
    """Stacked params: {"scan": {pos_i: stacked params}, "tail": [...]}. """
    unit, reps, tail = cfg.layer_pattern()
    p: dict = {"scan": {}, "tail": []}
    a: dict = {"scan": {}, "tail": []}
    for i, kind in enumerate(unit):
        per_rep = []
        axes_one = None
        for r in range(reps):
            kk = jax.random.fold_in(key, i * 1000 + r)
            pp, aa = init_layer(kk, kind, cfg, dtype, cross=cross)
            per_rep.append(pp)
            axes_one = aa
        p["scan"][f"u{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
        a["scan"][f"u{i}"] = jax.tree.map(
            lambda ax: ("repeats",) + ax, axes_one,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    for j, kind in enumerate(tail):
        kk = jax.random.fold_in(key, 999_000 + j)
        pp, aa = init_layer(kk, kind, cfg, dtype, cross=cross)
        p["tail"].append(pp)
        a["tail"].append(aa)
    return p, a


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if cfg.remat == "attn":
        # save ONLY the attention sublayer outputs: skips re-running the
        # flash fwd scan during backward (the per-layer hot spot) at the
        # cost of one activation-sized residual per layer — the sweet spot
        # found in §Perf iteration 3.
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out"))
    return jax.checkpoint(fn)


def apply_stack(p, x: Array, cfg: ArchConfig, *, pos: Array,
                pos3: Optional[Array] = None, memory: Optional[Array] = None,
                causal: bool = True, impl: str = "flash_xla",
                compute_dtype=jnp.bfloat16):
    """Train-mode stack. Returns (x, total_aux)."""
    unit, reps, tail = cfg.layer_pattern()

    def block(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(unit):
            x, a = apply_layer(unit_params[f"u{i}"], x, kind, cfg, pos=pos,
                               pos3=pos3, memory=memory, causal=causal,
                               impl=impl, compute_dtype=compute_dtype)
            aux = aux + a
        return x, aux

    blk = _remat(block, cfg)
    x, auxs = jax.lax.scan(lambda c, w: blk(c, w), x, p["scan"])
    aux = jnp.sum(auxs)
    for j, kind in enumerate(tail):
        x, a = apply_layer(p["tail"][j], x, kind, cfg, pos=pos, pos3=pos3,
                           memory=memory, causal=causal, impl=impl,
                           compute_dtype=compute_dtype)
        aux = aux + a
    return x, aux


def stack_cache_shape(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, cross_len: int = 0):
    """Cache SDS pytree matching the stack structure (scan-stacked)."""
    unit, reps, tail = cfg.layer_pattern()
    c: dict = {"scan": {}, "tail": []}
    a: dict = {"scan": {}, "tail": []}
    for i, kind in enumerate(unit):
        shp, ax = layer_cache_shape(kind, cfg, batch, max_len, dtype,
                                    cross_len)
        c["scan"][f"u{i}"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), shp)
        a["scan"][f"u{i}"] = jax.tree.map(
            lambda t: ("repeats",) + t, ax,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
    for kind in tail:
        shp, ax = layer_cache_shape(kind, cfg, batch, max_len, dtype,
                                    cross_len)
        c["tail"].append(shp)
        a["tail"].append(ax)
    return c, a


def apply_stack_decode(p, cache, x: Array, cfg: ArchConfig, *, pos: Array,
                       pos3: Optional[Array] = None,
                       compute_dtype=jnp.bfloat16):
    """One-token decode through the whole stack. Returns (x, new_cache)."""
    unit, reps, tail = cfg.layer_pattern()

    def block(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = {}
        for i, kind in enumerate(unit):
            x, nc = apply_layer_decode(unit_params[f"u{i}"],
                                       unit_cache[f"u{i}"], x, kind, cfg,
                                       pos=pos, pos3=pos3,
                                       compute_dtype=compute_dtype)
            new_cache[f"u{i}"] = nc
        return x, new_cache

    x, new_scan_cache = jax.lax.scan(block, x, (p["scan"], cache["scan"]))
    out_cache = {"scan": new_scan_cache, "tail": []}
    for j, kind in enumerate(tail):
        x, nc = apply_layer_decode(p["tail"][j], cache["tail"][j], x, kind,
                                   cfg, pos=pos, pos3=pos3,
                                   compute_dtype=compute_dtype)
        out_cache["tail"].append(nc)
    return x, out_cache


def apply_stack_prefill(p, x: Array, cfg: ArchConfig, *, pos: Array,
                        max_len: int, pos3: Optional[Array] = None,
                        memory: Optional[Array] = None,
                        impl: str = "flash_xla",
                        compute_dtype=jnp.bfloat16):
    """Full-sequence prefill producing the stacked cache."""
    unit, reps, tail = cfg.layer_pattern()

    def block(x, unit_params):
        caches = {}
        for i, kind in enumerate(unit):
            x, c = apply_layer_prefill(unit_params[f"u{i}"], x, kind, cfg,
                                       pos=pos, max_len=max_len, pos3=pos3,
                                       memory=memory, impl=impl,
                                       compute_dtype=compute_dtype)
            caches[f"u{i}"] = c
        return x, caches

    blk = _remat(block, cfg)
    x, scan_caches = jax.lax.scan(lambda c, w: blk(c, w), x, p["scan"])
    cache = {"scan": scan_caches, "tail": []}
    for j, kind in enumerate(tail):
        x, c = apply_layer_prefill(p["tail"][j], x, kind, cfg, pos=pos,
                                   max_len=max_len, pos3=pos3, memory=memory,
                                   impl=impl, compute_dtype=compute_dtype)
        cache["tail"].append(c)
    return x, cache
