"""GQA attention: train/prefill (blocked flash) and cached decode.

Three attention impls, all numerically interchangeable:

* ``flash_xla``   — pure-jnp blocked online-softmax (lax.scan over kv
  blocks). Memory O(T·bk) instead of O(T·S); lowers on every backend, so
  the multi-pod dry-run and CPU tests use it. This is the default.
* ``flash_pallas`` — repro.kernels.flash_attn (TPU Mosaic fast path).
* ``ref``          — O(T·S) reference (tiny smoke shapes only).

Decode attends a (B, S, kv, dh) static cache (vLLM-style preallocation).
Sliding-window layers keep a ring buffer of size W instead of S — this is
what makes recurrentgemma / llama4-scout long_500k-capable. Global layers
at 500k shard the cache along the sequence ("kv_seq" logical axis);
the softmax reductions then lower to psums on the model axis.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import layers as L

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init(key, cfg: ArchConfig, dtype, cross: bool = False):
    """QKV/O projections (+ optional qk-norm scales). ``cross`` builds a
    cross-attention block (q from decoder, kv from encoder memory)."""
    dh = cfg.dh
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {}
    a = {}
    p["wq"], a["wq"] = _proj(kq, cfg.d_model, cfg.n_heads * dh,
                             ("embed", "heads"), cfg.qkv_bias, dtype)
    p["wk"], a["wk"] = _proj(kk, cfg.d_model, cfg.n_kv_heads * dh,
                             ("embed", "kv_heads"), cfg.qkv_bias, dtype)
    p["wv"], a["wv"] = _proj(kv, cfg.d_model, cfg.n_kv_heads * dh,
                             ("embed", "kv_heads"), cfg.qkv_bias, dtype)
    p["wo"], a["wo"] = _proj(ko, cfg.n_heads * dh, cfg.d_model,
                             ("heads", "embed"), False, dtype)
    if cfg.qk_norm and not cross:
        p["qknorm"], a["qknorm"] = L.qk_norm_init(dh, dtype)
    return p, a


def _proj(key, din, dout, axes, bias, dtype):
    return L.dense_init(key, din, dout, dtype, axes=axes, bias=bias)


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _mask_for(j: int, bk: int, S: int, qpos: Array, causal: bool,
              window: Optional[int]) -> Array:
    """Additive mask penalty (T, bk): 0 where attendable, NEG_INF where not.

    Returned as a small 2-D additive term (not a broadcast pred + where):
    XLA hoists loop-invariant mask tensors out of the kv scan, and a
    (nblk, T, bk) f32 penalty is ~1000x smaller than the broadcast
    (nblk, B, T, KV, G, bk) predicate the `where` formulation produces.
    """
    kpos = (j * bk + jnp.arange(bk))[None, :]            # (1, bk)
    mask = kpos <= (S - 1)                               # hide padding
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)   # (T, bk)


def _flash_fwd_scan(qg, kb, vb, S, bk, qpos, causal, window):
    """Online-softmax forward. Returns (out_unnorm acc, m, l)."""
    B, T, KV, G, dh = qg.shape

    def body(carry, inp):
        acc, m, l = carry
        kblk, vblk, j = inp
        logits = jnp.einsum("btkgd,bskd->btkgs", qg,
                            kblk.astype(jnp.float32))     # (B,T,KV,G,bk)
        pen = _mask_for(j, bk, S, qpos, causal, window)
        logits = logits + pen[None, :, None, None, :]
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("btkgs,bskd->btkgd", p, vblk.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, T, KV, G, dh), jnp.float32)
    m0 = jnp.full((B, T, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, T, KV, G), jnp.float32)
    nblk = kb.shape[0]
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  (kb, vb, jnp.arange(nblk)))
    return acc, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _blocked_flash_core(q, k, v, causal, window, q_offset, bk):
    """Flash attention with a flash-style backward.

    The custom VJP is what keeps training memory O(T·bk): differentiating
    through the forward scan would store per-block (B,T,KV,G,bk) logits;
    instead the backward re-walks the kv blocks using only the saved
    softmax stats (m, l) and output — the standard FlashAttention-2
    recomputation, expressed in lax.scan.
    """
    out, _ = _blocked_flash_fwd(q, k, v, causal, window, q_offset, bk)
    return out


def _blocked_flash_fwd(q, k, v, causal, window, q_offset, bk):
    B, T, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qg = q.reshape(B, T, KV, G, dh).astype(jnp.float32) * scale
    kb, vb, nblk = _pad_blocks(k, v, bk)
    qpos = (jnp.arange(T) + q_offset)[:, None]
    acc, m, l = _flash_fwd_scan(qg, kb, vb, S, bk, qpos, causal, window)
    lsafe = jnp.maximum(l, 1e-30)
    out = (acc / lsafe[..., None]).reshape(B, T, H, dh).astype(q.dtype)
    return out, (q, k, v, out, m, lsafe)


def _pad_blocks(k, v, bk):
    B, S, KV, dh = k.shape
    nblk = -(-S // bk)
    Sp = nblk * bk
    if Sp != S:
        pad = Sp - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = jnp.moveaxis(k.reshape(B, nblk, bk, KV, dh), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, bk, KV, dh), 1, 0)
    return kb, vb, nblk


def _blocked_flash_bwd(causal, window, q_offset, bk, res, dout):
    q, k, v, out, m, l = res
    B, T, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qg = q.reshape(B, T, KV, G, dh).astype(jnp.float32) * scale
    og = out.reshape(B, T, KV, G, dh).astype(jnp.float32)
    dog = dout.reshape(B, T, KV, G, dh).astype(jnp.float32)
    D = jnp.sum(dog * og, axis=-1)                        # (B,T,KV,G)
    kb, vb, nblk = _pad_blocks(k, v, bk)
    Sp = nblk * bk
    qpos = (jnp.arange(T) + q_offset)[:, None]

    def body(dq, inp):
        kblk, vblk, j = inp
        logits = jnp.einsum("btkgd,bskd->btkgs", qg,
                            kblk.astype(jnp.float32))
        pen = _mask_for(j, bk, S, qpos, causal, window)
        logits = logits + pen[None, :, None, None, :]
        p = jnp.exp(logits - m[..., None]) / l[..., None]  # (B,T,KV,G,bk)
        dp = jnp.einsum("btkgd,bskd->btkgs", dog, vblk.astype(jnp.float32))
        dv = jnp.einsum("btkgs,btkgd->bskd", p, dog)
        ds = p * (dp - D[..., None])                       # (B,T,KV,G,bk)
        # qg already carries the softmax scale: dlogits/dq = scale*k,
        # dlogits/dk = qg (scale baked in) — no second scale on dk.
        dq = dq + jnp.einsum("btkgs,bskd->btkgd", ds,
                             kblk.astype(jnp.float32)) * scale
        dk = jnp.einsum("btkgs,btkgd->bskd", ds, qg)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, T, KV, G, dh), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0,
                                  (kb, vb, jnp.arange(nblk)))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sp, KV, dh)[:, :S]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sp, KV, dh)[:, :S]
    dq = dq.reshape(B, T, H, dh).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_blocked_flash_core.defvjp(
    lambda q, k, v, causal, window, q_offset, bk: _blocked_flash_fwd(
        q, k, v, causal, window, q_offset, bk),
    _blocked_flash_bwd)


def _blocked_flash(q: Array, k: Array, v: Array, *, causal: bool,
                   window: Optional[int], q_offset: int,
                   bk: int = 512) -> Array:
    """Online-softmax flash in pure jnp with flash-style custom VJP.

    q (B,T,H,dh), k/v (B,S,KV,dh). GQA handled by reshaping q to
    (B, T, KV, G, dh) so einsums broadcast over the group dim without
    materializing repeated k/v.
    """
    S = k.shape[1]
    bk = min(bk, S)
    out = _blocked_flash_core(q, k, v, causal, window, q_offset, bk)
    # note: dk/dv of the padded tail are dropped by slicing inside the
    # core's bwd reshape; padding only exists when S % bk != 0, and those
    # keys receive zero probability so their grads are zero anyway.
    return out


def _ref_attention(q, k, v, *, causal, window, q_offset):
    from repro.kernels import ref
    # ref.mha wants (B, H, T, D)
    o = ref.mha(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                jnp.moveaxis(v, 2, 1), causal=causal, window=window)
    return jnp.moveaxis(o, 1, 2)


def attend(q: Array, k: Array, v: Array, *, causal: bool = True,
           window: Optional[int] = None, q_offset: int = 0,
           impl: str = "flash_xla") -> Array:
    """q (B, T, H, dh); k/v (B, S, KV, dh) -> (B, T, H, dh).

    When a mesh is active and the head count divides the model axis, the
    flash computation runs under shard_map with q/out sharded over heads
    and k/v replicated on the model axis (gathered once per layer). This
    pins one consistent layout on the 5-D GQA intermediates — letting the
    SPMD partitioner pick leads to conflicting (KV, G) factorizations and
    "involuntary full rematerialization" (measured: TB-scale all-gathers
    inside the bwd scan on dbrx-132b).
    """
    if impl == "flash_pallas":
        from repro.kernels import ops
        o = ops.flash_attention(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=causal, window=window)
        return jnp.moveaxis(o, 1, 2)
    if impl == "ref":
        return _ref_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    mesh = sharding._ACTIVE["mesh"]
    H = q.shape[2]
    if (mesh is not None and "model" in mesh.shape
            and H % mesh.shape["model"] == 0 and impl == "flash_xla"):
        return _flash_sharded(q, k, v, mesh, causal=causal, window=window,
                              q_offset=q_offset)
    if mesh is not None:
        # heads do not divide the model axis (e.g. 40 heads / 16-way axis,
        # smollm's 9 heads): pin batch-only sharding on the flash operands
        # so the partitioner cannot invent conflicting (KV, G)
        # factorizations (attention compute is then model-axis redundant —
        # the divisibility fallback's price, revisited in §Perf).
        pin = lambda x: sharding.constrain(x, ("batch", None, None, None))
        q, k, v = pin(q), pin(k), pin(v)
        out = _blocked_flash(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
        return pin(out)
    return _blocked_flash(q, k, v, causal=causal, window=window,
                          q_offset=q_offset)


def _flash_sharded(q: Array, k: Array, v: Array, mesh, *, causal: bool,
                   window: Optional[int], q_offset: int) -> Array:
    """Head-parallel flash under shard_map.

    q/out: heads sharded over the model axis; k/v replicated over it (the
    one per-layer kv gather is the price of GQA head parallelism — tiny:
    KV heads only). Each rank expands its local q heads' kv on the fly, so
    the inner flash runs MHA-style (G=1) with no factored-dim ambiguity.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, T, H, dh = q.shape
    KV = k.shape[2]
    n_m = mesh.shape["model"]
    H_loc = H // n_m
    G = H // KV
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    qspec = P(batch_axes, None, "model", None)
    kvspec = P(batch_axes, None, None, None)

    def block(q_loc, k_rep, v_rep):
        m = jax.lax.axis_index("model")
        hidx = m * H_loc + jnp.arange(H_loc)
        kvidx = hidx // G
        k_loc = jnp.take(k_rep, kvidx, axis=2)
        v_loc = jnp.take(v_rep, kvidx, axis=2)
        return _blocked_flash(q_loc, k_loc, v_loc, causal=causal,
                              window=window, q_offset=q_offset)

    return shard_map(block, mesh=mesh, in_specs=(qspec, kvspec, kvspec),
                     out_specs=qspec, check_rep=False)(q, k, v)


# ---------------------------------------------------------------------------
# layer-level forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(p, x: Array, cfg: ArchConfig, *, pos: Array,
            causal: bool = True, window: Optional[int] = None,
            use_rope: bool = True, pos3: Optional[Array] = None,
            memory: Optional[Array] = None, impl: str = "flash_xla",
            compute_dtype=jnp.bfloat16) -> Array:
    """Full-sequence attention sublayer (no residual/norm — caller owns).

    memory: encoder output for cross-attention (kv come from memory).
    """
    B, T, D = x.shape
    dh = cfg.dh
    kv_src = x if memory is None else memory
    q = L.apply_dense(p["wq"], x, compute_dtype).reshape(B, T, cfg.n_heads, dh)
    k = L.apply_dense(p["wk"], kv_src, compute_dtype).reshape(
        B, kv_src.shape[1], cfg.n_kv_heads, dh)
    v = L.apply_dense(p["wv"], kv_src, compute_dtype).reshape(
        B, kv_src.shape[1], cfg.n_kv_heads, dh)
    if "qknorm" in p:
        q = L.apply_head_rmsnorm(q, p["qknorm"]["q_scale"])
        k = L.apply_head_rmsnorm(k, p["qknorm"]["k_scale"])
    if use_rope and memory is None:
        if cfg.rope_kind == "mrope" and pos3 is not None:
            q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        elif cfg.rope_kind != "none":
            q = L.apply_rope(q, pos, cfg.rope_theta)
            k = L.apply_rope(k, pos, cfg.rope_theta)
    q = sharding.constrain(q, ("batch", "seq", "heads", None))
    k = sharding.constrain(k, ("batch", "seq", "kv_heads", None))
    v = sharding.constrain(v, ("batch", "seq", "kv_heads", None))
    o = attend(q, k, v, causal=causal and memory is None, window=window,
               impl=impl)
    o = o.reshape(B, T, cfg.n_heads * dh)
    return L.apply_dense(p["wo"], o, compute_dtype)


# ---------------------------------------------------------------------------
# decode with static caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               window: Optional[int] = None, dtype=jnp.bfloat16):
    """Static KV cache for one layer. Window layers allocate min(W, S)."""
    S = max_len if window is None else min(window, max_len)
    shape = (batch, S, cfg.n_kv_heads, cfg.dh)
    kv_axes = ("batch", "kv_seq", "kv_heads", None) if window is None else \
              ("batch", None, "kv_heads", None)
    return ({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)},
            {"k": kv_axes, "v": kv_axes})


def cache_shape(cfg: ArchConfig, batch: int, max_len: int,
                window: Optional[int] = None, dtype=jnp.bfloat16):
    S = max_len if window is None else min(window, max_len)
    shape = (batch, S, cfg.n_kv_heads, cfg.dh)
    kv_axes = ("batch", "kv_seq", "kv_heads", None) if window is None else \
              ("batch", None, "kv_heads", None)
    sds = jax.ShapeDtypeStruct(shape, dtype)
    return {"k": sds, "v": sds}, {"k": kv_axes, "v": kv_axes}


def decode_step(p, cache, x: Array, cfg: ArchConfig, *, pos: Array,
                window: Optional[int] = None, use_rope: bool = True,
                pos3: Optional[Array] = None,
                compute_dtype=jnp.bfloat16):
    """One-token decode. x (B, 1, D); pos () int32 current position.

    Returns (out (B, 1, D), new_cache). Ring-buffer write for window
    layers; full-cache masked attend otherwise.
    """
    B, T, D = x.shape
    assert T == 1
    dh = cfg.dh
    q = L.apply_dense(p["wq"], x, compute_dtype).reshape(B, 1, cfg.n_heads, dh)
    k = L.apply_dense(p["wk"], x, compute_dtype).reshape(B, 1, cfg.n_kv_heads, dh)
    v = L.apply_dense(p["wv"], x, compute_dtype).reshape(B, 1, cfg.n_kv_heads, dh)
    if "qknorm" in p:
        q = L.apply_head_rmsnorm(q, p["qknorm"]["q_scale"])
        k = L.apply_head_rmsnorm(k, p["qknorm"]["k_scale"])
    if use_rope:
        pvec = pos[None] if pos.ndim == 0 else pos
        if cfg.rope_kind == "mrope" and pos3 is not None:
            q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        elif cfg.rope_kind != "none":
            q = L.apply_rope(q, jnp.broadcast_to(pvec, (B, 1)), cfg.rope_theta)
            k = L.apply_rope(k, jnp.broadcast_to(pvec, (B, 1)), cfg.rope_theta)
    S = cache["k"].shape[1]
    slot = pos % S if window is not None else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    # masked attend over the whole static cache
    KV = cfg.n_kv_heads
    G = cfg.n_heads // KV
    qg = q.reshape(B, 1, KV, G, dh).astype(jnp.float32) * dh ** -0.5
    logits = jnp.einsum("btkgd,bskd->btkgs", qg, ck.astype(jnp.float32))
    kpos = jnp.arange(S)
    if window is None:
        valid = kpos <= pos
    else:
        # ring buffer: slot i holds absolute position i + floor stuff; valid
        # iff its absolute position in (pos-window, pos]. Absolute position
        # of slot i: the latest write at or before `pos` congruent to i.
        age = (slot - kpos) % S                          # 0 = newest
        valid = (age < jnp.minimum(pos + 1, S))
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    prob = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", prob, cv.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * dh).astype(compute_dtype)
    out = L.apply_dense(p["wo"], o, compute_dtype)
    return out, {"k": ck, "v": cv}
