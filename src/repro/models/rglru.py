"""RG-LRU recurrent block (recurrentgemma-9b / Griffin, arXiv:2402.19427).

Recurrent block (the "rec" element of the (rec, rec, attn) pattern):

  x -> [branch 1] linear (d -> w) -> causal conv1d (width 4) -> RG-LRU
       [branch 2] linear (d -> w) -> GeLU
  out = (branch1 * branch2) -> linear (w -> d)

RG-LRU cell (diagonal gated linear recurrence):

  r_t = sigmoid(W_a x_t + b_a)          recurrence gate
  i_t = sigmoid(W_x x_t + b_x)          input gate
  a_t = exp(c * softplus(Λ) * (-r_t))   per-channel decay, Λ learned, c=8
  h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

Training/prefill uses jax.lax.associative_scan over the full sequence
(state is (B, w) per step — no Mamba-style N-dim blow-up, so no chunking
is needed). Decode is the exact one-step recurrence: O(1) state, which is
why recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import layers as L

Array = jax.Array

_C = 8.0      # Griffin's fixed decay temperature


def width(cfg: ArchConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init(key, cfg: ArchConfig, dtype):
    w = width(cfg)
    ks = jax.random.split(key, 6)
    scale = cfg.d_model ** -0.5
    p = {
        "in_x": {"w": jax.random.normal(ks[0], (cfg.d_model, w), dtype) * scale},
        "in_gate": {"w": jax.random.normal(ks[1], (cfg.d_model, w), dtype) * scale},
        "conv": {"w": jax.random.normal(ks[2], (cfg.rglru.conv, w), dtype) * 0.1,
                 "b": jnp.zeros((w,), dtype)},
        "gate_a": {"w": jax.random.normal(ks[3], (w, w), dtype) * w ** -0.5,
                   "b": jnp.zeros((w,), dtype)},
        "gate_x": {"w": jax.random.normal(ks[4], (w, w), dtype) * w ** -0.5,
                   "b": jnp.zeros((w,), dtype)},
        # Λ init so that a ≈ uniform(0.9, 0.999) at r = 1 (Griffin A.2)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(dtype),
        "out": {"w": jax.random.normal(ks[5], (w, cfg.d_model), dtype) * w ** -0.5},
    }
    a = {
        "in_x": {"w": ("embed", "mlp")},
        "in_gate": {"w": ("embed", "mlp")},
        "conv": {"w": ("conv", "mlp"), "b": ("mlp",)},
        "gate_a": {"w": ("mlp", None), "b": ("mlp",)},
        "gate_x": {"w": ("mlp", None), "b": ("mlp",)},
        "lam": ("mlp",),
        "out": {"w": ("mlp", "embed")},
    }
    return p, a


def _lru_coeffs(p, xc: Array):
    """Per-step (a_t, b_t) of the diagonal recurrence, from conv output xc."""
    r = jax.nn.sigmoid(xc @ p["gate_a"]["w"].astype(xc.dtype)
                       + p["gate_a"]["b"].astype(xc.dtype))
    i = jax.nn.sigmoid(xc @ p["gate_x"]["w"].astype(xc.dtype)
                       + p["gate_x"]["b"].astype(xc.dtype))
    lam = jax.nn.softplus(p["lam"].astype(jnp.float32))
    log_a = -_C * lam * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    gate = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    b = gate * (i.astype(jnp.float32) * xc.astype(jnp.float32))
    return a, b


def forward(p, x: Array, cfg: ArchConfig, compute_dtype) -> Array:
    """Full-sequence recurrent block (train / prefill)."""
    B, T, D = x.shape
    xb = L.apply_dense(p["in_x"], x, compute_dtype)       # (B, T, w)
    g = jax.nn.gelu(L.apply_dense(p["in_gate"], x, compute_dtype))
    xc = _causal_conv(xb, p["conv"], compute_dtype)
    xc = sharding.constrain(xc, ("batch", "seq", "mlp"))
    a, b = _lru_coeffs(p, xc)                             # (B, T, w) fp32

    def op(l, r_):
        al, bl = l
        ar, br = r_
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    y = h.astype(compute_dtype) * g
    return L.apply_dense(p["out"], y, compute_dtype)


def _causal_conv(xb: Array, pc, compute_dtype) -> Array:
    K = pc["w"].shape[0]
    w = pc["w"].astype(compute_dtype)
    pads = jnp.pad(xb, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pads[:, k:k + xb.shape[1], :] * w[k] for k in range(K))
    return y + pc["b"].astype(compute_dtype)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_state(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    w = width(cfg)
    p = {"h": jnp.zeros((batch, w), jnp.float32),
         "conv": jnp.zeros((batch, cfg.rglru.conv - 1, w), dtype)}
    a = {"h": ("batch", "mlp"), "conv": ("batch", None, "mlp")}
    return p, a


def state_shape(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    w = width(cfg)
    sds = jax.ShapeDtypeStruct
    p = {"h": sds((batch, w), jnp.float32),
         "conv": sds((batch, cfg.rglru.conv - 1, w), dtype)}
    a = {"h": ("batch", "mlp"), "conv": ("batch", None, "mlp")}
    return p, a


def decode_step(p, state, x: Array, cfg: ArchConfig, compute_dtype):
    """One-token step. x (B, 1, D) -> (out (B, 1, D), new state)."""
    xb = L.apply_dense(p["in_x"], x[:, 0], compute_dtype)   # (B, w)
    g = jax.nn.gelu(L.apply_dense(p["in_gate"], x[:, 0], compute_dtype))
    hist = jnp.concatenate([state["conv"].astype(compute_dtype),
                            xb[:, None]], axis=1)
    wconv = p["conv"]["w"].astype(compute_dtype)
    xc = jnp.einsum("bkd,kd->bd", hist, wconv) + p["conv"]["b"].astype(compute_dtype)
    a, b = _lru_coeffs(p, xc)
    h = a * state["h"] + b
    y = h.astype(compute_dtype) * g
    out = L.apply_dense(p["out"], y, compute_dtype)[:, None]
    return out, {"h": h, "conv": hist[:, 1:].astype(state["conv"].dtype)}
