"""Primitive layers: norms, projections, embeddings, RoPE/M-RoPE, MLPs.

Parameters are plain dict pytrees; every init function returns
``(params, axes)`` where ``axes`` mirrors ``params`` with tuples of
*logical* axis names consumed by repro.sharding. No framework dependency
(flax-free) so everything works identically under jit / shard_map / scan.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding

Array = jax.Array
PyTree = Any


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# -- precision boundary -------------------------------------------------------
# Sublayer outputs are the tensors the SPMD partitioner all-reduces (TP
# partial sums) / all-gathers (block-boundary reshards). XLA hoists the
# bf16->f32 converts of downstream fp32 consumers (norms, loss) ABOVE
# those collectives, silently doubling wire bytes (measured on dbrx /
# qwen2-vl: the top all-reduces were f32 activations). This boundary pins
# the compute dtype on both sides: an optimization_barrier stops convert
# hoisting in the forward, and the custom VJP rounds cotangents back to
# the activation dtype (the standard mixed-precision contract) with its
# own barrier for the backward collectives.

@jax.custom_vjp
def precision_boundary(y: Array) -> Array:
    return jax.lax.optimization_barrier(y)


def _pb_fwd(y):
    # residual: a zero-size array carrying the activation dtype (dtypes
    # themselves are not valid JAX residuals)
    return jax.lax.optimization_barrier(y), jnp.zeros((0,), y.dtype)


def _pb_bwd(proto, ct):
    return (jax.lax.optimization_barrier(ct.astype(proto.dtype)),)


precision_boundary.defvjp(_pb_fwd, _pb_bwd)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, axes=("embed", "mlp"),
               bias: bool = False):
    scale = in_dim ** -0.5
    p = {"w": jax.random.normal(key, (in_dim, out_dim), dtype) * scale}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (axes[1],)
    return p, a


def dense_shape(in_dim: int, out_dim: int, dtype, axes=("embed", "mlp"),
                bias: bool = False):
    """ShapeDtypeStruct twin of dense_init (dry-run, no allocation)."""
    p = {"w": jax.ShapeDtypeStruct((in_dim, out_dim), dtype)}
    a = {"w": axes}
    if bias:
        p["b"] = jax.ShapeDtypeStruct((out_dim,), dtype)
        a["b"] = (axes[1],)
    return p, a


def apply_dense(p, x: Array, compute_dtype) -> Array:
    w = p["w"].astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(dim: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}
    return ({"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def apply_norm(p, x: Array, kind: str, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def qk_norm_init(dh: int, dtype):
    return ({"q_scale": jnp.ones((dh,), dtype), "k_scale": jnp.ones((dh,), dtype)},
            {"q_scale": ("head_dim",), "k_scale": ("head_dim",)})


def apply_head_rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    """RMS norm over the trailing head_dim (qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, dim: int, dtype):
    # d^-0.5 keeps tied-unembed logits O(1) at init (loss starts ~ log V)
    p = {"table": jax.random.normal(key, (vocab, dim), dtype) * dim ** -0.5}
    return p, {"table": ("vocab", "embed")}


def apply_embed(p, ids: Array, compute_dtype) -> Array:
    return p["table"].astype(compute_dtype)[ids]


def apply_unembed(p, x: Array, compute_dtype) -> Array:
    """Tied output head: logits = x @ tableᵀ."""
    return x @ p["table"].astype(compute_dtype).T


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dh: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))


def apply_rope(x: Array, pos: Array, theta: float) -> Array:
    """x (..., S, H, dh); pos (..., S) int32 positions."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                  # (..., S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, pos3: Array, theta: float, sections: tuple) -> Array:
    """Qwen2-VL M-RoPE. x (B, S, H, dh); pos3 (3, B, S) temporal/h/w ids.

    The dh/2 frequency slots are split into ``sections`` (t, h, w); each
    section rotates by its own position stream.
    """
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    # per-frequency-slot stream id (t/h/w), then gather the position stream
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=dh // 2)     # (dh/2,)
    pos_sel = jnp.moveaxis(jnp.take(pos3, sec_id, axis=0), 0, -1)  # (B,S,dh/2)
    angles = pos_sel.astype(jnp.float32) * freqs         # (B, S, dh/2)
    cos = jnp.cos(angles)[..., None, :]                  # (B, S, 1, dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "silu":                                    # SwiGLU: 3 matrices
        p = {"wi": dense_init(k1, d_model, d_ff, dtype)[0],
             "wg": dense_init(k2, d_model, d_ff, dtype)[0],
             "wo": dense_init(k3, d_ff, d_model, dtype)[0]}
        a = {"wi": {"w": ("embed", "mlp")}, "wg": {"w": ("embed", "mlp")},
             "wo": {"w": ("mlp", "embed")}}
    else:                                                # plain 2-mat GELU
        p = {"wi": dense_init(k1, d_model, d_ff, dtype)[0],
             "wo": dense_init(k3, d_ff, d_model, dtype)[0]}
        a = {"wi": {"w": ("embed", "mlp")}, "wo": {"w": ("mlp", "embed")}}
    return p, a


def apply_mlp(p, x: Array, act: str, compute_dtype) -> Array:
    if act == "silu":
        h = jax.nn.silu(apply_dense(p["wg"], x, compute_dtype)) * \
            apply_dense(p["wi"], x, compute_dtype)
    else:
        h = jax.nn.gelu(apply_dense(p["wi"], x, compute_dtype))
    h = sharding.constrain(h, ("batch", "seq", "mlp"))
    return apply_dense(p["wo"], h, compute_dtype)
