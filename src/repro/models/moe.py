"""Mixture-of-Experts FFN (dbrx top-4, llama4-scout top-1 + shared expert).

Dispatch is scatter-based (GShard semantics without the (T, E, C) one-hot
blow-up): router top-k picks experts; each (token, k) slot's position
inside its expert is a cumsum over the one-hot assignment matrix (T·k × E
ints — cheap); tokens scatter-add into an (E, C, d) buffer, experts run as
one batched einsum (E sharded over the mesh "model" axis = expert
parallelism; the scatter/gather lower to XLA collectives standing in for
the all-to-all), and results gather back weighted by the gate.

Capacity C = ceil(top_k · T / E · capacity_factor); overflow tokens drop
(contribute zero), standard GShard behaviour. An auxiliary load-balance
loss (Switch-style) is returned for the train loop.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import layers as L

Array = jax.Array


def init(key, cfg: ArchConfig, dtype):
    moe = cfg.moe
    d_ff = moe.d_ff_expert or cfg.d_ff
    E = moe.n_experts
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    scale_in = cfg.d_model ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "router": {"w": jax.random.normal(kr, (cfg.d_model, E), dtype) * scale_in},
        "wi": jax.random.normal(k1, (E, cfg.d_model, d_ff), dtype) * scale_in,
        "wg": jax.random.normal(k2, (E, cfg.d_model, d_ff), dtype) * scale_in,
        "wo": jax.random.normal(k3, (E, d_ff, cfg.d_model), dtype) * scale_out,
    }
    a = {
        "router": {"w": ("embed", None)},
        "wi": ("experts", "embed", "mlp"),
        "wg": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    if moe.shared_expert:
        p["shared"], a["shared"] = L.mlp_init(ks, cfg.d_model, d_ff,
                                              cfg.act, dtype)
    return p, a


def forward(p, x: Array, cfg: ArchConfig, compute_dtype,
            full_capacity: bool = False) -> tuple[Array, Array]:
    """x (B, S, D) -> (out (B, S, D), aux_loss ()).

    Dispatches to the shard_map expert-parallel path when a mesh is active
    (true all-to-alls; see forward_sharded) and the expert count divides
    the model axis; otherwise runs the single-device scatter path below.

    full_capacity=True sets C = T (an expert can never receive more than T
    tokens), guaranteeing zero drops — used by the decode path, where T is
    tiny and train/serve consistency matters more than the buffer size.
    """
    mesh = sharding._ACTIVE["mesh"]
    if mesh is not None and "model" in mesh.shape \
            and cfg.moe.n_experts % mesh.shape["model"] == 0 \
            and x.shape[0] % _token_shards(mesh) == 0:
        return forward_sharded(p, x, cfg, compute_dtype, mesh,
                               full_capacity=full_capacity)
    return _forward_local(p, x, cfg, compute_dtype,
                          full_capacity=full_capacity)


def _token_shards(mesh) -> int:
    n = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    return n


def _forward_local(p, x: Array, cfg: ArchConfig, compute_dtype,
                   full_capacity: bool = False) -> tuple[Array, Array]:
    moe = cfg.moe
    B, S, D = x.shape
    E, k = moe.n_experts, moe.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt @ p["router"]["w"].astype(compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)      # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e (frac_tokens_e * mean_prob_e)
    frac = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32),
                    axis=0)
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    if full_capacity:
        C = T
    else:
        C = min(int(-(-k * T // E) * moe.capacity_factor), T)
    C = max(C, 1)

    flat_e = expert_ids.reshape(-1)                      # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1)          # (T*k, E)
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = slot < C                                      # capacity mask

    # scatter tokens into the (E, C, D) buffer
    xk = jnp.repeat(xt, k, axis=0)                       # (T*k, D)
    xk = sharding.constrain(xk, ("batch", None))
    w = gate_vals.reshape(-1)                            # (T*k,)
    slot_c = jnp.where(keep, slot, 0)
    e_c = jnp.where(keep, flat_e, 0)
    contrib = jnp.where(keep[:, None], xk, 0.0)
    contrib = sharding.constrain(contrib, ("batch", None))
    buf = jnp.zeros((E, C, D), compute_dtype)
    buf = buf.at[e_c, slot_c].add(contrib.astype(compute_dtype),
                                  mode="drop")
    buf = sharding.constrain(buf, ("experts", None, "embed"))

    # expert FFN as batched einsums (E on the model axis = EP)
    wi = p["wi"].astype(compute_dtype)
    wg = p["wg"].astype(compute_dtype)
    wo = p["wo"].astype(compute_dtype)
    if cfg.act == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * \
            jnp.einsum("ecd,edf->ecf", buf, wi)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, wi))
    h = sharding.constrain(h, ("experts", None, "mlp"))
    eout = jnp.einsum("ecf,efd->ecd", h, wo)             # (E, C, D)

    # gather back with gate weighting
    eout = sharding.constrain(eout, ("experts", None, "embed"))
    out_k = eout[e_c, slot_c]                            # (T*k, D)
    out_k = sharding.constrain(out_k, ("batch", None))
    out_k = jnp.where(keep[:, None], out_k, 0.0) * w[:, None].astype(compute_dtype)
    out = jnp.sum(out_k.reshape(T, k, D), axis=1)
    out = sharding.constrain(out, ("batch", None))

    if moe.shared_expert:
        out = out + L.apply_mlp(p["shared"], xt, cfg.act, compute_dtype)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# expert-parallel path (shard_map + all-to-all)
# ---------------------------------------------------------------------------
# Under pjit auto-sharding, scatter/gather across a sharded expert dim
# lowers to full-buffer all-reduces (measured: ~6.8 TB/device/step on
# dbrx-132b train_4k). Expert parallelism needs *all-to-alls*: each data
# shard routes its own tokens locally, sends per-expert slices to the
# model-axis peer that owns the expert, and receives its expert's tokens
# from every peer. shard_map expresses this directly with
# lax.all_to_all; traffic drops to k·T·d bytes per layer total — the
# theoretical minimum for token routing (measured: ~256x less wire bytes).
#
# Mesh contract: tokens sharded over ("pod","data"); experts over "model"
# (weights wi/wg/wo sharded on their leading E dim). Every (pod, data) row
# has the full expert set in its model group, so the a2a stays within the
# row — no cross-row traffic.

def forward_sharded(p, x: Array, cfg: ArchConfig, compute_dtype, mesh,
                    full_capacity: bool = False) -> tuple[Array, Array]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    moe = cfg.moe
    B, S, D = x.shape
    E, k = moe.n_experts, moe.top_k
    n_tok_shards = _token_shards(mesh)
    n_exp = mesh.shape["model"]
    E_loc = E // n_exp
    T_row = (B // n_tok_shards) * S        # tokens per (pod,data) row
    if S % n_exp != 0:
        return _forward_local(p, x, cfg, compute_dtype,
                              full_capacity=full_capacity)
    T_m = (B // n_tok_shards) * (S // n_exp)   # tokens per rank
    if full_capacity:
        C_m = T_m
    else:
        C_m = max(1, min(int(-(-k * T_m // E) * moe.capacity_factor), T_m))

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    # tokens sharded over BOTH the batch (data/pod) and sequence (model)
    # dims: every rank routes a disjoint token slice — no slicing inside
    # the block, so the backward stays collective-free on the input path.
    x_spec = P(batch_axes, "model", None)
    w_repl = P()
    w_exp = P("model")                     # leading E dim of expert weights

    def block(xm, router_w, wi, wg, wo):
        # xm: (B_row, S/n_exp, D) — this rank's disjoint token slice
        Bl, Sl, _ = xm.shape
        xm = xm.reshape(Bl * Sl, D)

        logits = (xm @ router_w.astype(compute_dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
        frac = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E,
                                       dtype=jnp.float32), axis=0)
        mean_p = jnp.mean(probs, axis=0)
        axes_all = batch_axes + ("model",)
        aux = E * jnp.sum(jax.lax.pmean(frac, axes_all) *
                          jax.lax.pmean(mean_p, axes_all))

        # local dispatch of this rank's slice into (E, C_m, D)
        flat_e = expert_ids.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        slot = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                                   flat_e[:, None], axis=1)[:, 0]
        keep = slot < C_m
        slot_c = jnp.where(keep, slot, 0)
        e_c = jnp.where(keep, flat_e, 0)
        xk = jnp.repeat(xm, k, axis=0)
        contrib = jnp.where(keep[:, None], xk, 0.0).astype(compute_dtype)
        send = jnp.zeros((E, C_m, D), compute_dtype)
        send = send.at[e_c, slot_c].add(contrib, mode="drop")

        # a2a: split E across model ranks; recv (n_src, E_loc, C_m, D)
        recv = jax.lax.all_to_all(
            send.reshape(n_exp, E_loc, C_m, D), "model",
            split_axis=0, concat_axis=0, tiled=False)

        def ffn(xe, wi_e, wg_e, wo_e):
            # xe (n_src, C_m, D) — one local expert, all source slices
            if cfg.act == "silu":
                h = jax.nn.silu(jnp.einsum("scd,df->scf", xe, wg_e)) * \
                    jnp.einsum("scd,df->scf", xe, wi_e)
            else:
                h = jax.nn.gelu(jnp.einsum("scd,df->scf", xe, wi_e))
            return jnp.einsum("scf,fd->scd", h, wo_e)

        eout = jax.vmap(ffn, in_axes=(1, 0, 0, 0), out_axes=1)(
            recv, wi.astype(compute_dtype), wg.astype(compute_dtype),
            wo.astype(compute_dtype))      # (n_src, E_loc, C_m, D)

        # reverse a2a: results return to their source rank
        back = jax.lax.all_to_all(eout, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(E, C_m, D)

        out_k = back[e_c, slot_c]
        out_k = jnp.where(keep[:, None], out_k, 0.0) * \
            gate_vals.reshape(-1)[:, None].astype(compute_dtype)
        out_m = jnp.sum(out_k.reshape(T_m, k, D), axis=1)
        return out_m.reshape(Bl, Sl, D), aux

    shmapped = shard_map(
        block, mesh=mesh,
        in_specs=(x_spec, w_repl, w_exp, w_exp, w_exp),
        out_specs=(x_spec, P()),
        check_rep=False)
    out, aux = shmapped(x, p["router"]["w"], p["wi"], p["wg"], p["wo"])
    if moe.shared_expert:
        xt = x.reshape(B * S, D)
        out = out + L.apply_mlp(p["shared"], xt, cfg.act,
                                compute_dtype).reshape(B, S, D)
    return out, aux
