"""Top-level model: embeddings + stack(s) + head, for all 10 architectures.

Public surface (everything pure functions over param pytrees):

  init_params(key, cfg)        -> (params, axes)          [smoke tests]
  param_shapes(cfg)            -> (ShapeDtypeStruct tree, axes)  [dry-run]
  loss_fn(params, batch, cfg)  -> (loss, aux-metrics)     [train_step]
  prefill(params, batch, cfg)  -> (last_logits, cache)    [serving]
  decode(params, cache, tok, pos, cfg) -> (logits, cache) [serving]
  input_specs(cfg, shape)      -> batch of ShapeDtypeStructs [dry-run]

Input conventions per family:
  dense/moe/ssm/hybrid: {"tokens": (B,S) i32, "labels": (B,S) i32}
  vlm:    + {"pos3": (3,B,S) i32}  (M-RoPE streams; the vision frontend is
            a stub — tokens already include patch-embedding positions)
  encdec: + {"frames": (B,L_enc,D) bf16} precomputed frontend embeddings
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import layers as L, transformer as T

Array = jax.Array


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig):
    dtype = L.dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p: dict = {}
    a: dict = {}
    p["embed"], a["embed"] = L.embed_init(ks[0], cfg.padded_vocab,
                                          cfg.d_model, dtype)
    p["stack"], a["stack"] = T.init_stack(ks[1], cfg, dtype,
                                          cross=cfg.family == "encdec")
    p["final_norm"], a["final_norm"] = L.norm_init(cfg.d_model,
                                                   cfg.norm_kind, dtype)
    if not cfg.tie_embeddings:
        p["unembed"], a["unembed"] = L.dense_init(
            ks[2], cfg.d_model, cfg.padded_vocab, dtype,
            axes=("embed", "vocab"))
    if cfg.family == "encdec":
        enc = cfg.encoder
        p["enc_in"], a["enc_in"] = L.dense_init(
            ks[3], enc.frontend_dim, cfg.d_model, dtype,
            axes=(None, "embed"))
        enc_cfg = _encoder_cfg(cfg)
        p["encoder"], a["encoder"] = T.init_stack(ks[4], enc_cfg, dtype)
        p["enc_norm"], a["enc_norm"] = L.norm_init(cfg.d_model,
                                                   cfg.norm_kind, dtype)
    return p, a


def _encoder_cfg(cfg: ArchConfig) -> ArchConfig:
    import dataclasses
    return dataclasses.replace(cfg, family="dense",
                               n_layers=cfg.encoder.n_layers,
                               moe=None, ssm=None, rglru=None, encoder=None)


def param_shapes(cfg: ArchConfig):
    """(ShapeDtypeStruct tree, axes) without allocating anything.

    init_params runs under eval_shape (params become ShapeDtypeStructs,
    nothing is allocated); the axes tree is pure static Python, captured
    via closure.
    """
    captured = {}

    def thunk():
        p, a = init_params(jax.random.PRNGKey(0), cfg)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(thunk)
    return shapes, captured["axes"]


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _compute_dtype(cfg: ArchConfig):
    return L.dtype_of(cfg.compute_dtype)


def _encode(p, frames: Array, cfg: ArchConfig, impl: str):
    cdt = _compute_dtype(cfg)
    enc_cfg = _encoder_cfg(cfg)
    x = L.apply_dense(p["enc_in"], frames.astype(cdt), cdt)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, _ = T.apply_stack(p["encoder"], x, enc_cfg, pos=pos, causal=False,
                         impl=impl, compute_dtype=cdt)
    return L.apply_norm(p["enc_norm"], x, cfg.norm_kind)


def logits_fn(p, batch: dict, cfg: ArchConfig, *, impl: str = "flash_xla"):
    """Full-sequence logits (B, S, padded_vocab) + aux loss."""
    cdt = _compute_dtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.apply_embed(p["embed"], tokens, cdt)
    x = sharding.constrain(x, ("batch", "seq", "embed"))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos3 = batch.get("pos3")
    memory = None
    if cfg.family == "encdec":
        memory = _encode(p, batch["frames"], cfg, impl)
    x, aux = T.apply_stack(p["stack"], x, cfg, pos=pos, pos3=pos3,
                           memory=memory, impl=impl, compute_dtype=cdt)
    x = L.apply_norm(p["final_norm"], x, cfg.norm_kind)
    logits = _head(p, x, cfg, cdt)
    logits = sharding.constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux


def _head(p, x, cfg: ArchConfig, cdt):
    if cfg.tie_embeddings:
        return L.apply_unembed(p["embed"], x, cdt)
    return L.apply_dense(p["unembed"], x, cdt)


def loss_fn(p, batch: dict, cfg: ArchConfig, *, impl: str = "flash_xla",
            aux_weight: float = 0.01):
    """Causal-LM cross entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, aux = logits_fn(p, batch, cfg, impl=impl)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via one-hot contraction: unlike take_along_axis this keeps
    # the (sharded) vocab dim contracted locally + a tiny psum, instead of
    # all-gathering the full logits to every device.
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), logits.shape[-1],
                            dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.where(labels >= 0, nll, 0.0)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    total = loss + aux_weight * aux
    return total, {"nll": loss, "aux": aux,
                   "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(p, batch: dict, cfg: ArchConfig, *, max_len: int,
            impl: str = "flash_xla"):
    """Process the prompt; returns (last-token logits, stacked cache)."""
    cdt = _compute_dtype(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.apply_embed(p["embed"], tokens, cdt)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos3 = batch.get("pos3")
    memory = None
    if cfg.family == "encdec":
        memory = _encode(p, batch["frames"], cfg, impl)
    x, cache = T.apply_stack_prefill(p["stack"], x, cfg, pos=pos,
                                     max_len=max_len, pos3=pos3,
                                     memory=memory, impl=impl,
                                     compute_dtype=cdt)
    x = L.apply_norm(p["final_norm"], x[:, -1:], cfg.norm_kind)
    logits = _head(p, x, cfg, cdt)
    return logits, cache


def decode(p, cache, tokens: Array, pos: Array, cfg: ArchConfig, *,
           pos3: Optional[Array] = None):
    """One decode step. tokens (B, 1); pos () current absolute position.

    Returns (logits (B, 1, V), new cache)."""
    cdt = _compute_dtype(cfg)
    x = L.apply_embed(p["embed"], tokens, cdt)
    x = sharding.constrain(x, ("batch", None, "embed"))
    x, cache = T.apply_stack_decode(p["stack"], cache, x, cfg, pos=pos,
                                    pos3=pos3, compute_dtype=cdt)
    x = L.apply_norm(p["final_norm"], x, cfg.norm_kind)
    logits = _head(p, x, cfg, cdt)
    logits = sharding.constrain(logits, ("batch", None, "vocab"))
    return logits, cache


def cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    cross_len = cfg.encoder.frontend_len if cfg.family == "encdec" else 0
    return T.stack_cache_shape(cfg, batch, max_len, cross_len=cross_len)


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.rope_kind == "mrope":
            batch["pos3"] = sds((3, B, S), i32)
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder.frontend_len,
                                   cfg.encoder.frontend_dim), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.rope_kind == "mrope":
            batch["pos3"] = sds((3, B, S), i32)
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder.frontend_len,
                                   cfg.encoder.frontend_dim), jnp.bfloat16)
        return batch
    # decode: one new token against an S-long cache
    batch = {"tokens": sds((B, 1), i32), "pos": sds((), i32)}
    if cfg.rope_kind == "mrope":
        batch["pos3"] = sds((3, B, 1), i32)
    return batch


def batch_axes(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """Logical axes for the input batch (for in_shardings)."""
    if shape.kind in ("train", "prefill"):
        axes = {"tokens": ("batch", None)}
        if shape.kind == "train":
            axes["labels"] = ("batch", None)
        if cfg.rope_kind == "mrope":
            axes["pos3"] = (None, "batch", None)
        if cfg.family == "encdec":
            axes["frames"] = ("batch", None, None)
        return axes
    axes = {"tokens": ("batch", None), "pos": ()}
    if cfg.rope_kind == "mrope":
        axes["pos3"] = (None, "batch", None)
    return axes
