"""Mamba-1 block (falcon-mamba-7b): selective SSM, attention-free.

Structure per layer (Gu & Dao 2023):
  x -> in_proj -> (x_branch, z_gate)           d -> 2 * d_inner
  x_branch -> causal depthwise conv1d (width 4) -> silu
  -> selective scan: h_t = Ā_t h_{t-1} + B̄_t x_t ; y_t = C_t h_t + D x_t
     with Ā_t = exp(Δ_t A), B̄_t = Δ_t B_t (ZOH), A diagonal (d_inner, N)
  y * silu(z_gate) -> out_proj                 d_inner -> d

Training/prefill runs a **chunked scan**: within a chunk of length L the
diagonal recurrence solves in closed form with log-space cumsums (numerics
bounded because |chunk| is small and Ā ∈ (0,1)); a lax.scan carries the
(B, d_inner, N) state across chunks. Peak memory is O(B · L · d_inner · N)
instead of O(B · T · d_inner · N) — the TPU adaptation of the paper's
SRAM-resident scan (VMEM-sized chunks instead of CUDA shared memory).

Decode is the exact single-step recurrence on the carried state — O(1) in
sequence length, which is why falcon-mamba runs the long_500k cell.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs.base import ArchConfig
from repro.models import layers as L

Array = jax.Array


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


def dt_rank(cfg: ArchConfig) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def init(key, cfg: ArchConfig, dtype):
    di = d_inner(cfg)
    N = cfg.ssm.state
    R = dt_rank(cfg)
    ks = jax.random.split(key, 7)
    scale = cfg.d_model ** -0.5
    p = {
        "in_proj": {"w": jax.random.normal(ks[0], (cfg.d_model, 2 * di), dtype) * scale},
        "conv": {"w": jax.random.normal(ks[1], (cfg.ssm.conv, di), dtype) * 0.1,
                 "b": jnp.zeros((di,), dtype)},
        # x -> (Delta_rank, B, C) data-dependent SSM params
        "x_proj": {"w": jax.random.normal(ks[2], (di, R + 2 * N), dtype) * di ** -0.5},
        "dt_proj": {"w": jax.random.normal(ks[3], (R, di), dtype) * R ** -0.5,
                    "b": jnp.zeros((di,), dtype) + jnp.log(jnp.expm1(0.01))},
        # A = -exp(A_log): init A_log = log(1..N) per channel (S4D-real)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": {"w": jax.random.normal(ks[4], (di, cfg.d_model), dtype) * di ** -0.5},
    }
    a = {
        "in_proj": {"w": ("embed", "mlp")},
        "conv": {"w": ("conv", "mlp"), "b": ("mlp",)},
        "x_proj": {"w": ("mlp", None)},
        "dt_proj": {"w": (None, "mlp"), "b": ("mlp",)},
        "A_log": ("mlp", "state"),
        "D": ("mlp",),
        "out_proj": {"w": ("mlp", "embed")},
    }
    return p, a


def _ssm_params(p, xb: Array, cfg: ArchConfig):
    """Data-dependent (Delta, B, C) from the conv branch xb (..., di)."""
    N = cfg.ssm.state
    R = dt_rank(cfg)
    dbc = xb @ p["x_proj"]["w"].astype(xb.dtype)          # (..., R+2N)
    dt, Bm, Cm = jnp.split(dbc, [R, R + N], axis=-1)
    delta = jax.nn.softplus(dt @ p["dt_proj"]["w"].astype(xb.dtype)
                            + p["dt_proj"]["b"].astype(xb.dtype))  # (..., di)
    return delta, Bm, Cm


def _chunk_scan(a: Array, bx: Array, h0: Array):
    """Diagonal linear recurrence within one chunk (associative scan).

    a, bx: (B, Lc, di, N) with a ∈ (0, 1); h0: (B, di, N).
    h_t = a_t h_{t-1} + bx_t. The affine maps h -> a h + b compose
    associatively: (a2, b2) ∘ (a1, b1) = (a2 a1, a2 b1 + b2), so a
    log-depth associative_scan gives all prefixes stably (no division by
    prefix products — avoids the exp overflow of the log-space cumsum
    formulation).
    """
    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    A, Bc = jax.lax.associative_scan(op, (a, bx), axis=1)
    h = A * h0[:, None] + Bc                              # (B, Lc, di, N)
    return h, h[:, -1]


def _chunk_fwd(A, h, d_c, B_c, C_c, x_c):
    """One chunk forward: returns (y (B,Lc,di), h_all (B,Lc,di,N))."""
    d_f = d_c.astype(jnp.float32)
    a = jnp.exp(d_f[..., None] * A)                       # (B,Lc,di,N)
    bx = (d_f * x_c.astype(jnp.float32))[..., None] * \
        B_c.astype(jnp.float32)[:, :, None, :]            # (B,Lc,di,N)
    hs, h_last = _chunk_scan(a, bx, h)
    y = jnp.einsum("blds,bls->bld", hs, C_c.astype(jnp.float32))
    return y, hs, h_last, a


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _chunked_ssm(delta, Bm, Cm, xb, A, h0):
    """y_t = C_t · h_t with h_t = exp(δ_t A) h_{t-1} + δ_t x_t B_t.

    Chunked scan with a hand-written VJP: differentiating through the
    forward scan would store the (B, Lc, di, N) recurrence intermediates
    of every chunk (O(T di N) — hundreds of GB at train_4k); instead the
    backward saves only the chunk-boundary states and re-expands each
    chunk on the fly, mirroring the SRAM-resident strategy of the Mamba
    CUDA kernel (VMEM-sized chunks on TPU). The adjoint of the diagonal
    recurrence h_t = a_t h_{t-1} + b_t is the *reverse* affine recurrence
    r_t = ĥ_t + a_{t+1} r_{t+1}, so the backward is itself an
    associative scan (run on flipped arrays).
    """
    out, _ = _chunked_ssm_fwd(delta, Bm, Cm, xb, A, h0)
    return out


_CHUNK = 64


def _chunked_ssm_fwd(delta, Bm, Cm, xb, A, h0):
    B, T, di = xb.shape
    N = Bm.shape[-1]
    L = min(_CHUNK, T)
    nchunks = T // L
    resh = lambda z: jnp.moveaxis(
        z.reshape(B, nchunks, L, *z.shape[2:]), 1, 0)

    def body(h, inp):
        d_c, B_c, C_c, x_c = inp
        y, hs, h_last, a = _chunk_fwd(A, h, d_c, B_c, C_c, x_c)
        return h_last, (y, h)          # emit the chunk's INCOMING state

    h_last, (ys, h_bounds) = jax.lax.scan(
        body, h0.astype(jnp.float32),
        (resh(delta), resh(Bm), resh(Cm), resh(xb)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, di)
    res = (delta, Bm, Cm, xb, A, h_bounds)
    return ((y, h_last), res)


def _chunked_ssm_bwd(res, cts):
    dy, dh_last = cts
    delta, Bm, Cm, xb, A, h_bounds = res
    B, T, di = xb.shape
    N = Bm.shape[-1]
    L = min(_CHUNK, T)
    nchunks = T // L
    resh = lambda z: jnp.moveaxis(
        z.reshape(B, nchunks, L, *z.shape[2:]), 1, 0)

    def body(carry, inp):
        rc, dA_acc = carry                       # rc: cotangent into h_last
        d_c, B_c, C_c, x_c, dy_c, h_in = inp
        d_f = d_c.astype(jnp.float32)
        y, hs, h_last, a = _chunk_fwd(A, h_in, d_c, B_c, C_c, x_c)
        # cotangent on each h_t from y_t = C_t · h_t, plus carry into h_L
        hbar = dy_c.astype(jnp.float32)[..., None] * \
            C_c.astype(jnp.float32)[:, :, None, :]        # (B,L,di,N)
        hbar = hbar.at[:, -1].add(rc)
        # r_t = hbar_t + a_{t+1} r_{t+1}  (reverse affine recurrence)
        a_shift = jnp.concatenate(
            [a[:, 1:], jnp.ones_like(a[:, :1])], axis=1)

        def op(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, ar * bl + br

        af = jnp.flip(a_shift, axis=1)
        hf = jnp.flip(hbar, axis=1)
        _, rf = jax.lax.associative_scan(op, (af, hf), axis=1)
        r = jnp.flip(rf, axis=1)                          # (B,L,di,N)
        # h_{t-1} sequence
        h_prev = jnp.concatenate([h_in[:, None], hs[:, :-1]], axis=1)
        da = r * h_prev
        dbx = r
        # a = exp(delta A): ddelta += sum_n da*a*A ; dA += sum_{B,l} da*a*delta
        ddelta = jnp.sum(da * a * A, axis=-1)             # (B,L,di)
        dA_acc = dA_acc + jnp.einsum("blds,bld->ds", da * a, d_f)
        # bx = (delta*x)[...,None] * B[:,:,None,:]
        dB_c = jnp.einsum("blds,bld->bls", dbx, d_f * x_c.astype(jnp.float32))
        ddx = jnp.sum(dbx * B_c.astype(jnp.float32)[:, :, None, :], axis=-1)
        ddelta = ddelta + ddx * x_c.astype(jnp.float32)
        dx_c = ddx * d_f
        dC_c = jnp.einsum("bld,blds->bls", dy_c.astype(jnp.float32), hs)
        rc_next = a[:, 0] * r[:, 0]                       # into previous chunk
        return (rc_next, dA_acc), (ddelta, dB_c, dC_c, dx_c)

    dA0 = jnp.zeros_like(A)
    (dh0, dA), (dd, dB, dC, dx) = jax.lax.scan(
        body, (dh_last.astype(jnp.float32), dA0),
        (resh(delta), resh(Bm), resh(Cm), resh(xb), resh(dy),
         h_bounds),
        reverse=True)
    unr = lambda z: jnp.moveaxis(z, 0, 1).reshape(B, T, *z.shape[3:])
    return (unr(dd).astype(delta.dtype), unr(dB).astype(Bm.dtype),
            unr(dC).astype(Cm.dtype), unr(dx).astype(xb.dtype),
            dA.astype(A.dtype), dh0)


_chunked_ssm.defvjp(lambda delta, Bm, Cm, xb, A, h0:
                    _chunked_ssm_fwd(delta, Bm, Cm, xb, A, h0),
                    _chunked_ssm_bwd)


def scan_sequence(p, xb: Array, cfg: ArchConfig, h0: Array,
                  chunk: int = 64):
    """Full selective scan. xb (B, T, di) conv+silu output; h0 (B, di, N).

    Returns (y (B, T, di), h_final)."""
    del chunk                                             # fixed _CHUNK
    B, T, di = xb.shape
    delta, Bm, Cm = _ssm_params(p, xb, cfg)               # (B,T,di),(B,T,N),(B,T,N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, N)
    # pad T to a chunk multiple: delta=0 => a=1, bx=0, so padded steps pass
    # the state through unchanged and their y is discarded.
    L = min(_CHUNK, T)
    Tp = -(-T // L) * L
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0))
        delta = jnp.pad(delta, pad)
        xb_p = jnp.pad(xb, pad)
        Bm = jnp.pad(Bm, pad)
        Cm = jnp.pad(Cm, pad)
    else:
        xb_p = xb
    y, h_final = _chunked_ssm(delta, Bm, Cm, xb_p, A,
                              h0.astype(jnp.float32))
    y = y[:, :T]
    y = y + xb.astype(jnp.float32) * p["D"].astype(jnp.float32)
    return y.astype(xb.dtype), h_final


def forward(p, x: Array, cfg: ArchConfig, compute_dtype,
            chunk: int = 64) -> Array:
    """Full-sequence mamba block (train / prefill, no state in/out)."""
    B, T, D = x.shape
    di = d_inner(cfg)
    xz = L.apply_dense(p["in_proj"], x, compute_dtype)    # (B, T, 2di)
    xb, z = jnp.split(xz, 2, axis=-1)
    xb = _causal_conv(xb, p["conv"], compute_dtype)
    xb = jax.nn.silu(xb)
    xb = sharding.constrain(xb, ("batch", "seq", "mlp"))
    h0 = jnp.zeros((B, di, cfg.ssm.state), jnp.float32)
    y, _ = scan_sequence(p, xb, cfg, h0, chunk=chunk)
    y = y * jax.nn.silu(z)
    return L.apply_dense(p["out_proj"], y, compute_dtype)


def _causal_conv(xb: Array, pc, compute_dtype) -> Array:
    """Depthwise causal conv1d, width K: y_t = sum_k w_k x_{t-K+1+k} + b."""
    K = pc["w"].shape[0]
    w = pc["w"].astype(compute_dtype)                     # (K, di)
    pads = jnp.pad(xb, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(pads[:, k:k + xb.shape[1], :] * w[k] for k in range(K))
    return y + pc["b"].astype(compute_dtype)


# ---------------------------------------------------------------------------
# decode (single step, carried state)
# ---------------------------------------------------------------------------

def init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di = d_inner(cfg)
    p = {"h": jnp.zeros((batch, di, cfg.ssm.state), jnp.float32),
         "conv": jnp.zeros((batch, cfg.ssm.conv - 1, di), dtype)}
    a = {"h": ("batch", "mlp", "state"), "conv": ("batch", None, "mlp")}
    return p, a


def state_shape(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16):
    di = d_inner(cfg)
    sds = jax.ShapeDtypeStruct
    p = {"h": sds((batch, di, cfg.ssm.state), jnp.float32),
         "conv": sds((batch, cfg.ssm.conv - 1, di), dtype)}
    a = {"h": ("batch", "mlp", "state"), "conv": ("batch", None, "mlp")}
    return p, a


def decode_step(p, state, x: Array, cfg: ArchConfig, compute_dtype):
    """One-token step. x (B, 1, D) -> (out (B, 1, D), new state)."""
    B = x.shape[0]
    di = d_inner(cfg)
    K = cfg.ssm.conv
    xz = L.apply_dense(p["in_proj"], x[:, 0], compute_dtype)   # (B, 2di)
    xb, z = jnp.split(xz, 2, axis=-1)
    # conv ring: state["conv"] holds the previous K-1 inputs
    hist = jnp.concatenate([state["conv"].astype(compute_dtype),
                            xb[:, None]], axis=1)         # (B, K, di)
    w = p["conv"]["w"].astype(compute_dtype)
    xc = jnp.einsum("bkd,kd->bd", hist, w) + p["conv"]["b"].astype(compute_dtype)
    xc = jax.nn.silu(xc)
    delta, Bm, Cm = _ssm_params(p, xc, cfg)               # (B,di),(B,N),(B,N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    d_f = delta.astype(jnp.float32)
    a = jnp.exp(d_f[..., None] * A)                       # (B, di, N)
    bx = (d_f * xc.astype(jnp.float32))[..., None] * \
        Bm.astype(jnp.float32)[:, None, :]
    h = a * state["h"] + bx
    y = jnp.einsum("bds,bs->bd", h, Cm.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(compute_dtype) * jax.nn.silu(z)
    out = L.apply_dense(p["out_proj"], y, compute_dtype)[:, None]
    new_state = {"h": h, "conv": hist[:, 1:].astype(state["conv"].dtype)}
    return out, new_state
