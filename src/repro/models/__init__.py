"""LM substrate: the 10 assigned architectures as pure-function models."""
from repro.models import (attention, layers, mamba, model, moe, rglru,
                          transformer)

__all__ = ["attention", "layers", "mamba", "model", "moe", "rglru",
           "transformer"]
