"""Training launcher: end-to-end LM training with the full runtime.

Wires together: config registry -> mesh + logical shardings -> synthetic
data pipeline -> jit'd train step (remat, optional grad accum /
compression) -> checkpoint manager (async, atomic, retention) ->
restart/resume (--resume restores params/opt/step and the data cursor).

CPU-scale by default (smoke config + host mesh); pass --full-config to
use the published architecture (needs a real pod). This is the same code
path the dry-run lowers — launching on hardware only changes the mesh.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b \
      --steps 100 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2x4' to build a (data, model) host mesh")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro import configs, sharding
    from repro.data import lm as lmdata
    from repro.distributed.checkpoint import CheckpointManager
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.optim import adamw, compress
    from repro.train import steps as steps_mod

    cfg = (configs.get if args.full_config else configs.get_smoke)(args.arch)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = make_host_mesh((d, m), ("data", "model"))

    tc = steps_mod.TrainConfig(
        optimizer=adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                                    total_steps=args.steps),
        compression=compress.CompressConfig(codec=args.compress),
        grad_accum=args.grad_accum)
    use_ef = args.compress != "none"

    params, axes = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    state = steps_mod.TrainState.create(params, use_ef=use_ef)

    step_fn = steps_mod.make_train_step(cfg, tc)
    if mesh is not None:
        state_axes = steps_mod.TrainState.axes(axes, use_ef=use_ef)
        state_sh = sharding.tree_shardings(state_axes, state, mesh)
        state = jax.tree.map(lambda x, s: jax.device_put(x, s), state,
                             state_sh)

        def wrapped(st, b):
            with sharding.use_mesh(mesh):
                return step_fn(st, b)

        jstep = jax.jit(wrapped, in_shardings=(state_sh, None),
                        out_shardings=(state_sh, None))
    else:
        jstep = jax.jit(step_fn)

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if args.resume and mgr.latest_step() is not None:
            meta = mgr.metadata()
            start_step = int(meta["metadata"].get("data_step",
                                                  meta["step"]))
            state = mgr.restore(state)
            print(f"[train] resumed from step {start_step}")

    dc = lmdata.LMDataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                             global_batch=args.global_batch, seed=args.seed)

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = lmdata.batch_at(dc, step)
        state, metrics = jstep(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f}"
                  f" gnorm {float(metrics['grad_norm']):.3f}"
                  f" lr {float(metrics['lr']):.2e}"
                  f" {time.time() - t0:.1f}s", flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, state, {"data_step": step + 1,
                                             "arch": args.arch})
    if mgr is not None:
        mgr.wait()
        mgr.save(args.steps, state, {"data_step": args.steps,
                                     "arch": args.arch})
        print(f"[train] final checkpoint at step {args.steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
