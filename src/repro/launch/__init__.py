# Launchers import lazily — repro.launch.dryrun must set XLA_FLAGS before
# jax initializes, so nothing here may import jax at module load.
