import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything else follows.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
(No `from __future__` here: the XLA_FLAGS lines must stay first.)

For each cell this script:
  1. builds the production mesh (16,16) or (2,16,16);
  2. resolves logical-axis shardings for params / optimizer state / batch
     / caches;
  3. jits the right step (train_step / prefill / serve_step) with explicit
     in/out shardings and ``.lower().compile()``s it with
     ShapeDtypeStruct inputs — no arrays are ever allocated;
  4. records memory_analysis(), cost_analysis(), HLO collective bytes
     (repro.launch.hlo_analysis) and the three roofline terms.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k --multi-pod

Exit code 0 iff every attempted cell compiled.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def _top_collectives(hlo: str, n: int = 10):
    """Aggregate wire bytes per (kind, shape, group) — the §Perf profile."""
    import re
    from collections import defaultdict
    from repro.launch import hlo_analysis as ha
    comps = ha._split_computations(hlo)
    entry = ha._entry_name(hlo)
    mult = ha._multiplicities(comps, entry)
    agg = defaultdict(float)
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for ln in lines:
            opm = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^=]*?\)|\S+)"
                           r"\s+(all-gather|all-reduce|reduce-scatter|"
                           r"all-to-all|collective-permute)", ln)
            if not opm:
                continue
            kind = opm.group(2)
            rb = ha.shape_bytes(opm.group(1))
            g = ha._group_size(ln)
            agg[(kind, opm.group(1)[:48], g)] += m * ha._wire_bytes(kind, rb, g)
    top = sorted(agg.items(), key=lambda kv: -kv[1])[:n]
    return [{"kind": k, "shape": s, "group": g, "gib": round(b / 2**30, 2)}
            for (k, s, g), b in top]


def _cell(arch: str, shape_name: str, multi_pod: bool,
          rules_name: str = "default", attn_impl: str = "flash_xla",
          grad_accum: int = 1, diag: bool = False,
          remat: str = None, param_dtype: str = None) -> dict:
    from repro import configs, sharding
    from repro.configs.base import shape_applicable
    from repro.launch import hlo_analysis as ha
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as M
    from repro.optim import adamw
    from repro.train import steps

    cfg = configs.get(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if param_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    shape = configs.get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = _rules(rules_name)
    t0 = time.time()

    import math
    shapes_p, axes_p = M.param_shapes(cfg)
    n_params = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes_p))
    specs = M.input_specs(cfg, shape)
    baxes = M.batch_axes(cfg, shape)
    batch_sh = sharding.tree_shardings(baxes, specs, mesh, rules)
    from jax.sharding import NamedSharding, PartitionSpec as P
    repl = NamedSharding(mesh, P())

    tc = steps.TrainConfig(attn_impl=attn_impl, grad_accum=grad_accum)
    if shape.kind == "train":
        state_shapes = steps.TrainState.shapes(shapes_p, use_ef=False)
        state_axes = steps.TrainState.axes(axes_p, use_ef=False)
        state_sh = sharding.tree_shardings(state_axes, state_shapes, mesh,
                                           rules)
        fn = steps.make_train_step(cfg, tc)

        def wrapped(state, batch):
            with sharding.use_mesh(mesh, rules):
                return fn(state, batch)

        jfn = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                      out_shardings=(state_sh, None))
        lowered = jfn.lower(state_shapes, specs)
    elif shape.kind == "prefill":
        param_sh = sharding.tree_shardings(axes_p, shapes_p, mesh, rules)
        cshapes, caxes = M.cache_shapes(cfg, shape.global_batch,
                                        shape.seq_len)
        cache_sh = sharding.tree_shardings(caxes, cshapes, mesh, rules)
        fn = steps.make_prefill(cfg, max_len=shape.seq_len,
                                attn_impl=attn_impl)

        def wrapped(params, batch):
            with sharding.use_mesh(mesh, rules):
                return fn(params, batch)

        jfn = jax.jit(wrapped, in_shardings=(param_sh, batch_sh),
                      out_shardings=(None, cache_sh))
        lowered = jfn.lower(shapes_p, specs)
    else:  # decode
        param_sh = sharding.tree_shardings(axes_p, shapes_p, mesh, rules)
        cshapes, caxes = M.cache_shapes(cfg, shape.global_batch,
                                        shape.seq_len)
        cache_sh = sharding.tree_shardings(caxes, cshapes, mesh, rules)
        fn = steps.make_serve_step(cfg)

        def wrapped(params, cache, batch):
            with sharding.use_mesh(mesh, rules):
                return fn(params, cache, batch)

        # donate the cache: decode_32k caches are GB-scale; without
        # donation the updated cache double-counts in live memory
        jfn = jax.jit(wrapped, in_shardings=(param_sh, cache_sh, batch_sh),
                      out_shardings=(None, cache_sh), donate_argnums=(1,))
        lowered = jfn.lower(shapes_p, cshapes, specs)

    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # one-dict-per-device on old jax
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = ha.collective_bytes(hlo)

    n_dev = mesh.size
    # XLA's cost_analysis counts while bodies once (no trip multiplication)
    # — scan-stacked layers would be ~n_layers x under-reported. dot_flops
    # re-counts matmuls with trip accounting; take the max of both.
    flops_xla = float(cost.get("flops", 0.0))
    flops_dots = ha.dot_flops(hlo)
    flops_dev = max(flops_xla, flops_dots)
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    rl = ha.roofline(flops_dev, bytes_dev, coll.total_bytes)

    # MODEL_FLOPS: 6 N D (train) / 2 N D (inference), N = active params
    n_active = _active_params(cfg, n_params)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    hlo_flops_total = flops_dev * n_dev

    out = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "rules": rules_name,
        "n_devices": n_dev,
        "compile_s": round(t_compile, 1),
        "n_params": n_params,
        "n_active_params": n_active,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {"flops_per_device": flops_dev,
                 "flops_per_device_xla": flops_xla,
                 "flops_per_device_dots": flops_dots,
                 "hbm_bytes_per_device": bytes_dev},
        "collectives": {"bytes_by_kind": coll.bytes_by_kind,
                        "count_by_kind": coll.count_by_kind,
                        "total_bytes_per_device": coll.total_bytes},
        "roofline": {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "bound_s": rl.bound_s,
        },
        "model_flops": {
            "model_flops_total": model_flops,
            "hlo_flops_total": hlo_flops_total,
            "useful_ratio": (model_flops / hlo_flops_total
                             if hlo_flops_total else 0.0),
        },
    }
    if diag:
        out["top_collectives"] = _top_collectives(hlo)
    return out


def _active_params(cfg, n_params: int) -> int:
    """Active parameters per token (MoE: only top_k experts count)."""
    if cfg.moe is None:
        return n_params
    # expert weights: 3 matrices per layer (wi, wg, wo) x experts
    d_ff = cfg.moe.d_ff_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * d_ff
    expert_total = cfg.n_layers * cfg.moe.n_experts * per_expert
    expert_active = cfg.n_layers * cfg.moe.top_k * per_expert
    return n_params - expert_total + expert_active


def _rules(name: str):
    from repro import sharding
    if name == "default":
        return sharding.ShardingRules()
    if name == "pure_dp":              # batch over EVERY axis; no TP at all
        return sharding.ShardingRules().replace(
            batch=("pod", "data", "model"), embed=None, mlp=None,
            heads=None, kv_heads=None, vocab=None, experts=None,
            kv_seq=None)
    if name == "dp_fsdp":              # batch over all axes + FSDP weights
        return sharding.ShardingRules().replace(
            batch=("pod", "data", "model"), embed="data", mlp="model",
            heads=None, kv_heads=None, vocab="model", experts=None,
            kv_seq=None)
    if name == "no_fsdp":              # embed replicated (pure TP + DP)
        return sharding.ShardingRules().replace(embed=None)
    if name == "seq_data":             # decode cache sharded on data axis
        return sharding.ShardingRules().replace(kv_seq="data")
    if name == "fsdp_model":           # embed sharded on model axis instead
        return sharding.ShardingRules().replace(embed="model", mlp="data",
                                                heads="data", kv_heads="data",
                                                vocab="data", experts="data")
    raise KeyError(name)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rules", default="default")
    ap.add_argument("--attn-impl", default="flash_xla")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default=None,
                    choices=[None, "full", "dots", "attn", "none"])
    ap.add_argument("--param-dtype", default=None,
                    choices=[None, "float32", "bfloat16"])
    ap.add_argument("--diag", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro import configs

    cells = []
    if args.all:
        for a in configs.ARCH_NAMES:
            for s in configs.SHAPES:
                cells.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
            try:
                r = _cell(arch, shape, mp, rules_name=args.rules,
                          attn_impl=args.attn_impl, grad_accum=args.accum,
                          diag=args.diag, remat=args.remat,
                          param_dtype=args.param_dtype)
            except Exception as e:
                traceback.print_exc()
                r = {"arch": arch, "shape": shape, "status": "error",
                     "mesh": "multi_pod" if mp else "single_pod",
                     "error": f"{type(e).__name__}: {e}"}
                failures += 1
            results.append(r)
            status = r["status"]
            extra = ""
            if status == "ok":
                rl = r["roofline"]
                extra = (f" dominant={rl['dominant']}"
                         f" bound={rl['bound_s']:.4f}s"
                         f" compile={r['compile_s']}s")
            elif status == "skipped":
                extra = f" ({r['reason'][:60]})"
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
