"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is
data-parallel by default (gradient all-reduce across pods over DCN/ICI).
"""
from __future__ import annotations

import jax

from repro.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(2, 4), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over host devices for CI-scale distributed tests."""
    return make_mesh(shape, axes)
