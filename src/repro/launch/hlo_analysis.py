"""Parse compiled HLO text: collective bytes (per device), with while-loop
trip-count accounting.

cost_analysis() gives FLOPs and HBM bytes but not collective traffic, so
we walk the HloModule text:

1. split into computations (ENTRY / %name { ... });
2. count execution multiplicity of each computation: ENTRY x1; a while's
   body/cond inherit caller multiplicity x trip count (trip count read
   from the loop condition's compare-against-constant — exact for
   lax.scan-lowered loops); fusions/calls inherit x1;
3. sum wire bytes of every collective op, weighted by multiplicity.

Wire-byte conventions (ring algorithms, per participating device):
  all-gather       (g-1)/g x result_bytes      (receives everyone else's shard)
  reduce-scatter   (g-1)/g x input_bytes  = (g-1) x result_bytes
  all-reduce       2 (g-1)/g x bytes           (reduce-scatter + all-gather)
  all-to-all       (g-1)/g x bytes             (keeps own shard)
  collective-permute  1.0 x bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(shape_text: str) -> int:
    """Sum bytes over every 'dtype[dims]' occurrence in a shape string
    (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    """Participants per replica group from 'replica_groups=[G,S]<=...' or
    explicit '{{0,1},{2,3}}' lists."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its lines. Handles 'ENTRY %name ... {' and
    '%name ... {' headers with '}' terminators at column 0."""
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$",
                     line) if not line.startswith(" ") else None
        if m and cur is None:
            cur = m.group(1)
            comps[cur] = []
            continue
        if line.startswith("}") and cur is not None:
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    return m.group(1) if m else None


def _trip_count(cond_lines: list[str]) -> int:
    """Largest compare-constant in the condition — exact for scan loops."""
    best = 1
    for ln in cond_lines:
        if "compare" in ln:
            for c in re.findall(r"constant\((\d+)\)", ln):
                best = max(best, int(c))
    # fall back: any integer constant in the condition
    if best == 1:
        for ln in cond_lines:
            for c in re.findall(r"constant\((\d+)\)", ln):
                best = max(best, int(c))
    return best


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)        # input = result * g
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0



def _multiplicities(comps: dict[str, list[str]], entry: str) -> dict[str, float]:
    """Execution count of each computation (while trips, calls, fusions)."""
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        name = order.pop(0)
        m = mult[name]
        for ln in comps.get(name, ()):
            wm = re.search(r"while\(.*?\)\s*,\s*condition=%?([\w\.\-]+)\s*,"
                           r"\s*body=%?([\w\.\-]+)", ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                # XLA annotates scan-lowered loops with the exact trip
                # count; prefer it over parsing the condition computation
                km = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
                trips = int(km.group(1)) if km \
                    else _trip_count(comps.get(cond, []))
                for target, k in ((cond, trips + 1), (body, trips)):
                    if target in comps:
                        mult[target] += m * k
                        if target not in seen:
                            seen.add(target)
                            order.append(target)
                continue
            for cm in re.finditer(r"(?:calls|to_apply|branch_computations)="
                                  r"\{?%?([\w\.\-]+)", ln):
                target = cm.group(1)
                if target in comps:
                    mult[target] += m
                    if target not in seen:
                        seen.add(target)
                        order.append(target)
    return mult


def collective_bytes(hlo: str) -> CollectiveStats:
    """Per-device collective wire bytes for one execution of the module."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    if entry is None or entry not in comps:
        # single-computation fallback: treat the whole text as one body
        comps = {"__all__": [l.strip() for l in hlo.splitlines()]}
        entry = "__all__"

    mult = _multiplicities(comps, entry)

    bytes_by_kind: dict[str, float] = defaultdict(float)
    count_by_kind: dict[str, float] = defaultdict(float)
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        for ln in lines:
            opm = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+"
                           r"(all-gather|all-reduce|reduce-scatter|"
                           r"all-to-all|collective-permute)", ln)
            if not opm:
                continue
            kind = opm.group(2)
            rb = shape_bytes(opm.group(1))
            g = _group_size(ln)
            bytes_by_kind[kind] += m * _wire_bytes(kind, rb, g)
            count_by_kind[kind] += m
    return CollectiveStats(bytes_by_kind=dict(bytes_by_kind),
                           count_by_kind=dict(count_by_kind))


# ---------------------------------------------------------------------------
# dot FLOPs with loop accounting
# ---------------------------------------------------------------------------
# XLA's HloCostAnalysis counts a while body ONCE regardless of trip count,
# so cost_analysis() under-reports scan-stacked models by ~n_layers x. We
# re-count matmul FLOPs ourselves: per computation, build a symbol table of
# operand shapes, find every `dot`, compute 2 x prod(result) x
# prod(contracting dims), and weight by the computation's execution
# multiplicity (while trip counts, from the same machinery as collectives).

_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"((?:\([^)]*\)|[\w\[\],\{\}]+))\s+([\w\-]+)\(")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_operands(ln: str) -> list[str]:
    """Operand names of a `dot(...)` call.

    Current jaxlibs print typed operands — `dot(f32[64,64]{1,0} %a, ...)` —
    older ones plain `dot(%a, %b)`; dot operands are always arrays (never
    tuples) so the call contains no nested parens and each operand's name
    is the last %-token (or bare token) of its argument.
    """
    m = re.search(r"\bdot\(([^)]*)\)", ln)
    if not m:
        return []
    inside = m.group(1)
    names = re.findall(r"%([\w\.\-]+)", inside)
    if names:
        return names
    # %-less operands: shape literals (f32[64,32]{1,0}) contain commas, so
    # split on top-level commas only and take each argument's last token
    args: list[str] = []
    depth, cur = 0, []
    for ch in inside:
        if ch in "[{":
            depth += 1
        elif ch in "]}":
            depth -= 1
        elif ch == "," and depth == 0:
            args.append("".join(cur))
            cur = []
            continue
        cur.append(ch)
    args.append("".join(cur))
    return [a.split()[-1] for a in args if a.strip()]


def xla_flops(compiled) -> float:
    """FLOPs reported by XLA's cost model for a jax ``Compiled`` object.

    ``Compiled.cost_analysis()`` returns a dict on current JAX and a
    one-dict-per-device list on older versions; normalize both. This is the
    number :func:`dot_flops` corrects — XLA counts a while body once
    regardless of trip count.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0))


def _first_shape(shape_text: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def dot_flops(hlo: str) -> float:
    """Total per-device matmul FLOPs, with while-loop trip accounting."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    if entry is None or entry not in comps:
        comps = {"__all__": [l.strip() for l in hlo.splitlines()]}
        entry = "__all__"

    mult = _multiplicities(comps, entry)

    total = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        # symbol table: op name -> dims
        shapes: dict[str, list[int]] = {}
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if dm:
                _, dims = _first_shape(dm.group(2))
                shapes[dm.group(1)] = dims
        for ln in lines:
            dm = _DEF_RE.match(ln)
            if not dm or dm.group(3) != "dot":
                continue
            _, out_dims = _first_shape(dm.group(2))
            cm = _DOT_CONTRACT_RE.search(ln)
            operands = _dot_operands(ln)
            if not operands:
                continue
            lhs = shapes.get(operands[0], [])
            contract = 1
            if cm and cm.group(1):
                for d in cm.group(1).split(","):
                    di = int(d)
                    if di < len(lhs):
                        contract *= lhs[di]
            n_out = 1
            for d in out_dims:
                n_out *= d
            total += m * 2.0 * n_out * contract
    return total

@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e."""
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9


V5E = Hardware()


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline(flops_per_device: float, hbm_bytes_per_device: float,
             coll_bytes_per_device: float, hw: Hardware = V5E) -> Roofline:
    return Roofline(
        compute_s=flops_per_device / hw.peak_flops,
        memory_s=hbm_bytes_per_device / hw.hbm_bw,
        collective_s=coll_bytes_per_device / hw.ici_bw,
        flops=flops_per_device,
        hbm_bytes=hbm_bytes_per_device,
        coll_bytes=coll_bytes_per_device,
    )
