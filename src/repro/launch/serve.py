"""Serving launcher: prefill + batched decode with static caches.

Demonstrates the full serving path: prompt prefill fills the per-layer
caches (KV ring buffers for windowed layers, SSM/RG-LRU states for
recurrent layers), then a jit'd decode step generates tokens
autoregressively for the whole batch. Greedy or temperature sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
      --prompt-len 32 --gen 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro import configs
    from repro.models import model as M
    from repro.train import steps as steps_mod

    cfg = (configs.get if args.full_config else configs.get_smoke)(args.arch)
    max_len = args.max_len or (args.prompt_len + args.gen)

    key = jax.random.PRNGKey(args.seed)
    params, _ = M.init_params(key, cfg)

    B = args.batch
    toks = jax.random.randint(jax.random.fold_in(key, 1),
                              (B, args.prompt_len), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(args.prompt_len),
                               (B, args.prompt_len))
        batch["pos3"] = jnp.stack([pos, pos, pos])
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (B, cfg.encoder.frontend_len, cfg.encoder.frontend_dim),
            jnp.bfloat16)

    t0 = time.time()
    prefill = jax.jit(steps_mod.make_prefill(cfg, max_len=max_len))
    logits, cache = prefill(params, batch)
    print(f"[serve] prefill {args.prompt_len} tokens x{B}: "
          f"{time.time() - t0:.2f}s")

    decode = jax.jit(steps_mod.make_serve_step(cfg))
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for t in range(args.gen):
        pos = jnp.int32(args.prompt_len + t)
        dbatch = {"tokens": tok, "pos": pos}
        if cfg.rope_kind == "mrope":
            p3 = jnp.broadcast_to(pos, (B, 1))
            dbatch["pos3"] = jnp.stack([p3, p3, p3])
        logits, cache = decode(params, cache, dbatch)
        if args.temperature > 0:
            tok = jax.random.categorical(
                jax.random.fold_in(key, 100 + t),
                logits[:, -1] / args.temperature, axis=-1)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(tok)
    gen = jnp.concatenate(out_tokens, axis=1)
    dt = time.time() - t0
    print(f"[serve] generated {args.gen} tokens x{B} in {dt:.2f}s "
          f"({args.gen * B / max(dt, 1e-9):.1f} tok/s)")
    print(f"[serve] sample row 0: {gen[0].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
