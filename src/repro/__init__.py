"""repro — Scalable Optimal Margin Distribution Machine (SODM) as a
production JAX framework (IJCAI 2023 reproduction + TPU-native extension).
"""

__version__ = "0.1.0"
