"""Deterministic synthetic LM token pipeline.

Produces next-token-prediction batches from a seeded Markov-ish stream:
tokens follow a Zipf marginal with a shallow bigram structure so the loss
actually decreases during the example training runs (pure-uniform data
would pin the loss at log V). Sharded iteration: each data-parallel rank
derives its slice from (seed, step, rank) — restart-safe (the data cursor
is just the step counter, saved in checkpoints) and elastic-safe (rank
count is an input, not baked state).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1


def _zipf_logits(vocab: int, a: float) -> Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -a * jnp.log(ranks)


def batch_at(cfg: LMDataConfig, step: int) -> dict:
    """The full global batch for a step (host-side; used by examples/tests)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    base = _zipf_logits(V, cfg.zipf_a)
    k1, k2 = jax.random.split(key)
    # shallow bigram structure: token t+1 biased toward (t * 31 + 7) % V
    first = jax.random.categorical(k1, base, shape=(B, 1))

    def step_fn(prev, k):
        nxt_bias = (prev * 31 + 7) % V
        logits = base[None, :] + 2.0 * jax.nn.one_hot(nxt_bias[:, 0], V)
        nxt = jax.random.categorical(k, logits, shape=(B,))[:, None]
        return nxt, nxt

    keys = jax.random.split(k2, S - 1)
    _, rest = jax.lax.scan(step_fn, first, keys)
    toks = jnp.concatenate([first, rest[:, :, 0].T], axis=1)   # (B, S)
    labels = jnp.concatenate([toks[:, 1:], toks[:, :1] * 0 - 1], axis=1)
    return {"tokens": toks, "labels": labels}


def rank_slice(batch: dict, rank: int, n_ranks: int) -> dict:
    """This DP rank's shard of the global batch."""
    def sl(x):
        if x.ndim >= 2 and x.shape[0] % n_ranks == 0:
            per = x.shape[0] // n_ranks
            return x[rank * per:(rank + 1) * per]
        return x
    return {k: sl(v) for k, v in batch.items()}
