"""Synthetic stand-ins for the paper's 8 LIBSVM data sets (Table 1).

The real files are not available offline; generators match each set's
cardinality, dimensionality, class balance and a comparable level of class
overlap (calibrated so linear ODM lands near the paper's accuracy band).
All features are scaled into [0, 1] as in the paper's setup. Sizes can be
scaled down with ``scale`` for CI (paper-scale SUSY = 5M rows is available
but slow on CPU).

Each generator is deterministic in ``seed``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    d: int
    balance: float       # fraction of +1
    sep: float           # class separation in feature units (overlap control)


# paper Table 1 statistics (gisette's 5000 features trimmed to 512 for CPU
# benches at scale<1; full d used when scale == 1.0)
# svmguide1's sep is calibrated against the paper band for *bias-free*
# linear ODM (~0.96 on the real set): at sep=1.6 even the Bayes rule
# through the origin tops out near 0.8.
PAPER_DATASETS: dict[str, DatasetSpec] = {
    "gisette": DatasetSpec("gisette", 6_000, 5_000, 0.50, 1.1),
    "svmguide1": DatasetSpec("svmguide1", 7_089, 4, 0.56, 3.0),
    "phishing": DatasetSpec("phishing", 11_055, 68, 0.56, 1.5),
    "a7a": DatasetSpec("a7a", 32_561, 123, 0.24, 1.3),
    "cod-rna": DatasetSpec("cod-rna", 59_535, 8, 0.33, 1.3),
    "ijcnn1": DatasetSpec("ijcnn1", 141_691, 22, 0.10, 1.2),
    "skin-nonskin": DatasetSpec("skin-nonskin", 245_057, 3, 0.21, 1.8),
    "SUSY": DatasetSpec("SUSY", 5_000_000, 18, 0.46, 0.7),
}


class Dataset(NamedTuple):
    x_train: jax.Array
    y_train: jax.Array
    x_test: jax.Array
    y_test: jax.Array
    name: str


def make_blobs(spec: DatasetSpec, seed: int = 0, scale: float = 1.0,
               max_d: int | None = None) -> Dataset:
    """Two anisotropic Gaussian blobs + label noise, normalized to [0, 1].

    A low-rank rotation couples the features so the decision boundary is
    not axis-aligned (keeps the RBF kernel honest).
    """
    n = max(64, int(spec.n * scale))
    n -= n % 8                                     # keep divisible for K
    d = spec.d if max_d is None else min(spec.d, max_d)
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n_pos = int(n * spec.balance)
    n_neg = n - n_pos
    # class means along a random *zero-mean* direction. The [0, 1]
    # normalization below shifts the data midpoint to ~0.5·1, and the
    # linear ODM has no bias term — it can only represent hyperplanes
    # through the origin. A generic direction leaves the class boundary
    # unreachable (accuracy ceilings near 0.75 no matter the separation);
    # a zero-mean direction keeps the boundary normal orthogonal to the
    # all-ones shift, matching the homogeneous separability of the real
    # LIBSVM sets these stand in for.
    u = jax.random.normal(k1, (d,))
    u = u - jnp.mean(u)
    u = u / jnp.linalg.norm(u)
    rot = jax.random.normal(k2, (d, d)) / jnp.sqrt(d)
    xp = jax.random.normal(k3, (n_pos, d)) @ (jnp.eye(d) + 0.3 * rot) \
        + spec.sep * u
    xn = jax.random.normal(k4, (n_neg, d)) @ (jnp.eye(d) + 0.3 * rot) \
        - spec.sep * u
    x = jnp.concatenate([xp, xn])
    y = jnp.concatenate([jnp.ones(n_pos), -jnp.ones(n_neg)])
    perm = jax.random.permutation(k5, n)
    x, y = x[perm], y[perm]
    # 2% label noise (class overlap)
    noise = jax.random.bernoulli(jax.random.fold_in(key, 9), 0.02, (n,))
    y = jnp.where(noise, -y, y)
    # normalize features into [0, 1] (paper setup)
    lo = jnp.min(x, axis=0, keepdims=True)
    hi = jnp.max(x, axis=0, keepdims=True)
    x = (x - lo) / jnp.maximum(hi - lo, 1e-9)
    # 80/20 split
    n_tr = int(n * 0.8)
    n_tr -= n_tr % 8
    return Dataset(x_train=x[:n_tr], y_train=y[:n_tr],
                   x_test=x[n_tr:], y_test=y[n_tr:], name=spec.name)


def load(name: str, seed: int = 0, scale: float = 1.0,
         max_d: int | None = 512) -> Dataset:
    if name not in PAPER_DATASETS:
        raise KeyError(f"unknown dataset {name!r}; one of {list(PAPER_DATASETS)}")
    return make_blobs(PAPER_DATASETS[name], seed=seed, scale=scale,
                      max_d=max_d)
