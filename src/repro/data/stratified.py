"""Stratified data-parallel sharding — the paper's partition strategy as a
first-class data-pipeline feature.

The paper's Section-3.2 insight (every partition should preserve the
global distribution) applies directly to data-parallel training: if each
DP rank's local shard is distributionally skewed, per-rank gradients are
biased and large-batch training degrades. ``assign_ranks`` runs the
landmark/stratum construction on a feature sketch of the corpus (e.g.
pooled embeddings, or token histograms for LM data) and deals every
stratum round-robin across ranks — each rank sees the global mixture.

This is the LM-substrate integration point #2 of DESIGN.md §4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import kernel_fns as kf
from repro.core import partition as part

Array = jax.Array


def assign_ranks(features: Array, n_ranks: int, n_landmarks: int = 8,
                 seed: int = 0, kernel: str = "rbf",
                 gamma: float = 1.0) -> Array:
    """Returns perm such that rank r owns features[perm[r*m:(r+1)*m]].

    features: (N, d) sketch of the corpus items (one row per shard-able
    unit — documents, shards, or examples).
    """
    n = features.shape[0]
    if n % n_ranks != 0:
        raise ValueError(f"n_ranks={n_ranks} must divide N={n}")
    spec = kf.KernelSpec(name=kernel, gamma=gamma)
    plan = part.make_plan(spec, features, n_landmarks, n_ranks,
                          jax.random.PRNGKey(seed))
    return plan.perm


def distribution_skew(features: Array, perm: Array, n_ranks: int) -> Array:
    """Max over ranks of || mean_rank - mean_global || — the first-order
    distribution preservation metric the paper optimizes. Lower is better;
    tests assert stratified < random."""
    n, d = features.shape
    m = n // n_ranks
    xp = features[perm].reshape(n_ranks, m, d)
    means = jnp.mean(xp, axis=1)
    g = jnp.mean(features, axis=0)
    return jnp.max(jnp.linalg.norm(means - g[None, :], axis=1))
