from repro.data import lm, stratified, streaming, synthetic

__all__ = ["lm", "stratified", "streaming", "synthetic"]
