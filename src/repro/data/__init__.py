from repro.data import lm, stratified, synthetic

__all__ = ["lm", "stratified", "synthetic"]
