"""Prefetching shard loader + fixed-slab re-slabber.

:class:`PrefetchLoader` walks a :class:`~repro.data.streaming.sources.ShardedSource`
shard by shard while a background executor keeps a bounded window of
``depth`` reads in flight — the double buffer that overlaps host shard
I/O with device compute. Determinism hooks mirror the rest of the repo:

* ``executor`` — any ``submit()``-shaped pool. Default is an owned
  single worker thread; chaos tests inject :class:`SerialExecutor` so
  reads happen inline at a deterministic point.
* ``clock`` — timestamp function for the shard-read latency histogram.
* ``faults`` — a :class:`repro.distributed.faults.FaultPlan`; each read
  passes through the ``data.prefetch`` site so plans can kill or delay
  a specific shard read (`Preemption` propagates out of ``__iter__``).

Observability (satellite 1): every read runs under a ``data.shard``
span and, when a ``MetricsRegistry`` is supplied, feeds a
``data.prefetch.depth`` gauge, a ``data.shard.read_s`` histogram and a
``data.rows`` counter.

:class:`ByteAccountant` tracks live host bytes held by the plane
(queue + slab carry) with a high-water mark — the number the
beyond-RAM acceptance test compares against ``source.total_bytes``.

:func:`iter_slabs` re-cuts the shard stream into fixed-size
:class:`Slab` rows-blocks whose boundaries are global row indices, not
shard boundaries. That makes downstream accumulation order a function
of (M, slab_rows) only — bitwise invariant to how the data was
sharded — and lets a resume skip whole shards that precede
``start_row`` without reading them.
"""
from __future__ import annotations

import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.observe import span

__all__ = ["PrefetchLoader", "SerialExecutor", "ByteAccountant", "Slab",
           "iter_slabs"]


class SerialExecutor:
    """Deterministic drop-in for ``ThreadPoolExecutor``: runs the task
    inline at ``submit()`` time. Chaos tests use it so a ``FaultPlan``
    kill fires at a reproducible point in the shard walk."""

    def submit(self, fn, *args, **kwargs) -> Future:
        fut: Future = Future()
        try:
            fut.set_result(fn(*args, **kwargs))
        except BaseException as exc:   # Preemption must propagate too
            fut.set_exception(exc)
        return fut

    def shutdown(self, wait: bool = True) -> None:
        del wait


class ByteAccountant:
    """Live host-byte ledger with a high-water mark.

    The loader charges each shard when its read completes and releases
    it when the consumer moves past it; ``iter_slabs`` additionally
    charges its carry buffer. ``peak`` is therefore the most data-plane
    host memory that was ever live at once — what the beyond-RAM test
    asserts stays under the dataset size.
    """

    def __init__(self) -> None:
        self.current = 0
        self.peak = 0

    def hold(self, n_bytes: int) -> None:
        self.current += int(n_bytes)
        if self.current > self.peak:
            self.peak = self.current

    def release(self, n_bytes: int) -> None:
        self.current -= int(n_bytes)
        if self.current < 0:
            raise RuntimeError(
                f"ByteAccountant released more than held ({self.current})")


def _shard_bytes(x: np.ndarray, y: np.ndarray) -> int:
    return int(x.size) * x.dtype.itemsize + int(y.size) * y.dtype.itemsize


class PrefetchLoader:
    """Iterate ``(shard_index, x, y)`` with ≤ ``depth`` reads in flight.

    Iteration is single-use per instance; construct a fresh loader to
    re-walk the source. ``start_shard`` skips earlier shards without
    reading them (resume path).
    """

    def __init__(self, source, *, depth: int = 2, start_shard: int = 0,
                 executor=None, metrics=None, faults=None,
                 clock=time.perf_counter,
                 accountant: ByteAccountant | None = None):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self.source = source
        self.depth = int(depth)
        self.start_shard = int(start_shard)
        self._owned = executor is None
        self.executor = (ThreadPoolExecutor(max_workers=1)
                         if executor is None else executor)
        self.metrics = metrics
        self.faults = faults
        self.clock = clock
        self.accountant = ByteAccountant() if accountant is None else accountant

    # -- instruments -----------------------------------------------------
    def _gauge(self, value: int) -> None:
        if self.metrics is not None:
            self.metrics.gauge("data.prefetch.depth").set(value)

    def _observe_read(self, seconds: float, rows: int) -> None:
        if self.metrics is not None:
            self.metrics.histogram("data.shard.read_s").observe(seconds)
            self.metrics.counter("data.rows").inc(rows)

    # -- shard read task -------------------------------------------------
    def _read(self, index: int):
        if self.faults is not None:
            self.faults.site("data.prefetch", shard=index)
        t0 = self.clock()
        with span("data.shard", shard=index):
            x, y = self.source.read_shard(index)
            # materialize memmap pages now, on the prefetch thread, so
            # the consumer never blocks on disk
            x = np.ascontiguousarray(x)
            y = np.ascontiguousarray(y)
        self._observe_read(self.clock() - t0, int(y.shape[0]))
        return x, y

    # -- iteration -------------------------------------------------------
    def __iter__(self) -> Iterator[tuple[int, np.ndarray, np.ndarray]]:
        n = len(self.source.shard_sizes())
        pending: list[tuple[int, Future]] = []
        nxt = self.start_shard
        try:
            while pending or nxt < n:
                while nxt < n and len(pending) < self.depth:
                    pending.append((nxt, self.executor.submit(self._read, nxt)))
                    nxt += 1
                    self._gauge(len(pending))
                index, fut = pending.pop(0)
                x, y = fut.result()
                self._gauge(len(pending))
                self.accountant.hold(_shard_bytes(x, y))
                try:
                    yield index, x, y
                finally:
                    self.accountant.release(_shard_bytes(x, y))
        finally:
            if self._owned:
                self.executor.shutdown(wait=True)


@dataclass
class Slab:
    """A fixed-size block of the global row stream.

    ``start`` is the global index of row 0; rows ``n_valid:`` are
    zero-padding (zero rows contribute nothing to ODM sums — the same
    convention as ``dsvrg._pad_batches``).
    """
    start: int
    x: np.ndarray
    y: np.ndarray
    n_valid: int


def _check_labels(y: np.ndarray, shard: int) -> None:
    bad = ~np.isin(y, (-1.0, 1.0))
    if bad.any():
        raise ValueError(
            f"shard {shard}: labels must be exactly -1/+1; "
            f"{int(bad.sum())} of {y.shape[0]} rows violate this")


def iter_slabs(source, slab_rows: int, *, start_row: int = 0,
               depth: int = 2, executor=None, metrics=None, faults=None,
               clock=time.perf_counter,
               accountant: ByteAccountant | None = None) -> Iterator[Slab]:
    """Yield :class:`Slab` blocks of exactly ``slab_rows`` rows.

    Slab k covers global rows ``[k * slab_rows, (k+1) * slab_rows)``
    regardless of the source's shard layout; the final slab is
    zero-padded and carries ``n_valid < slab_rows``. ``start_row`` must
    be a slab boundary — shards wholly before it are skipped unread.
    """
    if slab_rows <= 0:
        raise ValueError(f"slab_rows must be positive, got {slab_rows}")
    if start_row % slab_rows:
        raise ValueError(
            f"start_row ({start_row}) must be a multiple of slab_rows "
            f"({slab_rows})")
    sizes = source.shard_sizes()
    M = source.n_rows
    if start_row >= M:
        return
    # first shard that overlaps [start_row, M)
    first, seen = 0, 0
    while first < len(sizes) and seen + sizes[first] <= start_row:
        seen += sizes[first]
        first += 1

    acct = ByteAccountant() if accountant is None else accountant
    loader = PrefetchLoader(source, depth=depth, start_shard=first,
                            executor=executor, metrics=metrics,
                            faults=faults, clock=clock, accountant=acct)
    d = source.n_features
    dtype = np.dtype(source.dtype)
    carry_x = np.zeros((slab_rows, d), dtype=dtype)
    carry_y = np.zeros((slab_rows,), dtype=dtype)
    fill = 0
    pos = start_row            # global row index of the next carry row
    carry_bytes = carry_x.nbytes + carry_y.nbytes
    acct.hold(carry_bytes)
    try:
        for index, x, y in loader:
            _check_labels(np.asarray(y, dtype=np.float64), index)
            shard_lo = seen if index == first else None
            off = start_row - shard_lo if shard_lo is not None else 0
            row = off
            rows = x.shape[0]
            while row < rows:
                take = min(slab_rows - fill, rows - row)
                carry_x[fill:fill + take] = x[row:row + take]
                carry_y[fill:fill + take] = y[row:row + take]
                fill += take
                row += take
                if fill == slab_rows:
                    yield from _emit(acct, pos, carry_x, carry_y, slab_rows)
                    pos += slab_rows
                    fill = 0
            if index == first:
                seen = None    # offset applies only to the first shard
        if fill:
            carry_x[fill:] = 0
            carry_y[fill:] = 0
            yield from _emit(acct, pos, carry_x, carry_y, fill)
    finally:
        acct.release(carry_bytes)


def _emit(acct: ByteAccountant, pos: int, carry_x: np.ndarray,
          carry_y: np.ndarray, n_valid: int) -> Iterator[Slab]:
    """Hand the consumer its OWN copy of the carry buffer. ``jnp.asarray``
    zero-copies host numpy on CPU backends, so yielding the reused carry
    directly would let the next slab's fill race whatever computation
    still reads this one. The copy is charged to the accountant for
    exactly as long as the consumer holds the yield."""
    sx, sy = carry_x.copy(), carry_y.copy()
    n_bytes = sx.nbytes + sy.nbytes
    acct.hold(n_bytes)
    try:
        yield Slab(pos, sx, sy, n_valid)
    finally:
        acct.release(n_bytes)
