"""Out-of-core streaming data plane: sharded sources, bounded
prefetch, and one-pass partitioning (see ROADMAP open item 2).

Quickstart::

    from repro.data import streaming

    src = streaming.SyntheticSource(n_rows=2_000_000, n_features=18,
                                    shard_rows=65536, seed=0)
    est = ODMEstimator(problem, route="dsvrg", cfg=cfg)
    model = est.fit(src)           # never materializes (M, d)
"""
from repro.data.streaming.loader import (ByteAccountant, PrefetchLoader,
                                         SerialExecutor, Slab, iter_slabs)
from repro.data.streaming.plan import (StreamingAssigner, StreamingPlan,
                                       assign_strata_values,
                                       reservoir_sample, sketch_landmarks,
                                       streaming_plan)
from repro.data.streaming.sources import (ArraySource, NpyShardSource,
                                          RawBinarySource, ShardedSource,
                                          SyntheticSource, is_source,
                                          materialize)

__all__ = [
    "ShardedSource", "ArraySource", "NpyShardSource", "RawBinarySource",
    "SyntheticSource", "is_source", "materialize",
    "PrefetchLoader", "SerialExecutor", "ByteAccountant", "Slab",
    "iter_slabs",
    "reservoir_sample", "sketch_landmarks", "assign_strata_values",
    "StreamingAssigner", "StreamingPlan", "streaming_plan",
]
