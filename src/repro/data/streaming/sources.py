"""Sharded data sources — the supply side of the streaming data plane.

A :class:`ShardedSource` is anything that can hand back shard ``i`` of a
(M, d) training set as host numpy arrays without ever materializing the
whole matrix: the loader (:mod:`repro.data.streaming.loader`) pulls
shards through a bounded prefetch queue, the one-pass partitioner
(:mod:`repro.data.streaming.plan`) sketches them, and the streaming
solver drivers (``core.dsvrg._solve_stream`` /
``core.baselines._cascade_solve_stream``) consume them slab by slab.

Four concrete sources cover the supported storage shapes:

* :class:`ArraySource` — in-memory arrays presented as shards. The
  "same data presented the other way" half of every streaming-vs-
  in-memory parity test, and the zero-setup path for small jobs.
* :class:`NpyShardSource` — one ``.npy`` pair per shard, opened with
  ``np.load(mmap_mode="r")`` so a read touches only that shard's pages.
  :meth:`NpyShardSource.write` lays a dataset out in this format.
* :class:`RawBinarySource` — headerless binary (the LIBSVM-converted
  dump format), one features + one labels file per shard via
  ``np.memmap``; ``n_features``/``dtype`` come from the caller.
* :class:`SyntheticSource` — generates shard ``i`` on the fly from a
  seed (no disk at all): two blob classes separated along a zero-mean
  direction, the same construction as :mod:`repro.data.synthetic` but
  shard-deterministic, so tests and benches can stream "datasets"
  orders of magnitude larger than host RAM.

Every source counts per-shard reads (``source.reads``) — the resume
tests assert completed shards are *not* re-read — and fingerprints
itself (:meth:`fingerprint`) for the resume provenance check.
"""
from __future__ import annotations

import os
from typing import Iterable, Protocol, runtime_checkable

import numpy as np

__all__ = ["ShardedSource", "ArraySource", "NpyShardSource",
           "RawBinarySource", "SyntheticSource", "is_source",
           "materialize"]


@runtime_checkable
class ShardedSource(Protocol):
    """Structural protocol every source implements (and ducks satisfy)."""

    n_rows: int
    n_features: int

    def shard_sizes(self) -> tuple[int, ...]:
        """Rows per shard; sums to ``n_rows``."""
        ...

    def read_shard(self, index: int) -> tuple[np.ndarray, np.ndarray]:
        """Shard ``index`` as host arrays ``(x (rows, d), y (rows,))``."""
        ...

    def fingerprint(self) -> dict:
        """JSON-able identity for resume provenance."""
        ...


def is_source(obj) -> bool:
    """Duck check used by ``ODMEstimator.fit`` to detect a source in the
    ``x`` slot (arrays have ``shape``; sources have ``read_shard``)."""
    return (hasattr(obj, "read_shard") and hasattr(obj, "shard_sizes")
            and hasattr(obj, "n_rows"))


class _SourceBase:
    """Shared bookkeeping: read counters, byte math, iteration."""

    n_rows: int
    n_features: int
    dtype: np.dtype

    def _init_counts(self, sizes: tuple[int, ...]) -> None:
        self._sizes = tuple(int(s) for s in sizes)
        if any(s <= 0 for s in self._sizes):
            raise ValueError(f"every shard needs >= 1 row, got {self._sizes}")
        self.n_rows = sum(self._sizes)
        #: per-shard read counts — chaos tests assert completed shards
        #: are not re-read after a resume
        self.reads = [0] * len(self._sizes)

    @property
    def n_shards(self) -> int:
        return len(self._sizes)

    def shard_sizes(self) -> tuple[int, ...]:
        return self._sizes

    @property
    def total_bytes(self) -> int:
        """Feature + label bytes of the full dataset (the beyond-RAM
        budget tests compare the loader's peak against this)."""
        item = np.dtype(self.dtype).itemsize
        return self.n_rows * (self.n_features + 1) * item

    def read_shard(self, index: int) -> tuple[np.ndarray, np.ndarray]:
        if not 0 <= index < self.n_shards:
            raise IndexError(
                f"shard {index} out of range [0, {self.n_shards})")
        self.reads[index] += 1
        x, y = self._read(index)
        if x.shape != (self._sizes[index], self.n_features):
            raise ValueError(
                f"shard {index}: expected x {(self._sizes[index], self.n_features)}, "
                f"got {x.shape}")
        if y.shape != (self._sizes[index],):
            raise ValueError(
                f"shard {index}: expected y ({self._sizes[index]},), got "
                f"{y.shape}")
        return x, y

    def _read(self, index: int):   # pragma: no cover - abstract
        raise NotImplementedError


def materialize(source: ShardedSource) -> tuple[np.ndarray, np.ndarray]:
    """Concatenate every shard (tests / small jobs only — this is exactly
    the global load the streaming plane exists to avoid)."""
    xs, ys = zip(*(source.read_shard(i)
                   for i in range(len(source.shard_sizes()))))
    return np.concatenate(xs, axis=0), np.concatenate(ys, axis=0)


class ArraySource(_SourceBase):
    """In-memory arrays presented through the source protocol.

    ``shard_rows=None`` presents the whole set as one shard; otherwise
    contiguous row blocks of ``shard_rows`` (ragged tail allowed).
    Shards are views — no copy until the loader materializes one.
    """

    def __init__(self, x, y, shard_rows: int | None = None):
        x = np.asarray(x)
        y = np.asarray(y)
        if x.ndim != 2 or y.ndim != 1 or x.shape[0] != y.shape[0]:
            raise ValueError(
                f"need x (M, d) and y (M,), got {x.shape} / {y.shape}")
        self._x, self._y = x, y
        self.n_features = int(x.shape[1])
        self.dtype = x.dtype
        M = int(x.shape[0])
        rows = M if shard_rows is None else int(shard_rows)
        if rows <= 0:
            raise ValueError(f"shard_rows must be positive, got {rows}")
        sizes = [rows] * (M // rows)
        if M % rows:
            sizes.append(M % rows)
        self._init_counts(tuple(sizes))
        self._starts = np.concatenate([[0], np.cumsum(self._sizes)])

    def _read(self, index: int):
        lo, hi = self._starts[index], self._starts[index + 1]
        return self._x[lo:hi], self._y[lo:hi]

    def fingerprint(self) -> dict:
        return {
            "kind": "array",
            "shape": [self.n_rows, self.n_features],
            "dtype": str(self.dtype),
            "shards": list(self._sizes),
            "x_sum": float(np.sum(self._x, dtype=np.float64)),
            "y_sum": float(np.sum(self._y, dtype=np.float64)),
        }


class NpyShardSource(_SourceBase):
    """Memory-mapped ``.npy`` shard pairs.

    ``pairs`` is a sequence of ``(x_path, y_path)``. Headers are parsed
    eagerly (cheap) for sizes/dtype; row data is paged in lazily by the
    OS on read, so the resident set stays bounded by what the loader
    holds, not by the dataset.
    """

    def __init__(self, pairs: Iterable[tuple[str, str]]):
        self.pairs = [(os.fspath(a), os.fspath(b)) for a, b in pairs]
        if not self.pairs:
            raise ValueError("NpyShardSource needs >= 1 shard pair")
        sizes = []
        d = dtype = None
        for xp, yp in self.pairs:
            xm = np.load(xp, mmap_mode="r")
            ym = np.load(yp, mmap_mode="r")
            if xm.ndim != 2 or ym.ndim != 1 or xm.shape[0] != ym.shape[0]:
                raise ValueError(
                    f"shard {xp!r}/{yp!r}: need (rows, d) + (rows,), got "
                    f"{xm.shape} / {ym.shape}")
            if d is None:
                d, dtype = int(xm.shape[1]), xm.dtype
            elif int(xm.shape[1]) != d:
                raise ValueError(
                    f"shard {xp!r} has d={xm.shape[1]}, first shard had {d}")
            sizes.append(int(xm.shape[0]))
        self.n_features = d
        self.dtype = dtype
        self._init_counts(tuple(sizes))

    def _read(self, index: int):
        xp, yp = self.pairs[index]
        return (np.load(xp, mmap_mode="r"), np.load(yp, mmap_mode="r"))

    def fingerprint(self) -> dict:
        return {
            "kind": "npy",
            "paths": [list(p) for p in self.pairs],
            "shards": list(self._sizes),
            "d": self.n_features,
            "dtype": str(self.dtype),
        }

    @staticmethod
    def write(directory: str, x, y, shard_rows: int) -> "NpyShardSource":
        """Lay ``(x, y)`` out as npy shards under ``directory``."""
        x = np.asarray(x)
        y = np.asarray(y)
        os.makedirs(directory, exist_ok=True)
        pairs = []
        for s, lo in enumerate(range(0, x.shape[0], int(shard_rows))):
            hi = min(lo + int(shard_rows), x.shape[0])
            xp = os.path.join(directory, f"shard_{s:05d}_x.npy")
            yp = os.path.join(directory, f"shard_{s:05d}_y.npy")
            np.save(xp, x[lo:hi])
            np.save(yp, y[lo:hi])
            pairs.append((xp, yp))
        return NpyShardSource(pairs)


class RawBinarySource(_SourceBase):
    """Headerless binary shard pairs via ``np.memmap``.

    Each pair is ``(x_path, y_path)`` holding ``rows * n_features`` and
    ``rows`` items of ``dtype`` respectively; ``rows`` is inferred from
    the label file size.
    """

    def __init__(self, pairs: Iterable[tuple[str, str]], n_features: int,
                 dtype=np.float32):
        self.pairs = [(os.fspath(a), os.fspath(b)) for a, b in pairs]
        if not self.pairs:
            raise ValueError("RawBinarySource needs >= 1 shard pair")
        if n_features <= 0:
            raise ValueError(f"n_features must be positive, got {n_features}")
        self.n_features = int(n_features)
        self.dtype = np.dtype(dtype)
        item = self.dtype.itemsize
        sizes = []
        for xp, yp in self.pairs:
            rows, rem = divmod(os.path.getsize(yp), item)
            if rem:
                raise ValueError(
                    f"label file {yp!r} is not a whole number of "
                    f"{self.dtype} items")
            want = rows * self.n_features * item
            if os.path.getsize(xp) != want:
                raise ValueError(
                    f"feature file {xp!r} holds {os.path.getsize(xp)} bytes, "
                    f"expected {want} ({rows} rows x {self.n_features})")
            sizes.append(int(rows))
        self._init_counts(tuple(sizes))

    def _read(self, index: int):
        xp, yp = self.pairs[index]
        rows = self._sizes[index]
        x = np.memmap(xp, dtype=self.dtype, mode="r",
                      shape=(rows, self.n_features))
        y = np.memmap(yp, dtype=self.dtype, mode="r", shape=(rows,))
        return x, y

    def fingerprint(self) -> dict:
        return {
            "kind": "raw",
            "paths": [list(p) for p in self.pairs],
            "shards": list(self._sizes),
            "d": self.n_features,
            "dtype": str(self.dtype),
        }


class SyntheticSource(_SourceBase):
    """On-the-fly generator source: shard ``i`` is a pure function of
    ``(seed, i)``, so an arbitrarily large "dataset" occupies zero disk
    and exactly one shard of host memory at a time.

    Construction mirrors :func:`repro.data.synthetic.make_blobs` where
    it matters for the linear route: ±1 labels at ``balance``, features
    ``0.5 + noise + y * sep * u`` with ``u`` a zero-mean unit direction
    (the data midpoint sits on the all-ones shift, which a bias-free
    linear ODM cannot represent — a zero-mean boundary normal keeps the
    problem homogeneous-separable). Unlike ``make_blobs`` there is no
    global normalization pass: every statistic is shard-local and
    deterministic, which is what makes single-scan streaming exact.
    """

    def __init__(self, n_rows: int, n_features: int, shard_rows: int,
                 seed: int = 0, sep: float = 1.0, balance: float = 0.5,
                 noise: float = 0.15, dtype=np.float32):
        if n_rows <= 0 or n_features <= 0 or shard_rows <= 0:
            raise ValueError(
                f"n_rows/n_features/shard_rows must be positive, got "
                f"{n_rows}/{n_features}/{shard_rows}")
        self.n_features = int(n_features)
        self.dtype = np.dtype(dtype)
        self.seed = int(seed)
        self.sep = float(sep)
        self.balance = float(balance)
        self.noise = float(noise)
        n_rows, shard_rows = int(n_rows), int(shard_rows)
        sizes = [shard_rows] * (n_rows // shard_rows)
        if n_rows % shard_rows:
            sizes.append(n_rows % shard_rows)
        self._init_counts(tuple(sizes))
        # class direction: shared across shards, derived from seed only
        rng = np.random.default_rng([self.seed, 0x0D1])
        u = rng.standard_normal(self.n_features)
        u = u - u.mean()
        self._u = (u / np.linalg.norm(u)).astype(self.dtype)

    def _read(self, index: int):
        rows = self._sizes[index]
        rng = np.random.default_rng([self.seed, 1 + index])
        y = np.where(rng.random(rows) < self.balance, 1.0, -1.0)
        z = rng.standard_normal((rows, self.n_features))
        x = 0.5 + self.noise * (z + (self.sep * y)[:, None] * self._u)
        return x.astype(self.dtype), y.astype(self.dtype)

    def fingerprint(self) -> dict:
        return {
            "kind": "synthetic",
            "n_rows": self.n_rows,
            "d": self.n_features,
            "shards": list(self._sizes),
            "seed": self.seed,
            "sep": self.sep,
            "balance": self.balance,
            "noise": self.noise,
            "dtype": str(self.dtype),
        }
