"""One-pass streaming partitioner: Eqn. 8 landmarks + Eqn. 7 strata
from a single scan over shards, with no global materialization.

Dense :mod:`repro.core.partition` needs the whole (M, d) matrix twice:
once for greedy det-max landmark selection (Eqn. 8, pivoted Cholesky
over all rows) and once for stratum assignment (Eqn. 7 argmin RKHS
distance). The streaming versions replace each global pass:

* **Landmarks** — :func:`sketch_landmarks` maintains an Algorithm-R
  reservoir while the shards stream by, then runs the *exact* pivoted
  Cholesky greedy selection on the reservoir. The sketch is unbiased
  uniform over rows; when ``reservoir >= n_rows`` the reservoir IS the
  stream in order, so the selected landmark set matches the dense
  Eqn. 8 result on the same data exactly (pinned by parity tests).
* **Strata + partitions** — :class:`StreamingAssigner` assigns each
  arriving row its stratum (same argmin-distance formula as
  ``partition.assign_strata``) and then a partition by per-stratum
  round-robin over running counts. Assignment is integer-exact and
  depends only on each row's global position within its stratum, never
  on shard boundaries — the same data sharded two ways gets bitwise
  identical partition labels.

:func:`streaming_plan` glues both into one scan: pass 1 sketches the
landmarks, after which assignment is a pure per-row function applied
shard-locally as the solver streams the data (no second global pass is
stored — strata fall out of the rows the consumer already holds).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import partition as partition_mod

__all__ = ["reservoir_sample", "sketch_landmarks", "assign_strata_values",
           "StreamingAssigner", "StreamingPlan", "streaming_plan"]


def reservoir_sample(source, k: int, *, seed: int = 0,
                     loader=None) -> np.ndarray:
    """Uniform row sample of size ``min(k, n_rows)`` in one scan
    (Algorithm R, deterministic in ``seed`` and the stream order).

    Returns the sampled rows as a dense ``(s, d)`` array. When
    ``k >= n_rows`` this degenerates to the stream itself in order —
    the property the exact-parity tests lean on.
    """
    if k <= 0:
        raise ValueError(f"reservoir size must be positive, got {k}")
    rng = np.random.default_rng([int(seed), 0x5EED])
    res = np.zeros((min(k, source.n_rows), source.n_features),
                   dtype=source.dtype)
    filled = 0      # rows placed so far while the reservoir fills
    seen = 0        # total rows seen
    shards = (loader if loader is not None else
              ((i, *source.read_shard(i))
               for i in range(len(source.shard_sizes()))))
    for _, x, _ in shards:
        for row in np.asarray(x):
            if filled < res.shape[0]:
                res[filled] = row
                filled += 1
            else:
                j = rng.integers(0, seen + 1)
                if j < res.shape[0]:
                    res[j] = row
            seen += 1
    return res


def sketch_landmarks(spec, source, n_landmarks: int, *,
                     reservoir: int = 4096, seed: int = 0,
                     jitter: float = 1e-6, loader=None) -> jnp.ndarray:
    """Eqn. 8 landmark *values* ``(n_landmarks, d)`` from one scan.

    Reservoir-sample ``reservoir`` rows, then run the exact greedy
    det-max (pivoted Cholesky) of :func:`repro.core.partition.select_landmarks`
    on the sample. Dense selection returns row *indices*; a stream has
    no stable global index to hand back, so this returns the landmark
    rows themselves — every downstream consumer only ever uses
    ``x[landmarks]`` anyway.
    """
    if reservoir < n_landmarks:
        raise ValueError(
            f"reservoir ({reservoir}) must be >= n_landmarks "
            f"({n_landmarks})")
    sample = reservoir_sample(source, reservoir, seed=seed, loader=loader)
    sample_j = jnp.asarray(sample)
    idx = partition_mod.select_landmarks(spec, sample_j, n_landmarks,
                                         jitter=jitter)
    return sample_j[idx]


def assign_strata_values(spec, x, z) -> jnp.ndarray:
    """Eqn. 7 stratum for each row of ``x`` against landmark *values*
    ``z (S, d)`` — same RKHS-distance argmin as
    :func:`repro.core.partition.assign_strata`, which takes indices."""
    from repro.core import kernel_fns as kf
    x = jnp.asarray(x)
    z = jnp.asarray(z)
    kxz = kf.gram(spec, x, z)
    kzz = kf.gram_diag(spec, z)
    d2 = kzz[None, :] - 2.0 * kxz
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


class StreamingAssigner:
    """Stateful per-stratum round-robin partition assignment.

    Row ``r`` in stratum ``s`` gets partition ``c_s mod K`` where
    ``c_s`` counts rows of stratum ``s`` seen so far in stream order.
    Integer arithmetic only — the assignment for a given row depends on
    its global position within its stratum, so re-sharding the same
    stream leaves every label bitwise unchanged. This is the
    deterministic streaming analogue of
    :func:`repro.core.partition.stratified_partitions` (which breaks
    ties randomly): both spread each stratum evenly over the K
    partitions, the streaming rule just fixes the order.
    """

    def __init__(self, spec, landmarks, n_partitions: int):
        if n_partitions < 1:
            raise ValueError(
                f"n_partitions must be >= 1, got {n_partitions}")
        self.spec = spec
        self.landmarks = jnp.asarray(landmarks)
        self.n_partitions = int(n_partitions)
        self._counts = np.zeros(int(self.landmarks.shape[0]),
                                dtype=np.int64)

    def assign(self, x) -> tuple[np.ndarray, np.ndarray]:
        """Strata + partition labels for the next batch of rows, in
        stream order. Returns ``(stratum (n,), part (n,))`` int arrays.
        """
        stratum = np.asarray(assign_strata_values(self.spec, x,
                                                  self.landmarks))
        part = np.empty(stratum.shape[0], dtype=np.int32)
        # vectorized running count: offset of each row within the rows
        # of its stratum *inside this batch*, plus the carried count
        for s in np.unique(stratum):
            where = np.flatnonzero(stratum == s)
            part[where] = (self._counts[s] + np.arange(where.size)) \
                % self.n_partitions
            self._counts[s] += where.size
        return stratum, part


class StreamingPlan(NamedTuple):
    """Output of :func:`streaming_plan`: landmark values + a primed
    assigner. Counterpart of the dense ``partition.PartitionPlan``
    (which stores a full perm — a stream assigns lazily instead)."""
    landmarks: jnp.ndarray
    assigner: StreamingAssigner
    n_partitions: int


def streaming_plan(spec, source, n_partitions: int, n_landmarks: int, *,
                   reservoir: int = 4096, seed: int = 0,
                   loader=None) -> StreamingPlan:
    """One-scan plan: sketch Eqn. 8 landmarks, return an assigner that
    labels rows shard-locally as the solver streams them."""
    z = sketch_landmarks(spec, source, n_landmarks, reservoir=reservoir,
                         seed=seed, loader=loader)
    return StreamingPlan(z, StreamingAssigner(spec, z, n_partitions),
                         int(n_partitions))
