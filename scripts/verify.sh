#!/usr/bin/env bash
# The ROADMAP verify commands as executable one-liners.
#
#   scripts/verify.sh          # fast tier (skips the multi-minute SPMD
#                              # battery and other slow suites)
#   scripts/verify.sh tier1    # full tier-1 suite
#   scripts/verify.sh lint     # repo-convention lint + the quick static
#                              # analysis battery (tests/test_analysis.py)
#   scripts/verify.sh chaos    # fault-injection battery only (the `chaos`
#                              # marker: kill/resume + crash-window tests)
#   scripts/verify.sh perf     # quick-tier benchmarks -> bench_out/, then
#                              # the regression gate against the committed
#                              # baselines (benchmarks/baselines/)
#
# Markers are registered in pytest.ini; tests/conftest.py also prepends
# src/ to sys.path, but exporting PYTHONPATH here keeps subprocess-based
# tests (the SPMD battery) working too.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
case "${1:-fast}" in
  fast)  exec python -m pytest -x -q -m "not slow" ;;
  tier1) exec python -m pytest -x -q ;;
  lint)
    python scripts/lint.py
    exec python -m pytest -x -q tests/test_analysis.py -m "not slow"
    ;;
  chaos) exec python -m pytest -x -q -m chaos ;;
  perf)
    python -m benchmarks.run --quick --out-dir bench_out
    exec python scripts/bench_gate.py bench_out benchmarks/baselines
    ;;
  *) echo "usage: $0 [fast|tier1|lint|chaos|perf]" >&2; exit 2 ;;
esac
