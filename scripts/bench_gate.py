#!/usr/bin/env python
"""CI perf gate: compare a bench run against committed baselines.

Usage::

    python scripts/bench_gate.py CURRENT_DIR BASELINE_DIR \
        [--wall-rtol R] [--wall-floor-s S] [--bytes-rtol R]

Exits 0 when every baseline bench is present and within the noise band,
1 on any regression (see :mod:`repro.observe.trend` for the policy).
Typical CI wiring::

    python -m benchmarks.run --quick --out-dir bench_out
    python scripts/bench_gate.py bench_out benchmarks/baselines

No jax import — the gate itself runs anywhere Python does.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.observe import trend  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("current_dir", help="directory of fresh BENCH_*.json")
    p.add_argument("baseline_dir", help="directory of committed baselines")
    p.add_argument("--wall-rtol", type=float, default=trend.WALL_RTOL,
                   help="relative wall-clock noise band (default %(default)s)")
    p.add_argument("--wall-floor-s", type=float, default=trend.WALL_FLOOR_S,
                   help="absolute wall-clock slack in seconds")
    p.add_argument("--bytes-rtol", type=float, default=trend.BYTES_RTOL,
                   help="relative peak-bytes noise band")
    p.add_argument("--bytes-floor", type=int, default=trend.BYTES_FLOOR,
                   help="absolute peak-bytes slack")
    args = p.parse_args(argv)

    findings = trend.compare_dirs(
        args.current_dir, args.baseline_dir,
        wall_rtol=args.wall_rtol, wall_floor_s=args.wall_floor_s,
        bytes_rtol=args.bytes_rtol, bytes_floor=args.bytes_floor)
    print(trend.format_report(findings))
    regressed = any(f.regressed for f in findings)
    print("bench gate:", "FAIL" if regressed else "PASS")
    return 1 if regressed else 0


if __name__ == "__main__":
    raise SystemExit(main())
