#!/usr/bin/env python
"""Repo-convention linter (see ``repro.analysis.boundary_lint``).

Usage:
    python scripts/lint.py                 # lint src/ benchmarks/ examples/ scripts/
    python scripts/lint.py FILE [FILE...]  # lint exactly these files
    python scripts/lint.py --list-rules

Exit status 1 when any violation is found — CI runs this as the first
half of the ``lint`` job. Stdlib-only (no jax import): fast enough for a
pre-commit reflex.

Suppression: ``# lint: ignore[CODE]`` on the offending line, or
``# lint: allow[CODE]`` anywhere in a file to waive a rule file-wide.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis import boundary_lint as bl  # noqa: E402


def main(argv: list[str]) -> int:
    if "--list-rules" in argv:
        for code, desc in sorted(bl.RULES.items()):
            print(f"{code}  {desc}")
        return 0
    paths = [a for a in argv if not a.startswith("-")]
    if not paths:
        paths = bl.walk_default(_ROOT)
    violations = bl.lint_paths(paths)
    for v in violations:
        print(v)
    if violations:
        print(f"\n{len(violations)} violation(s) in "
              f"{len({v.file for v in violations})} file(s). "
              f"See `python scripts/lint.py --list-rules`.")
        return 1
    print(f"lint OK ({len(paths)} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
