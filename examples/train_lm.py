"""Train a ~100M-class LM for a few hundred steps end to end, with the
paper's machinery integrated:

  * --stratified-dp : assign data shards to DP ranks with the paper's
    landmark/stratum partitioner (repro.data.stratified);
  * --odm-head      : after LM training, fit an ODM classifier head on
    pooled hidden states via the SODM solver (integration point #1).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data import lm as lmdata
from repro.models import model as M
from repro.train import steps as steps_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--odm-head", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
    state = steps_mod.TrainState.create(params, use_ef=False)
    import dataclasses
    from repro.optim import adamw
    tc = steps_mod.TrainConfig(optimizer=adamw.AdamWConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps))
    step = jax.jit(steps_mod.make_train_step(cfg, tc))
    dc = lmdata.LMDataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                             global_batch=args.batch)

    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        state, mets = step(state, lmdata.batch_at(dc, i))
        if i == 0:
            first = float(mets["loss"])
        last = float(mets["loss"])
        if i % 25 == 0:
            print(f"step {i:4d} loss {last:.4f} ({time.time() - t0:.0f}s)",
                  flush=True)
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")

    if args.odm_head:
        # integration point: ODM margin-distribution classifier on pooled
        # features, trained through the unified API
        from repro.api import ODMEstimator, ProblemSpec
        from repro.core import sodm
        print("fitting ODM head on pooled hidden states...")
        B, S, n = 8, args.seq_len, 32
        feats, labels = [], []
        for i in range(n):
            b = lmdata.batch_at(dc, 1000 + i)
            logits, _ = M.logits_fn(state["params"], b, cfg)
            pooled = jnp.mean(logits, axis=1)          # (B, V) proxy feature
            feats.append(pooled[:, :64])
            # synthetic binary target: does the sequence end high-token?
            labels.append(jnp.sign(b["tokens"][:, -1] - cfg.vocab // 2 + 0.5))
        xf = jnp.concatenate(feats).astype(jnp.float32)
        yf = jnp.concatenate(labels).astype(jnp.float32)
        Mn = xf.shape[0] - xf.shape[0] % 8
        xf, yf = xf[:Mn], yf[:Mn]
        est = ODMEstimator(
            ProblemSpec.create("rbf", gamma=0.5, lam=10.0),
            cfg=sodm.SODMConfig(p=2, levels=2, n_landmarks=4))
        est.fit(xf, yf, jax.random.PRNGKey(1))
        print(f"ODM head train accuracy: {est.score(xf, yf):.3f}")


if __name__ == "__main__":
    main()
