"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b
Delegates to the production serving launcher (repro.launch.serve).
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or
                  ["--arch", "qwen3-0.6b", "--batch", "4",
                   "--prompt-len", "32", "--gen", "16"]))
