"""Serve a small *language model* with batched requests (prefill + decode).

This drives the transformer scaffold's serving launcher
(repro.launch.serve) — KV-cache prefill plus a jit'd decode loop. The
paper's model (ODM) has its own serving subsystem with compiled
artifacts, Nyström compression and a microbatching scorer: see
``examples/serve_odm.py`` and ``repro.serve``.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or
                  ["--arch", "qwen3-0.6b", "--batch", "4",
                   "--prompt-len", "32", "--gen", "16"]))
