"""Quickstart: train SODM through the unified API and evaluate.

    PYTHONPATH=src python examples/quickstart.py

One front door for every training route: describe the problem with a
``ProblemSpec``, hand it to ``ODMEstimator``, get back a deployable
``FittedODM`` artifact plus a uniform ``FitReport`` — whichever solver
the registry resolves (Alg. 1 partitioned dual CD here; Alg. 2 DSVRG for
the linear kernel below).
"""
import jax

from repro.api import ODMEstimator, ProblemSpec
from repro.core import dsvrg, kernel_fns as kf, sodm
from repro.data import synthetic


def main():
    # a stand-in for the paper's `phishing` set (11k x 68, scaled to CPU)
    ds = synthetic.load("phishing", scale=0.05)
    M = ds.x_train.shape[0] - ds.x_train.shape[0] % 8
    x, y = ds.x_train[:M], ds.y_train[:M]
    print(f"dataset: {ds.name}  train={x.shape}  test={ds.x_test.shape}")

    # the 10-line front door: spec -> estimator -> artifact + report
    problem = ProblemSpec.create("rbf", gamma=kf.median_gamma(x),
                                 lam=100.0, theta=0.1, ups=0.5)
    est = ODMEstimator(problem, cfg=sodm.SODMConfig(
        p=2, levels=3, n_landmarks=8, tol=1e-4, max_sweeps=200))
    model, report = est.fit(x, y, jax.random.PRNGKey(0))
    print(report.summary())
    print(f"test accuracy: {est.score(ds.x_test, ds.y_test):.4f}")

    # linear-kernel path (DSVRG, Algorithm 2) — same door, another route.
    # Large linear problems reach this route automatically; naming it
    # keeps the demo explicit.
    lin = ODMEstimator(
        ProblemSpec.create("linear", lam=100.0, theta=0.1, ups=0.5),
        route="dsvrg",
        cfg=sodm.SODMConfig(dsvrg=dsvrg.DSVRGConfig(
            n_partitions=8, epochs=8, batch=16)))   # eta <= 0: auto step
    _, rep = lin.fit(x, y, jax.random.PRNGKey(1))
    print(f"DSVRG (linear) test accuracy: "
          f"{lin.score(ds.x_test, ds.y_test):.4f} "
          f"obj history: {[round(h, 4) for h in rep.history]}")


if __name__ == "__main__":
    main()
