"""Quickstart: train SODM on a synthetic data set and evaluate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import kernel_fns as kf, odm, sodm
from repro.data import synthetic


def main():
    # a stand-in for the paper's `phishing` set (11k x 68, scaled to CPU)
    ds = synthetic.load("phishing", scale=0.05)
    M = ds.x_train.shape[0] - ds.x_train.shape[0] % 8
    x, y = ds.x_train[:M], ds.y_train[:M]
    print(f"dataset: {ds.name}  train={x.shape}  test={ds.x_test.shape}")

    spec = kf.KernelSpec(name="rbf", gamma=kf.median_gamma(x))
    params = odm.ODMParams(lam=100.0, theta=0.1, ups=0.5)
    cfg = sodm.SODMConfig(p=2, levels=3, n_landmarks=8, tol=1e-4,
                          max_sweeps=200)

    res = sodm.solve(spec, x, y, params, cfg, jax.random.PRNGKey(0))
    print(f"SODM: levels={res.levels_run} sweeps/level={res.sweeps_per_level}"
          f" final KKT={float(res.kkt):.2e}")

    pred = sodm.predict(spec, res, x, y, ds.x_test)
    acc = float(odm.accuracy(ds.y_test, pred))
    print(f"test accuracy: {acc:.4f}")

    # linear-kernel path (DSVRG, Algorithm 2)
    from repro.core import dsvrg
    dcfg = dsvrg.DSVRGConfig(n_partitions=8, epochs=8, batch=16)  # auto eta
    dres = dsvrg.solve(x, y, params, dcfg, jax.random.PRNGKey(1))
    acc2 = float(odm.accuracy(ds.y_test, jnp.sign(ds.x_test @ dres.w)))
    print(f"DSVRG (linear) test accuracy: {acc2:.4f} "
          f"obj history: {[round(float(h), 4) for h in dres.history]}")


if __name__ == "__main__":
    main()
