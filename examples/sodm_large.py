"""End-to-end SODM driver: the paper's training pipeline at scale.

    PYTHONPATH=src python examples/sodm_large.py [--rows 2000000]
    PYTHONPATH=src python examples/sodm_large.py --dense [--engine pallas]
    PYTHONPATH=src python examples/sodm_large.py --handloop [--resume]

This is the 'train a model for real' driver of deliverable (b): a
SUSY-shaped problem (the paper's 5M-row set) sized by ``--rows``.

Default path: train PAST host RAM. The data is a
:class:`repro.data.streaming.SyntheticSource` — a generator whose shard
``i`` is a pure function of ``(seed, i)``, so ``--rows`` can exceed what
the host could ever materialize (the dataset occupies zero disk and is
never resident). ``ODMEstimator.fit(source)`` streams it through the
prefetch loader into the out-of-core DSVRG route; a
:class:`~repro.data.streaming.ByteAccountant` proves the point by
printing peak resident data bytes next to the dataset's logical size.

``--dense`` keeps the previous resident-API demo (route resolution,
per-level checkpointing via the ``level_callback`` hook); ``--handloop``
keeps the hand-rolled production-runtime demo: stratified partitioning,
level-parallel solves dispatched through the speculative straggler
scheduler, per-level checkpointing, and ``--resume`` restart — the
subsystems the estimator hides.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import ODMEstimator, ProblemSpec
from repro.core import dual_cd, kernel_fns as kf, odm, partition, sodm
from repro.core.dsvrg import DSVRGConfig
from repro.data import streaming, synthetic
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.straggler import SpecConfig, SpeculativeScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=1_000_000,
                    help="streamed training rows — set this past host "
                         "RAM freely; the generator source is never "
                         "materialized")
    ap.add_argument("--features", type=int, default=18)   # SUSY's d
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--shard-rows", type=int, default=65_536)
    ap.add_argument("--dense", action="store_true",
                    help="previous resident-data estimator demo (SUSY "
                         "stand-in + sodm route) instead of streaming")
    ap.add_argument("--resume", action="store_true",
                    help="restart from the latest checkpoint (--handloop)")
    ap.add_argument("--handloop", action="store_true",
                    help="hand-rolled level loop with the speculative "
                         "straggler scheduler instead of the estimator")
    ap.add_argument("--ckpt-dir", default="/tmp/sodm_large_ckpt")
    ap.add_argument("--scale", type=float, default=0.002)   # of 5M rows
    ap.add_argument("--engine", default="scalar",
                    choices=("scalar", "block", "pallas"),
                    help="local solver for --dense/--handloop: "
                         "paper-faithful scalar CD, the jnp block "
                         "oracle, or the Pallas greedy block-CD tile "
                         "kernel")
    args = ap.parse_args()
    if args.handloop and args.engine == "block":
        ap.error("--handloop dispatches per-partition solves (scalar | "
                 "pallas); the block engine is a level solver — drop "
                 "--handloop to use it")

    if not (args.dense or args.handloop):
        return stream(args)

    ds = synthetic.load("SUSY", scale=args.scale)
    M = ds.x_train.shape[0] - ds.x_train.shape[0] % 32
    x, y = ds.x_train[:M], ds.y_train[:M]
    print(f"SUSY stand-in: train={x.shape}")

    spec = kf.KernelSpec(name="rbf", gamma=kf.median_gamma(x))
    params = odm.ODMParams(lam=100.0, theta=0.1, ups=0.5)
    p_factor, levels = 2, 5            # 32 partitions

    if args.handloop:
        return handloop(args, spec, x, y, params, p_factor, levels, ds)

    # --- the resident front door ------------------------------------------
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    est = ODMEstimator(
        ProblemSpec(kernel=spec, params=params),
        route="sodm",
        cfg=sodm.SODMConfig(p=p_factor, levels=levels, n_landmarks=8,
                            tol=1e-4, max_sweeps=150, engine=args.engine))

    def checkpoint_level(level, alphas):
        # same fault-tolerance contract as the hand loop: every finished
        # level is an atomic versioned restart point
        mgr.save(levels - level + 1, alphas,
                 {"level": level, "n_partitions": int(alphas.shape[0])})

    t0 = time.time()
    model, report = est.fit(x, y, jax.random.PRNGKey(0),
                            level_callback=checkpoint_level)
    print(report.summary())
    print(f"trained + compiled {model.n_sv} SVs in {time.time() - t0:.1f}s")
    print(f"final test accuracy: {est.score(ds.x_test, ds.y_test):.4f}")


def stream(args):
    """Train beyond host RAM: generator source -> out-of-core DSVRG."""
    rows = args.rows - args.rows % 256
    src = streaming.SyntheticSource(rows, args.features,
                                    shard_rows=args.shard_rows, seed=0,
                                    sep=1.5)
    print(f"generator source: {rows} rows x {args.features} features = "
          f"{src.total_bytes / 1e9:.2f} GB logical, 0 bytes resident")

    est = ODMEstimator(
        ProblemSpec(kernel=kf.KernelSpec(name="linear"),
                    params=odm.ODMParams(lam=100.0, theta=0.1, ups=0.5)),
        route="dsvrg",
        cfg=sodm.SODMConfig(engine="dsvrg", dsvrg=DSVRGConfig(
            epochs=args.epochs, batch=512, schedule="serial",
            stream_slab=8_192)))
    acct = streaming.ByteAccountant()
    t0 = time.time()
    model, report = est.fit(src, key=jax.random.PRNGKey(0),
                            accountant=acct)
    wall = time.time() - t0
    print(report.summary())
    print(f"streamed {args.epochs} epochs over {rows} rows in {wall:.1f}s "
          f"({args.epochs * rows / wall:.0f} rows/s)")
    print(f"peak resident data bytes: {acct.peak:,} "
          f"({acct.peak / src.total_bytes:.1%} of the dataset)")

    # held-out rows from the SAME generator distribution: shard i is a
    # pure function of (seed, i), so a longer source's first shards are
    # the training stream and its extra shard is fresh test data
    probe = streaming.SyntheticSource(rows + args.shard_rows,
                                      args.features,
                                      shard_rows=args.shard_rows, seed=0,
                                      sep=1.5)
    x_test, y_test = probe.read_shard(len(probe.shard_sizes()) - 1)
    acc = float(odm.accuracy(jnp.asarray(y_test),
                             model.predict(jnp.asarray(x_test))))
    print(f"held-out accuracy on {x_test.shape[0]} fresh rows: {acc:.4f}")


def handloop(args, spec, x, y, params, p_factor, levels, ds):
    """The PR 1-era production runtime, kept as the subsystem demo."""
    M = x.shape[0]
    K = p_factor ** levels
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    sched = SpeculativeScheduler(SpecConfig(max_workers=8))

    # --- partition (Section 3.2) ------------------------------------
    t0 = time.time()
    plan = partition.make_plan(spec, x, n_landmarks=8, n_partitions=K,
                               key=jax.random.PRNGKey(0))
    xp, yp = x[plan.perm], y[plan.perm]
    print(f"stratified partitioning: {K} partitions, "
          f"{time.time() - t0:.1f}s")

    # --- hierarchical solve with checkpoint/restart -------------------
    start_level = levels
    m = M // K
    alphas = jnp.zeros((K, 2 * m))
    if args.resume and mgr.latest_step() is not None:
        meta = mgr.metadata()
        start_level = meta["metadata"]["level"] - 1
        K_res = meta["metadata"]["n_partitions"]
        m = M // K_res
        alphas = mgr.restore(jax.ShapeDtypeStruct((K_res, 2 * m),
                                                  jnp.float32))
        K = K_res
        print(f"resumed at level {start_level} (K={K})")

    level = start_level
    while True:
        xs = xp.reshape(K, m, -1)
        ys = yp.reshape(K, m)
        t0 = time.time()

        # partition solves are pure + idempotent: dispatch through the
        # speculative scheduler (first-completion wins on duplicates)
        def _prep(Q, ak):
            # merged children were solved at scale m/p; the ray rescale
            # conditions them to this level's scale (see
            # repro.core.odm.warm_start_scale / sodm's scale note)
            zk, bk = odm.split_alpha(ak)
            u = Q @ (zk - bk)
            t = odm.warm_start_scale(u, ak, params, float(m))
            return ak * t, u * t

        if args.engine == "pallas":
            from repro.kernels import ops

            def _pallas_one(xk, yk, ak):
                Q = kf.signed_gram(spec, xk, yk)
                ak, _ = _prep(Q, ak)
                alpha, _, _ = ops.dual_cd_solve(
                    Q, c=params.c, ups=params.ups, theta=params.theta,
                    mscale=float(m), n_passes=150, tol=1e-4, alpha0=ak)
                return alpha
            solve_one = jax.jit(_pallas_one)
        else:
            def _scalar_one(xk, yk, ak):
                Q = kf.signed_gram(spec, xk, yk)
                ak, uk = _prep(Q, ak)
                return dual_cd.solve(Q, params, mscale=float(m), alpha0=ak,
                                     u0=uk, tol=1e-4, max_sweeps=150).alpha
            solve_one = jax.jit(_scalar_one)
        tasks = [(lambda i=i: solve_one(xs[i], ys[i], alphas[i]))
                 for i in range(K)]
        results = sched.run(tasks)
        alphas = jnp.stack(results)
        print(f"level {level}: solved {K} partitions of {m} rows "
              f"in {time.time() - t0:.1f}s")
        mgr.save(levels - level + 1, alphas,
                 {"level": level, "n_partitions": K})

        if K == 1:
            break
        Kn = K // p_factor
        grouped = alphas.reshape(Kn, p_factor, 2 * m)
        alphas = jax.vmap(sodm.merge_alphas)(grouped)
        K, m = Kn, m * p_factor
        level -= 1

    alpha = alphas.reshape(-1)
    pred = odm.predict(spec, xp, yp, alpha, ds.x_test)
    print(f"final test accuracy: {float(odm.accuracy(ds.y_test, pred)):.4f}")


if __name__ == "__main__":
    main()
