"""Serve a trained ODM: fit -> compress -> checkpoint -> microbatch loop.

The full deployment lifecycle of the paper's model on the serving
subsystem (``repro.serve``):

  1. fit SODM and compile the dual into a ``FittedODM`` artifact
     (exact-zero duals pruned into a packed SV slab);
  2. Nyström-compress the slab to a landmark budget within an accuracy
     target (the Eqn. 8 pivoted-Cholesky picks double as Nyström pivots);
  3. save the artifact atomically and reload it (what a serving replica
     would do at startup);
  4. drive a synthetic request stream through the deadline microbatcher
     and report accuracy, latency percentiles and throughput.

    PYTHONPATH=src python examples/serve_odm.py [--scale 0.1] [--budget 64]
"""
import argparse
import time

import jax

from repro import serve
from repro.api import ODMEstimator, ProblemSpec
from repro.core import kernel_fns as kf, odm, sodm
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--target", type=float, default=0.05,
                    help="max decision-value gap allowed by compression")
    ap.add_argument("--ckpt-dir", default="/tmp/serve_odm_ckpt")
    ap.add_argument("--requests", type=int, default=2048)
    ap.add_argument("--rate", type=float, default=5000.0,
                    help="synthetic arrival rate (requests/s)")
    args = ap.parse_args()

    ds = synthetic.load("svmguide1", scale=args.scale, max_d=64)
    M = ds.x_train.shape[0] - ds.x_train.shape[0] % 8
    x, y = ds.x_train[:M], ds.y_train[:M]
    problem = ProblemSpec(
        kernel=kf.KernelSpec(name="rbf", gamma=kf.median_gamma(x)),
        params=odm.ODMParams(lam=100.0, theta=0.1, ups=0.5))
    cfg = sodm.SODMConfig(p=2, levels=3, n_landmarks=8, tol=1e-4,
                          max_sweeps=200)

    # 1. fit + compile through the unified API (the permutation gather
    # and SV packing happen once; training output IS the artifact)
    est = ODMEstimator(problem, route="sodm", cfg=cfg)
    model, report = est.fit(x, y, jax.random.PRNGKey(0))
    print(f"[fit] M={M} -> {model.n_sv} SVs ({model.compression}) "
          f"in {report.wall_clock:.1f}s  [{report.summary()}]")

    # 2. compress to the landmark budget within the accuracy target
    comp = serve.compress(model, args.budget, target=args.target)
    print(f"[compress] {model.n_sv} -> {comp.n_sv} SVs "
          f"({comp.compression}, decision gap {comp.gap:.4f})")

    # 3. checkpoint round trip (what a replica does at startup)
    comp.save(args.ckpt_dir)
    served = serve.load_model(args.ckpt_dir)
    print(f"[ckpt] saved + reloaded from {args.ckpt_dir} "
          f"({served.compression}, {served.n_sv} SVs)")

    for name, m in (("exact", model), ("served", served)):
        acc = float(odm.accuracy(ds.y_test, m.predict(ds.x_test)))
        print(f"[accuracy] {name}: {acc:.4f}")

    # 4. microbatched serving loop over a synthetic arrival stream
    scorer = serve.MicrobatchScorer(served, max_batch=128)
    batcher = serve.Batcher(scorer, max_batch=64, max_wait=2e-3)
    T = ds.x_test.shape[0]
    arrivals = ((i / args.rate, ds.x_test[i % T])
                for i in range(args.requests))
    t0 = time.time()
    stats = serve.serve_stream(batcher, arrivals)
    wall = time.time() - t0
    print(f"[serve] {len(stats['results'])} requests in {wall:.2f}s wall "
          f"({len(stats['results']) / max(wall, 1e-9):.0f} rps), "
          f"mean batch {stats['mean_batch']:.1f}, "
          f"latency p50 {stats['p50'] * 1e3:.2f}ms "
          f"p95 {stats['p95'] * 1e3:.2f}ms, "
          f"jit cache {scorer.compiles}/{len(scorer.buckets)} buckets")


if __name__ == "__main__":
    main()
