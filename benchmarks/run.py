"""Benchmark driver: one module per paper table/figure + kernel micro +
roofline aggregation. Prints CSV-ish lines; `python -m benchmarks.run`.

Select subsets: `python -m benchmarks.run table2 fig4`.
"""
from __future__ import annotations

import sys
import time

from benchmarks import (fig2_speedup, fig4_gradient, kernels_bench,
                        roofline_report, serve_bench, table2_rbf,
                        table3_linear, table4_svm)

ALL = {
    "table2": table2_rbf.run,
    "table3": table3_linear.run,
    "table4": table4_svm.run,
    "fig2": fig2_speedup.run,
    "fig4": fig4_gradient.run,
    "kernels": kernels_bench.run,
    "roofline": roofline_report.run,
    "serve": serve_bench.run,
}


def main() -> int:
    picks = sys.argv[1:] or list(ALL)
    out: list[str] = []
    for name in picks:
        if name not in ALL:
            print(f"unknown benchmark {name!r}; options: {list(ALL)}")
            return 1
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        ALL[name](out)
        for line in out:
            print(line, flush=True)
        print(f"=== {name} done in {time.time() - t0:.1f}s ===", flush=True)
        out.clear()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
