"""Benchmark driver: one module per paper table/figure + kernel micro +
roofline aggregation. Prints CSV-ish lines; `python -m benchmarks.run`.

Select subsets: `python -m benchmarks.run table2 fig4`.
Flags: `--quick` routes each bench through its toy-scale path;
`--out-dir DIR` additionally persists one ``BENCH_<name>.json`` per bench
(schema below) so CI runs leave a machine-readable trail instead of only
scrollback.

Persisted schema (schema_version 2):

    {"schema_version": 2, "bench": "<name>", "device_kind": "...",
     "backend": "cpu|gpu|tpu", "jax_version": "...",
     "wall_clock_s": 1.23, "peak_bytes": 0-or-device-peak,
     "rows": <len(lines)>, "lines": ["table2,...", ...],
     "metrics": {"serve.request.latency_s.p99": ..., ...}}

``peak_bytes`` comes from ``device.memory_stats()`` when the backend
exposes it (TPU/GPU) and is 0 on backends that don't (CPU) — absent
telemetry is not an error. ``metrics`` (new in schema 2) is whatever
flat instrument snapshot the bench's ``run()`` returns — a
``repro.observe.MetricsRegistry.snapshot()`` dict of histogram
percentiles / counters — or ``{}`` for benches that return nothing.
``repro.observe.trend`` + ``scripts/bench_gate.py`` consume these
records and compare them against ``benchmarks/baselines/``.
"""
from __future__ import annotations

import json
import os
import sys
import time

from benchmarks import (data_bench, fig2_speedup, fig4_gradient,
                        kernels_bench, roofline_report, serve_bench,
                        table2_rbf, table3_linear, table4_svm)

ALL = {
    "table2": table2_rbf.run,
    "table3": table3_linear.run,
    "table4": table4_svm.run,
    "fig2": fig2_speedup.run,
    "fig4": fig4_gradient.run,
    "kernels": kernels_bench.run,
    "roofline": roofline_report.run,
    "serve": serve_bench.run,
    "data": data_bench.run,
}

# how each bench spells "toy scale" (run() signatures differ)
_QUICK_KW = {
    "table2": {"datasets": ["svmguide1"], "scale_factor": 0.1},
    "table3": {"datasets": ["svmguide1"], "scale_factor": 0.1},
    "fig2": {"quick": True},
    "fig4": {"datasets": [("a7a", 0.01)]},
    "kernels": {"quick": True},
    "serve": {"quick": True},
    "data": {"quick": True},
}


def _peak_bytes() -> int:
    import jax
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return 0
    if not stats:
        return 0
    return int(stats.get("peak_bytes_in_use", stats.get("bytes_in_use", 0)))


def _persist(out_dir: str, name: str, lines: list[str],
             wall_s: float, metrics: dict | None = None) -> str:
    import jax
    dev = jax.local_devices()[0]
    record = {
        "schema_version": 2,
        "bench": name,
        "device_kind": dev.device_kind,
        "backend": dev.platform,
        "jax_version": jax.__version__,
        "wall_clock_s": round(wall_s, 4),
        "peak_bytes": _peak_bytes(),
        "rows": len(lines),
        "lines": list(lines),
        "metrics": dict(metrics) if isinstance(metrics, dict) else {},
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    return path


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    quick = False
    out_dir = None
    picks: list[str] = []
    it = iter(argv)
    for a in it:
        if a == "--quick":
            quick = True
        elif a == "--out-dir":
            out_dir = next(it, None)
            if out_dir is None:
                print("--out-dir needs a directory argument")
                return 1
        else:
            picks.append(a)
    picks = picks or list(ALL)

    out: list[str] = []
    for name in picks:
        if name not in ALL:
            print(f"unknown benchmark {name!r}; options: {list(ALL)}")
            return 1
        kw = _QUICK_KW.get(name, {}) if quick else {}
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        ret = ALL[name](out, **kw)
        wall = time.time() - t0
        for line in out:
            print(line, flush=True)
        if out_dir is not None:
            print(f"wrote {_persist(out_dir, name, out, wall, ret)}",
                  flush=True)
        print(f"=== {name} done in {wall:.1f}s ===", flush=True)
        out.clear()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
